"""End-to-end system behaviour: train a tiny model to a lower loss with
the full stack (data pipeline -> train step -> checkpoints -> FT loop),
then serve it — the paper's inference-system shape, plus the FengHuang
paging configuration on the same model."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import memory
from repro.configs import get_config, build_model
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.runtime import optim
from repro.runtime.ft import FTConfig, FaultTolerantLoop
from repro.runtime.serve import BatchedServer
from repro.runtime.train import TrainConfig, make_train_step


def test_train_loss_decreases_end_to_end():
    cfg = get_config("minicpm-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = optim.init_opt_state(params)
    tcfg = TrainConfig(adamw=optim.AdamWConfig(
        lr=3e-3, total_steps=40, warmup_steps=4, schedule="wsd"))
    step_fn = jax.jit(make_train_step(model, tcfg))
    data = SyntheticLM(DataConfig(batch=8, seq=32, vocab=cfg.vocab, seed=1))

    losses = []
    with tempfile.TemporaryDirectory() as d:
        def ft_step(state, i):
            p, o = state
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            p, o, m = step_fn(p, o, batch)
            losses.append(float(m["loss"]))
            return (p, o), m

        loop = FaultTolerantLoop(
            FTConfig(ckpt_dir=d, ckpt_every=10, async_save=False), ft_step)
        (params, opt), end = loop.run((params, opt), num_steps=25)

    assert end == 25
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_paged_model_matches_unpaged():
    """FengHuang paging is semantically invisible: same logits."""
    base = dataclasses.replace(get_config("qwen3-14b").reduced(),
                               remat=False, dtype=jnp.float32)
    model = build_model(base)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, base.vocab)
    ref = model.forward(params, tokens)

    paged_cfg = base.with_pager(enabled=True, lookahead=1)
    paged_model = build_model(paged_cfg)
    # move the stacked layers to the remote tier
    params_paged = dict(params)
    params_paged["layers"] = memory.host_put(params["layers"])
    got = jax.jit(paged_model.forward)(params_paged, tokens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)

    # ... and for prefill with the cache paths
    cache = model.init_cache(2, 32)
    lg_ref, _ = model.prefill(params, tokens, cache)
    lg_paged, _ = jax.jit(paged_model.prefill)(params_paged, tokens, cache)
    np.testing.assert_allclose(np.asarray(lg_paged), np.asarray(lg_ref),
                               atol=1e-5, rtol=1e-5)


def test_serve_after_submit_queue():
    cfg = get_config("starcoder2-15b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = BatchedServer(model, params, batch_size=2, max_seq=48)
    reqs = [server.submit(np.asarray([1, 2, 3], np.int32), max_new_tokens=4)
            for _ in range(3)]
    served = server.run_once() + server.run_once()
    assert {r.uid for r in served} == {r.uid for r in reqs}
    for r in reqs:
        assert len(r.output) == 4
