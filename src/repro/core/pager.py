"""DEPRECATED shim — the TensorPager moved to :mod:`repro.memory`.

The FengHuang memory orchestration that used to live here is now a
subsystem with a policy seam:

* tier resolution + placement  -> :mod:`repro.memory.tiers`
* paged scans + donation       -> :mod:`repro.memory.orchestrator`
* residency policies           -> :mod:`repro.memory.policies`
* byte accounting              -> :mod:`repro.memory.accounting`

This module re-exports the old names for one release so downstream code
keeps importing ``repro.core.pager``; new code should use
``repro.memory`` (most callers want
``MemoryOrchestrator.plan(model_config)``).
"""
from __future__ import annotations

from typing import Any

from repro.memory.accounting import (resident_window_bytes,  # noqa: F401
                                     tree_bytes)
from repro.memory.orchestrator import (MemoryOrchestrator,  # noqa: F401
                                       donating_jit, paged_map, paged_scan,
                                       paged_scan_cache)
from repro.memory.policies import OffloadBetweenSteps, PagerConfig  # noqa: F401
from repro.memory.tiers import (LOCAL_KIND, REMOTE_KIND,  # noqa: F401
                                host_put, local_sharding, page_in, page_out,
                                remote_sharding, resolved_local_kind,
                                resolved_remote_kind, supports_memory_spaces,
                                to_remote)


def place_kv_pool(cache: Any, config: PagerConfig) -> Any:
    """Deprecated: use ``MemoryOrchestrator.place_kv_pool`` (the policy
    seam decides residency; this free function re-derives it from the
    config for old callers)."""
    if not (config.enabled and config.offload_kv):
        return cache
    return OffloadBetweenSteps().place(cache)
