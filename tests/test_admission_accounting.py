"""Property tests for paged admission accounting: worst-case page
reservations (``_worst_pages`` / ``_admission_pages_ready``) and the
prefix-sharing eligibility rule (``_shareable_pages``) at page-boundary
and ``max_seq``-clamp edges.  Pure host math — one server instance,
no dispatches."""
import dataclasses
import functools

import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 runs without hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs import build_model, get_config
from repro.runtime.serve import BatchedServer, Request

MAX_SEQ = 64
PAGE = 4


@functools.lru_cache(maxsize=1)
def _server() -> BatchedServer:
    cfg = get_config("qwen2.5-14b").reduced()
    cfg = dataclasses.replace(cfg, remat=False, page_size=PAGE)
    model = build_model(cfg)
    return BatchedServer(model, model.init(jax.random.PRNGKey(0)),
                         batch_size=2, max_seq=MAX_SEQ, paged=True)


def _valid(plen: int, mnt: int) -> bool:
    """Would submit() accept this (prompt + decode budget fits)?"""
    return plen + max(mnt - 1, 0) <= MAX_SEQ


@given(plen=st.integers(1, MAX_SEQ), mnt=st.integers(0, MAX_SEQ))
@settings(max_examples=60, deadline=None)
def test_worst_pages_covers_every_write_and_respects_max_seq(plen, mnt):
    srv = _server()
    if not _valid(plen, mnt):
        return
    worst = srv._worst_pages(plen, mnt)
    plen_adm = srv._admit_plen(plen, mnt)
    # bucketing only ever pads the prompt, and never past the point
    # where a decode write could land outside the cache
    assert plen_adm >= plen
    assert plen_adm + max(mnt - 1, 0) <= MAX_SEQ or plen_adm == plen
    # the reservation covers the admitted prompt AND the whole decode
    # budget, clamped at max_seq (positions past it are never written)
    lifetime_tokens = min(plen_adm + max(mnt - 1, 0), MAX_SEQ)
    assert worst == srv.manager.pages_for(lifetime_tokens)
    assert worst <= srv.manager.pages_for(MAX_SEQ)      # max_seq clamp
    assert worst >= srv.manager.pages_for(plen)         # prompt fits


@pytest.mark.parametrize("plen,mnt", [
    (PAGE, 0), (PAGE, 1), (2 * PAGE, 0), (2 * PAGE, 1),     # page edges
    (PAGE + 1, 1), (MAX_SEQ, 1), (MAX_SEQ - 1, 2),          # clamp edges
])
def test_worst_pages_boundary_cases(plen, mnt):
    srv = _server()
    worst = srv._worst_pages(plen, mnt)
    plen_adm = srv._admit_plen(plen, mnt)
    assert worst == srv.manager.pages_for(
        min(plen_adm + max(mnt - 1, 0), MAX_SEQ))
    if mnt <= 1:
        # no decode writes beyond the sampled-at-admission token: the
        # reservation is exactly the admitted prompt's pages
        assert worst == srv.manager.pages_for(plen_adm)


@given(reqs=st.lists(st.integers(1, MAX_SEQ), min_size=1, max_size=24))
@settings(max_examples=30, deadline=None)
def test_admission_gate_never_oversubscribes(reqs):
    """Follow the gate exactly as _admit_from_queue does: a request is
    admitted only when its worst case fits beside every live
    reservation — so total reservations can never exceed capacity, and
    an admitted request can never hit mid-decode pool exhaustion."""
    srv = _server()
    srv._reserved = {}
    cap = srv.manager.capacity
    slot = 0
    for plen in reqs:
        mnt = (plen % 7) + 1                   # deterministic budget mix
        if not _valid(plen, mnt):
            continue
        req = Request(uid=slot, prompt=np.zeros(plen, np.int32),
                      max_new_tokens=mnt)
        if srv._admission_pages_ready(req):
            srv._reserved[slot] = srv._worst_pages(plen, mnt)
            slot += 1
        assert sum(srv._reserved.values()) <= cap
        if slot and slot % 5 == 0:             # periodic reclamation
            srv._reserved.pop(min(srv._reserved), None)
    srv._reserved = {}


@given(plen=st.integers(1, MAX_SEQ))
@settings(max_examples=40, deadline=None)
def test_shareable_pages_never_cover_a_written_position(plen):
    """Shared prompt pages must lie strictly before the last prompt
    token: admission always keeps at least one suffix token to prefill,
    and decode's first write (position >= plen) can never land in a
    shared page."""
    srv = _server()
    n = srv._shareable_pages(plen)
    assert n == (plen - 1) // PAGE             # maximal whole pages
    assert n * PAGE <= plen - 1                # excludes the last token
    # decode writes start at position >= plen, strictly past the shared
    # region [0, n*PAGE)
    assert n * PAGE < plen
    if plen % PAGE == 0:
        # page-boundary edge: the final FULL page still stays private
        assert n == plen // PAGE - 1
