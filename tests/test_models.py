"""Per-architecture smoke tests (reduced configs) + decode consistency.

Each assigned arch: instantiate the reduced same-family config, run one
forward and one train step on CPU, assert output shapes + no NaNs; then
verify prefill+decode reproduces teacher-forced logits (fp32 exactness).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, build_model
from repro.runtime import optim
from repro.runtime.train import TrainConfig, make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _extra(cfg, key=KEY, dtype=jnp.float32):
    extra = {}
    if cfg.family == "encdec":
        extra["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), dtype)
    if cfg.family == "vlm":
        extra["patches"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), dtype)
    return extra


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_shapes(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    extra = _extra(cfg)
    logits = jax.jit(lambda p, t: model.forward(p, t, extra or None))(
        params, tokens)
    exp_seq = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_seq, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    opt = optim.init_opt_state(params)
    step = jax.jit(make_train_step(model, TrainConfig(
        adamw=optim.AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=1))))
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens, **_extra(cfg)}
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # params actually changed
    changed = any(
        not np.array_equal(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert changed
    assert int(opt2["step"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_teacher_forcing(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(), remat=False,
                              dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, 12), 0, cfg.vocab)
    extra = _extra(cfg)
    full = model.forward(params, tokens, extra or None)
    offs = cfg.num_patches if cfg.family == "vlm" else 0
    k = 9
    cache = model.init_cache(B, 64)
    lg, cache = model.prefill(params, tokens[:, :k], cache, extra or None)
    np.testing.assert_allclose(np.asarray(lg[:, -1]),
                               np.asarray(full[:, offs + k - 1]),
                               atol=5e-4, rtol=1e-3)
    for i in range(k, 12):
        pos = jnp.full((B,), offs + i, jnp.int32)
        lg, cache = model.decode_step(params, tokens[:, i:i + 1], cache, pos)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, offs + i]),
                                   atol=5e-4, rtol=1e-3)


def test_sliding_window_semantics():
    """Window attention: tokens beyond the window don't influence logits."""
    cfg = dataclasses.replace(get_config("recurrentgemma-9b").reduced(),
                              remat=False, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(KEY)
    t1 = jax.random.randint(jax.random.PRNGKey(3), (1, 12), 0, cfg.vocab)
    # same suffix, different ancient prefix -> attention part must match
    # within the window;  recurrent part DOES carry state, so only check
    # the attention mask path via the pure attention layer:
    from repro.models import layers as L
    q = jax.random.normal(KEY, (1, 12, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 12, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(5), (1, 12, 2, 16))
    w = 4
    out = L.flash_attention(q, k, v, causal=True, window=w,
                            q_block=4, kv_block=4)
    k2 = k.at[:, :4].set(999.0)   # clobber tokens outside window of pos>=8
    v2 = v.at[:, :4].set(999.0)
    out2 = L.flash_attention(q, k2, v2, causal=True, window=w,
                             q_block=4, kv_block=4)
    np.testing.assert_allclose(np.asarray(out[:, 8:]),
                               np.asarray(out2[:, 8:]), atol=1e-5)


def test_vocab_padding_masked_in_loss():
    from repro.models.transformer import vocab_mask_logits
    cfg = get_config("qwen2.5-14b").reduced()
    cfg = dataclasses.replace(cfg, vocab=500)  # force padding to 512
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    logits = model.forward(params, tokens)
    assert logits.shape[-1] == 512
    masked = vocab_mask_logits(logits.astype(jnp.float32), cfg.vocab)
    probs = jax.nn.softmax(masked, axis=-1)
    # padded columns carry no probability mass
    assert float(probs[..., cfg.vocab:].max()) < 1e-6
    from repro.runtime.train import lm_loss
    loss = lm_loss(model, params, {"tokens": tokens, "labels": tokens})
    assert bool(jnp.isfinite(loss))


def test_moe_routing_is_sparse():
    """Each token gets exactly top_k experts' outputs combined."""
    from repro.models.moe import moe_ffn, moe_params
    cfg = get_config("granite-moe-3b-a800m").reduced()
    p = moe_params(KEY, cfg)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model), cfg.dtype)
    out, aux = moe_ffn(p, x, cfg, return_aux=True)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
    assert float(aux) > 0.5   # LB loss near 1 for near-uniform routing


def test_kv_quant_decode_close_to_fp32():
    """int8 KV cache (§Perf A3): decode matches the full-precision model
    within int8 quantization tolerance; cache dtypes are int8."""
    cfg = dataclasses.replace(get_config("qwen3-14b").reduced(),
                              remat=False, dtype=jnp.float32, kv_quant=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, 12), 0, cfg.vocab)
    full = model.forward(params, tokens)
    cache = model.init_cache(B, 64)
    assert cache["k"].dtype == jnp.int8 and "k_scale" in cache
    lg, cache = model.prefill(params, tokens[:, :9], cache)
    errs = [float(jnp.abs(lg[:, -1] - full[:, 8]).max())]
    for i in range(9, 12):
        pos = jnp.full((B,), i, jnp.int32)
        lg, cache = model.decode_step(params, tokens[:, i:i + 1], cache, pos)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, i]).max()))
    assert max(errs) < 0.15, errs


def test_kv_quant_roundtrip_property():
    from repro.models.layers import kv_dequantize, kv_quantize
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 4, 8, 16)) * 3.0
    q, s = kv_quantize(x)
    back = kv_dequantize(q, s, jnp.float32)
    rel = float(jnp.max(jnp.abs(back - x) / (jnp.max(jnp.abs(x)) + 1e-9)))
    assert q.dtype == jnp.int8
    assert rel < 1.0 / 100   # absmax int8: <=1/254 of per-vector range
