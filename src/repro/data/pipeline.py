"""Data pipeline: deterministic synthetic LM streams, byte-level file
datasets, sequence packing, background prefetch, and straggler-mitigating
speculative batches.

Determinism: batch ``i`` of a given (seed, config) is always identical —
required for fault-tolerant restart (the loader can resume at any step
index without replaying).
"""
from __future__ import annotations

import dataclasses
import hashlib
import queue
import threading
import time
from pathlib import Path
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int = 8
    seq: int = 128
    vocab: int = 512
    seed: int = 0
    prefetch: int = 2
    straggler_deadline_s: float = 30.0


def _rng_for(seed: int, step: int) -> np.random.Generator:
    h = hashlib.sha256(f"{seed}:{step}".encode()).digest()
    return np.random.default_rng(int.from_bytes(h[:8], "little"))


class SyntheticLM:
    """Deterministic synthetic token stream with local structure (Markov-ish
    bigrams) so losses actually decrease during smoke training."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = _rng_for(cfg.seed, -1)
        self.table = rng.integers(0, cfg.vocab, size=(cfg.vocab,), dtype=np.int32)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = _rng_for(cfg.seed, step)
        first = rng.integers(0, cfg.vocab, size=(cfg.batch, 1), dtype=np.int32)
        toks = [first[:, 0]]
        noise = rng.random((cfg.batch, cfg.seq - 1))
        rand = rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq - 1),
                            dtype=np.int32)
        for t in range(cfg.seq - 1):
            follow = self.table[toks[-1]]
            toks.append(np.where(noise[:, t] < 0.8, follow, rand[:, t]))
        tokens = np.stack(toks, axis=1).astype(np.int32)
        return {"tokens": tokens, "labels": tokens.copy()}


class ByteFileLM:
    """Byte-level tokens from a text file, packed into fixed-length rows."""

    def __init__(self, path: str | Path, cfg: DataConfig):
        data = Path(path).read_bytes()
        self.tokens = np.frombuffer(data, np.uint8).astype(np.int32)
        self.cfg = cfg
        if cfg.vocab < 256:
            self.tokens = self.tokens % cfg.vocab

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        n = len(self.tokens) - cfg.seq - 1
        rng = _rng_for(cfg.seed, step)
        starts = rng.integers(0, max(n, 1), size=(cfg.batch,))
        rows = np.stack([self.tokens[s:s + cfg.seq] for s in starts])
        return {"tokens": rows, "labels": rows.copy()}


def pack_documents(docs: list[np.ndarray], seq: int,
                   pad_id: int = 0) -> np.ndarray:
    """Greedy sequence packing: concatenate docs into rows of length
    ``seq``; overflow flows to the next row."""
    flat = np.concatenate(docs) if docs else np.zeros((0,), np.int32)
    n_rows = max(1, (len(flat) + seq - 1) // seq)
    out = np.full((n_rows, seq), pad_id, np.int32)
    for i in range(n_rows):
        chunk = flat[i * seq:(i + 1) * seq]
        out[i, :len(chunk)] = chunk
    return out


class PrefetchingLoader:
    """Background-thread prefetch with speculative (straggler-backup)
    batch production.

    A worker thread materializes batches ahead of the consumer.  If a batch
    is not ready ``straggler_deadline_s`` after being requested, a backup
    producer regenerates it from the deterministic source (the same batch —
    determinism makes the backup exact, so whichever copy lands first wins).
    """

    def __init__(self, source, cfg: DataConfig):
        self.source = source
        self.cfg = cfg
        self._results: dict[int, dict] = {}
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._next_produce = 0
        self._next_consume = 0
        self._stop = False
        self._backups = 0
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop:
            with self._cv:
                while (self._next_produce - self._next_consume
                        > self.cfg.prefetch) and not self._stop:
                    self._cv.wait(0.05)
                if self._stop:
                    return
                step = self._next_produce
                self._next_produce += 1
            batch = self.source.batch_at(step)
            with self._cv:
                self._results[step] = batch
                self._cv.notify_all()

    def __next__(self) -> dict:
        step = self._next_consume
        deadline = time.monotonic() + self.cfg.straggler_deadline_s
        with self._cv:
            while step not in self._results:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cv.wait(min(remaining, 0.05))
        if step not in self._results:
            # straggler: produce the (deterministic) batch inline
            self._backups += 1
            batch = self.source.batch_at(step)
        else:
            with self._lock:
                batch = self._results.pop(step)
        self._next_consume += 1
        with self._cv:
            self._cv.notify_all()
        return batch

    def __iter__(self) -> Iterator[dict]:
        return self

    @property
    def backup_batches(self) -> int:
        return self._backups

    def close(self):
        self._stop = True
        with self._cv:
            self._cv.notify_all()
        self._thread.join(timeout=1.0)
