"""Benchmark: Table 3.1 minimal-operation latencies + Eq. 3.1-3.4 / 4.1.

Recomputes the component totals and evaluates the latency equations at the
paper's 2KB reference size and across a size sweep (the Eq. 4.1 efficiency
curve drives the size-dependent effective bandwidth).
"""
from __future__ import annotations

import time

from repro.core import hw, latency


def run() -> list[str]:
    rows = []
    t0 = time.perf_counter()
    totals = latency.table_3_1_totals_ns()
    us = (time.perf_counter() - t0) * 1e6
    rows.append(f"table31_read_total,{us:.1f},{totals['read']:.0f}ns "
                f"(paper 220)")
    rows.append(f"table31_write_total,{us:.1f},{totals['write']:.0f}ns "
                f"(paper 90)")
    rows.append(f"table31_atomic_completion,{us:.1f},"
                f"{totals['atomic_completion']:.0f}ns (paper 40)")

    bw = 4.0e12   # 4 TB/s
    for size in (2 * 1024, 64 * 1024, 1 << 20, 64 << 20):
        r = latency.fh_read_latency_s(size, bw) * 1e9
        w = latency.fh_write_latency_s(size, bw) * 1e9
        rows.append(f"eq31_read_{size}B,{us:.1f},{r:.1f}ns")
        rows.append(f"eq32_write_{size}B,{us:.1f},{w:.1f}ns")
    link = latency.LinkModel(hw.PAPER_READ_LATENCY_NS * 1e-9, bw)
    for size in (4 * 1024, 1 << 20, 256 << 20):
        eff = link.efficiency(size)
        t = latency.prefetch_overhead_s(size, bw, link) * 1e6
        rows.append(f"eq41_prefetch_{size}B,{us:.1f},"
                    f"{t:.2f}us eff={eff:.3f}")
    return rows
