"""Pure-jnp oracle for the flash-attention kernel (naive softmax attention)."""
from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True,
                  window: int = 0) -> jnp.ndarray:
    """q: (B, Sq, H, d); k, v: (B, Sk, H, d) — same head count (the GQA
    group expansion happens in ops.py)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)   # aligned to the suffix
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = _softmax(s)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def _softmax(s):
    m = s.max(axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    return e / e.sum(axis=-1, keepdims=True)
