"""End-to-end FengHuang serving driver (the paper's workload shape):
a small dense LM serving batched requests, run twice — shared-nothing
baseline vs FengHuang-paged (weights in the remote tier, TensorPager
double-buffered prefetch) — and verified to emit identical tokens.

    PYTHONPATH=src python examples/serve_fenghuang.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_config, build_model
from repro.core import pager
from repro.runtime.serve import BatchedServer

PROMPTS = [
    np.asarray([11, 42, 7, 3], np.int32),
    np.asarray([5, 9], np.int32),
    np.asarray([100, 101, 102, 103, 104], np.int32),
    np.asarray([1], np.int32),
]


def serve_all(model, params, tag, paged=None):
    # 2 slots for 4 requests: the back half is admitted MID-STREAM via
    # continuous batching when the front half's slots free up.
    server = BatchedServer(model, params, batch_size=2, max_seq=96,
                           block_size=8, paged=paged)
    t0 = time.perf_counter()
    reqs = [server.submit(p, max_new_tokens=12) for p in PROMPTS]
    while any(not r.done.is_set() for r in reqs):
        server.run_once()
    dt = time.perf_counter() - t0
    s = server.stats
    print(f"[{tag}] served {len(reqs)} requests, {s['tokens']} tokens "
          f"in {dt:.2f}s — {s['dispatches']} block dispatches "
          f"({s['tokens'] / max(s['dispatches'], 1):.1f} tok/dispatch), "
          f"{s['host_syncs']} host syncs")
    if server.paged:
        m = server.manager
        print(f"[{tag}] block-pool KV: page={m.page_size} tok, peak "
              f"{m.hwm}/{m.capacity} pages "
              f"({server.kv_bytes_capacity()/1e3:.0f} KB pool, dense slab "
              f"would be resident at 100%)")
    return [tuple(r.output) for r in reqs]


def main():
    cfg = get_config("qwen2.5-14b").reduced(num_layers=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve] model: {cfg.name} "
          f"({sum(x.size for x in jax.tree.leaves(params))/1e6:.1f}M params)")

    # 1) shared-nothing baseline: weights AND a dense KV slab in device
    #    memory
    base_out = serve_all(model, params, "baseline ", paged=False)

    # 1b) block-pool paged KV (the serving default for dense models):
    #     fixed-size pages allocated on demand, reclaimed on EOS —
    #     identical tokens, KV footprint tracking live tokens
    paged_out = serve_all(model, params, "paged-kv ")
    assert paged_out == base_out, "paged KV must be semantically invisible"

    # 2) FengHuang: stacked layer weights live in the remote tier
    #    (pinned_host); the TensorPager pages them per layer with
    #    lookahead-1 double buffering.
    print(f"[serve] memory spaces supported: "
          f"{pager.supports_memory_spaces()}")
    paged_cfg = cfg.with_pager(enabled=True, lookahead=1)
    paged_model = build_model(paged_cfg)
    paged_params = dict(params)
    paged_params["layers"] = pager.host_put(params["layers"])
    resident = pager.resident_window_bytes(paged_params["layers"], 1)
    total = pager.tree_bytes(params["layers"])
    print(f"[serve] FengHuang local window: {resident/1e6:.2f} MB resident "
          f"of {total/1e6:.2f} MB weights "
          f"({100*(1-resident/total):.1f}% local-capacity reduction)")
    fh_out = serve_all(paged_model, paged_params, "fenghuang")

    assert base_out == fh_out, "paged serving must be semantically invisible"
    print("[serve] OK — identical tokens with and without paging")


if __name__ == "__main__":
    main()
