"""Benchmark: §3.3.3 theoretical speed-up analysis (paper Table: 70x / 15.56x).

Reproduces every number in the section from the component model and checks
them against the paper's quoted figures.
"""
from __future__ import annotations

import time

from repro.core import analysis


def run() -> list[str]:
    rows = []
    t0 = time.perf_counter()
    rep = analysis.speedup_report(8)
    headline = analysis.paper_headline_numbers(8)
    us = (time.perf_counter() - t0) * 1e6

    checks = [
        ("enabler1_latency_bound", rep.enabler1_latency_bound, 14.0),
        ("enabler1_bandwidth_bound", rep.enabler1_bandwidth_bound, 1.75),
        ("enabler2_bandwidth_bound", rep.enabler2_bandwidth_bound, 8.89),
        ("overall_latency_bound_paper", headline["overall_latency_bound"], 70.0),
        ("overall_bandwidth_bound_paper",
         headline["overall_bandwidth_bound"], 15.56),
    ]
    for name, got, want in checks:
        ok = abs(got - want) / want < 0.01
        rows.append(f"speedup_{name},{us:.1f},{got:.3f} (paper {want}"
                    f" match={ok})")
    rows.append(f"speedup_enabler2_latency_exact,{us:.1f},"
                f"read {rep.enabler2_latency_bound_read:.2f}x / "
                f"write {rep.enabler2_latency_bound_write:.2f}x "
                f"(paper rounds to 5x)")
    rows.append(f"speedup_overall_latency_exact,{us:.1f},"
                f"{rep.overall_latency_bound:.1f}x (with exact 1000/220)")
    return rows
