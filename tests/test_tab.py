"""TAB vs ring collectives on a real multi-device mesh (subprocess with
forced host devices, since the main test process must stay single-device)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import functools
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import tab

n = 8
try:                                    # jax >= 0.5 axis types
    from jax.sharding import AxisType
    mesh = jax.make_mesh((n,), ("model",), axis_types=(AxisType.Auto,))
except ImportError:
    mesh = jax.make_mesh((n,), ("model",))
try:                                    # jax >= 0.5 public shard_map
    shard_map, _sm_kw = jax.shard_map, {"check_vma": False}
except AttributeError:
    from jax.experimental.shard_map import shard_map
    _sm_kw = {"check_rep": False}

rng = np.random.RandomState(0)
x = jnp.asarray(rng.randn(n * 4, 16), jnp.float32)

def smap(fn, in_specs, out_specs):
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **_sm_kw))

# allreduce: tab == ring == jnp sum
want = np.tile(np.asarray(x).reshape(n, 4, 16).sum(0), (n, 1, 1)).reshape(n*4, 16)
for sched in ("tab", "ring"):
    f = smap(functools.partial(tab.allreduce, axis_name="model",
                               schedule=sched), P("model"), P("model"))
    got = np.asarray(f(x))
    assert np.allclose(got, want, atol=1e-4), f"allreduce {sched}"

# reduce_scatter: each device's shard of the sum
rs_t = smap(lambda v: tab.reduce_scatter(v[0], "model", schedule="tab")[None],
            P("model"), P("model"))
rs_r = smap(lambda v: tab.ring_reduce_scatter(v[0], "model")[None],
            P("model"), P("model"))
y = jnp.asarray(rng.randn(n, n * 2), jnp.float32)  # per-dev (1, 16)
a, b = np.asarray(rs_t(y)), np.asarray(rs_r(y))
assert np.allclose(a, b, atol=1e-4), "reduce_scatter mismatch"

# allgather
ag_t = smap(functools.partial(tab.allgather, axis_name="model",
                              schedule="tab"), P("model"), P(None))
ag_r = smap(functools.partial(tab.allgather, axis_name="model",
                              schedule="ring"), P("model"), P(None))
assert np.allclose(np.asarray(ag_t(x)), np.asarray(x), atol=1e-6)
assert np.allclose(np.asarray(ag_r(x)), np.asarray(x), atol=1e-6)

# all_to_all is its own inverse for a symmetric layout
a2a = smap(functools.partial(tab.tab_all_to_all, axis_name="model"),
           P("model"), P("model"))
z = jnp.arange(float(n * n)).reshape(n * n, 1)
once = a2a(z)
twice = a2a(once)
assert np.allclose(np.asarray(twice), np.asarray(z)), "a2a involution"

# p2p shift moves each shard to the next device
p2p = smap(functools.partial(tab.tab_p2p, axis_name="model"),
           P("model"), P("model"))
shifted = np.asarray(p2p(jnp.arange(float(n))[:, None])).ravel()
assert list(shifted) == [float((i - 1) % n) for i in range(n)], shifted

# Enabler 1 on real HLO: ring allreduce lowers to 2(N-1) permute steps
import re
f_ring = smap(functools.partial(tab.allreduce, axis_name="model",
                                schedule="ring"), P("model"), P("model"))
hlo = f_ring.lower(x).compile().as_text()
n_perm = len(re.findall(r"collective-permute(?:-start)?\(", hlo))
assert n_perm >= 2, f"ring should show permute steps, got {n_perm}"
f_tab = smap(functools.partial(tab.allreduce, axis_name="model",
                               schedule="tab"), P("model"), P("model"))
hlo_t = f_tab.lower(x).compile().as_text()
n_ar = len(re.findall(r"= [^=]*all-reduce(?:-start)?\(", hlo_t))
assert n_ar == 1, f"tab allreduce should be one op, got {n_ar}"
print("TAB_OK")
"""


@pytest.mark.slow
def test_tab_collectives_multi_device():
    src = str(Path(__file__).resolve().parent.parent / "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT, src],
                         capture_output=True, text=True, timeout=600,
                         env=env)
    assert "TAB_OK" in out.stdout, out.stderr[-3000:]
