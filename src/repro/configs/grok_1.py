"""Grok-1 (paper workload §4.1.2): 64L d=6144, MoE 8 experts top-2."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=32768, vocab=131072, head_dim=128,
    num_experts=8, top_k=2,
)
