"""Benchmark: serving hot path — seed-style host-driven per-token decode
vs the fused on-device block loop (§Perf iteration D).

The per-token baseline reproduces the seed ``BatchedServer.run_once``
anti-pattern exactly: one ``serve_step`` dispatch per token plus a
``int(cur[i, 0])`` host sync per slot per step.  The block path is one
dispatch and one host sync per ``BLOCK`` tokens.  The demo model is the
1-layer CPU smoke transformer — the decode-dispatch-bound regime the
paper's §4.2 TPOT claims assume (host overhead, not model math, bounds
the seed loop).  Deeper stacks shift the ratio toward compute: the
2-layer smoke config gives ~4x (see EXPERIMENTS.md).

Emits tokens/s, dispatches-per-step and host-syncs-per-token for both
paths, the speedup, and a continuous-batching row (mid-stream admission,
no batch restart).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import build_model, get_config
from repro.models.base import DecodeState
from repro.runtime.serve import (BatchedServer, make_decode_loop,
                                 make_prefill_step, make_serve_step, sample)

BATCH = 4
PROMPT = 8
NEW_TOKENS = 64
BLOCK = 32
MAX_SEQ = 128
REPEATS = 3          # timing = min over repeats (dispatch noise)


def _counted(fn, counter: dict):
    def wrapped(*a, **k):
        counter["n"] += 1
        return fn(*a, **k)
    return wrapped


def _setup():
    cfg = get_config("qwen2.5-14b").reduced(num_layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (BATCH, PROMPT), 0,
                                 cfg.vocab)
    return model, params, prompts


def _prefill(model, params, prompts):
    cache = model.init_cache(BATCH, MAX_SEQ)
    logits, cache = jax.jit(make_prefill_step(model))(params, prompts, cache)
    cur = sample(logits, model.cfg.vocab, 0.0, jax.random.PRNGKey(0))
    return cur, cache


def _per_token(model, params, prompts) -> tuple[float, int, int, list]:
    """Seed-style loop: dispatch + per-slot host sync every token."""
    dispatches = {"n": 0}
    sstep = _counted(jax.jit(make_serve_step(model)), dispatches)

    def once():
        cur, cache = _prefill(model, params, prompts)
        key = jax.random.PRNGKey(7)
        pos = jnp.full((BATCH,), PROMPT, jnp.int32)
        outs = [[] for _ in range(BATCH)]
        syncs = 0
        t0 = time.perf_counter()
        for _ in range(NEW_TOKENS):
            key, k = jax.random.split(key)
            cur, _, cache = sstep(params, cur, cache, pos, k)
            pos = pos + 1
            for i in range(BATCH):
                outs[i].append(int(cur[i, 0]))    # the seed's per-slot sync
                syncs += 1
        return time.perf_counter() - t0, syncs, outs

    once()                                        # warm the compile cache
    dispatches["n"] = 0
    runs = [once() for _ in range(REPEATS)]
    dt, syncs, outs = min(runs, key=lambda r: r[0])
    return dt, dispatches["n"] // REPEATS, syncs, outs


def _block_decode(model, params, prompts) -> tuple[float, int, int, list]:
    """Fused loop: one dispatch + one host sync per BLOCK tokens."""
    dispatches = {"n": 0}
    loop = _counted(make_decode_loop(model, block_size=BLOCK), dispatches)

    def once():
        cur, cache = _prefill(model, params, prompts)
        state = DecodeState(tokens=cur,
                            pos=jnp.full((BATCH,), PROMPT, jnp.int32),
                            active=jnp.ones((BATCH,), bool),
                            remaining=jnp.full((BATCH,), NEW_TOKENS,
                                               jnp.int32),
                            key=jax.random.PRNGKey(7))
        outs = [[] for _ in range(BATCH)]
        syncs = 0
        t0 = time.perf_counter()
        for _ in range(NEW_TOKENS // BLOCK):
            toks, valid, cache, state = loop(params, cache, state)
            blk = np.asarray(jax.device_get(toks))   # ONE sync per block
            syncs += 1
            for i in range(BATCH):
                outs[i].extend(int(t) for t in blk[i])
        return time.perf_counter() - t0, syncs, outs

    once()                                        # warm (donates warm bufs)
    dispatches["n"] = 0
    runs = [once() for _ in range(REPEATS)]
    dt, syncs, outs = min(runs, key=lambda r: r[0])
    return dt, dispatches["n"] // REPEATS, syncs, outs


def _continuous(model, params) -> str:
    server = BatchedServer(model, params, batch_size=2, max_seq=MAX_SEQ,
                           block_size=8)
    server.submit(np.arange(1, 6, dtype=np.int32), max_new_tokens=32)
    server.submit(np.arange(6, 9, dtype=np.int32), max_new_tokens=8)
    server.submit(np.arange(9, 11, dtype=np.int32), max_new_tokens=8)
    t0 = time.perf_counter()
    done = server.run_once()
    us = (time.perf_counter() - t0) * 1e6
    s = server.stats
    assert s["batches"] == 1 and len(done) == 3, (s, done)
    return (f"serve_continuous_batching,{us:.0f},"
            f"reqs={len(done)} slots=2 batches={s['batches']} "
            f"admitted_mid_stream={s['admitted'] - 2} "
            f"tok_per_dispatch={s['tokens'] / max(s['dispatches'], 1):.1f}")


def run() -> list[str]:
    model, params, prompts = _setup()
    total = BATCH * NEW_TOKENS

    dt_old, disp_old, sync_old, outs_old = _per_token(model, params, prompts)
    dt_new, disp_new, sync_new, outs_new = _block_decode(
        model, params, prompts)
    assert outs_old == outs_new, "block decode must match per-token decode"
    assert disp_old == NEW_TOKENS                  # 1 dispatch / token
    assert disp_new == NEW_TOKENS // BLOCK         # 1 dispatch / block
    assert sync_new == NEW_TOKENS // BLOCK         # 1 host sync / block

    tps_old, tps_new = total / dt_old, total / dt_new
    rows = [
        f"serve_per_token,{dt_old / NEW_TOKENS * 1e6:.0f},"
        f"tok_s={tps_old:.0f} dispatches_per_step="
        f"{disp_old / NEW_TOKENS:.3f} syncs_per_tok={sync_old / total:.3f}",
        f"serve_block{BLOCK},{dt_new / NEW_TOKENS * 1e6:.0f},"
        f"tok_s={tps_new:.0f} dispatches_per_step="
        f"{disp_new / NEW_TOKENS:.3f} syncs_per_tok={sync_new / total:.3f}"
        f" speedup={tps_new / tps_old:.2f}x",
        _continuous(model, params),
    ]
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    for row in run():
        print(row)
