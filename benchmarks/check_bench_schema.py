"""Schema + regression assertions for ``BENCH_serve.json`` — keeps the
serving perf record machine-readable as the benchmark evolves (CI gate).

    python benchmarks/check_bench_schema.py [path] [--require-sharded]

Asserts the top-level keys, the ``kv_memory`` / ``pipeline`` /
``prefix_cache`` / ``sharded`` sub-schemas, and the per-tier residency
blocks (every tier must carry ``in_use_bytes`` / ``hwm_bytes`` /
``by_class``; the ``tiers_peak`` mid-flight snapshot must be
non-degenerate — a live ``kv_pool`` class).  On top of the schema it
gates the headline numbers so they cannot silently rot:

* ``server_paged`` tokens/s must stay >= 0.95x ``server_dense``;
* ``bytes_per_active_token_paged`` must not exceed the dense value;
* the quantized pools must earn their keep: each ``kv_quant`` dtype's
  effective bytes per active token (dequant scales INCLUDED) must be
  <= 0.55x the bf16 pool, ``server_paged_q8`` tokens/s must stay
  >= 0.9x ``server_paged``, and the accuracy record (greedy token
  agreement, one-step max |Δlogit|) must stay inside its envelope;
* every reported tier with a provisioned capacity must satisfy
  ``hwm_bytes <= capacity_bytes`` (residency never exceeded what the
  ledger says was provisioned) — including the per-shard snapshot;
* the prefix-cache row must show a real residency reduction with
  bit-identical tokens;
* the ``server_sharded`` row must be token-identical to single-device,
  and with >= 2 model shards must show model-axis collective traffic
  plus a per-shard ledger snapshot.  ``--require-sharded`` (the forced
  multi-device CI job) rejects a degenerate 1-shard run;
* the ``preemption`` deep-queue scenario must show real preemption
  activity (>= 1 preemption AND resume, 0 sheds), bit-identical tokens,
  a clean allocator audit trail, and a shorter worst-case admission
  wait than the no-preemption server;
* canonical tiers (``local`` / ``remote`` / ``cold``) must appear in
  hierarchy order in every tier block, and the ``cold_park``
  deep-preemption row must show real cold parking: both victims parked
  AND promoted back, bit-identical tokens, a reduced remote-tier
  high-water mark, and nonzero modeled traffic on the ``local->cold``
  and ``cold->remote`` edges of its transfer ledger;
* the ``disagg`` interference scenario must show the async prefill
  engine earning its keep: worst-case decode stall <= 1 block vs >= 3
  for monolithic admission, tokens bit-identical at temperature 0.0 AND
  0.7, and ``server_disagg`` steady throughput >= 0.95x
  ``server_paged``;
* ``server_paged_fp8`` tokens/s must stay >= 0.8x ``server_paged``
  (the fp8 gather/dequant cliff must not come back);
* the ``overload`` admission-control scenario must show structured
  rejections AND SLA expiries on the controlled server, every terminal
  outcome summing to the offered load, zero leaked pages on both
  servers, and the admitted p99 TTFT bounded by the declared block
  ceiling while the uncontrolled baseline's tail is strictly worse.

Throughput-RATIO floors bind only on single-device runs: the forced
multi-device CPU job timeshares one physical core across its virtual
devices, so relative tokens/s between server variants is scheduler
noise there — its deterministic gates (identity, stall/wait block
counts, collective bytes, ledger invariants) still apply in full.

Exits nonzero with a readable message on any violation.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

TOP_KEYS = {
    "model", "batch", "prompt", "new_tokens", "block_size", "max_seq",
    "tokens_per_s", "speedup_block_vs_per_token",
    "paged_vs_dense_tokens_identical", "kv_memory", "kv_quant",
    "pipeline", "prefix_cache", "sharded", "preemption", "cold_park",
    "disagg", "overload", "tiers", "tiers_peak", "transfers",
    "attention_scaling",
}
TOKENS_PER_S_KEYS = {"per_token_dense", "block_dense", "server_dense",
                     "server_paged", "server_paged_q8",
                     "server_paged_fp8", "server_disagg"}
KV_MEMORY_KEYS = {
    "page_size", "dense_slab_bytes", "paged_pool_capacity_bytes",
    "paged_hwm_bytes", "peak_live_tokens", "bytes_per_active_token_dense",
    "bytes_per_active_token_paged", "local_kv_reduction_vs_dense",
    "fragmentation_hwm_bound",
}
PIPELINE_KEYS = {"enabled", "max_inflight", "compiles", "host_syncs",
                 "dispatches", "table_rebuilds", "table_delta_entries"}
PREFIX_KEYS = {
    "sys_prompt", "user_prompt", "new_tokens", "prefix_hits",
    "shared_pages", "tokens_per_s_shared", "tokens_per_s_unshared",
    "kv_hwm_bytes_shared", "kv_hwm_bytes_unshared",
    "bytes_per_active_token_shared", "bytes_per_active_token_unshared",
    "residency_reduction_vs_unshared", "tokens_identical_to_unshared",
}
SHARDED_KEYS = {
    "devices", "model_shards", "mesh_axes", "tokens_per_s_sharded",
    "tokens_identical_to_single_device",
    "collective_bytes_per_step_by_axis",
    "collective_bytes_per_token_by_axis", "tiers_peak_per_shard",
    "row_parallel",
}
KV_QUANT_DTYPES = ("int8", "fp8_e4m3")
KV_QUANT_DTYPE_KEYS = {
    "tokens_per_s", "pool_capacity_bytes", "kv_hwm_bytes",
    "bytes_per_active_token", "bytes_ratio_vs_bf16",
    "capacity_gain_vs_bf16", "greedy_match_rate_vs_bf16",
    "greedy_match_rate_first8", "max_abs_logit_err",
}
PREEMPTION_KEYS = {
    "policy", "num_pages", "page_size", "hogs", "shorts",
    "hog_new_tokens", "short_new_tokens", "preemptions", "resumes",
    "sheds", "preempted_pages", "swap_retries", "audits",
    "max_admission_wait_blocks_preempt",
    "max_admission_wait_blocks_no_preempt", "admission_wait_reduction",
    "drain_s_preempt", "drain_s_no_preempt",
    "tokens_identical_to_uncontended",
}
DISAGG_KEYS = {
    "steady_new_tokens", "long_prompt", "long_new_tokens", "n_long",
    "prefill_chunk_tokens", "handoffs", "prefill_chunks",
    "decode_stall_blocks_max_monolithic", "decode_stall_blocks_max_disagg",
    "decode_stall_blocks_total_monolithic",
    "decode_stall_blocks_total_disagg",
    "ttft_p50_blocks_monolithic", "ttft_p50_blocks_disagg",
    "ttft_p99_blocks_monolithic", "ttft_p99_blocks_disagg",
    "drain_s_monolithic", "drain_s_disagg",
    "tokens_identical_t0", "tokens_identical_t07", "chunk_sweep",
}
OVERLOAD_KEYS = {
    "offered", "batch", "num_pages", "page_size", "new_tokens",
    "max_pending", "overload_factor", "sla_probes", "deadline_blocks",
    "ttft_p99_bound_blocks", "controlled", "uncontrolled",
    "p99_ttft_bounded",
}
OVERLOAD_SIDE_KEYS = {
    "completed", "rejected", "expired", "sheds",
    "admitted_ttft_p50_blocks", "admitted_ttft_p99_blocks",
    "e2e_p50_blocks", "e2e_p99_blocks", "audits", "leaked_pages",
    "drain_s",
}
TIER_KEYS = {"in_use_bytes", "hwm_bytes", "capacity_bytes", "by_class"}
# canonical hierarchy order: any of these that appear in a tier block
# must appear in this relative order (the ledger iterates the registry's
# ordered hierarchy; a shuffled block means the ordering contract broke)
TIER_ORDER = ("local", "remote", "cold")
COLD_PARK_KEYS = {
    "num_pages", "page_size", "hogs", "hog_new_tokens", "big_new_tokens",
    "preemptions", "cold_parks", "cold_promotes",
    "remote_hwm_bytes_no_park", "remote_hwm_bytes_cold_park",
    "remote_hwm_reduction", "transfers_cold_park",
    "drain_s_no_park", "drain_s_cold_park",
    "tokens_identical_to_uncontended",
}
TRANSFER_EDGE_KEYS = {"bytes", "modeled_s", "count"}
# server_paged may not drop below this fraction of server_dense (the
# tentpole claim; headroom for CI timing noise)
PAGED_VS_DENSE_FLOOR = 0.95
# quantized pool gates: true bytes (scales included) must at least
# halve-ish the bf16 pool, and the fused-dequant read path may not give
# the throughput back
KV_QUANT_BYTES_CEIL = 0.55
Q8_VS_PAGED_FLOOR = 0.9
# fp8 pages gather through a uint8 bit-view + LUT dequant; this floor
# keeps the fp8 serving cliff (0.64x bf16 before the fix) from coming
# back via a slow-gather or slow-convert regression
FP8_VS_PAGED_FLOOR = 0.8
# the async prefill engine must not tax steady-state decode throughput
DISAGG_VS_PAGED_FLOOR = 0.95


def _timing_floors_apply(bench: dict) -> bool:
    """Throughput-RATIO floors are gated only on single-device runs.
    The forced-multi-device CI job (--require-sharded) timeshares one
    physical core across 8 virtual devices, so relative tokens/s between
    server variants is scheduler noise there — the deterministic gates
    (token identity, stall/wait block counts, collective bytes, ledger
    invariants) still apply in full.  The single-device smoke jobs keep
    every ratio floor binding."""
    return bench.get("sharded", {}).get("devices", 1) <= 1
# worst-case decode stall (blocks) with/without disaggregation: the
# interference headline — one chunk vs the whole mid-stream prompt
DISAGG_STALL_CEIL = 1
MONO_STALL_FLOOR = 3
# accuracy envelope for the quantized-vs-bf16 comparison.  Greedy
# decoding cascades — one flipped argmax rewrites the rest of the
# sequence — so the GATE sits on the first-8-token agreement (the
# stable KV-fidelity readout) plus the one-step max |Δlogit|; the
# full-horizon rate is recorded but not thresholded (on the random
# -weight smoke model it mostly measures when the first flip happened)
KV_QUANT_MATCH_FLOOR = 0.75
KV_QUANT_LOGIT_CEIL = 1.0


def check(path: Path, *, require_sharded: bool = False) -> list[str]:
    errors: list[str] = []
    try:
        bench = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"cannot load {path}: {e}"]

    missing = TOP_KEYS - bench.keys()
    if missing:
        errors.append(f"missing top-level keys: {sorted(missing)}")
    if not TOKENS_PER_S_KEYS <= bench.get("tokens_per_s", {}).keys():
        errors.append(
            f"tokens_per_s must contain {sorted(TOKENS_PER_S_KEYS)}, got "
            f"{sorted(bench.get('tokens_per_s', {}))}")
    km_missing = KV_MEMORY_KEYS - bench.get("kv_memory", {}).keys()
    if km_missing:
        errors.append(f"missing kv_memory keys: {sorted(km_missing)}")
    pl_missing = PIPELINE_KEYS - bench.get("pipeline", {}).keys()
    if pl_missing:
        errors.append(f"missing pipeline keys: {sorted(pl_missing)}")
    px_missing = PREFIX_KEYS - bench.get("prefix_cache", {}).keys()
    if px_missing:
        errors.append(f"missing prefix_cache keys: {sorted(px_missing)}")

    for block in ("tiers", "tiers_peak"):
        errors.extend(_check_tier_block(block, bench.get(block, {})))
    errors.extend(_check_peak_snapshot(bench))
    errors.extend(_check_kv_quant(bench))
    errors.extend(_check_sharded(bench, require_multi=require_sharded))
    errors.extend(_check_preemption(bench))
    errors.extend(_check_cold_park(bench))
    errors.extend(_check_transfer_map("transfers", bench.get("transfers")))
    errors.extend(_check_disagg(bench))
    errors.extend(_check_overload(bench))
    errors.extend(_check_regressions(bench))
    return errors


def _check_tier_block(block: str, tiers) -> list[str]:
    """One per-tier residency mapping: key shape, non-negative byte
    counters, and the ledger invariant ``hwm_bytes <= capacity_bytes``
    for every tier that declares a provisioned capacity (a tier whose
    high-water mark exceeds what was provisioned means some placement
    registered residency without registering capacity)."""
    errors: list[str] = []
    if not isinstance(tiers, dict) or not tiers:
        errors.append(f"{block} must be a non-empty per-tier mapping")
    for name, t in (tiers.items() if isinstance(tiers, dict) else ()):
        tk_missing = TIER_KEYS - (t.keys() if isinstance(t, dict)
                                  else set())
        if tk_missing:
            errors.append(
                f"{block} tier '{name}' missing {sorted(tk_missing)}")
        elif not isinstance(t["by_class"], dict):
            errors.append(f"{block} tier '{name}' by_class must be a "
                          f"mapping")
        else:
            for field in ("in_use_bytes", "hwm_bytes", "capacity_bytes"):
                if not isinstance(t[field], int) or t[field] < 0:
                    errors.append(
                        f"{block} tier '{name}' {field} must be a "
                        f"non-negative int, got {t[field]!r}")
                    break
            else:
                if t["capacity_bytes"] > 0 and \
                        t["hwm_bytes"] > t["capacity_bytes"]:
                    errors.append(
                        f"{block} tier '{name}' hwm_bytes "
                        f"({t['hwm_bytes']}) exceeds capacity_bytes "
                        f"({t['capacity_bytes']}): some placement "
                        f"records residency without capacity")
    if isinstance(tiers, dict) and "local" not in tiers:
        errors.append(f"{block} must include the 'local' tier")
    if isinstance(tiers, dict):
        canon = [n for n in tiers if n in TIER_ORDER]
        if canon != sorted(canon, key=TIER_ORDER.index):
            errors.append(
                f"{block} canonical tiers appear as {canon}; they must "
                f"follow the hierarchy order {list(TIER_ORDER)} (the "
                f"ledger's ordered-registry contract broke)")
    return errors


def _check_transfer_map(label: str, xfers) -> list[str]:
    """A tier-edge transfer ledger: ``"src->dst"`` keys mapping to
    non-negative ``bytes`` / ``modeled_s`` / ``count`` records."""
    errors: list[str] = []
    if not isinstance(xfers, dict):
        return [f"{label} must be a mapping of 'src->dst' edges"]
    for edge, rec in xfers.items():
        if not (isinstance(edge, str) and edge.count("->") == 1
                and all(edge.split("->"))):
            errors.append(f"{label} edge key {edge!r} is not 'src->dst'")
            continue
        missing = TRANSFER_EDGE_KEYS - (rec.keys() if isinstance(rec, dict)
                                        else set())
        if missing:
            errors.append(f"{label}['{edge}'] missing {sorted(missing)}")
            continue
        for field in TRANSFER_EDGE_KEYS:
            v = rec[field]
            if not isinstance(v, (int, float)) or v < 0:
                errors.append(f"{label}['{edge}'] {field} must be a "
                              f"non-negative number, got {v!r}")
    return errors


def _check_cold_park(bench: dict) -> list[str]:
    """The deep-preemption cold-parking row: parking must have really
    fired (both victims demoted AND promoted back), tokens bit-identical,
    the remote-tier high-water mark reduced, and real modeled traffic on
    the cold-tier edges of the transfer ledger."""
    cp = bench.get("cold_park")
    if not isinstance(cp, dict):
        return ["cold_park must be a mapping (the serve_cold_park row)"]
    missing = COLD_PARK_KEYS - cp.keys()
    if missing:
        return [f"missing cold_park keys: {sorted(missing)}"]
    errors: list[str] = []
    if cp["tokens_identical_to_uncontended"] is not True:
        errors.append("cold_park tokens_identical_to_uncontended must be "
                      "true (cold park/promote changed the tokens)")
    for field, floor in (("preemptions", 1), ("cold_parks", 2),
                         ("cold_promotes", 2)):
        v = cp.get(field)
        if not isinstance(v, int) or v < floor:
            errors.append(f"cold_park {field} must be an int >= {floor}, "
                          f"got {v!r}: the cold-parking scenario is "
                          f"degenerate")
    red = cp.get("remote_hwm_reduction")
    if not isinstance(red, (int, float)) or red <= 0:
        errors.append(
            f"cold_park remote_hwm_reduction must be > 0 (parking victims "
            f"cold must shrink the remote-tier high-water mark), got "
            f"{red!r}")
    xfers = cp.get("transfers_cold_park")
    errors.extend(_check_transfer_map("cold_park.transfers_cold_park",
                                      xfers))
    if isinstance(xfers, dict):
        for edge in ("local->cold", "cold->remote"):
            rec = xfers.get(edge)
            if not (isinstance(rec, dict) and rec.get("bytes", 0) > 0):
                errors.append(
                    f"cold_park.transfers_cold_park['{edge}'] must show "
                    f"nonzero bytes: the cold tier saw no traffic in the "
                    f"cold-park row")
    return errors


def _check_kv_quant(bench: dict) -> list[str]:
    """The quantized-pool record: both dtypes present with the full
    per-dtype schema, true-bytes ratio (scales included) at or under
    the 0.55x ceiling, q8 throughput at or above 0.9x the bf16 paged
    row, and the accuracy envelope respected."""
    kq = bench.get("kv_quant")
    if not isinstance(kq, dict):
        return ["kv_quant must be a mapping (the quantized-pool record)"]
    errors: list[str] = []
    bf16 = kq.get("bytes_per_active_token_bf16")
    if not isinstance(bf16, int) or bf16 <= 0:
        errors.append(f"kv_quant bytes_per_active_token_bf16 must be a "
                      f"positive int, got {bf16!r}")
    for kd in KV_QUANT_DTYPES:
        d = kq.get(kd)
        if not isinstance(d, dict):
            errors.append(f"kv_quant must contain a '{kd}' mapping")
            continue
        missing = KV_QUANT_DTYPE_KEYS - d.keys()
        if missing:
            errors.append(f"kv_quant.{kd} missing {sorted(missing)}")
            continue
        ratio = d["bytes_ratio_vs_bf16"]
        if not isinstance(ratio, (int, float)) or \
                ratio > KV_QUANT_BYTES_CEIL:
            errors.append(
                f"kv_quant.{kd} bytes_ratio_vs_bf16 ({ratio!r}) exceeds "
                f"{KV_QUANT_BYTES_CEIL} (scales ate the capacity win)")
        match = d["greedy_match_rate_first8"]
        if not isinstance(match, (int, float)) or \
                match < KV_QUANT_MATCH_FLOOR:
            errors.append(
                f"kv_quant.{kd} greedy_match_rate_first8 ({match!r}) "
                f"below {KV_QUANT_MATCH_FLOOR}: quantized decodes "
                f"diverged from bf16 immediately")
        err = d["max_abs_logit_err"]
        if not isinstance(err, (int, float)) or err > KV_QUANT_LOGIT_CEIL:
            errors.append(
                f"kv_quant.{kd} max_abs_logit_err ({err!r}) exceeds "
                f"{KV_QUANT_LOGIT_CEIL}")
    tps = bench.get("tokens_per_s", {})
    if not _timing_floors_apply(bench):
        return errors
    q8, paged = tps.get("server_paged_q8"), tps.get("server_paged")
    if isinstance(q8, (int, float)) and isinstance(paged, (int, float)) \
            and paged > 0 and q8 < Q8_VS_PAGED_FLOOR * paged:
        errors.append(
            f"server_paged_q8 ({q8} tok/s) dropped below "
            f"{Q8_VS_PAGED_FLOOR}x server_paged ({paged} tok/s): fused "
            f"dequant gave the throughput back")
    fp8 = tps.get("server_paged_fp8")
    if isinstance(fp8, (int, float)) and isinstance(paged, (int, float)) \
            and paged > 0 and fp8 < FP8_VS_PAGED_FLOOR * paged:
        errors.append(
            f"server_paged_fp8 ({fp8} tok/s) dropped below "
            f"{FP8_VS_PAGED_FLOOR}x server_paged ({paged} tok/s): the "
            f"fp8 gather/dequant cliff is back (pages must gather as a "
            f"uint8 bit-view and dequantize through the LUT)")
    return errors


def _check_disagg(bench: dict) -> list[str]:
    """The disaggregated prefill/decode scenario: bit-identity at both
    temperatures, the one-chunk stall bound vs the monolithic
    whole-prompt stall, and steady throughput within noise of the
    monolithic paged server."""
    dg = bench.get("disagg")
    if not isinstance(dg, dict):
        return ["disagg must be a mapping (the server_disagg scenario)"]
    missing = DISAGG_KEYS - dg.keys()
    if missing:
        return [f"missing disagg keys: {sorted(missing)}"]
    errors: list[str] = []
    for flag in ("tokens_identical_t0", "tokens_identical_t07"):
        if dg[flag] is not True:
            errors.append(f"disagg {flag} must be true (the async engine "
                          f"changed the tokens)")
    stall_d = dg["decode_stall_blocks_max_disagg"]
    stall_m = dg["decode_stall_blocks_max_monolithic"]
    if not isinstance(stall_d, int) or stall_d > DISAGG_STALL_CEIL:
        errors.append(
            f"disagg decode_stall_blocks_max_disagg ({stall_d!r}) exceeds "
            f"{DISAGG_STALL_CEIL}: chunked prefill is stalling decode for "
            f"more than one chunk")
    if not isinstance(stall_m, int) or stall_m < MONO_STALL_FLOOR:
        errors.append(
            f"disagg decode_stall_blocks_max_monolithic ({stall_m!r}) "
            f"below {MONO_STALL_FLOOR}: the interference scenario is "
            f"degenerate (long prompts never stalled the baseline)")
    if not isinstance(dg["chunk_sweep"], dict) or not dg["chunk_sweep"]:
        errors.append("disagg chunk_sweep must be a non-empty mapping")
    for field in ("handoffs", "prefill_chunks"):
        v = dg.get(field)
        if not isinstance(v, int) or v < 1:
            errors.append(f"disagg {field} must be an int >= 1, got {v!r}: "
                          f"the engine never ran")
    tps = bench.get("tokens_per_s", {})
    dis, paged = tps.get("server_disagg"), tps.get("server_paged")
    if _timing_floors_apply(bench) \
            and isinstance(dis, (int, float)) \
            and isinstance(paged, (int, float)) \
            and paged > 0 and dis < DISAGG_VS_PAGED_FLOOR * paged:
        errors.append(
            f"server_disagg ({dis} tok/s) dropped below "
            f"{DISAGG_VS_PAGED_FLOOR}x server_paged ({paged} tok/s): the "
            f"async engine is taxing steady-state decode")
    return errors


def _check_preemption(bench: dict) -> list[str]:
    """The memory-pressure scenario: preemption must have really fired
    (not a pool too big to contend), recovered without shedding, kept
    tokens bit-identical, audited clean, and beaten the no-preemption
    server's worst-case admission wait."""
    pr = bench.get("preemption")
    if not isinstance(pr, dict):
        return ["preemption must be a mapping (the serve_preemption row)"]
    missing = PREEMPTION_KEYS - pr.keys()
    if missing:
        return [f"missing preemption keys: {sorted(missing)}"]
    errors: list[str] = []
    if pr["tokens_identical_to_uncontended"] is not True:
        errors.append("preemption tokens_identical_to_uncontended must be "
                      "true (preempt/swap/resume changed the tokens)")
    for field, floor in (("preemptions", 1), ("resumes", 1), ("audits", 1)):
        v = pr.get(field)
        if not isinstance(v, int) or v < floor:
            errors.append(f"preemption {field} must be an int >= {floor}, "
                          f"got {v!r}: the pressure scenario is degenerate")
    if pr.get("sheds") != 0:
        errors.append(f"preemption sheds must be 0 (no victim may be "
                      f"dropped under plain pressure), got {pr.get('sheds')!r}")
    wp = pr.get("max_admission_wait_blocks_preempt")
    wn = pr.get("max_admission_wait_blocks_no_preempt")
    if not (isinstance(wp, int) and isinstance(wn, int) and wp < wn):
        errors.append(
            f"preemption must shorten the worst-case admission wait: "
            f"preempt={wp!r} blocks vs no_preempt={wn!r} blocks")
    return errors


def _check_overload(bench: dict) -> list[str]:
    """The overload admission-control scenario: the controlled server
    must have really rejected (structured, at submit time) AND expired
    (SLA probe deadlines) while completing the credible offers, every
    terminal outcome must be accounted for, both pools must drain to
    zero pages, and the headline must hold — admitted p99 TTFT bounded
    by the declared block ceiling while the uncontrolled queue's tail
    is strictly worse."""
    ov = bench.get("overload")
    if not isinstance(ov, dict):
        return ["overload must be a mapping (the serve_overload row)"]
    missing = OVERLOAD_KEYS - ov.keys()
    if missing:
        return [f"missing overload keys: {sorted(missing)}"]
    errors: list[str] = []
    sides = {}
    for name in ("controlled", "uncontrolled"):
        side = ov.get(name)
        if not isinstance(side, dict):
            errors.append(f"overload.{name} must be a mapping")
            continue
        side_missing = OVERLOAD_SIDE_KEYS - side.keys()
        if side_missing:
            errors.append(f"overload.{name} missing {sorted(side_missing)}")
            continue
        sides[name] = side
        total = sum(side[k] for k in ("completed", "rejected", "expired",
                                      "sheds"))
        if total != ov["offered"]:
            errors.append(
                f"overload.{name} outcome counts sum to {total}, not the "
                f"offered load {ov['offered']}: a request fell through "
                f"the lifecycle accounting")
        if side["leaked_pages"] != 0:
            errors.append(
                f"overload.{name} leaked_pages must be 0 after the drain, "
                f"got {side['leaked_pages']!r}")
    if len(sides) < 2:
        return errors
    ctl, unc = sides["controlled"], sides["uncontrolled"]
    for field, floor in (("completed", 1), ("rejected", 1), ("expired", 1),
                         ("audits", 1)):
        if not isinstance(ctl[field], int) or ctl[field] < floor:
            errors.append(
                f"overload.controlled {field} must be an int >= {floor}, "
                f"got {ctl[field]!r}: the overload scenario is degenerate")
    if unc["rejected"] != 0:
        errors.append(
            f"overload.uncontrolled rejected must be 0 (it is the "
            f"no-gate baseline), got {unc['rejected']!r}")
    p99_c = ctl["admitted_ttft_p99_blocks"]
    p99_u = unc["admitted_ttft_p99_blocks"]
    bound = ov["ttft_p99_bound_blocks"]
    if not (isinstance(p99_c, (int, float)) and p99_c <= bound):
        errors.append(
            f"overload controlled admitted_ttft_p99_blocks ({p99_c!r}) "
            f"exceeds the declared bound ({bound}): admission control "
            f"stopped bounding the admitted tail")
    if not (isinstance(p99_u, (int, float)) and p99_u > p99_c):
        errors.append(
            f"overload uncontrolled admitted_ttft_p99_blocks ({p99_u!r}) "
            f"must exceed controlled ({p99_c!r}): the scenario no longer "
            f"demonstrates queue-depth tail growth")
    if ov["p99_ttft_bounded"] is not True:
        errors.append("overload p99_ttft_bounded must be true")
    return errors


def _check_sharded(bench: dict, *, require_multi: bool = False) -> list[str]:
    """The tensor-parallel serving row: schema, bit-identity, and —
    when >= 2 model shards ran — real model-axis collective traffic and
    a per-shard residency snapshot.  ``require_multi`` (the forced
    multi-device CI job) additionally rejects a degenerate 1-shard run."""
    errors: list[str] = []
    sh = bench.get("sharded")
    if not isinstance(sh, dict):
        return ["sharded must be a mapping (the server_sharded row)"]
    missing = SHARDED_KEYS - sh.keys()
    if missing:
        return [f"missing sharded keys: {sorted(missing)}"]
    if sh["tokens_identical_to_single_device"] is not True:
        errors.append("sharded tokens_identical_to_single_device must be "
                      "true (tensor parallelism changed the tokens)")
    shards = sh.get("model_shards")
    if not isinstance(shards, int) or shards < 1:
        errors.append(f"sharded model_shards must be a positive int, got "
                      f"{shards!r}")
        return errors
    if require_multi and shards < 2:
        errors.append(
            f"sharded row ran with model_shards={shards}; the multi-device "
            f"job requires >= 2 (mesh fell back to a single shard)")
    tiers = sh.get("tiers_peak_per_shard")
    if not isinstance(tiers, dict) or "local" not in tiers:
        errors.append("sharded tiers_peak_per_shard must include 'local'")
    else:
        errors.extend(_check_tier_block("sharded.tiers_peak_per_shard",
                                        tiers))
    rp = sh.get("row_parallel")
    if not isinstance(rp, dict):
        errors.append("sharded row_parallel must be a mapping (the "
                      "deterministic=False Megatron placement row)")
    else:
        if rp.get("deterministic") is not False:
            errors.append("sharded row_parallel.deterministic must be "
                          "false (that is the point of the row)")
        if not isinstance(rp.get("collective_bytes_per_token_by_axis"),
                          dict):
            errors.append("sharded row_parallel must record "
                          "collective_bytes_per_token_by_axis")
        elif shards >= 2 and \
                rp["collective_bytes_per_token_by_axis"] \
                .get("model", 0) <= 0:
            errors.append(
                f"row_parallel run with {shards} model shards shows no "
                f"model-axis collective bytes: the partial-sum "
                f"all-reduce is missing from the decode executable")
    if shards >= 2:
        # an EMPTY by-axis block at >= 2 shards means the HLO parser
        # attributed no collectives at all — a dead mesh or a broken
        # attribution, either way the wire-traffic record is vacuous
        for key in ("collective_bytes_per_step_by_axis",
                    "collective_bytes_per_token_by_axis"):
            blk = sh.get(key)
            if not isinstance(blk, dict) or not blk:
                errors.append(
                    f"sharded.{key} must be a non-empty per-axis mapping "
                    f"at {shards} model shards, got {blk!r}")
        per_tok = sh.get("collective_bytes_per_token_by_axis", {})
        if not isinstance(per_tok, dict) or \
                per_tok.get("model", 0) <= 0:
            errors.append(
                f"sharded run with {shards} model shards shows no "
                f"model-axis collective bytes ({per_tok!r}): the mesh is "
                f"dead in the decode executable")
        local = tiers.get("local", {}) if isinstance(tiers, dict) else {}
        if isinstance(local, dict) and local.get("shards") != shards:
            errors.append(
                f"sharded tiers_peak_per_shard.local.shards "
                f"({local.get('shards')!r}) disagrees with model_shards "
                f"({shards}): ledger is not accounting per shard")
    return errors


def _check_peak_snapshot(bench: dict) -> list[str]:
    """The mid-flight snapshot must capture live kv_pool residency —
    the end-of-run ``tiers`` block legitimately drains to 0, so only
    ``tiers_peak`` is gated for non-degeneracy."""
    errors: list[str] = []
    local = bench.get("tiers_peak", {}).get("local")
    if not isinstance(local, dict):
        return errors                       # shape errors reported above
    if not isinstance(local.get("by_class"), dict):
        return errors
    kv = local["by_class"].get("kv_pool", 0)
    if not isinstance(kv, int) or kv <= 0:
        errors.append(
            f"tiers_peak local.by_class.kv_pool must be > 0 (peak "
            f"occupancy snapshot is degenerate), got {kv!r}")
    if local.get("in_use_bytes", 0) <= 0:
        errors.append("tiers_peak local.in_use_bytes must be > 0")
    return errors


def _check_regressions(bench: dict) -> list[str]:
    """Perf guards for the tentpole's headline numbers."""
    errors: list[str] = []
    tps = bench.get("tokens_per_s", {})
    paged, dense = tps.get("server_paged"), tps.get("server_dense")
    if _timing_floors_apply(bench) \
            and isinstance(paged, (int, float)) \
            and isinstance(dense, (int, float)) \
            and dense > 0 and paged < PAGED_VS_DENSE_FLOOR * dense:
        errors.append(
            f"server_paged ({paged} tok/s) dropped below "
            f"{PAGED_VS_DENSE_FLOOR}x server_dense ({dense} tok/s): the "
            f"paged serving hot path regressed")
    km = bench.get("kv_memory", {})
    bp, bd = (km.get("bytes_per_active_token_paged"),
              km.get("bytes_per_active_token_dense"))
    if isinstance(bp, (int, float)) and isinstance(bd, (int, float)) \
            and bp > bd:
        errors.append(
            f"bytes_per_active_token_paged ({bp}) exceeds the dense value "
            f"({bd}): the paged pool lost its memory advantage")
    px = bench.get("prefix_cache", {})
    if px:
        if px.get("tokens_identical_to_unshared") is not True:
            errors.append("prefix_cache tokens_identical_to_unshared must "
                          "be true")
        red = px.get("residency_reduction_vs_unshared", 0)
        if not isinstance(red, (int, float)) or red <= 0:
            errors.append(
                f"prefix_cache residency_reduction_vs_unshared must be "
                f"> 0, got {red!r}")
    return errors


def main() -> None:
    args = [a for a in sys.argv[1:] if a != "--require-sharded"]
    require_sharded = "--require-sharded" in sys.argv[1:]
    path = Path(args[0] if args else "BENCH_serve.json")
    errors = check(path, require_sharded=require_sharded)
    if errors:
        for e in errors:
            print(f"BENCH schema violation: {e}", file=sys.stderr)
        raise SystemExit(1)
    bench = json.loads(path.read_text())
    print(f"{path}: schema OK (tiers: {sorted(bench['tiers'])}, "
          f"model_shards: {bench['sharded']['model_shards']})")


if __name__ == "__main__":
    main()
