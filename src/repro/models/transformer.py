"""Dense decoder-only transformer (qwen2.5 / qwen3 / minicpm / starcoder2,
and the llava backbone) with FengHuang paging as a first-class option.

Layers are stacked on a leading L axis and executed with
:func:`repro.memory.orchestrator.paged_scan`, so the same model
definition runs shared-nothing (weights resident in HBM) or
FengHuang-paged (weights and optionally KV in the remote tier,
double-buffered prefetch).  Every model owns a
:class:`repro.memory.MemoryOrchestrator` (``self.mem``) planned from its
config's pager policy; all layer scans route through it.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.memory import MemoryOrchestrator
from repro.models import layers as L
from repro.models.base import (ModelConfig, BATCH_AXES, DecodeState,
                               split_keys)
from repro.runtime.sharding import SEQ_SHARDED_ACTS, maybe_constraint


def _scatter_pages(cache: dict, pages: jax.Array, k_new: jax.Array,
                   v_new: jax.Array, cfg: ModelConfig) -> dict:
    """Write (L, B, S, Hkv, hd) prompt KV into the page pools: ONE
    scatter per pool covering every layer, page and head.  ``pages``:
    (B, n) page ids with n * page >= S; KV positions start at the first
    mapped page's base, extra positions receive only padding (written —
    so a freshly filled page is valid in its entirety — but masked by
    seq_lens on every read).  Quantized pools (``cfg.kv_dtype``)
    quantize on write: per-(position, head) absmax scales land in the
    ``k_scale``/``v_scale`` arrays with the same scatter pattern, so a
    page's bytes are a pure function of the tokens it covers (the
    prefix-sharing contract)."""
    page = cache["k_pages"].shape[2]
    n = pages.shape[1]
    seq = k_new.shape[2]
    pad = n * page - seq
    if pad < 0:
        raise ValueError(f"page table maps {n * page} positions but the "
                         f"prompt chunk has {seq}")

    def scatter(pool, val, spec):
        # (L, B, S, ...) -> (L, B, n, page, ...), one scatter
        val = jnp.pad(val, ((0, 0), (0, 0), (0, pad))
                      + ((0, 0),) * (val.ndim - 3))
        l_, b_ = val.shape[:2]
        val = val.reshape((l_, b_, n, page) + val.shape[3:])
        # under a mesh the update's head axis matches the pool's shard
        # layout, so the scatter stays device-local per head shard
        val = maybe_constraint(val, spec)
        return pool.at[:, pages].set(val.astype(pool.dtype))

    kv_spec = P(None, None, None, None, "model", None)
    if cfg.kv_quantized:
        qdt, qmax = cfg.kv_pool_dtype(), cfg.kv_qmax()
        k_new, ks = L.kv_pool_quantize(k_new, qdt, qmax)
        v_new, vs = L.kv_pool_quantize(v_new, qdt, qmax)
        sc_spec = P(None, None, None, None, "model")
        return {"k_pages": scatter(cache["k_pages"], k_new, kv_spec),
                "v_pages": scatter(cache["v_pages"], v_new, kv_spec),
                "k_scale": scatter(cache["k_scale"], ks, sc_spec),
                "v_scale": scatter(cache["v_scale"], vs, sc_spec)}
    return {"k_pages": scatter(cache["k_pages"], k_new, kv_spec),
            "v_pages": scatter(cache["v_pages"], v_new, kv_spec)}


class DenseLM:
    """Decoder-only LM.  Also the base class for the MoE and VLM variants."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.mem = MemoryOrchestrator.plan(cfg)

    # ----- params -----------------------------------------------------------
    def init_layer(self, key) -> dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "attn": L.attn_params(k1, cfg),
            "mlp": L.mlp_params(k2, cfg),
            "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
            "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
        }

    def layer_specs(self) -> dict:
        return {
            "attn": L.attn_specs(self.cfg),
            "mlp": L.mlp_specs(),
            "ln1": P(None, None), "ln2": P(None, None),
        }

    def init(self, key) -> dict:
        cfg = self.cfg
        ke, kl = jax.random.split(key)
        layer_keys = split_keys(kl, cfg.num_layers)
        stacked = jax.vmap(self.init_layer)(jnp.stack(layer_keys))
        return {
            "embed": L.embed_params(ke, cfg),
            "layers": stacked,
            "ln_f": jnp.ones((cfg.d_model,), cfg.dtype),
        }

    def param_specs(self) -> dict:
        return {
            "embed": L.embed_specs(self.cfg),
            "layers": self.layer_specs(),
            "ln_f": P(None),
        }

    def serving_param_specs(self) -> dict:
        """``param_specs`` with the contraction-sharded output
        projections (``wo`` of attention and the MLP) replicated: the
        serving blocks all-gather their activations before these dots
        (:func:`repro.models.layers._tp_gathered`), so the full-width
        projection is bitwise identical to single-device — the placement
        and the constraint are two halves of one contract.  Everything
        else (QKV, gate/up, embeddings, LM head) keeps its model-axis
        shard."""
        def fix(path, s):
            key = jax.tree_util.keystr(path)
            # expert banks (['moe']['wo']) are expert-axis sharded, not
            # contraction-sharded — replicating them would multiply
            # per-device expert memory for no determinism gain
            if key.endswith("['wo']") and "['moe']" not in key:
                return P(*(None,) * len(s))
            return s
        return jax.tree_util.tree_map_with_path(
            fix, self.param_specs(),
            is_leaf=lambda x: isinstance(x, P))

    # ----- blocks ------------------------------------------------------------
    def ffn(self, lp: dict, x: jax.Array, *,
            gather_tp: bool = False) -> jax.Array:
        return L.mlp_forward(lp["mlp"], x, gather_tp=gather_tp)

    def block_train(self, lp: dict, x: jax.Array,
                    positions: jax.Array) -> jax.Array:
        cfg = self.cfg
        # constraining each sub-block's output to seq-sharded turns the
        # TP partial-sum into a reduce-scatter (half the wire of
        # all-reduce) — Megatron-SP proper (§Perf iteration C).
        a = maybe_constraint(
            L.attn_forward(lp["attn"], L.rmsnorm(x, lp["ln1"], cfg.norm_eps),
                           positions, cfg), SEQ_SHARDED_ACTS)
        h = x + a
        f = maybe_constraint(
            self.ffn(lp, L.rmsnorm(h, lp["ln2"], cfg.norm_eps)),
            SEQ_SHARDED_ACTS)
        return h + f

    def block_prefill(self, lp: dict, x: jax.Array, positions: jax.Array,
                      kv_roundtrip: bool = False):
        cfg = self.cfg
        a, kv = L.attn_prefill_kv(lp["attn"],
                                  L.rmsnorm(x, lp["ln1"], cfg.norm_eps),
                                  positions, cfg, kv_roundtrip=kv_roundtrip)
        h = x + a
        return h + self.ffn(lp, L.rmsnorm(h, lp["ln2"], cfg.norm_eps),
                            gather_tp=True), kv

    def block_decode(self, lp: dict, x: jax.Array, ck, cv, cur_pos):
        """Cache is read-only; returns the current token's (k, v) for the
        single post-scan batched write."""
        cfg = self.cfg
        a, k0, v0 = L.attn_decode(lp["attn"],
                                  L.rmsnorm(x, lp["ln1"], cfg.norm_eps),
                                  ck, cv, cur_pos, cfg)
        h = x + a
        return h + self.ffn(lp, L.rmsnorm(h, lp["ln2"], cfg.norm_eps),
                            gather_tp=True), k0, v0

    def block_prefill_prefix(self, lp: dict, x: jax.Array,
                             positions: jax.Array, k_prefix, v_prefix,
                             kv_roundtrip: bool = False):
        """block_prefill for a prompt suffix whose prefix KV already
        lives in the page pool (prefix-cached admission)."""
        cfg = self.cfg
        a, kv = L.attn_prefill_prefix_kv(
            lp["attn"], L.rmsnorm(x, lp["ln1"], cfg.norm_eps), positions,
            k_prefix, v_prefix, cfg, kv_roundtrip=kv_roundtrip)
        h = x + a
        return h + self.ffn(lp, L.rmsnorm(h, lp["ln2"], cfg.norm_eps),
                            gather_tp=True), kv

    def block_decode_paged(self, lp: dict, x: jax.Array, k_pages, v_pages,
                           pages, cur_pos, k_scales=None, v_scales=None):
        """block_decode against this layer's page pool (also read-only);
        ``k_scales``/``v_scales`` carry a quantized pool's per-slot
        dequant scales into the fused attention read."""
        cfg = self.cfg
        a, k0, v0 = L.attn_decode_paged(lp["attn"],
                                        L.rmsnorm(x, lp["ln1"], cfg.norm_eps),
                                        k_pages, v_pages, pages, cur_pos, cfg,
                                        k_scales=k_scales, v_scales=v_scales)
        h = x + a
        return h + self.ffn(lp, L.rmsnorm(h, lp["ln2"], cfg.norm_eps),
                            gather_tp=True), k0, v0

    # ----- forward passes ----------------------------------------------------
    def _embed(self, params, tokens):
        return L.embed_lookup(params["embed"], tokens)

    def forward_hidden(self, params: dict, tokens: jax.Array,
                       extra: dict | None = None) -> jax.Array:
        """Full-sequence forward without the LM head (chunked-loss path)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        if extra and "patches" in extra:   # VLM: prepend patch embeddings
            x = jnp.concatenate([extra["patches"].astype(x.dtype), x], axis=1)
        positions = jnp.arange(x.shape[1])

        def body(h, lp):
            # Megatron-style sequence parallelism: the residual saved per
            # layer for backward is seq-sharded over the model axis.
            h = maybe_constraint(h, SEQ_SHARDED_ACTS)
            fn = self.block_train
            if cfg.remat:
                fn = jax.checkpoint(fn)
            return fn(lp, h, positions), None

        x, _ = self.mem.layer_scan(body, x, params["layers"])
        return L.rmsnorm(x, params["ln_f"], cfg.norm_eps)

    def forward(self, params: dict, tokens: jax.Array,
                extra: dict | None = None) -> jax.Array:
        """Training/eval forward over a full sequence -> logits (B, S, V)."""
        x = self.forward_hidden(params, tokens, extra)
        return L.lm_head(params["embed"], x, self.cfg)

    # ----- KV cache -----------------------------------------------------------
    def cache_seq(self, max_seq: int) -> int:
        w = self.cfg.sliding_window
        return min(max_seq, w) if w > 0 else max_seq

    def init_cache(self, batch: int, max_seq: int) -> dict:
        cfg = self.cfg
        s = self.cache_seq(max_seq)
        # head-major layout (L, B, Hkv, S, hd): decode dots are
        # layout-native (no transposed cache copies) — §Perf iteration A.
        shape = (cfg.num_layers, batch, cfg.padded_kv_heads, s, cfg.head_dim)
        if cfg.kv_quant:
            # int8 values + per-token-per-head bf16 absmax scales (A3)
            return {"k": jnp.zeros(shape, jnp.int8),
                    "v": jnp.zeros(shape, jnp.int8),
                    "k_scale": jnp.zeros(shape[:-1], jnp.bfloat16),
                    "v_scale": jnp.zeros(shape[:-1], jnp.bfloat16)}
        return {"k": jnp.zeros(shape, cfg.dtype),
                "v": jnp.zeros(shape, cfg.dtype)}

    def cache_specs(self) -> dict:
        spec = P(None, BATCH_AXES, "model", None, None)
        if self.cfg.kv_quant:
            sc = P(None, BATCH_AXES, "model", None)
            return {"k": spec, "v": spec, "k_scale": sc, "v_scale": sc}
        return {"k": spec, "v": spec}

    # ----- block-pool paged KV cache ----------------------------------------
    def supports_paged_kv(self) -> bool:
        """Block-pool KV covers full causal attention in bf16/fp32;
        rolling-window and int8 caches keep the dense per-slot layout."""
        return self.cfg.sliding_window == 0 and not self.cfg.kv_quant

    def init_paged_cache(self, num_pages: int,
                         page_size: int | None = None) -> dict:
        """Stacked multi-layer page pools, (L, P, page, Hkv, hd).  Page 0
        is the null page (never allocated; absorbs idle-slot writes).

        With ``cfg.kv_dtype`` set the pools hold int8 / fp8 values and
        per-(page, slot, head) bf16 absmax scales ride alongside in
        ``k_scale``/``v_scale`` (L, P, page, Hkv) — dequant is fused into
        every pool read, so full-precision KV never materializes."""
        cfg = self.cfg
        if not self.supports_paged_kv():
            raise ValueError(
                "paged KV cache requires sliding_window == 0 and "
                "kv_quant == False")
        page = page_size or cfg.page_size
        shape = (cfg.num_layers, num_pages, page, cfg.padded_kv_heads,
                 cfg.head_dim)
        pool_dt = cfg.kv_pool_dtype()
        cache = {"k_pages": jnp.zeros(shape, pool_dt),
                 "v_pages": jnp.zeros(shape, pool_dt)}
        if cfg.kv_quantized:
            cache["k_scale"] = jnp.zeros(shape[:-1], jnp.bfloat16)
            cache["v_scale"] = jnp.zeros(shape[:-1], jnp.bfloat16)
        return cache

    def paged_cache_specs(self) -> dict:
        spec = P(None, None, None, "model", None)
        specs = {"k_pages": spec, "v_pages": spec}
        if self.cfg.kv_quantized:
            # scales shard on the head axis exactly like their pools
            sc = P(None, None, None, "model")
            specs["k_scale"] = sc
            specs["v_scale"] = sc
        return specs

    def prefill(self, params: dict, tokens: jax.Array, cache: dict,
                extra: dict | None = None):
        """Process the prompt, fill the cache, return last-position logits."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        if extra and "patches" in extra:
            x = jnp.concatenate([extra["patches"].astype(x.dtype), x], axis=1)
        seq = x.shape[1]
        positions = jnp.arange(seq)
        cs = self.cache_seq(cache["k"].shape[3])

        def body(h, lp):
            h, (k, v) = self.block_prefill(lp, h, positions)
            return h, (L.to_cache_layout(k[:, -cs:]),
                       L.to_cache_layout(v[:, -cs:]))

        x, kv = self.mem.layer_scan(body, x, params["layers"])
        k_new, v_new = kv
        if cfg.sliding_window > 0 and cs == cfg.sliding_window:
            # rolling cache: position p lives at slot p % W.  The last cs
            # keys cover positions seq-cs .. seq-1, so rotate them into
            # place: slot((seq-cs)+i) = (seq % W + i) % W.
            shift = seq % cs
            k_new = jnp.roll(k_new, shift, axis=3)
            v_new = jnp.roll(v_new, shift, axis=3)
        if cfg.kv_quant:
            kq, ks = L.kv_quantize(k_new)
            vq, vs = L.kv_quantize(v_new)
            upd = lambda buf, val, ax: jax.lax.dynamic_update_slice_in_dim(
                buf, val.astype(buf.dtype), 0, axis=ax)
            cache = {"k": upd(cache["k"], kq, 3),
                     "v": upd(cache["v"], vq, 3),
                     "k_scale": upd(cache["k_scale"], ks, 3),
                     "v_scale": upd(cache["v_scale"], vs, 3)}
        else:
            cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k_new.astype(cache["k"].dtype), 0, axis=3),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v_new.astype(cache["v"].dtype), 0, axis=3),
            }
        x = L.rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps)
        return L.lm_head(params["embed"], x, cfg), cache

    def prefill_paged(self, params: dict, tokens: jax.Array, cache: dict,
                      pages: jax.Array, extra: dict | None = None):
        """Prefill the prompt straight into freshly allocated pages.

        tokens: (B, S); pages: (B, n) page ids with n * page >= S (extra
        columns may map the null page — they receive only padding).  The
        whole prompt's KV lands in the pools with ONE scatter per pool
        covering every layer, page and head — no dense staging buffer, no
        per-slot splice.  Returns (last-position logits, cache).
        """
        cfg = self.cfg
        x = self._embed(params, tokens)
        if extra and "patches" in extra:
            x = jnp.concatenate([extra["patches"].astype(x.dtype), x], axis=1)
        seq = x.shape[1]
        positions = jnp.arange(seq)
        quant = cfg.kv_quantized

        def body(h, lp):
            # keep (B, S, Hkv, hd) attention layout: the page reshape
            # below wants seq-major.  Quantized pools attend the
            # quantize->dequantize round trip of the fresh KV — the same
            # values any pool read dequantizes — so a prefix-shared
            # admission is bit-identical to this unshared one.
            return self.block_prefill(lp, h, positions, kv_roundtrip=quant)

        x, (k_new, v_new) = self.mem.layer_scan(body, x, params["layers"])
        cache = _scatter_pages(cache, pages, k_new, v_new, cfg)
        x = L.rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps)
        return L.lm_head(params["embed"], x, cfg), cache

    def prefill_paged_prefix(self, params: dict, tokens: jax.Array,
                             cache: dict, prefix_pages: jax.Array,
                             pages: jax.Array):
        """Prefill only the prompt SUFFIX against a pool-resident shared
        prefix (prefix-cached admission).

        tokens: (B, S_new) suffix tokens starting at position
        ``prefix_pages.shape[1] * page`` (shared prefixes are whole
        pages, so the suffix always begins on a page boundary);
        prefix_pages: (B, n_pre) fully-shared page ids whose KV is read,
        never written; pages: (B, n_new) freshly allocated pages that
        receive the suffix KV.  Per-layer FLOPs scale with the suffix
        length — the prefix contributes only the attention reads — and
        the suffix hidden states are bit-identical to a full unshared
        prefill (see :func:`repro.models.layers.attn_prefill_prefix_kv`).
        Quantized pools dequantize the gathered prefix through its
        stored scales, and an unshared :meth:`prefill_paged` attends the
        same quantize->dequantize round trip of its fresh KV, so the
        bit-identity guarantee holds for quantized pools too: sharing or
        not sharing a prefix never changes a token.
        Returns (last-position logits, cache).
        """
        from repro.kernels.paged_attention.ops import (gather_pages_sharded,
                                                       gather_scales_sharded)

        cfg = self.cfg
        x = self._embed(params, tokens)
        seq = x.shape[1]
        page = cache["k_pages"].shape[2]
        prefix_len = prefix_pages.shape[1] * page
        positions = prefix_len + jnp.arange(seq)
        quant = cfg.kv_quantized

        def body(h, lp, cl):
            if quant:
                kp, vp, ksc, vsc = cl
            else:
                kp, vp = cl
            # (B, Hkv, pre, hd) cache layout
            kpre = gather_pages_sharded(kp, prefix_pages)
            vpre = gather_pages_sharded(vp, prefix_pages)
            if quant:
                ks = gather_scales_sharded(ksc, prefix_pages)  # (B, Hkv, pre)
                vs = gather_scales_sharded(vsc, prefix_pages)
                kpre = L.kv_dequantize(kpre, ks, cfg.dtype)
                vpre = L.kv_dequantize(vpre, vs, cfg.dtype)
            # -> (B, pre, Hkv, hd) attention layout
            kpre = kpre.transpose(0, 2, 1, 3)
            vpre = vpre.transpose(0, 2, 1, 3)
            return self.block_prefill_prefix(lp, h, positions, kpre, vpre,
                                             kv_roundtrip=quant)

        xs = ((cache["k_pages"], cache["v_pages"],
               cache["k_scale"], cache["v_scale"]) if quant
              else (cache["k_pages"], cache["v_pages"]))
        x, (k_new, v_new) = self.mem.layer_scan(body, x, params["layers"],
                                                xs=xs)
        cache = _scatter_pages(cache, pages, k_new, v_new, cfg)
        x = L.rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps)
        return L.lm_head(params["embed"], x, cfg), cache

    def prefill_paged_chunk(self, params: dict, tokens: jax.Array,
                            cache: dict, done_pages: jax.Array,
                            pages: jax.Array):
        """Continue a CHUNKED prefill: process the next page-aligned
        slice of the prompt against the request's own earlier chunks.

        tokens: (B, S_chunk) prompt slice starting at position
        ``done_pages.shape[1] * page``; done_pages: (B, n_done) pages
        already filled by prior chunks of the SAME request; pages:
        (B, n_new) fresh pages for this chunk.  This is exactly
        :meth:`prefill_paged_prefix` with the "prefix" being the
        request's own completed chunks instead of a shared prompt
        prefix — same gather-dequant read of pool-resident KV, same
        kv-roundtrip attention, so a prompt prefilled in page-aligned
        chunks is **bit-identical** (logits and pool bytes) to one
        monolithic :meth:`prefill_paged`.  The async prefill engine
        (``repro.runtime.prefill``) leans on this to bound the work a
        single dispatch injects ahead of decode.
        Returns (last-position logits, cache).
        """
        return self.prefill_paged_prefix(params, tokens, cache,
                                         done_pages, pages)

    def decode_step(self, params: dict, tokens: jax.Array, cache: dict,
                    cur_pos: jax.Array, extra: dict | None = None,
                    pages: jax.Array | None = None):
        """tokens: (B, 1); cur_pos: (B,) absolute position being written;
        pages: (B, n_pages) block-pool page table (None = dense cache)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        if pages is not None:
            x, cache = self._decode_pool(params, x, cache, cur_pos, pages)
        elif cfg.pager.offload_kv and not cfg.kv_quant:
            x, cache = self._decode_paged_cache(params, x, cache, cur_pos)
        else:
            x, cache = self._decode_scatter(params, x, cache, cur_pos)
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return L.lm_head(params["embed"], x, cfg), cache

    def _cache_slot(self, cache_seq: int, cur_pos: jax.Array) -> jax.Array:
        w = self.cfg.sliding_window
        return (cur_pos % cache_seq) if (w > 0 and cache_seq <= w) else cur_pos

    def _decode_scatter(self, params: dict, x: jax.Array, cache: dict,
                        cur_pos: jax.Array):
        cfg = self.cfg
        b = x.shape[0]

        def body(h, lp, cache_layer):
            if cfg.kv_quant:
                ck, cv, ks, vs = cache_layer
                ck = L.kv_dequantize(ck, ks, cfg.dtype)
                cv = L.kv_dequantize(cv, vs, cfg.dtype)
            else:
                ck, cv = cache_layer
            h, k0, v0 = self.block_decode(lp, h, ck, cv, cur_pos)
            return h, (k0, v0)

        # cache is READ-ONLY in the scan; per-layer new (k, v) come out as
        # tiny ys and are written in ONE batched scatter afterwards —
        # no per-layer slice copies / write-back round trips (§Perf A').
        xs = ((cache["k"], cache["v"], cache["k_scale"], cache["v_scale"])
              if cfg.kv_quant else (cache["k"], cache["v"]))
        x, (k_new, v_new) = self.mem.layer_scan(
            body, x, params["layers"], xs=xs,
            page_xs=cfg.pager.offload_kv, unroll=cfg.decode_unroll)
        slot = self._cache_slot(cache["k"].shape[3], cur_pos)
        bidx = jnp.arange(b)
        # advanced-index set: value layout (B, L, Hkv, hd)
        if cfg.kv_quant:
            kq, ks = L.kv_quantize(k_new)   # (L,B,H,hd) -> int8 + (L,B,H)
            vq, vs = L.kv_quantize(v_new)
            cache = {
                "k": cache["k"].at[:, bidx, :, slot].set(
                    kq.transpose(1, 0, 2, 3)),
                "v": cache["v"].at[:, bidx, :, slot].set(
                    vq.transpose(1, 0, 2, 3)),
                "k_scale": cache["k_scale"].at[:, bidx, :, slot].set(
                    ks.transpose(1, 0, 2)),
                "v_scale": cache["v_scale"].at[:, bidx, :, slot].set(
                    vs.transpose(1, 0, 2)),
            }
        else:
            cache = {
                "k": cache["k"].at[:, bidx, :, slot].set(
                    k_new.transpose(1, 0, 2, 3).astype(cache["k"].dtype)),
                "v": cache["v"].at[:, bidx, :, slot].set(
                    v_new.transpose(1, 0, 2, 3).astype(cache["v"].dtype)),
            }
        return x, cache

    def _decode_paged_cache(self, params: dict, x: jax.Array, cache: dict,
                            cur_pos: jax.Array):
        """FengHuang KV-offload decode: the cache rides in the scan CARRY
        (``paged_scan_cache``), each layer's slice paged in before
        attention and written back — with the current token's (k, v)
        merged in place — so only one layer's KV is device-resident."""
        cfg = self.cfg
        b = x.shape[0]
        slot = self._cache_slot(cache["k"].shape[3], cur_pos)
        bidx = jnp.arange(b)

        def body(h, lp, cache_layer):
            ck, cv = cache_layer
            h, k0, v0 = self.block_decode(lp, h, ck, cv, cur_pos)
            ck = ck.at[bidx, :, slot].set(k0.astype(ck.dtype))
            cv = cv.at[bidx, :, slot].set(v0.astype(cv.dtype))
            return h, (ck, cv)

        x, (ck, cv) = self.mem.layer_scan_cache(
            body, x, params["layers"], (cache["k"], cache["v"]))
        return x, {"k": ck, "v": cv}

    def _decode_pool(self, params: dict, x: jax.Array, cache: dict,
                     cur_pos: jax.Array, pages: jax.Array):
        """Block-pool paged decode: attention reads only the pages the
        (B, n_pages) table maps, so per-step cost scales with the actual
        sequence length instead of max_seq, and the new token's KV lands
        with ONE batched scatter over every layer and slot after the
        read-only layer scan.  With ``offload_kv`` the pools ride the
        scan carry instead (one layer's pool device-resident at a time,
        paged through the FengHuang remote tier)."""
        cfg = self.cfg
        b = x.shape[0]
        page = cache["k_pages"].shape[2]
        n_pages = pages.shape[1]
        bidx = jnp.arange(b)
        pi = cur_pos // page
        # writes past the mapped table (a finished slot re-feeding its
        # frozen position) are redirected to the null page 0 — never into
        # a live page of this or any other sequence
        pids = jnp.where(pi < n_pages,
                         pages[bidx, jnp.minimum(pi, n_pages - 1)], 0)
        slots = cur_pos % page
        quant = cfg.kv_quantized
        if quant:
            qdt, qmax = cfg.kv_pool_dtype(), cfg.kv_qmax()

        if cfg.pager.offload_kv:
            def body(h, lp, cl):
                if quant:
                    kp, vp, ksc, vsc = cl
                    h, k0, v0 = self.block_decode_paged(
                        lp, h, kp, vp, pages, cur_pos,
                        k_scales=ksc, v_scales=vsc)
                    k0, k0s = L.kv_pool_quantize(k0, qdt, qmax)
                    v0, v0s = L.kv_pool_quantize(v0, qdt, qmax)
                    ksc = ksc.at[pids, slots].set(k0s)
                    vsc = vsc.at[pids, slots].set(v0s)
                else:
                    kp, vp = cl
                    h, k0, v0 = self.block_decode_paged(lp, h, kp, vp, pages,
                                                        cur_pos)
                kp = kp.at[pids, slots].set(k0.astype(kp.dtype))
                vp = vp.at[pids, slots].set(v0.astype(vp.dtype))
                return h, (kp, vp, ksc, vsc) if quant else (kp, vp)

            pools = ((cache["k_pages"], cache["v_pages"],
                      cache["k_scale"], cache["v_scale"]) if quant
                     else (cache["k_pages"], cache["v_pages"]))
            x, out = self.mem.layer_scan_cache(body, x, params["layers"],
                                               pools)
            cache = {"k_pages": out[0], "v_pages": out[1]}
            if quant:
                cache["k_scale"], cache["v_scale"] = out[2], out[3]
            return x, cache

        def body(h, lp, cl):
            scales = {"k_scales": cl[2], "v_scales": cl[3]} if quant else {}
            h, k0, v0 = self.block_decode_paged(lp, h, cl[0], cl[1], pages,
                                                cur_pos, **scales)
            return h, (k0, v0)

        xs = ((cache["k_pages"], cache["v_pages"],
               cache["k_scale"], cache["v_scale"]) if quant
              else (cache["k_pages"], cache["v_pages"]))
        x, (k_new, v_new) = self.mem.layer_scan(
            body, x, params["layers"], xs=xs,
            unroll=cfg.decode_unroll)
        # one scatter per pool for all L layers and B slots — the fix for
        # the old host-side PagePool.append's dispatch-per-token writes;
        # the (L, B, Hkv, hd) updates keep the pool's head-shard layout
        if quant:
            k_new, ks = L.kv_pool_quantize(k_new, qdt, qmax)
            v_new, vs = L.kv_pool_quantize(v_new, qdt, qmax)
        k_new = maybe_constraint(k_new, P(None, None, "model", None))
        v_new = maybe_constraint(v_new, P(None, None, "model", None))
        cache = dict(cache)
        cache["k_pages"] = cache["k_pages"].at[:, pids, slots].set(
            k_new.astype(cache["k_pages"].dtype))
        cache["v_pages"] = cache["v_pages"].at[:, pids, slots].set(
            v_new.astype(cache["v_pages"].dtype))
        if quant:
            ks = maybe_constraint(ks, P(None, None, "model"))
            vs = maybe_constraint(vs, P(None, None, "model"))
            cache["k_scale"] = cache["k_scale"].at[:, pids, slots].set(ks)
            cache["v_scale"] = cache["v_scale"].at[:, pids, slots].set(vs)
        return x, cache

    def decode_loop(self, params: dict, cache: dict, state: DecodeState, *,
                    num_steps: int, temperature: float = 0.0,
                    eos_id: int | None = None,
                    detect_nonfinite: bool = False):
        """Fused multi-step decode — see module-level :func:`decode_loop`."""
        return decode_loop(self, params, cache, state, num_steps=num_steps,
                           temperature=temperature, eos_id=eos_id,
                           detect_nonfinite=detect_nonfinite)


def vocab_mask_logits(logits: jax.Array, vocab: int) -> jax.Array:
    """Mask padded vocabulary columns to -inf."""
    cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(cols < vocab, logits, L.NEG_INF)


def sample_tokens(logits: jax.Array, vocab: int, temperature: float,
                  key: jax.Array) -> jax.Array:
    """logits: (B, 1, V) -> (B, 1) token ids (greedy for temperature<=0)."""
    logits = vocab_mask_logits(logits, vocab).astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1).astype(jnp.int32)


def sample_tokens_per_slot(logits: jax.Array, vocab: int, temperature: float,
                           keys: jax.Array) -> jax.Array:
    """Per-slot sampling: logits (B, 1, V) with keys (B, 2) -> (B, 1).

    Each slot draws from its own PRNG key, so one slot's token never
    depends on which other slots happen to share the batch (the seam the
    preemption determinism contract rests on).  Greedy for
    temperature<=0, exactly like :func:`sample_tokens`."""
    logits = vocab_mask_logits(logits, vocab).astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.vmap(
        lambda lg, k: jax.random.categorical(k, lg / temperature, axis=-1)
    )(logits, keys).astype(jnp.int32)


def decode_loop(model, params: dict, cache: dict, state: DecodeState, *,
                num_steps: int, temperature: float = 0.0,
                eos_id: int | None = None, detect_nonfinite: bool = False):
    """Fused on-device decode: ``num_steps`` tokens in ONE dispatch.

    A ``lax.scan`` over decode steps — any model exposing
    ``decode_step(params, tokens, cache, cur_pos)`` works.  Per-slot
    ``active``/``remaining`` masks turn finished sequences into no-ops:
    their fed token and write position freeze, so a drained slot neither
    advances nor perturbs live neighbours, and the emitted ``valid`` mask
    tells the host which tokens are real.

    PRNG semantics depend on ``state.slot_keys``:

    * ``None`` (legacy): the batch-wide key is split exactly like the
      host-driven per-token loop (``key, k = split(key)`` per step), so
      block decoding is bit-identical to per-token decoding at any
      temperature — but a token then depends on the global step count.
    * per-slot keys (serving): the token a slot emits at sequence
      position ``q`` is sampled from ``fold_in(slot_key, q)`` via
      :func:`sample_tokens_per_slot` — a pure function of the request's
      own key and position, invariant under preemption/resume, block
      boundaries and batch composition.

    Returns ``(tokens (B, num_steps), valid (B, num_steps), cache,
    state)``.  Callers should jit this with the cache and state donated
    (:func:`repro.memory.donating_jit`) so the KV cache is aliased in
    place across dispatches — the decode-side donation contract of
    :class:`repro.models.base.DecodeState`.

    ``detect_nonfinite=True`` additionally emits a per-slot, per-step
    **poison mask** (True where an *emitting* slot sampled from
    non-finite logits — NaN/inf from corrupted KV or an overflowed
    activation) between ``valid`` and ``cache`` in the return tuple:
    ``(tokens, valid, poison, cache, state)``.  The serving harvest
    uses it to shed ONLY the poisoned sequence instead of letting one
    request's NaN silently corrupt a whole batch's sampled stream.
    Slots that are inactive at a step are never flagged (their frozen
    garbage is harmless by construction).
    """
    vocab = model.cfg.vocab

    def step(carry, _):
        cache, st = carry
        key, k = jax.random.split(st.key)
        if st.pages is None:
            logits, cache = model.decode_step(params, st.tokens, cache,
                                              st.pos)
        else:   # block-pool paged cache: st.pos doubles as seq_lens
            logits, cache = model.decode_step(params, st.tokens, cache,
                                              st.pos, pages=st.pages)
        if st.slot_keys is None:
            nxt = sample_tokens(logits, vocab, temperature, k)
        else:
            # the sampled token lands at sequence position pos + 1
            step_keys = jax.vmap(jax.random.fold_in)(
                st.slot_keys, (st.pos + 1).astype(jnp.uint32))
            nxt = sample_tokens_per_slot(logits, vocab, temperature,
                                         step_keys)
        # freeze finished slots: keep re-feeding the last token in place
        nxt = jnp.where(st.active[:, None], nxt, st.tokens)
        emitted = st.active
        pos = st.pos + emitted.astype(st.pos.dtype)
        remaining = st.remaining - emitted.astype(st.remaining.dtype)
        active = st.active & (remaining > 0)
        if eos_id is not None:
            active = active & (nxt[:, 0] != eos_id)
        new_state = DecodeState(tokens=nxt, pos=pos, active=active,
                                remaining=remaining, key=key, pages=st.pages,
                                slot_keys=st.slot_keys)
        out = (nxt[:, 0], emitted)
        if detect_nonfinite:
            bad = (~jnp.isfinite(logits).all(axis=(1, 2))) & emitted
            out = out + (bad,)
        return (cache, new_state), out

    (cache, state), outs = jax.lax.scan(
        step, (cache, state), None, length=num_steps)
    if detect_nonfinite:
        toks, valid, bad = outs
        return toks.T, valid.T, bad.T, cache, state
    toks, valid = outs
    return toks.T, valid.T, cache, state
