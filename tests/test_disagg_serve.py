"""Disaggregated prefill/decode serving: the async prefill engine
(``prefill_async=True``) must be a pure scheduling change — tokens
BIT-IDENTICAL to the monolithic server at any temperature, including
prefix-shared, quantized (int8/fp8) and tensor-parallel serving — while
bounding decode interference to one prefill chunk, keeping the block
pool auditable through the handoff registry, and surviving a
kill-and-restore with handoffs in flight."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.kernels.paged_attention.ops import BlockManager
from repro.runtime import ft
from repro.runtime.serve import BatchedServer

PAGE = 4
MAX_SEQ = 64
CHUNK = 8          # two pages per prefill chunk


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("qwen2.5-14b").reduced()
    cfg = dataclasses.replace(cfg, remat=False, page_size=PAGE)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _server(tiny_model, *, disagg=False, **kw):
    model, params = tiny_model
    kw.setdefault("batch_size", 3)
    kw.setdefault("max_seq", MAX_SEQ)
    kw.setdefault("page_size", PAGE)
    kw.setdefault("block_size", 4)
    kw.setdefault("audit", True)
    if disagg:
        kw.setdefault("prefill_async", True)
        kw.setdefault("prefill_chunk_tokens", CHUNK)
    return BatchedServer(model, params, **kw)


def _drive(server, reqs, max_rounds=60):
    finished = []
    for _ in range(max_rounds):
        finished += server.run_once()
        if all(r.done.is_set() for r in reqs):
            return finished
    raise AssertionError(
        f"requests stuck: {[(r.uid, r.done.is_set()) for r in reqs]}")


def _submit_mixed(server):
    """Short, long (multi-chunk), tiny and page-unaligned prompts plus a
    done-at-adoption request (max_new=1)."""
    rng = np.random.default_rng(0)
    shapes = [(6, 8), (24, 6), (3, 10), (13, 6), (9, 1)]
    return [server.submit(rng.integers(1, 500, size=p).astype(np.int32),
                          max_new_tokens=m) for p, m in shapes]


def _check_drained(srv):
    srv.manager.audit()
    assert srv.manager.handoff_pages == 0
    assert srv.prefill.idle


# ---------------------------------------------------------------------------
# bit-identity: disaggregated == monolithic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("temp", [0.0, 0.7])
def test_disagg_bit_identical(tiny_model, temp):
    ref_srv = _server(tiny_model, temperature=temp)
    ref = _submit_mixed(ref_srv)
    _drive(ref_srv, ref)

    srv = _server(tiny_model, disagg=True, temperature=temp)
    got = _submit_mixed(srv)
    _drive(srv, got)
    assert srv.stats["handoffs"] >= 4          # max_new=1 dies at adoption
    assert srv.stats["prefill_chunks"] > srv.stats["handoffs"]  # chunked
    for a, b in zip(ref, got):
        assert a.output == b.output, (temp, a.uid, a.output, b.output)
        assert b.error is None
        assert b.first_token_block is not None
        assert b.submitted_block is not None
    assert srv.stats["ttft_p50_blocks"] >= 0.0
    assert srv.stats["audits"] > 0
    _check_drained(srv)


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8_e4m3"])
def test_disagg_quantized_bit_identical(kv_dtype):
    """Handoffs carry quantized page bytes + scales; adoption must not
    perturb a single bit of either."""
    cfg = get_config("qwen2.5-14b").reduced()
    cfg = dataclasses.replace(cfg, remat=False, page_size=PAGE,
                              kv_dtype=kv_dtype)
    model = build_model(cfg)
    tm = (model, model.init(jax.random.PRNGKey(0)))
    ref_srv = _server(tm, temperature=0.7)
    ref = _submit_mixed(ref_srv)
    _drive(ref_srv, ref)

    srv = _server(tm, disagg=True, temperature=0.7)
    got = _submit_mixed(srv)
    _drive(srv, got)
    assert [r.output for r in ref] == [r.output for r in got]
    _check_drained(srv)


def test_disagg_prefix_shared_bit_identical(tiny_model):
    """Prefix-shared prompts: the engine adopts the shared pages as
    already-completed chunks and prefills only the suffix — the
    published pages and the tokens must match monolithic admission."""
    sys_toks = np.arange(3, 15, dtype=np.int32)        # 3 whole pages

    def submit_all(server):
        return [server.submit(
            np.concatenate([sys_toks, np.asarray([50 + i, 60 + i],
                                                 np.int32)]),
            max_new_tokens=12) for i in range(3)]

    ref_srv = _server(tiny_model, temperature=0.7, prefix_cache=True)
    ref = submit_all(ref_srv)
    _drive(ref_srv, ref)

    srv = _server(tiny_model, disagg=True, temperature=0.7,
                  prefix_cache=True)
    got = submit_all(srv)
    _drive(srv, got)
    assert srv.stats["prefix_hits"] >= 1
    assert srv.stats["prefix_shared_pages"] >= 3
    assert [r.output for r in ref] == [r.output for r in got]
    _check_drained(srv)


def test_prefill_async_requires_paged(tiny_model):
    model, params = tiny_model
    with pytest.raises(ValueError, match="paged"):
        BatchedServer(model, params, paged=False, prefill_async=True)


# ---------------------------------------------------------------------------
# interference: one chunk bounds the decode stall
# ---------------------------------------------------------------------------

def test_decode_stall_bounded_by_chunk(tiny_model):
    """A long prompt arriving beside live decoders stalls monolithic
    decode for the whole prefill but the async engine for at most one
    chunk (= one block here)."""
    def submit_all(server):
        rng = np.random.default_rng(1)
        reqs = [server.submit(rng.integers(1, 500, size=4).astype(np.int32),
                              max_new_tokens=24) for _ in range(2)]
        reqs.append(server.submit(
            rng.integers(1, 500, size=48).astype(np.int32),
            max_new_tokens=4))
        return reqs

    mono = _server(tiny_model)
    ref = submit_all(mono)
    _drive(mono, ref)
    assert mono.stats["decode_stall_blocks_max"] >= 3   # whole-prompt stall

    srv = _server(tiny_model, disagg=True, prefill_chunk_tokens=4)
    got = submit_all(srv)
    _drive(srv, got)
    assert srv.stats["decode_stall_blocks_max"] <= 1    # one chunk, ever
    assert [r.output for r in ref] == [r.output for r in got]
    _check_drained(srv)


# ---------------------------------------------------------------------------
# handoff registry: allocator invariants
# ---------------------------------------------------------------------------

def test_handoff_registry_audit_and_ownership():
    m = BlockManager(12, PAGE)
    m.ensure(0, 2 * PAGE)
    m.note_tokens(0, 2 * PAGE)
    pages = list(m.slot_pages(0))
    with pytest.raises(KeyError):
        m.detach_to_handoff(3)                  # slot owns nothing
    tok = m.detach_to_handoff(0)
    assert m.slot_pages(0) == []
    assert m.handoff_pages == 2
    m.audit()                                   # handoff pages are owned
    assert m.audit()["handoff_pages"] == 2
    m.ensure(1, PAGE)
    with pytest.raises(ValueError):
        m.adopt_from_handoff(1, tok)            # slot already owns pages
    with pytest.raises(KeyError):
        m.adopt_from_handoff(2, tok + 99)       # unknown token
    assert m.adopt_from_handoff(2, tok) == pages
    assert m.slot_pages(2) == pages
    assert m.handoff_pages == 0
    m.audit()
    # release path: an abandoned handoff returns its pages to the pool
    m.note_tokens(2, 2 * PAGE)
    tok2 = m.detach_to_handoff(2)
    free_before = m.capacity - m.pages_in_use
    m.release_handoff(tok2)
    assert m.capacity - m.pages_in_use == free_before + 2
    m.audit()


# ---------------------------------------------------------------------------
# kill-and-restore with handoffs in flight
# ---------------------------------------------------------------------------

def test_kill_mid_handoff_restores_bit_identical(tiny_model, tmp_path):
    """Snapshot a disaggregated server while the engine holds ready
    (unadopted) handoffs and mid-chunk prefills; restore into a fresh
    server: every sequence finishes with the monolithic run's tokens."""
    def submit_all(server):
        rng = np.random.default_rng(2)
        # two long-lived decoders pin both slots; the multi-chunk
        # prompts behind them complete with nowhere to go — parked
        # handoffs the snapshot must catch in flight
        shapes = [(4, 40), (4, 40), (14, 6), (12, 6)]
        return [server.submit(rng.integers(1, 500, size=p).astype(np.int32),
                              max_new_tokens=m) for p, m in shapes]

    kw = dict(temperature=0.7, batch_size=2, num_pages=48)
    ref_srv = _server(tiny_model, **kw)
    ref = submit_all(ref_srv)
    _drive(ref_srv, ref)

    srv = _server(tiny_model, disagg=True, **kw)
    reqs = submit_all(srv)
    early = []
    for _ in range(12):           # stop as soon as a handoff is parked
        early += srv.run_once(max_blocks=1)
        if srv.prefill.ready:
            break
    assert srv.prefill.ready, "no ready handoff to kill mid-flight"
    assert srv.manager.handoff_pages > 0
    srv.manager.audit()           # registry pages audit while staged
    snap = ft.snapshot_server(srv)
    path = ft.save_server_snapshot(tmp_path / "disagg_ckpt", snap)
    del srv                       # the "crash"

    srv2 = _server(tiny_model, disagg=True, temperature=0.7, batch_size=2)
    ft.restore_server(srv2, ft.load_server_snapshot(path))
    finished = list(early)
    for _ in range(60):
        finished += srv2.run_once()
        if len(finished) == len(reqs):
            break
    by_uid = {r.uid: r for r in finished}
    assert len(by_uid) == len(ref)
    for a in ref:
        b = by_uid[a.uid]
        assert a.output == b.output, (a.uid, a.output, b.output)
        assert b.error is None
    _check_drained(srv2)


def test_restore_rejects_busy_prefill_engine(tiny_model):
    """An engine with an in-flight prefill is NOT an idle server."""
    srv = _server(tiny_model, disagg=True, batch_size=2)
    srv.submit(np.arange(1, 30, dtype=np.int32), max_new_tokens=8)
    srv._drain_queue()
    srv.prefill.start(srv._backlog.popleft())
    assert not srv.prefill.idle
    with pytest.raises(ValueError, match="idle"):
        srv.restore({"seed": srv.seed, "uid": 0, "sequences": []})


# ---------------------------------------------------------------------------
# tensor-parallel disaggregation (subprocess: forced host devices)
# ---------------------------------------------------------------------------

SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, sys.argv[1])
import dataclasses
import jax, numpy as np
from repro.configs import get_config, build_model
from repro.launch.mesh import make_serving_mesh
from repro.runtime.serve import BatchedServer

cfg = get_config("qwen2.5-14b").reduced()
cfg = dataclasses.replace(cfg, remat=False, page_size=4)
params = build_model(cfg).init(jax.random.PRNGKey(0))

def serve(mesh, disagg, temp):
    kw = dict(batch_size=2, max_seq=64, block_size=4, page_size=4,
              temperature=temp, mesh=mesh, audit=True)
    if disagg:
        kw.update(prefill_async=True, prefill_chunk_tokens=8)
    srv = BatchedServer(build_model(cfg), params, **kw)
    rng = np.random.default_rng(3)
    reqs = [srv.submit(rng.integers(1, 500, size=p).astype(np.int32),
                       max_new_tokens=m) for p, m in ((5, 8), (20, 6))]
    for _ in range(60):
        srv.run_once()
        if all(r.done.is_set() for r in reqs):
            break
    srv.manager.audit()
    assert srv.manager.handoff_pages == 0
    return [tuple(r.output) for r in reqs], srv

mesh = make_serving_mesh(model=2)
for temp in (0.0, 0.7):
    ref, _ = serve(None, False, temp)
    got, srv = serve(mesh, True, temp)
    assert srv.stats["model_shards"] == 2
    assert srv.stats["handoffs"] >= 2
    assert got == ref, (f"sharded disagg diverged (temp={temp}):\n"
                        f"  mono ={ref}\n  disagg={got}")
print("DISAGG_SHARDED_OK")
"""


@pytest.mark.slow
def test_disagg_sharded_bit_identical():
    """2-shard TP disaggregated serving emits the single-device
    monolithic server's exact tokens (handoff staging gathers sharded
    pools through the same swapper contract as preemption)."""
    import os
    import subprocess
    import sys
    from pathlib import Path
    src = str(Path(__file__).resolve().parent.parent / "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT, src],
                         capture_output=True, text=True, timeout=600,
                         env=env)
    assert "DISAGG_SHARDED_OK" in out.stdout, \
        out.stdout[-1500:] + out.stderr[-3000:]
