"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
under-reports flops/bytes/collective traffic for scan-structured models by
a factor of num_layers (and microbatches, loss chunks, ...).  This module
parses the post-optimization HLO text, builds the computation call graph,
and multiplies each while body by its ``known_trip_count`` (falling back to
the loop-condition compare constant).

Reported per device (SPMD modules carry local shapes):

* ``flops``            — 2 * numel(out) * contracted for every dot
* ``bytes``            — operand + output bytes at fusion boundaries
* ``collectives``      — per-op-kind payload bytes and instruction counts
* ``transcendentals``  — numel of exp/log/tanh/rsqrt/power outputs
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from collections import defaultdict

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "c64": 8, "c128": 16,
}

SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
SIMPLE_SHAPE_RE = re.compile(
    r"^((?:\w+\[[0-9,]*\])(?:\{[^}]*\})?)\s+([\w\-]+)\((.*)$")


def _parse_instr(line: str):
    """'%name = SHAPE op(rest' -> (name, shape, op, rest) or None.

    Handles tuple shapes with layout braces and /*index=N*/ comments
    (which defeat a single regex)."""
    m = ASSIGN_RE.match(line)
    if not m:
        return None
    name, rhs = m.groups()
    if rhs.startswith("("):           # tuple shape: find matching paren
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape = rhs[: i + 1]
                    m2 = re.match(r"\s*([\w\-]+)\((.*)$", rhs[i + 1:])
                    if not m2:
                        return None
                    return name, shape, m2.group(1), m2.group(2)
        return None
    m2 = SIMPLE_SHAPE_RE.match(rhs)
    if not m2:
        return None
    shape, op, rest = m2.groups()
    return name, shape, op, rest
PARAM_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\))|(?:\w+\[[0-9,]*\](?:\{[0-9,]*\})?))")
COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{")
TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
CALLED_RE = re.compile(r"(?:condition|body|to_apply|calls)=%([\w.\-]+)")
BRANCHES_RE = re.compile(r"branches=\{([^}]*)\}")

SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "after-all", "partition-id", "replica-id",
    "call", "opt-barrier", "domain", "add-dependency",
}

COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}

TRANSCENDENTAL_OPS = {"exponential", "log", "tanh", "rsqrt", "power",
                      "logistic", "exponential-minus-one", "log-plus-one",
                      "sine", "cosine", "sqrt"}


def shape_bytes(shape_text: str) -> int:
    """Total bytes of a (possibly tuple) shape string."""
    total = 0
    for dt, dims in SHAPE_RE.findall(shape_text):
        nb = DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def shape_numel(shape_text: str) -> int:
    m = SHAPE_RE.search(shape_text)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def shape_dims(shape_text: str) -> list[int]:
    m = SHAPE_RE.search(shape_text)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    symtab: dict           # name -> shape text


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_counts: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] += v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] += v * mult

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "bytes": self.bytes,
            "transcendentals": self.transcendentals,
            "collective_bytes": dict(self.collective_bytes),
            "collective_counts": dict(self.collective_counts),
            "collective_total_bytes": float(
                sum(self.collective_bytes.values())),
        }


def parse_module(hlo: str) -> tuple[dict, str]:
    """Returns ({name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = COMP_START_RE.match(line.strip())
            if m and "{" in line:
                name = m.group(1)
                cur = Computation(name, [], {})
                if line.strip().startswith("ENTRY"):
                    entry = name
                # parameters from the signature carry shapes
                for pname, pshape in PARAM_RE.findall(m.group(2)):
                    cur.symtab[pname] = pshape
            continue
        if line.strip() == "}" or line.strip().startswith("} //"):
            comps[cur.name] = cur
            cur = None
            continue
        parsed = _parse_instr(line)
        if parsed:
            name, shape, op, rest = parsed
            cur.symtab[name] = shape
            cur.instrs.append(Instr(name, shape, op, rest))
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _operand_names(rest: str) -> list[str]:
    # operands are inside the first balanced paren group
    depth, end = 1, len(rest)
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return re.findall(r"%([\w.\-]+)", rest[:end])


def _dot_flops(instr: Instr, symtab: dict) -> float:
    out_numel = shape_numel(instr.shape)
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    ops = _operand_names(instr.rest)
    if not mc or not ops:
        return 2.0 * out_numel  # degenerate
    lhs_shape = symtab.get(ops[0], "")
    dims = shape_dims(lhs_shape)
    contracted = 1
    for idx in mc.group(1).split(","):
        if idx and int(idx) < len(dims):
            contracted *= dims[int(idx)]
    return 2.0 * out_numel * contracted


def _trip_count(instr: Instr, comps: dict) -> float:
    m = TRIP_RE.search(instr.rest)
    if m:
        return float(m.group(1))
    # fallback: find the compare bound in the condition computation
    mc = re.search(r"condition=%([\w.\-]+)", instr.rest)
    if mc and mc.group(1) in comps:
        cond = comps[mc.group(1)]
        consts = {}
        for ins in cond.instrs:
            mm = re.match(r"constant\((\d+)\)", ins.op + "(" + ins.rest)
            if ins.op == "constant":
                mm2 = re.match(r"(\d+)\)", ins.rest)
                if mm2:
                    consts[ins.name] = int(mm2.group(1))
        if consts:
            return float(max(consts.values()))
    return 1.0


PASSTHROUGH_OPS = {"convert", "bitcast", "copy", "reshape", "transpose",
                   "broadcast"}


def _fusion_bytes(ins: Instr, comp: Computation, comps: dict) -> float:
    """Memory traffic of a fusion at its boundary.

    A kLoop fusion makes ONE pass: each parameter is read only in the
    region its internal consumers touch (a fused dynamic-slice reads just
    the slice), and a root dynamic-update-slice writes just the updated
    region (the rest of the buffer is aliased through).  Pure dtype/layout
    chains (convert/bitcast/copy) are followed transparently — XLA:CPU
    emulates bf16 in f32 and wraps buffers in converts that native-bf16
    TPUs never materialize.
    """
    callees = CALLED_RE.findall(ins.rest)
    fused = comps.get(callees[0]) if callees else None
    ops_names = _operand_names(ins.rest)
    if fused is None:
        nbytes = shape_bytes(ins.shape)
        for o in ops_names:
            nbytes += shape_bytes(comp.symtab.get(o, ""))
        return nbytes

    # map parameter NUMBER -> name ("%p = shape parameter(2)" ordering in
    # the text does not follow the operand order)
    by_idx: dict[int, str] = {}
    for fi in fused.instrs:
        if fi.op == "parameter":
            m = re.match(r"(\d+)\)", fi.rest)
            if m:
                by_idx[int(m.group(1))] = fi.name
    param_names = [by_idx.get(i, "") for i in range(len(ops_names))]

    # Pure dtype/layout fusion (convert/bitcast/copy/transpose chains):
    # the consumer reads the narrow form and widens in registers/VMEM on
    # TPU (bf16 native, int8 dequant fused into the MXU load) — charge a
    # single pass at the NARROW width instead of in+out at both widths.
    body_ops = [fi.op for fi in fused.instrs if fi.op != "parameter"]
    if body_ops and all(op in PASSTHROUGH_OPS or op == "multiply"
                        for op in body_ops):
        in_bytes = sum(shape_bytes(comp.symtab.get(o, "")) for o in ops_names)
        return 2.0 * min(in_bytes, shape_bytes(ins.shape))

    # def-use inside the fused computation
    consumers: dict[str, list[Instr]] = {}
    producer: dict[str, Instr] = {}
    for fi in fused.instrs:
        producer[fi.name] = fi
        for o in _operand_names(fi.rest):
            consumers.setdefault(o, []).append(fi)

    def terminal_consumers(name: str, depth: int = 0) -> list[Instr]:
        """Consumers reached through pure dtype/layout chains."""
        outs: list[Instr] = []
        for c in consumers.get(name, []):
            if c.op in PASSTHROUGH_OPS and depth < 8:
                outs.extend(terminal_consumers(c.name, depth + 1))
            else:
                outs.append(c)
        return outs

    root = fused.instrs[-1] if fused.instrs else None

    def effective_root(r: Instr | None, depth: int = 0) -> Instr | None:
        """Skip convert/bitcast wrappers around the real root op."""
        while (r is not None and r.op in PASSTHROUGH_OPS and depth < 8):
            srcs = _operand_names(r.rest)
            if not srcs or srcs[0] not in producer:
                break
            r = producer[srcs[0]]
            depth += 1
        return r

    eroot = effective_root(root)
    # in-place updates: DUS and scatter write only the updated region on
    # hardware with buffer aliasing (TPU); the base buffer passes through.
    INPLACE = {"dynamic-update-slice": 1, "scatter": 2}
    inplace_base: str | None = None
    upd_idx = INPLACE.get(eroot.op) if eroot is not None else None
    if upd_idx is not None:
        e_ops = _operand_names(eroot.rest)
        if e_ops:
            b = e_ops[0]
            for _ in range(8):
                if b in param_names:
                    inplace_base = b
                    break
                pr = producer.get(b)
                if pr is None or pr.op not in PASSTHROUGH_OPS:
                    break
                srcs = _operand_names(pr.rest)
                if not srcs:
                    break
                b = srcs[0]

    total = 0.0
    for idx, o in enumerate(ops_names):
        pname = param_names[idx] if idx < len(param_names) else None
        full = shape_bytes(comp.symtab.get(o, ""))
        if pname == inplace_base and inplace_base is not None:
            continue   # aliased passthrough: only the region is written
        terms = terminal_consumers(pname) if pname else []
        if terms and all(t.op in ("dynamic-slice", "slice", "gather")
                         for t in terms):
            total += sum(shape_bytes(t.shape) for t in terms)
        else:
            total += full
    if upd_idx is not None:
        e_ops = _operand_names(eroot.rest)
        upd = e_ops[upd_idx] if len(e_ops) > upd_idx else ""
        upd_bytes = shape_bytes(fused.symtab.get(upd, ""))
        # read+write of the updated region only (native-dtype size)
        total += 2 * min(upd_bytes, shape_bytes(ins.shape))
    else:
        total += shape_bytes(ins.shape)
    return max(total, 0.0)


def computation_cost(name: str, comps: dict, memo: dict) -> Cost:
    if name in memo:
        return memo[name]
    memo[name] = Cost()  # cycle guard
    comp = comps.get(name)
    if comp is None:
        return memo[name]
    cost = Cost()
    for ins in comp.instrs:
        op = ins.op
        if op == "while":
            callees = CALLED_RE.findall(ins.rest)
            trip = _trip_count(ins, comps)
            for callee in callees:
                cost.add(computation_cost(callee, comps, memo), trip)
            continue
        if op == "conditional":
            mb = BRANCHES_RE.search(ins.rest)
            if mb:
                branch_costs = [computation_cost(b.strip().lstrip("%"),
                                                 comps, memo)
                                for b in mb.group(1).split(",")]
                if branch_costs:
                    best = max(branch_costs, key=lambda c: c.flops + c.bytes)
                    cost.add(best)
            continue
        if op in ("call", "async-start"):
            for callee in CALLED_RE.findall(ins.rest):
                cost.add(computation_cost(callee, comps, memo))
            continue
        if op == "fusion":
            for callee in CALLED_RE.findall(ins.rest):
                sub = computation_cost(callee, comps, memo)
                # flops & transcendentals inside the fusion body; traffic
                # at the fusion boundary only.
                cost.flops += sub.flops
                cost.transcendentals += sub.transcendentals
        if op in COLLECTIVE_OPS:
            kind = op.replace("-start", "")
            payload = max(
                shape_bytes(ins.shape),
                sum(shape_bytes(comp.symtab.get(o, ""))
                    for o in _operand_names(ins.rest)))
            cost.collective_bytes[kind] += payload
            cost.collective_counts[kind] += 1
        if op == "dot":
            cost.flops += _dot_flops(ins, comp.symtab)
        if op == "convolution":
            cost.flops += 2.0 * shape_numel(ins.shape) * 128  # coarse
        if op in TRANSCENDENTAL_OPS:
            cost.transcendentals += shape_numel(ins.shape)
        if op not in SKIP_BYTES_OPS and not op.endswith("-done"):
            ops_names = _operand_names(ins.rest)
            if op == "fusion":
                nbytes = _fusion_bytes(ins, comp, comps)
            elif op == "dynamic-update-slice":
                # in-place (aliased): traffic = read+write of the update
                # region, not the whole buffer.
                upd = ops_names[1] if len(ops_names) > 1 else ""
                nbytes = 2 * shape_bytes(comp.symtab.get(upd, ""))
            elif op in ("dynamic-slice", "slice", "gather"):
                nbytes = 2 * shape_bytes(ins.shape)
            elif op == "scatter":
                upd = ops_names[2] if len(ops_names) > 2 else ""
                nbytes = 3 * shape_bytes(comp.symtab.get(upd, ""))
            else:
                nbytes = shape_bytes(ins.shape)
                for o in ops_names:
                    nbytes += shape_bytes(comp.symtab.get(o, ""))
            cost.bytes += nbytes
    memo[name] = cost
    return cost


def module_cost(hlo_text: str) -> dict:
    comps, entry = parse_module(hlo_text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    memo: dict[str, Cost] = {}
    return computation_cost(entry, comps, memo).as_dict()
