"""MemoryOrchestrator: tensor classes -> residency policies, plus the
paged execution transforms they ride.

The *Tensor Prefetcher* becomes :func:`paged_scan`: a scan over stacked
per-layer weights whose carry holds a **double buffer** — iteration *i*
computes layer *i* from the already-fetched buffer while the fetch of
layer *i+1* is issued *before* the compute, so XLA's async
copy-start/copy-done pair (the "paging stream") overlaps the transfer
with layer *i*'s compute.  Peak device residency is 2 layers of weights
+ activations, which is the paper's Table 4.3 result (10–20 GB instead
of 144 GB).

Everything degrades gracefully: with ``enabled=False`` (or on backends
without host memory spaces) the transforms are plain ``lax.scan``s over
device-resident weights, so models are paging-agnostic.

:class:`MemoryOrchestrator` is the subsystem's front door:
``MemoryOrchestrator.plan(model_config)`` resolves the policy matrix
from the config's :class:`~repro.memory.policies.PagerConfig`, and the
instance then owns placement (``place_layer_weights`` /
``place_kv_pool`` / ``block_pool``), the layer scans (with the expert
banks automatically pinned out of the prefetch window when expert
paging is on), the donation contract, and the shared ledger.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.memory import tiers
from repro.memory.accounting import (MemoryLedger, paged_window_bytes,
                                     tree_bytes)
from repro.memory.policies import (BlockPoolResidency, DoubleBufferPrefetch,
                                   OffloadBetweenSteps, PagerConfig, PinLocal,
                                   TopKExpertPrefetch)


def donating_jit(fn: Callable, *, donate_argnums: tuple[int, ...] = (),
                 config: PagerConfig | None = None, **jit_kwargs) -> Callable:
    """``jax.jit`` with the FengHuang donation contract.

    The serving hot path hands its KV cache and decode state to every
    dispatch and never touches the old buffers again — exactly the
    "consumed double buffer" the pager's eviction policy describes.
    Donating them lets XLA alias input and output so the cache is updated
    in place instead of copied once per dispatch.  ``config.donate_evicted
    = False`` turns the aliasing off (debug mode: old buffers stay live).
    """
    if config is not None and not config.donate_evicted:
        donate_argnums = ()
    return jax.jit(fn, donate_argnums=donate_argnums, **jit_kwargs)


def _index_layer(stacked: Any, i) -> Any:
    """Slice layer ``i`` out of a stacked (L, ...) pytree (stays in its
    current memory space)."""
    return jax.tree.map(
        lambda x: jax.lax.dynamic_index_in_dim(x, i, 0, keepdims=False),
        stacked)


def _page_in_filtered(layer: Any, fetch_filter: Callable | None) -> Any:
    """page_in the layer, leaving leaves the filter rejects at rest
    (expert banks under TopKExpertPrefetch stay remote — their rows are
    gathered on demand inside the layer body instead)."""
    if fetch_filter is None:
        return tiers.page_in(layer)
    return jax.tree_util.tree_map_with_path(
        lambda p, x: (tiers.page_in(x)
                      if fetch_filter(jax.tree_util.keystr(p)) else x),
        layer)


def paged_scan(
    body: Callable[..., tuple[Any, Any]],
    carry: Any,
    stacked_weights: Any,
    xs: Any = None,
    *,
    config: PagerConfig,
    length: int | None = None,
    unroll: int = 1,
    page_xs: bool = False,
    fetch_filter: Callable[[str], bool] | None = None,
) -> tuple[Any, Any]:
    """FengHuang-paged scan over layers.

    ``body(carry, layer_weights[, x]) -> (carry, out)`` — layer weights
    arrive in the local tier.  With paging enabled, ``stacked_weights`` is
    expected to live in the remote tier; the double-buffered carry implements
    the lookahead-1 Tensor Prefetcher.  Differentiable (the transfers are
    linear), so the same transform serves training.

    ``xs`` is an optional extra per-layer input (e.g. the KV-cache slice for
    this layer).  With ``page_xs=True`` it is paged in alongside the weights
    and the per-layer output ``out`` is written back to the remote tier
    (FengHuang KV paging).  ``fetch_filter(leaf_path) -> bool`` excludes
    weight leaves from the prefetch window (False = leaf stays at rest).
    """
    if length is None:
        length = jax.tree.leaves(stacked_weights)[0].shape[0]

    if not config.enabled:
        if fetch_filter is None:
            if xs is None:
                return jax.lax.scan(body, carry, stacked_weights,
                                    unroll=unroll)
            return jax.lax.scan(lambda c, wx: body(c, wx[0], wx[1]), carry,
                                (stacked_weights, xs), unroll=unroll)

        # at-rest leaves (expert banks) must not stream through the scan
        # xs — index the layer inside the body so they stay in their tier
        # and only the rows the body gathers cross it
        def step(c, i):
            w = _index_layer(stacked_weights, i)
            if xs is None:
                return body(c, w)
            return body(c, w, _index_layer(xs, i))

        return jax.lax.scan(step, carry, jnp.arange(length), unroll=unroll)

    def fetch(i):
        return _page_in_filtered(_index_layer(stacked_weights, i),
                                 fetch_filter)

    last = length - 1
    w0 = fetch(0)

    def step(state, i):
        inner_carry, w_cur = state
        # Issue the prefetch of layer i+1 BEFORE the compute of layer i so
        # the copy-start precedes the matmuls in program order; XLA overlaps.
        w_next = fetch(jnp.minimum(i + 1, last))
        if xs is None:
            inner_carry, out = body(inner_carry, w_cur)
        else:
            x = _index_layer(xs, i)
            if page_xs:
                x = tiers.page_in(x)
            inner_carry, out = body(inner_carry, w_cur, x)
            if page_xs:
                out = tiers.page_out(out)
        return (inner_carry, w_next), out

    (carry, _), outs = jax.lax.scan(step, (carry, w0), jnp.arange(length),
                                    unroll=unroll)
    return carry, outs


def paged_scan_cache(
    body: Callable[..., tuple[Any, Any]],
    carry: Any,
    stacked_weights: Any,
    cache: Any,
    *,
    config: PagerConfig,
    length: int | None = None,
    fetch_filter: Callable[[str], bool] | None = None,
) -> tuple[Any, Any]:
    """Layer scan with the (stacked) cache threaded through the CARRY.

    ``body(carry, layer_weights, cache_layer) -> (carry, new_cache_layer)``.

    Unlike passing the cache as scan xs/ys — which makes XLA materialize a
    second full-size stacked buffer and copy the untouched layers every
    iteration — the carried buffer is updated in place with a
    dynamic-update-slice (while-loop state aliases input/output), so
    per-layer traffic is just that layer's slice.  With
    ``config.offload_kv`` the slice pages through the FengHuang remote
    tier (page-in before attention, write-back after).
    """
    if length is None:
        length = jax.tree.leaves(stacked_weights)[0].shape[0]
    last = length - 1

    def fetch(i):
        w = _index_layer(stacked_weights, i)
        return (_page_in_filtered(w, fetch_filter) if config.enabled else w)

    def update(buf, i, new_layer):
        return jax.tree.map(
            lambda b, u: jax.lax.dynamic_update_index_in_dim(
                b, u.astype(b.dtype), i, 0),
            buf, new_layer)

    if not config.enabled:
        def step(state, i):
            inner, cache_buf = state
            cl = _index_layer(cache_buf, i)
            inner, new_cl = body(inner, fetch(i), cl)
            return (inner, update(cache_buf, i, new_cl)), None

        (carry, cache), _ = jax.lax.scan(step, (carry, cache),
                                         jnp.arange(length))
        return carry, cache

    w0 = fetch(0)

    def step(state, i):
        inner, cache_buf, w_cur = state
        w_next = fetch(jnp.minimum(i + 1, last))    # lookahead-1 prefetch
        cl = _index_layer(cache_buf, i)
        if config.offload_kv:
            cl = tiers.page_in(cl)
        inner, new_cl = body(inner, w_cur, cl)
        if config.offload_kv:
            new_cl = tiers.page_out(new_cl)
        return (inner, update(cache_buf, i, new_cl), w_next), None

    (carry, cache, _), _ = jax.lax.scan(step, (carry, cache, w0),
                                        jnp.arange(length))
    return carry, cache


def paged_map(fn: Callable[[Any], Any], stacked: Any, *,
              config: PagerConfig) -> Any:
    """Apply ``fn`` per layer with paging (utility for cache init etc.)."""
    def body(carry, w):
        return carry, fn(w)
    _, outs = paged_scan(body, (), stacked, config=config)
    return outs


class MemoryOrchestrator:
    """Binds tensor classes to residency policies for one model/server.

    Tensor classes: ``layer_weights`` (stacked per-layer params),
    ``kv_pool`` (dense slab or block pool), ``expert_weights`` (MoE
    banks).  ``plan`` resolves the policy matrix from a
    :class:`PagerConfig`; everything downstream — placement, layer
    scans, donation, block-pool bookkeeping, accounting — goes through
    the instance, so the server, benchmarks and examples never hand-wire
    pager calls.
    """

    def __init__(self, config: PagerConfig,
                 policies: dict[str, Any] | None = None,
                 ledger: MemoryLedger | None = None):
        self.config = config
        self.ledger = ledger if ledger is not None else MemoryLedger()
        self.policies = dict(policies or {})
        self.policies.setdefault("layer_weights", PinLocal())
        self.policies.setdefault("kv_pool", PinLocal())
        self.mesh = None          # bound by bind_mesh (sharded serving)
        self.model_shards = 1
        # tensor class -> reason, recorded when an unrecoverable tier
        # fault forced a documented degradation (e.g. remote KV offload
        # falling back to local residency)
        self.degraded: dict[str, str] = {}

    # ----- planning ---------------------------------------------------------
    @classmethod
    def plan(cls, model_config: Any = None,
             pager_config: PagerConfig | None = None,
             ledger: MemoryLedger | None = None) -> "MemoryOrchestrator":
        """The one entry point: resolve the policy matrix.

        ``model_config`` is a :class:`repro.models.base.ModelConfig` (its
        ``pager`` policy supplies the knobs unless ``pager_config``
        overrides) or None for a bare default orchestrator.
        """
        if pager_config is None:
            pp = getattr(model_config, "pager", None)
            pager_config = PagerConfig(
                enabled=getattr(pp, "enabled", False),
                lookahead=getattr(pp, "lookahead", 1),
                offload_kv=getattr(pp, "offload_kv", False),
                page_experts=getattr(pp, "page_experts", False))
        ledger = ledger if ledger is not None else MemoryLedger()
        policies: dict[str, Any] = {}
        policies["layer_weights"] = (
            DoubleBufferPrefetch(lookahead=pager_config.lookahead)
            if pager_config.enabled else PinLocal())
        policies["kv_pool"] = (
            OffloadBetweenSteps()
            if pager_config.enabled and pager_config.offload_kv
            else PinLocal())
        num_experts = getattr(model_config, "num_experts", 0)
        if pager_config.page_experts and num_experts:
            policies["expert_weights"] = TopKExpertPrefetch(
                num_experts=num_experts,
                top_k=getattr(model_config, "top_k", 1),
                ledger=ledger)
        return cls(pager_config, policies, ledger)

    # ----- mesh awareness ---------------------------------------------------
    def bind_mesh(self, mesh) -> "MemoryOrchestrator":
        """Make the orchestrator mesh-aware: residency policies then emit
        NamedShardings against ``mesh`` (with each policy's tier resolved
        to the backend's memory kind) and the ledger switches to
        per-shard accounting — the bytes ONE device holds — so
        ``capacity_reduction`` stays comparable to the per-GPU Table 4.3
        simulator.  ``bind_mesh(None)`` returns to single-device mode."""
        self.mesh = mesh
        if mesh is None:
            self.model_shards = 1
        else:
            from repro.runtime.sharding import mesh_axis_sizes
            self.model_shards = int(mesh_axis_sizes(mesh).get("model", 1))
        self.ledger.shards = self.model_shards
        return self

    def sharding_for(self, tensor_class: str, spec, *, key: str | None = None):
        """The NamedSharding a tensor-class leaf should carry on the
        bound mesh: the resolved partition spec + the class's policy tier
        (``key`` disambiguates per-leaf tiers, e.g. OffloadBetweenSteps
        pool vs bookkeeping leaves)."""
        if self.mesh is None:
            raise ValueError("no mesh bound; call bind_mesh first")
        from repro.runtime.sharding import resolve_spec
        policy = self.policies.get(tensor_class, PinLocal())
        resolved = resolve_spec(spec, self.mesh)
        if isinstance(policy, OffloadBetweenSteps):
            return policy.sharding(self.mesh, resolved, key=key)
        return policy.sharding(self.mesh, resolved)

    @staticmethod
    def placed_bytes(tree: Any) -> int:
        """Bytes ONE device holds of a placed pytree (exact via each
        leaf's shard shape; total bytes for sharding-less leaves)."""
        total = 0
        for x in jax.tree.leaves(tree):
            sharding = getattr(x, "sharding", None)
            if sharding is not None and hasattr(sharding, "shard_shape"):
                n = 1
                for d in sharding.shard_shape(x.shape):
                    n *= d
                total += n * x.dtype.itemsize
            else:
                total += x.size * x.dtype.itemsize
        return total

    def place_params(self, params: Any, spec_tree: Any) -> Any:
        """Mesh-aware whole-model placement: logical specs are resolved
        by ``runtime.sharding.named_shardings`` (pageable groups land in
        the remote tier when paging is enabled), and the ledger records
        the per-shard residency of both tiers."""
        from repro.runtime.sharding import PAGEABLE_GROUPS, named_shardings
        if self.mesh is None:
            raise ValueError("no mesh bound; call bind_mesh first")
        shardings = named_shardings(spec_tree, self.mesh,
                                    pageable_remote=self.config.enabled)
        placed = jax.tree.map(jax.device_put, params, shardings)
        local = remote = 0
        for path, x in jax.tree_util.tree_leaves_with_path(placed):
            nb = self.placed_bytes(x)
            if (self.config.enabled and path
                    and getattr(path[0], "key", None) in PAGEABLE_GROUPS):
                remote += nb
            else:
                local += nb
        if remote:
            self.ledger.record(tiers.REMOTE, "params", remote)
            self.ledger.record_capacity(tiers.REMOTE, "params", remote)
        self.ledger.record(tiers.LOCAL, "params", local)
        # placements provision exactly what they hold: registering the
        # bytes as capacity too keeps the per-tier ``hwm_bytes <=
        # capacity_bytes`` invariant meaningful (a placed class that only
        # recorded residency used to push hwm past the provisioned total)
        self.ledger.record_capacity(tiers.LOCAL, "params", local)
        return placed

    @property
    def expert_policy(self) -> TopKExpertPrefetch | None:
        return self.policies.get("expert_weights")

    def weights_fetch_filter(self) -> Callable[[str], bool] | None:
        """Leaf filter for the layer scans: expert banks stay at rest
        when an expert policy owns them (their rows are gathered on
        demand), everything else rides the prefetch window."""
        ep = self.expert_policy
        if ep is None:
            return None
        return lambda path: not ep.matches(path)

    # ----- placement --------------------------------------------------------
    def place(self, tensor_class: str, tree: Any,
              access_stats: dict | None = None) -> Any:
        """Place a whole tensor class in the tier its policy picks —
        the home tier, or a colder one when ``access_stats`` justify it
        (``pick_tier``) — recording residency, provisioned capacity and
        the placement's tier-edge transfer charge.

        Degradation contract (same as :meth:`place_kv_pool`): an eager
        placement that exhausts its retry budget falls back to LOCAL
        residency and records the reason in ``degraded[tensor_class]``
        — a failed placement is never silent."""
        policy = self.policies.get(tensor_class, PinLocal())
        # pick_tier is optional on ad-hoc policies — home tier then
        tier = (policy.pick_tier(access_stats)
                if hasattr(policy, "pick_tier") else policy.tier)
        nb = tree_bytes(tree)
        try:
            placed = (policy.place(tree) if tier == policy.tier
                      else tiers.eager_to_tier(
                          tree, tier, what=f"place_{tensor_class}"))
        except tiers.TierTransferError as e:
            self.degraded[tensor_class] = (
                f"{tier} placement -> local residency ({e})")
            tier = tiers.LOCAL
            placed = tree
        self.ledger.record(tier, tensor_class, nb)
        self.ledger.record_capacity(tier, tensor_class, nb)
        if tier != tiers.LOCAL:
            self.ledger.charge_transfer(tiers.LOCAL, tier, nb)
        return placed

    def place_layer_weights(self, stacked: Any) -> Any:
        """Place stacked per-layer params: expert-bank leaves go to the
        expert policy's tier, the rest to the layer-weights policy's.
        Records both residencies plus the local prefetch window, and
        charges the placement transfers.  An unrecoverable tier fault
        degrades to local residency (paging disabled, reason recorded
        in ``degraded["layer_weights"]``) — same contract as
        :meth:`place_kv_pool`."""
        wp = self.policies["layer_weights"]
        ep = self.expert_policy

        def put(path, x):
            p = jax.tree_util.keystr(path)
            if ep.matches(p):
                return tiers.host_put(x)
            return x if isinstance(wp, PinLocal) else tiers.host_put(x)

        try:
            placed = (wp.place(stacked) if ep is None
                      else jax.tree_util.tree_map_with_path(put, stacked))
        except tiers.TierTransferError as e:
            self.degraded["layer_weights"] = (
                f"remote paging -> local residency ({e})")
            wp = PinLocal()
            self.policies["layer_weights"] = wp
            self.config = dataclasses.replace(self.config, enabled=False)
            placed = stacked
            ep = None
        if ep is None:
            expert_bytes = 0
        else:
            expert_bytes = sum(
                x.size * x.dtype.itemsize
                for p, x in jax.tree_util.tree_leaves_with_path(stacked)
                if ep.matches(jax.tree_util.keystr(p)))
            self.ledger.record(ep.tier, ep.tensor_class, expert_bytes)
            self.ledger.record_capacity(ep.tier, ep.tensor_class,
                                        expert_bytes)
            if ep.tier != tiers.LOCAL:
                self.ledger.charge_transfer(tiers.LOCAL, ep.tier,
                                            expert_bytes)
        total = tree_bytes(stacked)
        if wp.tier == tiers.REMOTE:
            self.ledger.charge_transfer(tiers.LOCAL, tiers.REMOTE,
                                        total - expert_bytes)
            self.ledger.record(tiers.REMOTE, "layer_weights",
                               total - expert_bytes)
            self.ledger.record_capacity(tiers.REMOTE, "layer_weights",
                                        total - expert_bytes)
            # the prefetch window covers only leaves the scan fetches —
            # expert banks stay at rest (rows gather on demand instead)
            num_layers = jax.tree.leaves(stacked)[0].shape[0]
            per_layer = (total - expert_bytes) // max(num_layers, 1)
            window = int(paged_window_bytes(per_layer, self.config.lookahead))
            self.ledger.record(tiers.LOCAL, "layer_weights_window", window)
            self.ledger.record_capacity(tiers.LOCAL, "layer_weights_window",
                                        window)
        else:
            self.ledger.record(tiers.LOCAL, "layer_weights",
                               total - expert_bytes)
            self.ledger.record_capacity(tiers.LOCAL, "layer_weights",
                                        total - expert_bytes)
        return placed

    def place_kv_pool(self, cache: Any, specs: Any = None) -> Any:
        """Residency for the serving KV cache (dense slab or block
        pool): parked in the remote tier under ``offload_kv`` (only one
        layer's slice local at a time), device-resident otherwise.

        With a bound mesh and a spec tree (``model.cache_specs()`` /
        ``model.paged_cache_specs()``) the cache is sharded — KV heads
        over the ``"model"`` axis — and capacity is recorded per shard.
        """
        policy = self.policies["kv_pool"]
        if self.mesh is not None and specs is not None:
            placed = {k: jax.device_put(
                          v, self.sharding_for("kv_pool", specs[k], key=k))
                      for k, v in cache.items()}
            self.ledger.record_capacity(policy.tier, "kv_pool",
                                        self.placed_bytes(placed))
            return placed
        try:
            placed = policy.place(cache)
        except tiers.TierTransferError as e:
            # documented degradation: when the remote tier cannot take
            # the KV pool (unrecoverable transfer fault), fall back to
            # local residency instead of failing the server — capacity
            # reduction is lost, correctness is not.  The offload
            # transform is disabled too so decode stops round-tripping
            # slices through the faulty tier.
            self.degraded["kv_pool"] = (
                f"remote offload -> local residency ({e})")
            policy = PinLocal()
            self.policies["kv_pool"] = policy
            self.config = dataclasses.replace(self.config, offload_kv=False)
            placed = policy.place(cache)
        # capacity, not residency: a pool slab is provisioned at full
        # size while only live pages count as in-use (no double count)
        self.ledger.record_capacity(policy.tier, "kv_pool",
                                    tree_bytes(cache))
        if policy.tier != tiers.LOCAL:
            self.ledger.charge_transfer(tiers.LOCAL, policy.tier,
                                        tree_bytes(cache))
        return placed

    # ----- block pool -------------------------------------------------------
    def block_pool(self, num_pages: int, page_size: int,
                   **kwargs) -> BlockPoolResidency:
        """A ledger-connected block-pool residency (see
        :class:`BlockPoolResidency`); home tier follows the kv_pool
        policy."""
        kwargs.setdefault("tier", self.policies["kv_pool"].tier)
        kwargs.setdefault("shard_factor", self.model_shards)
        return BlockPoolResidency(num_pages, page_size,
                                  ledger=self.ledger, **kwargs)

    def staging_swapper(self, *, tensor_class: str = "kv_handoff",
                        **kwargs):
        """A ledger-connected :class:`repro.memory.swap.PageSwapper`
        whose stash lines post under ``tensor_class`` (default
        ``"kv_handoff"`` — the prefill->decode staging buffer in the
        remote tier), keeping engine-handoff bytes separate from the
        preemption swapper's ``"kv_swap"`` lines.  The engine boundary
        runs entirely through this staging contract, so a later
        multi-host deployment only has to re-point the transfer
        functions at a real remote peer."""
        from repro.memory.swap import PageSwapper
        return PageSwapper(ledger=self.ledger, tensor_class=tensor_class,
                           **kwargs)

    # ----- execution --------------------------------------------------------
    def layer_scan(self, body, carry, stacked_weights, xs=None, **kw):
        kw.setdefault("fetch_filter", self.weights_fetch_filter())
        return paged_scan(body, carry, stacked_weights, xs,
                          config=self.config, **kw)

    def layer_scan_cache(self, body, carry, stacked_weights, cache, **kw):
        kw.setdefault("fetch_filter", self.weights_fetch_filter())
        return paged_scan_cache(body, carry, stacked_weights, cache,
                                config=self.config, **kw)

    def layer_map(self, fn, stacked):
        return paged_map(fn, stacked, config=self.config)

    def donating_jit(self, fn: Callable, *,
                     donate_argnums: tuple[int, ...] = (),
                     **jit_kwargs) -> Callable:
        return donating_jit(fn, donate_argnums=donate_argnums,
                            config=self.config, **jit_kwargs)

    def gather_experts(self, banks: dict, ids: jax.Array) -> dict:
        """Routed-expert row gather: through the expert policy when one
        is planned (remote banks, residency recorded), a plain local
        take otherwise."""
        ep = self.expert_policy
        if ep is not None:
            return ep.gather(banks, ids)
        keys = ("wi", "wg", "wo")
        return {k: jnp.take(banks[k], ids, axis=0) for k in keys}

    # ----- introspection ----------------------------------------------------
    def describe(self) -> dict:
        """Policy matrix (+ any fault-forced degradations), for logs."""
        out = {cls: type(p).__name__ for cls, p in self.policies.items()}
        if self.degraded:
            out["degraded"] = dict(self.degraded)
        return out

    def with_config(self, **overrides) -> "MemoryOrchestrator":
        return MemoryOrchestrator(
            dataclasses.replace(self.config, **overrides),
            self.policies, self.ledger)
