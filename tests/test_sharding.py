"""Sharding resolution layer: registry-routed memory kinds (the CPU
backend regression), logical-spec resolution, and the ambient-mesh
constraint path on a real multi-device host mesh (subprocess, since the
main test process must stay single-device)."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_smoke_mesh, serving_model_shards
from repro.memory import tiers
from repro.runtime.sharding import (SEQ_SHARDED_ACTS, ambient_mesh,
                                    maybe_constraint, named_shardings,
                                    resolve_spec)


# ---------------------------------------------------------------------------
# named_shardings: memory kinds come from the TierRegistry, never hardcoded
# ---------------------------------------------------------------------------

def test_named_shardings_resolves_tiers_on_cpu_backend():
    """Regression: the remote tier used to be hardcoded ``pinned_host``
    (and local ``device``) — on the CPU backend neither kind exists
    (only ``unpinned_host``) so every construction raised.  Routed
    through the registry, both tiers resolve to backend-real kinds and
    the shardings actually place arrays."""
    mesh = make_smoke_mesh()
    spec_tree = {
        "embed": {"tok": P("model", None)},
        "layers": {"attn": {"wq": P(None, None, "model")}},
    }
    sh = named_shardings(spec_tree, mesh, pageable_remote=True)
    assert sh["layers"]["attn"]["wq"].memory_kind == \
        tiers.resolved_remote_kind()
    assert sh["embed"]["tok"].memory_kind == tiers.resolved_local_kind()
    if jax.default_backend() == "cpu":
        # the exact CPU shape of the old bug: no pinned_host, no device
        assert sh["layers"]["attn"]["wq"].memory_kind == "unpinned_host"
        assert sh["embed"]["tok"].memory_kind == "unpinned_host"
    # placement must work, not just construct
    placed = jax.device_put(jnp.zeros((4, 4)), sh["embed"]["tok"])
    assert placed.sharding.memory_kind == tiers.resolved_local_kind()
    placed_r = jax.device_put(jnp.zeros((2, 4, 4)),
                              sh["layers"]["attn"]["wq"])
    assert placed_r.sharding.memory_kind == tiers.resolved_remote_kind()


def test_named_shardings_pageable_only_under_pageable_groups():
    mesh = make_smoke_mesh()
    tree = {"layers": {"w": P(None)}, "ln_f": P(None)}
    sh = named_shardings(tree, mesh, pageable_remote=True)
    assert sh["layers"]["w"].memory_kind == tiers.resolved_remote_kind()
    assert sh["ln_f"].memory_kind == tiers.resolved_local_kind()
    sh_off = named_shardings(tree, mesh, pageable_remote=False)
    assert sh_off["layers"]["w"].memory_kind == tiers.resolved_local_kind()


def test_resolve_spec_drops_missing_axes():
    mesh = make_smoke_mesh()                      # ("data", "model")
    assert resolve_spec(P(("pod", "data"), "model"), mesh) == \
        P(("data",), "model")
    assert resolve_spec(P("pod", None), mesh) == P(None, None)


def test_serving_model_shards_divisibility():
    # expectation derived from the live device count so the test holds
    # on multi-device machines too
    limit = min(8, jax.device_count())
    want = max(m for m in range(1, limit + 1) if 4 % m == 0 and 2 % m == 0)
    assert serving_model_shards(8, 4, 2) == want
    # an explicit cap of 1 wins regardless of devices
    assert serving_model_shards(1, 48, 16) == 1


def test_mesh_compatibility_checks():
    from repro.configs import get_config
    dense = get_config("qwen2.5-14b").reduced()
    dense.assert_mesh_compatible({"model": 1})
    dense.assert_mesh_compatible({"model": 2})
    with pytest.raises(ValueError, match="cannot shard"):
        dense.assert_mesh_compatible({"model": 16})   # 4 heads % 16
    # MoE banks are not covered by the all-gather-TP determinism
    # contract: reject up front instead of serving diverging tokens
    moe = get_config("grok-1").reduced()
    with pytest.raises(ValueError, match="expert-parallel"):
        moe.assert_mesh_compatible({"model": 2})
    moe.assert_mesh_compatible({"model": 1})          # degenerate ok


# ---------------------------------------------------------------------------
# maybe_constraint: strict no-op outside a mesh, real constraint inside
# ---------------------------------------------------------------------------

def test_maybe_constraint_is_identity_without_mesh():
    assert ambient_mesh() is None
    x = jnp.ones((4, 8, 16))
    assert maybe_constraint(x, SEQ_SHARDED_ACTS) is x


MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_host_mesh
from repro.runtime.sharding import (SEQ_SHARDED_ACTS, ambient_mesh,
                                    collective_bytes_by_axis,
                                    maybe_constraint, mesh_axis_sizes)

mesh = make_host_mesh(data=2, model=4)
assert mesh_axis_sizes(mesh) == {"data": 2, "model": 4}, mesh_axis_sizes(mesh)

# outside any mesh: strict no-op (identity)
x = jnp.ones((4, 8, 6))
assert ambient_mesh() is None
assert maybe_constraint(x, SEQ_SHARDED_ACTS) is x

with mesh:
    am = ambient_mesh()
    assert am is not None, "ambient mesh not detected inside the context"
    assert mesh_axis_sizes(am) == {"data": 2, "model": 4}
    # divisible dims: the constraint APPLIES (returns a new value) ...
    y = maybe_constraint(x, SEQ_SHARDED_ACTS)
    assert y is not x, "constraint silently no-op'd on a live mesh"
    # ... and survives into the lowered module as a sharding annotation
    txt = jax.jit(lambda a: maybe_constraint(a, SEQ_SHARDED_ACTS) * 2) \
        .lower(x).as_text()
    assert "sharding" in txt, "no sharding annotation in lowered HLO"
    # non-divisible dims: no-op, not an error and not a bogus constraint
    z = jnp.ones((3, 5, 6))
    assert maybe_constraint(z, SEQ_SHARDED_ACTS) is z

# the all-gather-TP replication constraint is armed ONLY inside
# gather_tp_mode (the serving dispatch context) — a bare mesh context
# (e.g. the dry-run cost model) must leave it a strict no-op
from repro.runtime.sharding import gather_tp_mode, replicate_constraint
with mesh:
    assert replicate_constraint(x) is x, "fired outside gather_tp_mode"
    with gather_tp_mode():
        assert replicate_constraint(x) is not x, "did not fire when armed"
    assert replicate_constraint(x) is x, "mode leaked past its context"
with gather_tp_mode():
    assert replicate_constraint(x) is x, "fired without an ambient mesh"

# per-axis collective accounting: a contraction over a model-sharded dim
# must show model-axis traffic and no data-axis traffic
a = jax.device_put(jnp.ones((8, 64), jnp.float32), NamedSharding(mesh, P()))
w = jax.device_put(jnp.ones((64, 32), jnp.float32),
                   NamedSharding(mesh, P("model", None)))
hlo = jax.jit(lambda a, w: a @ w).lower(a, w).compile().as_text()
by_axis = collective_bytes_by_axis(hlo, mesh)
assert by_axis.get("model", 0) > 0, by_axis
assert by_axis.get("data", 0) == 0, by_axis

# attribution is by concrete replica_groups device sets, so two axes of
# EQUAL size must still attribute correctly (size-matching would tie)
mesh22 = make_host_mesh(data=2, model=2)
for axis in ("data", "model"):
    wq = jax.device_put(jnp.ones((64, 32), jnp.float32),
                        NamedSharding(mesh22, P(axis, None)))
    aq = jax.device_put(jnp.ones((8, 64), jnp.float32),
                        NamedSharding(mesh22, P()))
    h = jax.jit(lambda a, w: a @ w).lower(aq, wq).compile().as_text()
    ba = collective_bytes_by_axis(h, mesh22)
    other = "model" if axis == "data" else "data"
    assert ba.get(axis, 0) > 0, (axis, ba)
    assert ba.get(other, 0) == 0, (axis, ba)

# a family without serving_param_specs is rejected up front, and the
# failed construction leaves no sharded state behind
import types
from repro.configs import build_model, get_config
from repro.runtime.serve import BatchedServer
cfg = get_config("qwen2.5-14b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
fake = types.SimpleNamespace(cfg=cfg, mem=model.mem)
try:
    BatchedServer(fake, params, batch_size=1, max_seq=32,
                  mesh=make_host_mesh(model=2))
except ValueError as e:
    assert "serving_param_specs" in str(e), e
else:
    raise AssertionError("family without serving_param_specs not rejected")
assert model.mem.mesh is None and model.mem.model_shards == 1, \
    "rejected mesh leaked into the shared orchestrator"
assert model.mem.ledger.shards == 1
print("MESH_OK")
"""


@pytest.mark.slow
def test_maybe_constraint_on_host_mesh():
    """satellite: a real mesh bug can no longer silently no-op the
    constraint — inside a host mesh the constraint must apply (and
    lower), outside it must be an identity."""
    src = str(Path(__file__).resolve().parent.parent / "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", MESH_SCRIPT, src],
                         capture_output=True, text=True, timeout=600,
                         env=env)
    assert "MESH_OK" in out.stdout, out.stderr[-3000:]
