"""Reproduce the paper's quantitative artifacts from the simulator:
§3.3.3 speed-ups, Figure 4.1 (TTFT/TPOT/E2E vs remote bandwidth) and
Table 4.3 (local memory capacity), printed as aligned tables.

    PYTHONPATH=src python examples/paper_figures.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import analysis, graphs as G, hw, simulator as S


def main():
    print("== §3.3.3 speed-up analysis ==")
    h = analysis.paper_headline_numbers(8)
    for k, v in h.items():
        print(f"  {k:32s} {v:8.2f}x")

    print("\n== Figure 4.1: FH4-1.5xM vs Baseline8 (QA 4096->1024, b8) ==")
    base = S.baseline8()
    hdr = f"  {'model':12s} {'metric':6s} base     " + "  ".join(
        f"{bw:>7.1f}T" for bw in hw.PAPER_REMOTE_BW_SWEEP_TBPS)
    print(hdr)
    for name, cfg in G.PAPER_WORKLOADS.items():
        rb = S.run_workload(cfg, S.QA_TASK, base)
        ttfts, tpots = [], []
        for bw in hw.PAPER_REMOTE_BW_SWEEP_TBPS:
            rf = S.run_workload(cfg, S.QA_TASK, S.fh4(1.5, bw))
            ttfts.append(rf["ttft_s"] * 1e3)
            tpots.append(rf["tpot_s"] * 1e3)
        print(f"  {name:12s} TTFT   {rb['ttft_s']*1e3:7.1f}  " +
              "  ".join(f"{t:7.1f}" for t in ttfts))
        print(f"  {'':12s} TPOT   {rb['tpot_s']*1e3:7.2f}  " +
              "  ".join(f"{t:7.2f}" for t in tpots))

    print("\n== Table 4.3: FengHuang local memory capacity (GB) ==")
    cases = [(n, c, S.QA_TASK) for n, c in G.PAPER_WORKLOADS.items()]
    cases.append(("qwen3-235b-R", G.QWEN3_235B, S.REASONING_TASK))
    paper = {"gpt3-175b": 10, "grok-1": 18, "qwen3-235b": 20,
             "qwen3-235b-R": 20}
    for name, cfg, task in cases:
        r = S.run_workload(cfg, task, S.fh4(1.5, 4.0))
        print(f"  {name:14s} ours {r['peak_local_gb']:5.1f} GB   "
              f"paper {paper[name]:3d} GB   baseline-resident 144 GB")


if __name__ == "__main__":
    main()
