"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens):
    """Decode attention over a paged KV cache.

    q:          (B, Hkv, G, d)       one query token, grouped heads
    k_pages:    (P, page, Hkv, d)    global page pool
    v_pages:    (P, page, Hkv, d)
    page_table: (B, pages_per_seq)   int32 page ids
    seq_lens:   (B,)                 valid tokens per sequence
    returns     (B, Hkv, G, d)
    """
    b, hkv, g, d = q.shape
    pages_per_seq = page_table.shape[1]
    page = k_pages.shape[1]

    k = k_pages[page_table]          # (B, pages, page, Hkv, d)
    v = v_pages[page_table]
    k = k.reshape(b, pages_per_seq * page, hkv, d)
    v = v.reshape(b, pages_per_seq * page, hkv, d)

    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    pos = jnp.arange(pages_per_seq * page)[None, :]
    valid = pos < seq_lens[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
