"""Request-lifecycle chaos on the REAL server: SLA deadlines cancel a
request at whatever stage it is in (backlog, live decode, swapped-out
victim, async prefill / staged handoff), engine crashes recover through
handoff leases to BIT-IDENTICAL tokens at any temperature, poisoned
(non-finite) logits shed only the poisoned sequence, overload rejects
fast with a structured error, and a kill-and-restore carries remaining
TTLs across the restart.  Every server here runs with ``audit=True``
and every scenario ends fully reclaimed: clean ``audit()``, zero pages
in use, zero handoff pages, zero stash bytes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.memory.tiers import FaultPlan, fault_plan
from repro.runtime import ft
from repro.runtime.serve import BatchedServer

PAGE = 4
MAX_SEQ = 64
CHUNK = 8
# see test_chaos_serve: two 7-page worst cases fit, the third preempts
SMALL_POOL = 18


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("qwen2.5-14b").reduced()
    cfg = dataclasses.replace(cfg, remat=False, page_size=PAGE)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _server(tiny_model, *, disagg=False, **kw):
    model, params = tiny_model
    kw.setdefault("batch_size", 3)
    kw.setdefault("max_seq", MAX_SEQ)
    kw.setdefault("page_size", PAGE)
    kw.setdefault("block_size", 4)
    kw.setdefault("audit", True)
    if disagg:
        kw.setdefault("prefill_async", True)
        kw.setdefault("prefill_chunk_tokens", CHUNK)
    return BatchedServer(model, params, **kw)


def _drive(server, reqs, max_rounds=80):
    finished = []
    for _ in range(max_rounds):
        finished += server.run_once()
        if all(r.done.is_set() for r in reqs):
            return finished
    raise AssertionError(
        f"requests stuck: {[(r.uid, r.done.is_set()) for r in reqs]}")


def _assert_reclaimed(srv):
    """The zero-leak contract after a full drain, whatever mix of
    completions / expiries / sheds / crash recoveries got us here."""
    srv.manager.audit()
    assert srv.manager.pages_in_use == 0
    assert srv.manager.handoff_pages == 0
    assert not srv._preempted
    assert not srv._orphan_prefills and not srv._orphan_handoffs
    if srv.swapper is not None:
        assert srv.swapper.outstanding_bytes == 0


def _alive(srv):
    """The server serves fresh work after whatever just happened."""
    extra = srv.submit(np.asarray([7, 8], np.int32), max_new_tokens=4)
    _drive(srv, [extra])
    assert extra.error is None and len(extra.output) == 4
    assert extra.outcome == "completed"


# ---------------------------------------------------------------------------
# deadlines: cancellation at every lifecycle stage
# ---------------------------------------------------------------------------

def test_deadline_expires_in_backlog(tiny_model):
    """No free slot, no preemption: the queued request's TTL lapses
    while it waits and it is cancelled without ever touching a page."""
    srv = _server(tiny_model, batch_size=1, preempt=False,
                  num_pages=SMALL_POOL)
    a = srv.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=24)
    b = srv.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=24,
                   deadline_blocks=2)
    _drive(srv, [a, b])
    assert a.outcome == "completed" and len(a.output) == 24
    assert b.outcome == "expired" and b.done.is_set()
    assert b.error["reason"] == "deadline_expired"
    assert "backlog" in b.error["detail"]
    assert b.error["tokens_emitted"] == 0
    assert srv.stats["expired"] == 1
    _assert_reclaimed(srv)
    _alive(srv)


def test_deadline_expires_mid_decode_reclaims_slot(tiny_model):
    """A live slot past its deadline is evicted only after the pipeline
    drains; its partial output survives on the Request."""
    srv = _server(tiny_model, batch_size=1)
    req = srv.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=24,
                     deadline_blocks=2)
    _drive(srv, [req])
    assert req.outcome == "expired"
    assert req.error["reason"] == "deadline_expired"
    assert 0 < len(req.output) < 24
    assert req.error["tokens_emitted"] == len(req.output)
    _assert_reclaimed(srv)
    _alive(srv)


def test_deadline_expires_while_preempted_drops_stash(tiny_model):
    """A swapped-out victim whose TTL lapses never resumes: its remote
    stash is released, not leaked."""
    srv = _server(tiny_model, num_pages=SMALL_POOL, temperature=0.7)
    reqs = [srv.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=24)
            for _ in range(3)]
    victim = None
    for _ in range(60):
        srv.run_once(max_blocks=1)
        if srv._preempted:
            victim = srv._preempted[0].req
            # TTL already lapsed: submitted at block 0, clock past 1
            victim.deadline_blocks = 1
            break
    assert victim is not None, "preemption never happened"
    _drive(srv, reqs)
    assert victim.outcome == "expired"
    assert "preempted" in victim.error["detail"]
    assert srv.stats["expired"] == 1
    for r in reqs:
        if r is not victim:
            assert r.outcome == "completed" and len(r.output) == 24
    _assert_reclaimed(srv)


def test_deadline_expires_during_async_prefill(tiny_model):
    """Disaggregated admission: prompts whose TTL lapses before their
    prefill/handoff can reach a decode slot are cancelled mid-engine
    and every staged page comes back."""
    srv = _server(tiny_model, disagg=True)
    b = srv.submit(np.arange(1, 7, dtype=np.int32), max_new_tokens=24)
    a = srv.submit(np.arange(1, 25, dtype=np.int32), max_new_tokens=16,
                   deadline_blocks=2)
    c = srv.submit(np.arange(1, 14, dtype=np.int32), max_new_tokens=16,
                   deadline_blocks=2)
    _drive(srv, [a, b, c])
    assert b.outcome == "completed" and len(b.output) == 24
    for r in (a, c):
        assert r.outcome == "expired", (r.uid, r.outcome)
        assert r.error["reason"] == "deadline_expired"
    assert srv.stats["expired"] == 2
    assert srv.prefill.idle
    _assert_reclaimed(srv)
    _alive(srv)


# ---------------------------------------------------------------------------
# engine crashes: recovery must be bit-identical
# ---------------------------------------------------------------------------

def _submit_crash_mix(server):
    return [server.submit(np.arange(1, 7, dtype=np.int32),
                          max_new_tokens=24),
            server.submit(np.arange(1, 25, dtype=np.int32),
                          max_new_tokens=8),
            server.submit(np.arange(1, 14, dtype=np.int32),
                          max_new_tokens=12)]


@pytest.mark.parametrize("temp", [0.0, 0.7])
def test_prefill_crash_mid_chunk_recovers_bit_identical(tiny_model, temp):
    """The prefill engine dies between chunks: in-flight prefills lose
    their partial pages and requeue; staged handoffs are reclaimed on
    lease expiry.  Retried requests emit the exact tokens of the
    crash-free run."""
    ref_srv = _server(tiny_model, disagg=True, temperature=temp)
    ref = _submit_crash_mix(ref_srv)
    _drive(ref_srv, ref)

    srv = _server(tiny_model, disagg=True, temperature=temp,
                  handoff_lease_blocks=3)
    got = _submit_crash_mix(srv)
    with fault_plan(FaultPlan(crash_prefill_at_chunk=2)):
        _drive(srv, got)
    assert srv.stats["engine_crashes"] >= 1
    assert srv.stats["crash_requeues"] >= 1
    for a, b in zip(ref, got):
        assert a.output == b.output, (temp, a.uid, a.output, b.output)
        assert b.error is None and b.outcome == "completed"
    _assert_reclaimed(srv)


@pytest.mark.parametrize("temp", [0.0, 0.7])
def test_adopt_crash_lease_reclaim_bit_identical(tiny_model, temp):
    """The decode side dies mid-adoption: the popped handoff is
    orphaned, the watchdog reclaims it when its lease lapses, the
    victim re-prefills from scratch — and still emits the exact tokens
    of the crash-free run."""
    def run(server, plan):
        a = server.submit(np.arange(1, 7, dtype=np.int32),
                          max_new_tokens=24)
        server.run_once(max_blocks=2)        # a adopted, clock ticking
        b = server.submit(np.arange(1, 14, dtype=np.int32),
                          max_new_tokens=8)
        if plan is not None:
            with fault_plan(plan):
                _drive(server, [a, b])
        else:
            _drive(server, [a, b])
        return a, b

    ref_srv = _server(tiny_model, disagg=True, temperature=temp)
    ref = run(ref_srv, None)

    srv = _server(tiny_model, disagg=True, temperature=temp,
                  handoff_lease_blocks=2)
    got = run(srv, FaultPlan(crash_adopt_at_block=1))
    assert srv.stats["engine_crashes"] >= 1
    assert srv.stats["lease_reclaims"] >= 1
    assert srv.stats["crash_requeues"] >= 1
    for a, b in zip(ref, got):
        assert a.output == b.output, (temp, a.uid, a.output, b.output)
        assert b.error is None and b.outcome == "completed"
    _assert_reclaimed(srv)


# ---------------------------------------------------------------------------
# poison shedding: one bad sequence must not take the batch down
# ---------------------------------------------------------------------------

def test_poisoned_logits_shed_only_the_victim(tiny_model):
    """NaN scribbled into ONE sequence's KV pages mid-decode: its next
    harvest hits non-finite logits and ONLY that sequence is shed with
    a structured error; batchmates decode every token they would have
    anyway."""
    srv = _server(tiny_model)
    reqs = [srv.submit(np.arange(1, 5, dtype=np.int32) + 10 * i,
                       max_new_tokens=24) for i in range(3)]
    srv.run_once(max_blocks=1)
    slot = 1
    victim = srv.slots[slot]
    assert victim is not None
    kept = len(victim.output)
    # poison a page OWNED by the victim alone — the bucketed prompt's
    # leading padding page is legitimately shared by the whole batch,
    # and NaN there would (correctly!) poison all three
    pid = next(p for p in srv.manager.pages[slot]
               if srv.manager.refcount[p] == 1)
    srv.cache["k_pages"] = srv.cache["k_pages"].at[:, pid].set(jnp.nan)
    for _ in range(60):
        srv.run_once(max_blocks=1)
        if victim.done.is_set():
            break
    # scrub non-finites out of the (now freed) pages before the pool
    # hands them to anyone else — the fault model is a one-shot
    # corruption, not a permanently broken device buffer; the victim's
    # last block also WROTE NaN activations into its own k/v pages
    for pool in ("k_pages", "v_pages"):
        srv.cache[pool] = jnp.nan_to_num(srv.cache[pool])
    _drive(srv, reqs)
    assert victim.outcome == "shed"
    assert victim.error["reason"] == "poisoned_logits"
    assert victim.error["tokens_emitted"] == len(victim.output) >= kept
    assert srv.stats["poison_sheds"] == 1
    assert srv.stats["sheds"] == 1
    for r in reqs:
        if r is not victim:
            assert r.outcome == "completed" and r.error is None
            assert len(r.output) == 24
    _assert_reclaimed(srv)
    _alive(srv)


# ---------------------------------------------------------------------------
# overload admission control on the live server
# ---------------------------------------------------------------------------

def test_overload_rejects_fast_with_structured_error(tiny_model):
    """Past ``max_pending`` the submitter gets an immediate structured
    rejection — no page touched, no queue joined — and the admitted
    requests all complete.  Once drained, the server accepts again."""
    srv = _server(tiny_model, batch_size=2, num_pages=SMALL_POOL,
                  max_pending=3, overload_factor=1.5)
    reqs = [srv.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=16)
            for _ in range(8)]
    rejected = [r for r in reqs if r.outcome == "rejected"]
    admitted = [r for r in reqs if r.outcome != "rejected"]
    assert len(rejected) == 5 and len(admitted) == 3
    for r in rejected:
        assert r.done.is_set() and len(r.output) == 0
        assert r.error["reason"] == "admission_rejected"
        assert "max_pending" in r.error["detail"]
    _drive(srv, admitted)
    for r in admitted:
        assert r.outcome == "completed" and len(r.output) == 16
    assert srv.stats["rejected"] == 5
    assert srv.stats["completed"] == 3
    assert srv.stats["e2e_p99_blocks"] > 0.0
    _assert_reclaimed(srv)
    _alive(srv)                               # not wedged shut


# ---------------------------------------------------------------------------
# restart: remaining TTLs survive a kill-and-restore
# ---------------------------------------------------------------------------

def test_restart_preserves_remaining_ttl(tiny_model, tmp_path):
    """Kill a server mid-decode and restore from disk: deadline
    metadata rides the snapshot and is REBASED onto the new server's
    clock, so a tight TTL still expires after the restart while
    generous ones complete bit-identically."""
    def submit_all(server):
        return [server.submit(np.arange(1, 5, dtype=np.int32),
                              max_new_tokens=24),
                server.submit(np.arange(1, 5, dtype=np.int32),
                              max_new_tokens=24, deadline_blocks=50),
                server.submit(np.arange(1, 5, dtype=np.int32),
                              max_new_tokens=24, deadline_blocks=3)]

    ref_srv = _server(tiny_model, temperature=0.7)
    ref = submit_all(ref_srv)
    _drive(ref_srv, ref)

    srv = _server(tiny_model, temperature=0.7)
    reqs = submit_all(srv)
    srv.run_once(max_blocks=1)
    snap = ft.snapshot_server(srv)
    assert snap["blocks"] == 1
    assert any(s.get("deadline_blocks") == 3 for s in snap["sequences"])
    path = ft.save_server_snapshot(tmp_path / "lifecycle_ckpt", snap)
    del srv

    srv2 = _server(tiny_model, temperature=0.7)
    ft.restore_server(srv2, ft.load_server_snapshot(path))
    by_uid = {r.uid: r for r in srv2._backlog}
    by_uid.update({ps.req.uid: ps.req for ps in srv2._preempted})
    got = [by_uid[r.uid] for r in reqs]
    _drive(srv2, got)
    assert got[0].outcome == "completed"
    assert got[1].outcome == "completed"
    assert got[2].outcome == "expired"        # 1 pre-crash + post-restart
    assert got[2].error["reason"] == "deadline_expired"
    for a, b in zip(ref[:2], got[:2]):
        assert a.output == b.output, (a.uid, a.output, b.output)
    assert srv2.stats["expired"] == 1
    _assert_reclaimed(srv2)
