"""Shared neural layers: RMSNorm, RoPE, GQA attention (blocked/flash and
decode paths), SwiGLU MLP, embeddings.

All attention entry points take and return ``(batch, seq, heads, head_dim)``
tensors.  The prefill/train path is a *blocked online-softmax* (flash-style)
implementation in pure jnp — differentiable, O(S·block) memory — which also
serves as the oracle for the Pallas kernels in ``repro.kernels``.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.base import ModelConfig, dense_init, ones_init, zeros_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms & RoPE
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                  # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked flash attention (static shapes, differentiable)
# ---------------------------------------------------------------------------

def _block_mask(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
                window: int, kv_len: jax.Array | None) -> jax.Array:
    """(qb, kb) boolean mask of VALID positions."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    if kv_len is not None:
        m &= k_pos[None, :] < kv_len
    return m


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    q_block: int = 512, kv_block: int = 512,
                    q_offset: int = 0) -> jax.Array:
    """Blocked online-softmax attention.

    q: (B, Sq, Hq, hd); k, v: (B, Sk, Hkv, hd) with Hq % Hkv == 0.
    Memory is O(Sq·kv_block) per step instead of O(Sq·Sk).
    ``q_offset`` shifts query positions (for chunked prefill).
    """
    b, sq, hq, hd = q.shape
    _, sk, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    groups = hq // hkv
    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    # pad seqs to block multiples
    pq = (-sq) % q_block
    pk = (-sk) % kv_block
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    nq, nk = qp.shape[1] // q_block, kp.shape[1] // kv_block

    # (nq, B, qb, Hkv, G, hd)
    qs = qp.reshape(b, nq, q_block, hkv, groups, hd).transpose(1, 0, 2, 3, 4, 5)
    ks = kp.reshape(b, nk, kv_block, hkv, hd)
    vs = vp.reshape(b, nk, kv_block, hkv, hd)
    scale = 1.0 / math.sqrt(hd)
    kv_valid = jnp.asarray(sk)

    def q_step(args):
        qi, q_blk = args
        q_pos = q_offset + qi * q_block + jnp.arange(q_block)

        def kv_step(carry, kj):
            o, m, l = carry
            k_blk = jax.lax.dynamic_index_in_dim(ks, kj, 1, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vs, kj, 1, keepdims=False)
            k_pos = kj * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bqkgd,bnkd->bqkgn", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            mask = _block_mask(q_pos, k_pos, causal=causal, window=window,
                               kv_len=kv_valid)        # (qb, kb)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            o_new = o * alpha[..., None] + jnp.einsum(
                "bqkgn,bnkd->bqkgd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (o_new, m_new, l_new), None

        o0 = jnp.zeros((b, q_block, hkv, groups, hd), jnp.float32)
        m0 = jnp.full((b, q_block, hkv, groups), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, q_block, hkv, groups), jnp.float32)
        (o, m, l), _ = jax.lax.scan(kv_step, (o0, m0, l0), jnp.arange(nk))
        return o / jnp.maximum(l[..., None], 1e-37)

    # Checkpoint per q-block: flash backward recomputes the kv scan from
    # (q, k, v) instead of storing per-kv-step probability blocks.
    out = jax.lax.map(jax.checkpoint(q_step), (jnp.arange(nq), qs))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * q_block, hq, hd)
    return out[:, :sq].astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cur_pos: jax.Array, *, window: int = 0,
                     extra_kv: tuple[jax.Array, jax.Array] | None = None,
                     ) -> jax.Array:
    """Single-token attention against a (B, Hkv, S, hd) cache.

    The head-major cache layout makes both dots layout-native (batch dims
    (b, h), contraction over the minor axis) — no transposed copies of the
    32k-token cache per layer (§Perf iteration A).

    ``extra_kv``: the CURRENT token's (k, v), each (B, Hkv, hd) — attended
    in addition to the cache, so the cache stays **read-only** inside the
    decode layer scan (its positions are masked strictly below cur_pos;
    the write happens once, batched over layers, after the scan).

    cur_pos: (B,) index of the token being generated (0-based).
    """
    b, hkv, sk, hd = k_cache.shape
    hq = q.shape[2]
    groups = hq // hkv
    qg = q.reshape(b, hkv, groups, hd)  # Sq==1 squeezed
    s = jnp.einsum("bkgd,bknd->bkgn", qg, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    pos = jnp.arange(sk)[None, :]                        # (1, S)
    if extra_kv is not None:
        valid = pos < cur_pos[:, None]     # cache: strictly past tokens
    else:
        valid = pos <= cur_pos[:, None]
    if window > 0:
        valid &= pos > (cur_pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    if extra_kv is not None:
        k0, v0 = extra_kv
        s_self = jnp.einsum("bkgd,bkd->bkg", qg, k0,
                            preferred_element_type=jnp.float32) / math.sqrt(hd)
        s = jnp.concatenate([s, s_self[..., None]], axis=-1)
    p = jax.nn.softmax(s, axis=-1)
    if extra_kv is not None:
        p_cache, p_self = p[..., :-1], p[..., -1]
        o = jnp.einsum("bkgn,bknd->bkgd", p_cache.astype(v_cache.dtype),
                       v_cache, preferred_element_type=jnp.float32)
        o = o + p_self[..., None] * extra_kv[1][:, :, None, :].astype(
            jnp.float32)
    else:
        o = jnp.einsum("bkgn,bknd->bkgd", p.astype(v_cache.dtype), v_cache,
                       preferred_element_type=jnp.float32)
    return o.reshape(b, 1, hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (params + apply)
# ---------------------------------------------------------------------------

def attn_params(key, cfg: ModelConfig, *, cross: bool = False) -> dict:
    ks = jax.random.split(key, 6)
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv_true = cfg.padded_heads, cfg.num_kv_heads
    hkv = cfg.padded_kv_heads
    wq = dense_init(ks[0], (d, hq * hd), cfg.dtype)
    if hq > cfg.num_heads:
        # zero the padded q-head slots so the padded model equals the true
        # architecture at init (wo rows zeroed below keeps them inert).
        mask = (jnp.arange(hq) < cfg.num_heads).repeat(hd)
        wq = wq * mask[None, :].astype(wq.dtype)
    # init true KV heads, tile to the padded/replicated count so the
    # architecture keeps its true number of distinct KV heads.
    wk1 = dense_init(ks[1], (d, hkv_true, hd), cfg.dtype)
    wv1 = dense_init(ks[2], (d, hkv_true, hd), cfg.dtype)
    reps = hkv // hkv_true if hkv % hkv_true == 0 else 0
    if reps:
        wk = jnp.tile(wk1, (1, reps, 1)).reshape(d, hkv * hd)
        wv = jnp.tile(wv1, (1, reps, 1)).reshape(d, hkv * hd)
    else:  # pad with fresh heads (e.g. 36 -> 48)
        extra = hkv - hkv_true
        wk = jnp.concatenate(
            [wk1, dense_init(ks[3], (d, extra, hd), cfg.dtype)],
            axis=1).reshape(d, hkv * hd)
        wv = jnp.concatenate(
            [wv1, dense_init(ks[4], (d, extra, hd), cfg.dtype)],
            axis=1).reshape(d, hkv * hd)
    wo = dense_init(ks[5], (hq * hd, d), cfg.dtype)
    if hq > cfg.num_heads:
        mask = (jnp.arange(hq) < cfg.num_heads).repeat(hd)
        wo = wo * mask[:, None].astype(wo.dtype)
    p = {"wq": wq, "wk": wk, "wv": wv, "wo": wo}
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((hq * hd,), cfg.dtype)
        p["bk"] = jnp.zeros((hkv * hd,), cfg.dtype)
        p["bv"] = jnp.zeros((hkv * hd,), cfg.dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.dtype)
        p["k_norm"] = jnp.ones((hd,), cfg.dtype)
    return p


def attn_specs(cfg: ModelConfig, *, cross: bool = False,
               stacked: bool = True) -> dict:
    L = (None,) if stacked else ()
    mk = lambda *dims: P(*L, *dims)
    p = {
        "wq": mk(None, "model"), "wk": mk(None, "model"),
        "wv": mk(None, "model"), "wo": mk("model", None),
    }
    if cfg.qkv_bias and not cross:
        p.update(bq=mk("model"), bk=mk("model"), bv=mk("model"))
    if cfg.qk_norm:
        p.update(q_norm=mk(None), k_norm=mk(None))
    return p


def _project_qkv(p: dict, x: jax.Array, x_kv: jax.Array, cfg: ModelConfig):
    b, s = x.shape[:2]
    skv = x_kv.shape[1]
    hq, hkv, hd = cfg.padded_heads, cfg.padded_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x_kv @ p["wk"]
    v = x_kv @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, skv, hkv, hd)
    v = v.reshape(b, skv, hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _heads_sharded(t: jax.Array) -> jax.Array:
    """Megatron-SP boundary: inside attention, tensors are
    (batch, FULL seq, sharded heads, hd).  Entering here from seq-sharded
    residuals lowers to one all-to-all per tensor instead of per-block
    resharding churn inside the flash loops."""
    from repro.runtime.sharding import maybe_constraint
    from repro.models.base import BATCH_AXES
    return maybe_constraint(t, P(BATCH_AXES, None, "model", None))


def _tp_gathered(t: jax.Array) -> jax.Array:
    """All-gather TP boundary for the SERVING path: replicate an
    activation (sharded heads or hidden dim) before an output projection
    against a *replicated* weight.

    An all-gather is pure data movement, and the full-width projection
    that follows runs the exact dot the single-device server runs — so
    sharded serving is **bit-identical** by construction.  The
    alternative (Megatron row-parallel: partial dots + all-reduce, kept
    for training where throughput beats determinism) rounds each
    shard's partial sum separately and flips greedy ties mid-stream.
    Outside a mesh this is a no-op."""
    from repro.runtime.sharding import replicate_constraint
    return replicate_constraint(t)


def attn_forward(p: dict, x: jax.Array, positions: jax.Array,
                 cfg: ModelConfig, *, causal: bool = True) -> jax.Array:
    """Full-sequence (train/prefill) self-attention; returns (B, S, d)."""
    q, k, v = _project_qkv(p, x, x, cfg)
    q = _heads_sharded(apply_rope(q, positions, cfg.rope_theta))
    k = _heads_sharded(apply_rope(k, positions, cfg.rope_theta))
    v = _heads_sharded(v)
    o = flash_attention(q, k, v, causal=causal, window=cfg.sliding_window,
                        q_block=cfg.q_block, kv_block=cfg.kv_block)
    b, s = x.shape[:2]
    return o.reshape(b, s, -1) @ p["wo"]


def _kv_roundtripped(k: jax.Array, v: jax.Array, cfg: ModelConfig):
    """The quantize->dequantize fixed point of (k, v) — exactly the
    values every later POOL read (shared-prefix gather, paged decode)
    dequantizes.  Quantized prefill attends these instead of the raw
    projections so a prefix-cached admission is bit-identical to an
    unshared one: both see the same round-tripped KV, whether it comes
    off the pool or is recomputed on the fly."""
    qdt, qmax = cfg.kv_pool_dtype(), cfg.kv_qmax()
    return (kv_dequantize(*kv_pool_quantize(k, qdt, qmax), k.dtype),
            kv_dequantize(*kv_pool_quantize(v, qdt, qmax), v.dtype))


def attn_prefill_kv(p: dict, x: jax.Array, positions: jax.Array,
                    cfg: ModelConfig, *, kv_roundtrip: bool = False):
    """Like attn_forward but also returns (k, v) for cache seeding.
    Serving path: the head axis is gathered before the out projection
    (all-gather TP — see :func:`_tp_gathered`).  ``kv_roundtrip``
    (quantized page pools) attends the quantize->dequantize round trip
    of K/V while still returning the raw projections for the pool
    write — scattering quantizes them to the very bytes the round trip
    came from."""
    q, k, v = _project_qkv(p, x, x, cfg)
    q = _heads_sharded(apply_rope(q, positions, cfg.rope_theta))
    k = _heads_sharded(apply_rope(k, positions, cfg.rope_theta))
    v = _heads_sharded(v)
    ka, va = _kv_roundtripped(k, v, cfg) if kv_roundtrip else (k, v)
    o = _tp_gathered(
        flash_attention(q, ka, va, causal=True, window=cfg.sliding_window,
                        q_block=cfg.q_block, kv_block=cfg.kv_block))
    b, s = x.shape[:2]
    return o.reshape(b, s, -1) @ p["wo"], (k, v)


def attn_prefill_prefix_kv(p: dict, x: jax.Array, positions: jax.Array,
                           k_prefix: jax.Array, v_prefix: jax.Array,
                           cfg: ModelConfig, *,
                           kv_roundtrip: bool = False):
    """Prefill attention for a prompt SUFFIX against a cached prefix.

    x: (B, S_new, d) hidden states of the suffix chunk only; positions:
    (S_new,) absolute positions (prefix_len + arange); k_prefix/v_prefix:
    (B, prefix_len, Hkv, hd) the shared prefix KV in attention layout
    (gathered from the page pool).  Computes exactly the suffix rows of
    the full-prompt flash attention: the concatenated K/V equal what a
    full prefill would have projected (causality makes prefix KV
    independent of the suffix, and the pool stores K/V in the same dtype
    attention consumes), Sk and hence the kv blocking match the full
    prompt, and ``q_offset`` shifts the causal mask — so suffix hidden
    states, and therefore the sampled tokens downstream, are
    bit-identical to an unshared prefill.  Returns
    (out (B, S_new, d), (k_new, v_new) for the pool write).
    """
    q, k, v = _project_qkv(p, x, x, cfg)
    q = _heads_sharded(apply_rope(q, positions, cfg.rope_theta))
    k = _heads_sharded(apply_rope(k, positions, cfg.rope_theta))
    v = _heads_sharded(v)
    # quantized pools: the gathered prefix is already the round-tripped
    # values; round-trip the suffix too so the concatenated KV equals a
    # full quantized prefill's (bit-identity across shared/unshared)
    ka, va = _kv_roundtripped(k, v, cfg) if kv_roundtrip else (k, v)
    prefix_len = k_prefix.shape[1]
    kf = jnp.concatenate([k_prefix.astype(k.dtype), ka], axis=1)
    vf = jnp.concatenate([v_prefix.astype(v.dtype), va], axis=1)
    o = _tp_gathered(
        flash_attention(q, kf, vf, causal=True, window=cfg.sliding_window,
                        q_block=cfg.q_block, kv_block=cfg.kv_block,
                        q_offset=prefix_len))
    b, s = x.shape[:2]
    return o.reshape(b, s, -1) @ p["wo"], (k, v)


def attn_decode(p: dict, x: jax.Array, cache_k: jax.Array,
                cache_v: jax.Array, cur_pos: jax.Array, cfg: ModelConfig):
    """One-token self-attention.  The cache is READ-ONLY here: the current
    token's (k, v) are attended via the extra_kv path and returned for a
    single post-scan batched write (§Perf iteration A').

    x: (B, 1, d); cache_[kv]: (B, Hkv, S, hd); cur_pos: (B,) position.
    Returns (out (B,1,d), k_new (B,Hkv,hd), v_new (B,Hkv,hd)).
    """
    q, k, v = _project_qkv(p, x, x, cfg)
    pos = cur_pos[:, None]                               # (B,1)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    b = x.shape[0]
    k0 = k[:, 0]                                         # (B, Hkv, hd)
    v0 = v[:, 0]
    if cfg.sliding_window > 0 and cache_k.shape[2] <= cfg.sliding_window:
        # rolling window cache (rotated slots): mask strictly-past written
        # slots; current token joins via extra_kv.
        o = _decode_window_rotated(q, cache_k, cache_v, cur_pos,
                                   cfg.sliding_window, extra_kv=(k0, v0))
    else:
        o = decode_attention(q, cache_k, cache_v, cur_pos,
                             window=cfg.sliding_window, extra_kv=(k0, v0))
    out = _tp_gathered(o).reshape(b, 1, -1) @ p["wo"]
    return out, k0, v0


def _decode_window_rotated(q, k_cache, v_cache, cur_pos, window,
                           extra_kv=None):
    """Attention over a rotated rolling-window cache (no RoPE re-rotation
    needed because keys were rotated at write time with absolute phase).
    Cache layout (B, Hkv, W, hd).

    With ``extra_kv`` the cache is READ-ONLY: slot (cur_pos % W) still
    holds the stale position cur_pos - W (outside the window) and is
    masked; the current token's fresh (k, v) join via the extra column.
    """
    b, hkv, w, hd = k_cache.shape
    hq = q.shape[2]
    groups = hq // hkv
    qg = q.reshape(b, hkv, groups, hd)
    s = jnp.einsum("bkgd,bknd->bkgn", qg, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    # slot n holds the largest written position p == n (mod W); with the
    # current token unwritten that p is < cur_pos and within the window
    # except for the own slot (exactly W back).
    slots = jnp.arange(w)[None, :]
    if extra_kv is not None:
        valid = slots < cur_pos[:, None]
        valid &= slots != (cur_pos % w)[:, None]
    else:
        valid = slots <= cur_pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    if extra_kv is not None:
        k0, v0 = extra_kv
        s_self = jnp.einsum("bkgd,bkd->bkg", qg, k0,
                            preferred_element_type=jnp.float32) / math.sqrt(hd)
        s = jnp.concatenate([s, s_self[..., None]], axis=-1)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgn,bknd->bkgd",
                       p[..., :-1].astype(v_cache.dtype), v_cache,
                       preferred_element_type=jnp.float32)
        o = o + p[..., -1][..., None] * v0[:, :, None, :].astype(jnp.float32)
    else:
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgn,bknd->bkgd", p.astype(v_cache.dtype), v_cache,
                       preferred_element_type=jnp.float32)
    return o.reshape(b, 1, hq, hd).astype(q.dtype)


def to_cache_layout(k: jax.Array) -> jax.Array:
    """(B, S, H, hd) attention layout -> (B, H, S, hd) cache layout."""
    return k.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# Block-pool paged decode attention (FengHuang KV paging in the serving hot
# path).  The pool holds strictly-past tokens; the current token's (k, v)
# joins as an extra softmax column so the pool stays read-only inside the
# decode layer scan, exactly like the dense extra_kv path above.
# ---------------------------------------------------------------------------

def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           cur_pos: jax.Array,
                           extra_kv: tuple[jax.Array, jax.Array], *,
                           k_scales: jax.Array | None = None,
                           v_scales: jax.Array | None = None,
                           use_kernel: bool | None = None,
                           interpret: bool = False) -> jax.Array:
    """Single-token attention against a (P, page, Hkv, hd) page pool.

    q: (B, 1, Hq, hd); page_table: (B, n_pages) int32 (null-page padded);
    cur_pos: (B,) — pooled positions < cur_pos are live, the current
    token arrives via ``extra_kv``.  ``k_scales``/``v_scales``
    ((P, page, Hkv), quantized pools only) dequantize inline: the kernel
    rescales each page tile inside its online-softmax loop; the fallback
    rescales the gathered fp32 view — full-precision KV never
    materializes pool-wide either way.  Routed once per backend: the
    Pallas ``paged_attention`` kernel on TPU (scalar-prefetched page
    tables), the gather + :func:`decode_attention` composition elsewhere.
    """
    from repro.kernels.paged_attention import ops as paged_ops

    b, _, hq, hd = q.shape
    if use_kernel is None:
        use_kernel = paged_ops.use_pallas_kernel()
    if use_kernel:
        hkv = k_pages.shape[2]
        qg = q.reshape(b, hkv, hq // hkv, hd)
        from repro.kernels.paged_attention.kernel import paged_attention
        o = paged_attention(qg, k_pages, v_pages, page_table, cur_pos,
                            extra_kv=extra_kv, k_scales=k_scales,
                            v_scales=v_scales, interpret=interpret)
        return o.reshape(b, 1, hq, hd).astype(q.dtype)
    # spec-threaded gather: each device gathers only its "model" head
    # shard of the mapped pages, so tensor-parallel paged decode reads
    # stay collective-free (see ops.GATHERED_KV_SPEC)
    k = paged_ops.gather_pages_sharded(k_pages, page_table)
    v = paged_ops.gather_pages_sharded(v_pages, page_table)
    if k_scales is not None:
        from repro.kernels.paged_attention.ref import to_f32
        ks = paged_ops.gather_scales_sharded(k_scales, page_table)
        vs = paged_ops.gather_scales_sharded(v_scales, page_table)
        # to_f32 dequantizes fp8 through the convert LUT (bit-identical
        # to astype; ~8x faster on CPU — see ref.gatherable_view)
        k = to_f32(k) * ks.astype(jnp.float32)[..., None]
        v = to_f32(v) * vs.astype(jnp.float32)[..., None]
    return decode_attention(q, k, v, cur_pos, extra_kv=extra_kv)


def attn_decode_paged(p: dict, x: jax.Array, k_pages: jax.Array,
                      v_pages: jax.Array, page_table: jax.Array,
                      cur_pos: jax.Array, cfg: ModelConfig,
                      k_scales: jax.Array | None = None,
                      v_scales: jax.Array | None = None):
    """One-token self-attention over this layer's page pool (read-only —
    the (k, v) returned are written post-scan in one batched scatter).

    x: (B, 1, d); [kv]_pages: (P, page, Hkv, hd); page_table: (B, n);
    cur_pos: (B,); [kv]_scales: (P, page, Hkv) dequant scales when the
    pool is quantized.  Returns (out (B,1,d), k0 (B,Hkv,hd), v0
    (B,Hkv,hd)) — k0/v0 full precision; the post-scan scatter quantizes.
    """
    q, k, v = _project_qkv(p, x, x, cfg)
    pos = cur_pos[:, None]                               # (B, 1)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    b = x.shape[0]
    k0 = k[:, 0]                                         # (B, Hkv, hd)
    v0 = v[:, 0]
    o = paged_decode_attention(q, k_pages, v_pages, page_table, cur_pos,
                               (k0, v0), k_scales=k_scales,
                               v_scales=v_scales)
    out = _tp_gathered(o).reshape(b, 1, -1) @ p["wo"]
    return out, k0, v0


# ---------------------------------------------------------------------------
# int8 KV-cache quantization (§Perf iteration A3): per-token-per-head
# absmax scales halve the decode memory term's KV component (the dominant
# term for batch-128 decode).
# ---------------------------------------------------------------------------

def kv_quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (..., hd) -> (int8 values, scale (...,) bf16)."""
    return kv_pool_quantize(x, jnp.int8, 127.0)


def kv_pool_quantize(x: jax.Array, qdtype,
                     qmax: float) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-(..., head)-vector absmax quantization shared by the
    int8 (qmax=127) and fp8_e4m3 (qmax=448) page pools.

    x: (..., hd) -> (``qdtype`` values, scale (...,) bf16).  The scale is
    computed from its own bf16 storage value so a write/read round trip
    reproduces exactly what the attention read path dequantizes — the
    invariant the quantized-vs-quantized bit-identity contract rests on.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / qmax, 1e-8).astype(jnp.bfloat16)
    y = x.astype(jnp.float32) / scale.astype(jnp.float32)[..., None]
    if jnp.issubdtype(jnp.dtype(qdtype), jnp.integer):
        y = jnp.round(y)
    q = jnp.clip(y, -qmax, qmax).astype(qdtype)
    return q, scale


def kv_dequantize(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    from repro.kernels.paged_attention.ref import to_f32
    return (to_f32(q) * scale.astype(jnp.float32)[..., None]).astype(dtype)


def cross_attn_forward(p: dict, x: jax.Array, enc_kv: tuple[jax.Array, jax.Array],
                       cfg: ModelConfig) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V."""
    b, s = x.shape[:2]
    hq, hd = cfg.padded_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k, v = enc_kv
    o = flash_attention(q, k, v, causal=False, q_block=cfg.q_block,
                        kv_block=cfg.kv_block)
    return o.reshape(b, s, -1) @ p["wo"]


def cross_kv(p: dict, enc_out: jax.Array, cfg: ModelConfig):
    b, s = enc_out.shape[:2]
    hkv, hd = cfg.padded_kv_heads, cfg.head_dim
    k = (enc_out @ p["wk"]).reshape(b, s, hkv, hd)
    v = (enc_out @ p["wv"]).reshape(b, s, hkv, hd)
    return k, v


# ---------------------------------------------------------------------------
# MLP / embeddings
# ---------------------------------------------------------------------------

def mlp_params(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, (cfg.d_model, d_ff), cfg.dtype),
        "wg": dense_init(k2, (cfg.d_model, d_ff), cfg.dtype),
        "wo": dense_init(k3, (d_ff, cfg.d_model), cfg.dtype),
    }


def mlp_specs(stacked: bool = True) -> dict:
    L = (None,) if stacked else ()
    return {"wi": P(*L, None, "model"), "wg": P(*L, None, "model"),
            "wo": P(*L, "model", None)}


def mlp_forward(p: dict, x: jax.Array, *, gather_tp: bool = False
                ) -> jax.Array:
    """``gather_tp`` (serving): gather the d_ff-sharded hidden before
    the down projection so the full-width dot is bit-identical to
    single-device (the weight is replicated in the serving placement);
    training keeps the Megatron partial-sum + reduce-scatter path."""
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    if gather_tp:
        h = _tp_gathered(h)
    return h @ p["wo"]


def mlp2_params(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    """Non-gated GELU MLP (whisper-style)."""
    d_ff = d_ff or cfg.d_ff
    k1, k2 = jax.random.split(key)
    return {"wi": dense_init(k1, (cfg.d_model, d_ff), cfg.dtype),
            "wo": dense_init(k2, (d_ff, cfg.d_model), cfg.dtype)}


def mlp2_specs(stacked: bool = True) -> dict:
    L = (None,) if stacked else ()
    return {"wi": P(*L, None, "model"), "wo": P(*L, "model", None)}


def mlp2_forward(p: dict, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]


def embed_params(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"tok": dense_init(k1, (cfg.padded_vocab, cfg.d_model), cfg.dtype,
                           scale=1.0)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(k2, (cfg.d_model, cfg.padded_vocab), cfg.dtype)
    return p


def embed_specs(cfg: ModelConfig) -> dict:
    p = {"tok": P("model", None)}
    if not cfg.tie_embeddings:
        p["head"] = P(None, "model")
    return p


def embed_lookup(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def lm_head(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return x @ p["tok"].T
    return x @ p["head"]
