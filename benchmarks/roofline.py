"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
from the dry-run artifacts in experiments/dryrun/.

    compute term    = HLO_FLOPs_per_dev / peak_FLOP/s          (197 TF bf16)
    memory term     = HLO_bytes_per_dev / HBM_bw               (819 GB/s)
    collective term = wire_bytes_per_dev / ICI_link_bw         (50 GB/s/link)

wire bytes apply the algorithm factor per collective kind (ring allreduce
moves ~2x the payload per device; all-gather/reduce-scatter/all-to-all
~1x; collective-permute 1x).

MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference), N_active for MoE; the
MODEL/HLO ratio exposes remat recompute, padding waste and masked-flash
overhead.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

from repro.core import hw

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"

SHAPE_TOKENS = {
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128,
    "long_500k": 1,
}
TRAIN_SHAPES = {"train_4k"}

ALGO_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def true_param_counts(arch: str) -> tuple[float, float]:
    """(N_total, N_active) for the unpadded architecture (tp=1 clone)."""
    import jax
    from repro.configs import get_config, build_model
    cfg = dataclasses.replace(get_config(arch), tp=1)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = sum(s.size for s in jax.tree.leaves(shapes))
    active = total
    if cfg.num_experts > 1:
        inactive = (cfg.num_experts - cfg.top_k) * 3 * cfg.d_model * cfg.d_ff
        active = total - cfg.num_layers * inactive
    return float(total), float(active)


def cell_roofline(rec: dict, n_params: float, n_active: float) -> dict:
    chip = hw.TPU_V5E
    flops = rec["cost"]["flops"]
    mem_bytes = rec["cost"]["bytes_accessed"]
    coll = rec["collectives"]["bytes"]
    wire = sum(v * ALGO_FACTOR.get(k, 1.0) for k, v in coll.items())

    t_comp = flops / chip.peak_bf16_flops
    t_mem = mem_bytes / chip.hbm_bw
    t_coll = wire / chip.ici_link_bw
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    shape = rec["shape"]
    tokens = SHAPE_TOKENS[shape]
    n_eff = n_active if n_active < n_params else n_params
    mult = 6.0 if shape in TRAIN_SHAPES else 2.0
    model_flops = mult * n_eff * tokens / rec["devices"]
    ratio = model_flops / flops if flops else 0.0
    bound = max(terms.values())
    # roofline fraction: useful work over the time the dominant term costs
    roofline_frac = (model_flops / chip.peak_bf16_flops) / bound if bound else 0.0

    hints = {
        "compute": "cut non-useful FLOPs (masked flash blocks, head/expert "
                   "padding, remat policy) or raise MXU utilization via "
                   "larger per-device tiles",
        "memory": "shrink resident traffic: fuse elementwise chains, quantize "
                  "weights/KV, stream weights via the pager, re-layout to "
                  "avoid transposes",
        "collective": "reshard to cut cross-device traffic: defer/batch "
                      "all-reduces, reduce-scatter instead of all-reduce, "
                      "overlap via scan double-buffering",
    }
    return {
        "cell": rec["cell"], "arch": rec["arch"], "shape": shape,
        "mesh": rec["mesh"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": model_flops,
        "hlo_flops_per_dev": flops,
        "model_over_hlo": ratio,
        "roofline_fraction": roofline_frac,
        "peak_gib": rec["memory"]["peak_device_bytes"] / 2**30,
        "hint": hints[dominant],
    }


def analyze(mesh: str = "pod16x16") -> list[dict]:
    out = []
    param_cache: dict[str, tuple[float, float]] = {}
    for path in sorted(DRYRUN_DIR.glob(f"*__{mesh}.json")):
        rec = json.loads(path.read_text())
        if rec.get("status") != "ok":
            if rec.get("status") == "skipped":
                out.append({"cell": rec["cell"], "skipped": rec["reason"]})
            continue
        arch = rec["arch"]
        if arch not in param_cache:
            param_cache[arch] = true_param_counts(arch)
        out.append(cell_roofline(rec, *param_cache[arch]))
    return out


def run() -> list[str]:
    rows = []
    t0 = time.perf_counter()
    for r in analyze("pod16x16"):
        us = (time.perf_counter() - t0) * 1e6
        if "skipped" in r:
            rows.append(f"roofline_{r['cell']},{us:.0f},SKIP ({r['skipped'][:40]})")
            continue
        rows.append(
            f"roofline_{r['cell']},{us:.0f},"
            f"comp={r['t_compute_s']*1e3:.2f}ms "
            f"mem={r['t_memory_s']*1e3:.2f}ms "
            f"coll={r['t_collective_s']*1e3:.2f}ms "
            f"dom={r['dominant']} "
            f"model/hlo={r['model_over_hlo']:.2f} "
            f"roofline={r['roofline_fraction']*100:.1f}%")
    return rows


def markdown_table(mesh: str = "pod16x16") -> str:
    lines = [
        f"| cell | compute (s) | memory (s) | collective (s) | dominant | "
        f"MODEL/HLO flops | roofline frac | peak GiB/dev | next lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in analyze(mesh):
        if "skipped" in r:
            lines.append(f"| {r['cell']} | — | — | — | skipped | — | — | — | "
                         f"{r['skipped'][:60]} |")
            continue
        lines.append(
            f"| {r['cell']} | {r['t_compute_s']:.4g} | {r['t_memory_s']:.4g} "
            f"| {r['t_collective_s']:.4g} | **{r['dominant']}** "
            f"| {r['model_over_hlo']:.2f} | {r['roofline_fraction']*100:.1f}% "
            f"| {r['peak_gib']:.1f} | {r['hint'][:70]} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "pod16x16"
    print(markdown_table(mesh))
