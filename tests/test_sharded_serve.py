"""Tensor-parallel serving end-to-end: a BatchedServer on a host mesh
with >= 2 "model" shards must emit bit-identical tokens to the
single-device server — dense and paged caches, greedy and sampled —
with per-shard residency in the ledger and real model-axis collectives
in the decode executable.  Runs in a subprocess with forced host
devices (the main test process must stay single-device)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import dataclasses
import jax, numpy as np
from repro.configs import get_config, build_model
from repro.launch.mesh import make_serving_mesh
from repro.runtime.serve import BatchedServer
from repro.runtime.sharding import collective_bytes_by_axis

cfg = get_config("qwen2.5-14b").reduced()
cfg = dataclasses.replace(cfg, remat=False)
params = build_model(cfg).init(jax.random.PRNGKey(0))

def serve(mesh, paged, temperature):
    srv = BatchedServer(build_model(cfg), params, batch_size=2, max_seq=64,
                        block_size=4, temperature=temperature, paged=paged,
                        mesh=mesh)
    r1 = srv.submit(np.asarray([5, 6, 7], np.int32), max_new_tokens=9)
    r2 = srv.submit(np.asarray([9, 10, 11, 12], np.int32), max_new_tokens=7)
    srv.run_once()
    return (tuple(r1.output), tuple(r2.output)), srv

mesh = make_serving_mesh(model=2)
for paged in (False, True):
    for temp in (0.0, 0.7):
        ref, srv_1 = serve(None, paged, temp)
        got, srv_m = serve(mesh, paged, temp)
        assert srv_m.stats["model_shards"] == 2
        assert got == ref, (
            f"sharded serving diverged (paged={paged}, temp={temp}):\n"
            f"  single={ref}\n  sharded={got}")
        if paged:
            # per-shard ledger: each of the 2 shards holds exactly half
            # the pool bytes the single-device server held at peak
            kv_1 = srv_1.tier_stats_peak()["local"]["by_class"]["kv_pool"]
            kv_m = srv_m.tier_stats_peak()["local"]["by_class"]["kv_pool"]
            assert kv_m * 2 == kv_1, (kv_m, kv_1)
            assert srv_m.tier_stats_peak()["local"]["shards"] == 2

# mesh incompatible with the head counts is rejected up front
try:
    BatchedServer(build_model(cfg), params, batch_size=2, max_seq=64,
                  mesh=make_serving_mesh(model=8))
except ValueError as e:
    assert "cannot shard" in str(e), e
else:
    raise AssertionError("8-way mesh should be rejected (2 kv heads)")

# the sharded decode executable really communicates over the model axis
srv = serve(mesh, False, 0.0)[1]
with srv._mesh_ctx():
    hlo = srv._decode_loop.lower(srv.params, srv.cache,
                                 srv.state).compile().as_text()
by_axis = collective_bytes_by_axis(hlo, mesh)
assert by_axis.get("model", 0) > 0, by_axis
print("SHARDED_SERVE_OK", by_axis)
"""


@pytest.mark.slow
def test_sharded_server_bit_identical_tokens():
    src = str(Path(__file__).resolve().parent.parent / "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT, src],
                         capture_output=True, text=True, timeout=600,
                         env=env)
    assert "SHARDED_SERVE_OK" in out.stdout, \
        out.stdout[-1500:] + out.stderr[-3000:]
