"""Runtime: optimizer schedules, grad accumulation, compression,
checkpointing (incl. elastic restore), data pipeline, fault tolerance."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 runs without hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs import get_config, build_model
from repro.data.pipeline import (ByteFileLM, DataConfig, PrefetchingLoader,
                                 SyntheticLM, pack_documents)
from repro.runtime import checkpoint, optim
from repro.runtime.ft import FTConfig, FaultTolerantLoop, StragglerMonitor
from repro.runtime.train import TrainConfig, make_train_step

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_wsd_schedule_shape():
    cfg = optim.AdamWConfig(lr=1.0, schedule="wsd", warmup_steps=10,
                            total_steps=100, decay_fraction=0.2)
    warm = optim.schedule_value(cfg, jnp.asarray(5))
    stable = optim.schedule_value(cfg, jnp.asarray(50))
    decay = optim.schedule_value(cfg, jnp.asarray(99))
    assert float(warm) == pytest.approx(0.5)
    assert float(stable) == pytest.approx(1.0)
    assert float(decay) < 0.15    # ~0.1x at the end (MiniCPM decay)


def test_cosine_schedule_endpoints():
    cfg = optim.AdamWConfig(lr=2.0, schedule="cosine", warmup_steps=10,
                            total_steps=100)
    assert float(optim.schedule_value(cfg, jnp.asarray(10))) == \
        pytest.approx(2.0, rel=0.05)
    assert float(optim.schedule_value(cfg, jnp.asarray(100))) == \
        pytest.approx(0.0, abs=1e-3)


def test_grad_accumulation_equivalence():
    """accum_steps=2 equals accum_steps=1 (same effective batch)."""
    cfg = get_config("whisper-base").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype=jnp.float32, remat=False)
    model = build_model(cfg)
    params = model.init(KEY)
    opt = optim.init_opt_state(params)
    tokens = jax.random.randint(KEY, (4, 16), 0, cfg.vocab)
    frames = jax.random.normal(KEY, (4, cfg.encoder_seq, cfg.d_model))
    batch = {"tokens": tokens, "labels": tokens, "frames": frames}
    acfg = optim.AdamWConfig(lr=1e-2, total_steps=10, warmup_steps=0)
    s1 = jax.jit(make_train_step(model, TrainConfig(adamw=acfg,
                                                    accum_steps=1)))
    s2 = jax.jit(make_train_step(model, TrainConfig(adamw=acfg,
                                                    accum_steps=2)))
    p1, _, m1 = s1(params, opt, batch)
    p2, _, m2 = s2(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-4, rtol=1e-3)


@given(scale=st.floats(min_value=1e-4, max_value=1e3))
@settings(max_examples=20, deadline=None)
def test_int8_compression_error_feedback(scale):
    g = jnp.asarray(np.random.RandomState(0).randn(64) * scale, jnp.float32)
    err = jnp.zeros_like(g)
    deq, err2 = optim.compressed_grad(g, err)
    # dequantized + residual error reconstructs the gradient exactly
    np.testing.assert_allclose(np.asarray(deq + err2), np.asarray(g),
                               rtol=1e-5, atol=1e-6 * scale)
    # quantization error bounded by the int8 step
    assert float(jnp.abs(err2).max()) <= float(jnp.abs(g).max()) / 127.0 + 1e-9


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc():
    tree = {"a": jnp.arange(6.0).reshape(2, 3).astype(jnp.bfloat16),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        for step in (1, 2, 3, 4, 5):
            checkpoint.save(d, step, tree, keep=2)
        assert checkpoint.latest_step(d) == 5
        restored, step = checkpoint.restore(d, tree)
        assert step == 5
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
        # GC kept only 2
        from pathlib import Path
        assert len(list(Path(d).glob("step_*"))) == 2


def test_checkpoint_async():
    tree = {"w": jnp.ones((8, 8))}
    with tempfile.TemporaryDirectory() as d:
        t = checkpoint.save_async(d, 7, tree)
        t.join(timeout=30)
        restored, step = checkpoint.restore(d, tree)
        assert step == 7


def test_elastic_restore_onto_mesh():
    """Restore re-shards for a (new) mesh — the elastic-scaling path."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_smoke_mesh
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 1, tree)
        mesh = make_smoke_mesh()
        restored, _ = checkpoint.restore(
            d, tree, mesh=mesh, specs={"w": P(None, "model")})
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        assert restored["w"].sharding.mesh.shape["model"] == 1


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_determinism():
    cfg = DataConfig(batch=2, seq=8, vocab=64, seed=3)
    a = SyntheticLM(cfg).batch_at(5)
    b = SyntheticLM(cfg).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(cfg).batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_byte_file_dataset(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_text("hello world, this is a tiny corpus for testing!" * 10)
    cfg = DataConfig(batch=2, seq=16, vocab=256)
    ds = ByteFileLM(p, cfg)
    b = ds.batch_at(0)
    assert b["tokens"].shape == (2, 16)
    assert b["tokens"].max() < 256


@given(lens=st.lists(st.integers(1, 50), min_size=1, max_size=10),
       seq=st.integers(4, 64))
@settings(max_examples=25, deadline=None)
def test_packing_conserves_tokens(lens, seq):
    docs = [np.arange(1, n + 1, dtype=np.int32) for n in lens]
    packed = pack_documents(docs, seq)
    total = sum(lens)
    assert packed.shape[1] == seq
    # all real tokens present (pad id 0 never used by docs)
    assert (packed > 0).sum() == total


def test_prefetch_loader_order():
    cfg = DataConfig(batch=2, seq=8, vocab=64, prefetch=3)
    src = SyntheticLM(cfg)
    loader = PrefetchingLoader(src, cfg)
    try:
        for i in range(5):
            got = next(loader)
            np.testing.assert_array_equal(got["tokens"],
                                          src.batch_at(i)["tokens"])
    finally:
        loader.close()


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_ft_loop_restart_from_checkpoint():
    with tempfile.TemporaryDirectory() as d:
        fail = {7}

        def step_fn(state, i):
            if i in fail:
                fail.clear()
                raise RuntimeError("injected")
            return state + 1, {"loss": float(state)}

        loop = FaultTolerantLoop(
            FTConfig(ckpt_dir=d, ckpt_every=3, async_save=False), step_fn)
        state, end = loop.run(jnp.asarray(0.0), start_step=0, num_steps=10)
        assert loop.restarts == 1
        assert end == 10
        # replayed steps 6..9 after restore at 6 => state counts all steps
        assert float(state) == 10.0


def test_ft_loop_degrade_hook():
    calls = []

    def step_fn(state, i):
        raise RuntimeError("always fails")

    def degrade():
        calls.append(1)
        raise KeyboardInterrupt   # escape the loop for the test

    with tempfile.TemporaryDirectory() as d:
        loop = FaultTolerantLoop(
            FTConfig(ckpt_dir=d, max_restarts=2, async_save=False),
            step_fn, on_degrade=degrade)
        with pytest.raises(KeyboardInterrupt):
            loop.run(0, num_steps=5)
    assert calls == [1]


def test_straggler_monitor():
    mon = StragglerMonitor(factor=3.0)
    for _ in range(10):
        assert not mon.observe(1.0)
    assert mon.observe(10.0)
    assert mon.flags == 1
