"""Per-tier byte accounting: one code path for measured AND simulated
local-capacity numbers (the paper's Table 4.3 / §4.2 claim).

Two halves:

* **Formulas** — :func:`paged_window_bytes` (the (1 + lookahead)-deep
  prefetch window), :func:`peak_local_bytes` (window + pinned +
  activations, exactly what the discrete-event simulator accounts per
  stream) and :func:`capacity_reduction` (the "93% less local memory"
  headline).  ``core.simulator`` and ``benchmarks/local_memory.py``
  compute Table 4.3 through these; the serving runtime computes its
  measured reduction through the same :func:`capacity_reduction`, so the
  two numbers are comparable by construction.
* **Ledger** — :class:`MemoryLedger`, the live-runtime side: current and
  high-water residency per (tier, tensor class), fed by the
  orchestrator's placements, the block-pool manager and the expert
  pager, and dumped into ``BENCH_serve.json`` per tier.

Remote-tier KV traffic posts under two tensor classes: ``"kv_swap"``
(preemption stashes — pages evicted under pressure and restored later)
and ``"kv_handoff"`` (the disaggregated prefill->decode staging buffer
— completed prefill pages in flight between engines).  Keeping them
separate lets the ledger answer "how much remote capacity does
disaggregation itself need" independently of pressure behaviour.
"""
from __future__ import annotations

from typing import Any

import jax

# Canonical hierarchy order for per-tier views (mirrors
# ``tiers.HIERARCHY`` without importing it — accounting sits below the
# registry in the import graph).  Unknown tier names sort after these,
# alphabetically.
_TIER_ORDER = ("local", "remote", "cold")


def tree_bytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def modeled_transfer_s(nbytes: float, *, bandwidth_gbps: float,
                       latency_us: float = 0.0,
                       efficiency: float = 1.0) -> float:
    """THE modeled transfer-time formula: fixed latency + bytes over
    effective bandwidth.  Both the live :class:`MemoryLedger` (per
    tier-edge charges) and the Table-4.3 simulator's
    :class:`~repro.core.latency.LinkModel` route through this, so
    measured and simulated transfer costs cannot drift apart."""
    lat = latency_us * 1e-6
    if nbytes <= 0 or bandwidth_gbps <= 0 or efficiency <= 0:
        return lat
    return lat + float(nbytes) / (bandwidth_gbps * 1e9 * efficiency)


def paged_window_bytes(per_layer_bytes: float, lookahead: int = 1) -> float:
    """Bytes the Tensor Prefetcher keeps resident for a stream of
    equal-size pageable units: the executing unit + ``lookahead``
    prefetched ones.  The simulator's per-node window reduces to this
    for equal nodes; the live pager's double buffer IS this for w=1."""
    return (1 + max(lookahead, 0)) * per_layer_bytes


def resident_window_bytes(stacked_weights: Any, lookahead: int = 1) -> int:
    """Peak local bytes the pager keeps resident of a stacked (L, ...)
    pytree: (1 + lookahead) layers."""
    leaves = jax.tree.leaves(stacked_weights)
    if not leaves:
        return 0
    num_layers = leaves[0].shape[0]
    per_layer = tree_bytes(stacked_weights) // max(num_layers, 1)
    return int(paged_window_bytes(per_layer, lookahead))


def peak_local_bytes(window_bytes: float, pinned_bytes: float = 0.0,
                     activation_bytes: float = 0.0) -> float:
    """Peak local-tier footprint: paged window + pinned tensors +
    activations (Table 4.3's per-GPU requirement)."""
    return window_bytes + pinned_bytes + activation_bytes


def capacity_reduction(peak_bytes: float, baseline_bytes: float) -> float:
    """Fractional local-capacity reduction vs a fully resident baseline
    (0.93 == the paper's 93% headline).  Negative if paging *costs*."""
    if baseline_bytes <= 0:
        return 0.0
    return 1.0 - peak_bytes / baseline_bytes


class MemoryLedger:
    """Current + high-water residency per (tier, tensor class).

    ``record`` sets the **current** bytes a tensor class occupies in a
    tier (residency is state, not a counter — policies re-record as
    their footprint changes); per-tier totals and high-water marks fall
    out.  Shape-derived residency recorded at trace time is fine: it
    re-records identically on every retrace of the same shapes.

    Residency (``record``) and provisioned capacity (``record_capacity``)
    are tracked separately so a pre-allocated slab is never
    double-counted: a block pool's *capacity* is the slab, its
    *residency* is the live pages inside it — only residency sums into
    ``in_use``/``hwm``.

    Under tensor-parallel serving the ledger accounts **per shard**: the
    orchestrator's mesh-aware placements record the bytes resident on
    ONE device (total / model shards for heads- or column-sharded
    classes), so ``capacity_reduction`` over ledger numbers stays
    directly comparable to the per-GPU Table 4.3 simulator.  ``shards``
    (stamped by ``MemoryOrchestrator.bind_mesh``) says how many
    model-axis shards the per-shard numbers multiply out to.
    """

    def __init__(self) -> None:
        self._now: dict[str, dict[str, int]] = {}
        self._hwm: dict[str, int] = {}
        self._cap: dict[str, dict[str, int]] = {}
        # per-tier-edge transfer accounting: (src, dst) -> counters
        self._xfer: dict[tuple[str, str], dict] = {}
        self.shards = 1          # model-axis shards the bytes are "per"

    def record(self, tier: str, tensor_class: str, nbytes: int) -> None:
        self._now.setdefault(tier, {})[tensor_class] = int(nbytes)
        self._hwm[tier] = max(self._hwm.get(tier, 0), self.in_use(tier))

    def record_capacity(self, tier: str, tensor_class: str,
                        nbytes: int) -> None:
        """Provisioned (not necessarily live) bytes, e.g. a pool slab."""
        self._cap.setdefault(tier, {})[tensor_class] = int(nbytes)

    def release(self, tier: str, tensor_class: str) -> None:
        self._now.get(tier, {}).pop(tensor_class, None)

    def in_use(self, tier: str) -> int:
        return sum(self._now.get(tier, {}).values())

    def hwm(self, tier: str) -> int:
        return self._hwm.get(tier, 0)

    def capacity(self, tier: str) -> int:
        return sum(self._cap.get(tier, {}).values())

    def classes(self, tier: str) -> dict[str, int]:
        return dict(self._now.get(tier, {}))

    def tiers(self) -> list[str]:
        """Every tier the ledger has seen, in hierarchy order (local,
        remote, cold, then any custom names alphabetically) — the order
        the BENCH ``tiers`` map is emitted and schema-checked in."""
        names = set(self._now) | set(self._hwm) | set(self._cap)
        rank = {n: i for i, n in enumerate(_TIER_ORDER)}
        return sorted(names, key=lambda n: (rank.get(n, len(rank)), n))

    # ----- tier-edge transfers ----------------------------------------------
    def charge_transfer(self, src: str, dst: str, nbytes: int, *,
                        bandwidth_gbps: float | None = None,
                        latency_us: float | None = None) -> float:
        """Charge one eager transfer of ``nbytes`` across the
        ``src -> dst`` tier edge: accumulates transfer bytes, a transfer
        count, and the MODELED transfer time (per-tier bandwidth/latency
        from the registry's edge model unless given explicitly).
        Returns the modeled seconds for this transfer.

        Only *eager* host-level movements charge here (placements,
        swap stashes, cold parks/promotes, handoff staging); the traced
        paging streams inside jit (layer prefetch, offload_kv round
        trips) are modeled by the simulator's paging stream instead —
        both through :func:`modeled_transfer_s`."""
        if bandwidth_gbps is None or latency_us is None:
            from repro.memory import tiers as _tiers
            e = _tiers.registry().edge(src, dst)
            bandwidth_gbps = e.bandwidth_gbps if bandwidth_gbps is None \
                else bandwidth_gbps
            latency_us = e.latency_us if latency_us is None else latency_us
        dt = modeled_transfer_s(nbytes, bandwidth_gbps=bandwidth_gbps,
                                latency_us=latency_us)
        edge = self._xfer.setdefault(
            (src, dst), {"bytes": 0, "modeled_s": 0.0, "count": 0})
        edge["bytes"] += int(nbytes)
        edge["modeled_s"] += dt
        edge["count"] += 1
        return dt

    def transferred_bytes(self, src: str, dst: str) -> int:
        return self._xfer.get((src, dst), {}).get("bytes", 0)

    def transfers(self) -> dict:
        """Per-edge transfer view (the BENCH ``transfers`` shape):
        ``{"src->dst": {bytes, modeled_s, count}}``."""
        return {f"{s}->{d}": {"bytes": v["bytes"],
                              "modeled_s": round(v["modeled_s"], 9),
                              "count": v["count"]}
                for (s, d), v in self._xfer.items()}

    def snapshot(self) -> dict:
        """Machine-readable per-tier view (the BENCH_serve.json shape).
        Byte values are per model-axis shard (``shards`` > 1 under
        tensor-parallel serving; 1 otherwise)."""
        return {t: {"in_use_bytes": self.in_use(t),
                    "hwm_bytes": self.hwm(t),
                    "capacity_bytes": self.capacity(t),
                    "shards": self.shards,
                    "by_class": self.classes(t)}
                for t in self.tiers()}
