"""Serving runtime: fused on-device block decode + continuous batching
over a block-pool paged KV cache.

The decode hot path is ONE dispatch per ``block_size`` tokens: a
``lax.scan`` decode loop (:func:`repro.models.transformer.decode_loop`)
emits a ``(B, block)`` token block with per-slot ``active``/``remaining``
masks, the KV cache and decode state are **donated** into every dispatch
(updated in place, never copied), and the host syncs once per block to
harvest tokens.  On top of it, :class:`BatchedServer` does continuous
batching: requests are admitted into individual slots between blocks —
no batch restart — and slots are recycled the moment a sequence hits EOS
or its token budget.

For models that support it (dense-family transformers with full causal
attention) the KV cache is a **device-resident block page pool** instead
of a dense ``(L, B, Hkv, max_seq, hd)`` slab: fixed-size pages allocated
on demand at block boundaries by a host-side :class:`BlockManager` and
reclaimed on EOS/eviction, with prefill writing straight into freshly
allocated pages and decode attention reading only the pages each slot's
table maps (the Pallas ``paged_attention`` kernel on TPU, its gather
oracle elsewhere).  KV memory then scales with live tokens rather than
``batch × max_seq``, and per-step attention cost with the actual
sequence length — while emitting bit-identical tokens to the dense path.

``serve_step`` (one per-token dispatch) is kept for dry-run lowering and
as the baseline the serving benchmark measures against.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import memory
from repro.memory import MemoryOrchestrator
from repro.models.base import DecodeState
from repro.models.transformer import (decode_loop, sample_tokens,
                                      vocab_mask_logits)

# Single source of truth for the logits -> token step; the old
# ``serve.sample`` duplicate of ``transformer.sample_tokens`` is gone.
sample = sample_tokens


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    output: list = dataclasses.field(default_factory=list)


def make_prefill_step(model) -> Callable:
    def prefill_step(params, tokens, cache, extra=None):
        logits, cache = model.prefill(params, tokens, cache, extra)
        return logits, cache
    return prefill_step


def make_serve_step(model, *, temperature: float = 0.0) -> Callable:
    """One decode step: (params, tokens (B,1), cache, cur_pos, key) ->
    (next_tokens (B,1), logits, cache).  The per-token baseline."""
    vocab = model.cfg.vocab

    def serve_step(params, tokens, cache, cur_pos, key):
        logits, cache = model.decode_step(params, tokens, cache, cur_pos)
        nxt = sample(logits, vocab, temperature, key)
        return nxt, logits, cache
    return serve_step


def make_decode_loop(model, *, block_size: int, temperature: float = 0.0,
                     eos_id: int | None = None, donate: bool = True
                     ) -> Callable:
    """Jit the fused decode loop with the donation contract: the cache
    (arg 1) and decode state (arg 2) are consumed by every dispatch."""
    def loop(params, cache, state):
        return decode_loop(model, params, cache, state, num_steps=block_size,
                           temperature=temperature, eos_id=eos_id)
    return memory.donating_jit(loop, donate_argnums=(1, 2) if donate else ())


def _bucket(n: int, quantum: int = 8) -> int:
    """Pad lengths to a bucket so admission compiles O(log) shapes."""
    b = quantum
    while b < n:
        b *= 2
    return b


class BatchedServer:
    """Continuous-batching inference server (single process).

    Decode runs in fixed-size fused blocks over a persistent ``batch_size``
    -slot state.  Between blocks, finished slots are recycled and queued
    requests are admitted into the live cache — mid-stream, without
    restarting or re-prefilling the rest of the batch.  Exactly one host
    transfer happens per decoded block (the token-block harvest).

    ``paged`` (default: auto) selects the block-pool paged KV cache when
    the model supports it.  ``num_pages`` sizes the pool — the default
    matches dense capacity (``batch × ceil(max_seq/page)`` plus the null
    page), so admission never blocks; smaller pools oversubscribe: queued
    requests wait at admission until reclamation frees enough pages, and
    mid-decode exhaustion raises ``MemoryError`` (no preemption yet).
    """

    def __init__(self, model, params, *, batch_size: int = 4,
                 max_seq: int = 256, temperature: float = 0.0, seed: int = 0,
                 block_size: int = 8, eos_id: int | None = None,
                 paged: bool | None = None, page_size: int | None = None,
                 num_pages: int | None = None):
        self.model = model
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq
        self.block_size = block_size
        self.temperature = temperature
        self.eos_id = eos_id
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self._backlog: list[Request] = []
        self._uid = 0
        if paged is None:
            paged = getattr(model, "supports_paged_kv", lambda: False)()
        self.paged = bool(paged)
        # the model's orchestrator (shared ledger: weight windows, expert
        # residency and KV pool report into one per-tier accounting);
        # models without one get a fresh plan from their config.
        self.mem: MemoryOrchestrator = (
            getattr(model, "mem", None) or MemoryOrchestrator.plan(model.cfg))
        self._decode_loop = make_decode_loop(
            model, block_size=block_size, temperature=temperature,
            eos_id=eos_id)
        self._admit_step = self.mem.donating_jit(self._make_admit_step(),
                                                 donate_argnums=(2, 3))
        # live slot state — donated through every dispatch
        if self.paged:
            cfg = model.cfg
            self.page_size = page_size or cfg.page_size
            per_seq = -(-max_seq // self.page_size)
            self.num_pages = num_pages or batch_size * per_seq + 1
            self.kv = self.mem.block_pool(self.num_pages, self.page_size)
            self.manager = self.kv.manager
            self.kv.bind_kv_shape(cfg.padded_kv_heads, cfg.head_dim,
                                  jnp.dtype(cfg.dtype).itemsize,
                                  cfg.num_layers)
            self.cache = self.mem.place_kv_pool(
                model.init_paged_cache(self.num_pages, self.page_size))
            init_pages = self._idle_pages()
        else:
            self.kv = None
            self.manager = None
            # dense slab: resident at full size regardless of occupancy
            # (capacity == residency), in the kv_pool policy's tier
            self.cache = self.mem.place_kv_pool(
                model.init_cache(batch_size, max_seq))
            self.mem.ledger.record(
                self.mem.policies["kv_pool"].tier, "kv_pool",
                memory.tree_bytes(self.cache))
            init_pages = None
        self.state = DecodeState.init(batch_size, jax.random.PRNGKey(seed),
                                      pages=init_pages)
        self.slots: list[Request | None] = [None] * batch_size
        self._slot_pos = [0] * batch_size      # host mirror of state.pos
        self._reserved: dict[int, int] = {}    # slot -> worst-case pages
        self.stats = {"steps": 0, "tokens": 0, "batches": 0, "blocks": 0,
                      "dispatches": 0, "admitted": 0, "host_syncs": 0,
                      "kv_pages_in_use": 0, "kv_pages_hwm": 0}

    # ----- request intake ----------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> Request:
        prompt = np.asarray(prompt, np.int32)
        # validate HERE so the caller sees the error; a raise mid-admission
        # would drop an already-dequeued request with done never set
        if len(prompt) + max(max_new_tokens - 1, 0) > self.max_seq:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens})"
                f" exceeds max_seq={self.max_seq}")
        if self.paged:
            worst = self._worst_pages(len(prompt), max_new_tokens)
            if worst > self.manager.capacity:
                raise ValueError(
                    f"request needs up to {worst} KV pages but the pool "
                    f"only has {self.manager.capacity}")
        self._uid += 1
        req = Request(self._uid, prompt, max_new_tokens=max_new_tokens)
        self.queue.put(req)
        return req

    def _idle_pages(self) -> jax.Array:
        """Canonical width-1 null page table carried OUTSIDE decode
        blocks: _prepare_block swaps the real table in right before each
        dispatch and run_block swaps an idle one back in afterwards, so
        admission always sees ONE page-table shape — no admit_step
        recompiles keyed on however long the longest live sequence
        happens to be.  Freshly allocated every time because the state
        (pages included) is donated into each dispatch."""
        return jnp.zeros((self.batch, 1), jnp.int32)

    # ----- admission ---------------------------------------------------------
    def _admit_plen(self, prompt_len: int, max_new_tokens: int) -> int:
        """Bucketed admission prompt length (see _admit)."""
        limit = self.max_seq - max(max_new_tokens - 1, 0)
        bucket = _bucket(prompt_len)
        return bucket if bucket <= limit else prompt_len

    def _make_admit_step(self) -> Callable:
        return (self._make_admit_step_paged() if self.paged
                else self._make_admit_step_dense())

    def _make_admit_step_dense(self) -> Callable:
        model, max_seq = self.model, self.max_seq
        vocab, temperature = self.model.cfg.vocab, self.temperature
        eos_id = self.eos_id

        def admit_step(params, ptoks, cache, state, slot, max_new):
            """Prefill ONE request and splice it into the live batch state.

            ptoks: (1, P) left-padded prompt; slot/max_new: traced scalars.
            Donates (cache, state) — the splice is in place.
            """
            key, k = jax.random.split(state.key)
            fresh = model.init_cache(1, max_seq)
            logits, fresh = model.prefill(params, ptoks, fresh)
            nxt = sample_tokens(logits, vocab, temperature, k)   # (1, 1)

            def splice(big, small):
                """Write the single-request leaf into the batch leaf at
                ``slot``.  The batch axis is found per leaf (the unique
                axis where the shapes differ), so non-transformer caches
                — e.g. recurrent state with batch leading — splice too."""
                if big.shape == small.shape:  # batch-1 server: whole swap
                    return small.astype(big.dtype)
                diff = [i for i, (bs, ss) in enumerate(zip(big.shape,
                                                           small.shape))
                        if bs != ss]
                if len(diff) != 1:
                    raise ValueError(
                        f"cannot infer the batch axis of cache leaf "
                        f"{big.shape} from single-request leaf "
                        f"{small.shape}")
                ax = diff[0]
                starts = (0,) * ax + (slot,) + (0,) * (big.ndim - ax - 1)
                return jax.lax.dynamic_update_slice(
                    big, small.astype(big.dtype), starts)

            cache = jax.tree.map(splice, cache, fresh)
            plen = ptoks.shape[1]
            state = self._spliced_state(state, nxt, plen, slot, max_new, key)
            return nxt, cache, state
        return admit_step

    def _make_admit_step_paged(self) -> Callable:
        model = self.model
        vocab, temperature = self.model.cfg.vocab, self.temperature

        def admit_step(params, ptoks, cache, state, slot, max_new, ptable):
            """Prefill ONE request straight into its freshly allocated
            pages — no dense staging cache, no splice.  ptable: (1, n)
            page ids covering the bucketed prompt.  Donates (cache,
            state): the page writes and slot activation are in place."""
            key, k = jax.random.split(state.key)
            logits, cache = model.prefill_paged(params, ptoks, cache, ptable)
            nxt = sample_tokens(logits, vocab, temperature, k)   # (1, 1)
            plen = ptoks.shape[1]
            state = self._spliced_state(state, nxt, plen, slot, max_new, key)
            return nxt, cache, state
        return admit_step

    def _spliced_state(self, state, nxt, plen, slot, max_new, key):
        """Activate ``slot`` in the decode state (shared by both admit
        paths).  The page table is NOT touched here — the host refreshes
        it at every block boundary."""
        active = max_new > 1
        if self.eos_id is not None:   # EOS at admission: never activate
            active = active & (nxt[0, 0] != self.eos_id)
        upd1 = lambda buf, val: jax.lax.dynamic_update_slice(
            buf, jnp.asarray(val, buf.dtype)[None], (slot,))
        return DecodeState(
            tokens=jax.lax.dynamic_update_slice(state.tokens, nxt,
                                                (slot, 0)),
            pos=upd1(state.pos, plen),
            active=upd1(state.active, active),
            remaining=upd1(state.remaining, max_new - 1),
            key=key, pages=state.pages)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def _worst_pages(self, prompt_len: int, max_new_tokens: int) -> int:
        """Worst-case page need of a request over its whole lifetime."""
        plen = self._admit_plen(prompt_len, max_new_tokens)
        return self.manager.pages_for(
            min(plen + max(max_new_tokens - 1, 0), self.max_seq))

    def _admission_pages_ready(self, req: Request) -> bool:
        """Page-accounting gate: every admitted request RESERVES its
        worst-case page count (allocation itself stays on-demand, so the
        live footprint still tracks actual tokens) — mid-decode pool
        exhaustion is then impossible without preemption machinery, and
        queued requests simply wait for reclamation."""
        reserved = sum(self._reserved.values())
        worst = self._worst_pages(len(req.prompt), req.max_new_tokens)
        return worst <= self.manager.capacity - reserved

    def _admit(self, req: Request, slot: int) -> bool:
        """Prefill ``req`` into ``slot`` of the live batch; True if the
        request finished at admission (budget of 1 / immediate EOS).

        Left-pad tokens (id 0) inside the bucket are attended like the
        seed server attended its batch-wide left-padding — deterministic,
        but outputs depend on the bucket quantum (see EXPERIMENTS.md).
        """
        # the bucketed start position must leave room for every decode
        # write (pos < max_seq, KV scatter past the cache end is silently
        # dropped by jit) — fall back to the exact prompt length (one
        # extra compile) when the bucket would overflow
        plen = self._admit_plen(len(req.prompt), req.max_new_tokens)
        toks = np.zeros((1, plen), np.int32)
        toks[0, plen - len(req.prompt):] = req.prompt        # left-pad
        if self.paged:
            self._reserved[slot] = self._worst_pages(len(req.prompt),
                                                     req.max_new_tokens)
            page_ids = self.manager.ensure(slot, plen)   # fresh slot: all new
            ptable = jnp.asarray([page_ids], jnp.int32)
            nxt, self.cache, self.state = self._admit_step(
                self.params, jnp.asarray(toks), self.cache, self.state,
                jnp.asarray(slot, jnp.int32),
                jnp.asarray(req.max_new_tokens, jnp.int32), ptable)
            self.manager.note_tokens(slot, plen)
        else:
            nxt, self.cache, self.state = self._admit_step(
                self.params, jnp.asarray(toks), self.cache, self.state,
                jnp.asarray(slot, jnp.int32),
                jnp.asarray(req.max_new_tokens, jnp.int32))
        self._slot_pos[slot] = plen
        first = int(jax.device_get(nxt)[0, 0])
        req.output.append(first)
        self.stats["tokens"] += 1
        self.stats["admitted"] += 1
        if req.max_new_tokens <= 1 or (self.eos_id is not None
                                       and first == self.eos_id):
            if self.paged:
                self.manager.free_slot(slot)   # reclaim at once
                self._reserved.pop(slot, None)
            req.done.set()
            return True
        self.slots[slot] = req
        return False

    def _admit_from_queue(self, finished: list[Request]) -> None:
        """Fill free slots from the queue (non-blocking, mid-stream).
        With a paged pool, admission is page-gated: the head request
        waits (FIFO order preserved) until reclamation frees enough."""
        while True:
            free = self._free_slots()
            if not free:
                return
            if not self._backlog:
                try:
                    self._backlog.append(self.queue.get_nowait())
                except queue.Empty:
                    return
            req = self._backlog[0]
            if self.paged and not self._admission_pages_ready(req):
                return                # blocked on pages, not on slots
            self._backlog.pop(0)
            if self._admit(req, free[0]):
                finished.append(req)      # done at admission: slot stays free

    # ----- decode ------------------------------------------------------------
    def _prepare_block(self) -> None:
        """Block-boundary page allocation + table refresh: every live slot
        gets pages covering its next ``block_size`` writes (capped by its
        remaining budget), and the decode state's (B, n_pages) table is
        rebuilt at a power-of-two bucketed width so attention cost tracks
        the longest LIVE sequence, not max_seq."""
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            budget = req.max_new_tokens - len(req.output)
            need = min(self._slot_pos[i] + min(self.block_size, budget),
                       self.max_seq)
            self.manager.ensure(i, need)
        n_dec = _bucket(max(self.manager.max_slot_pages(), 1), 1)
        table = self.manager.table(list(range(self.batch)), n_dec)
        self.state = dataclasses.replace(self.state,
                                         pages=jnp.asarray(table))

    def run_block(self) -> list[Request]:
        """One fused dispatch = ``block_size`` decode steps, then ONE host
        sync to harvest the token block.  Returns requests that finished."""
        if self.paged:
            self._prepare_block()
        toks, valid, self.cache, self.state = self._decode_loop(
            self.params, self.cache, self.state)
        self.stats["dispatches"] += 1
        self.stats["blocks"] += 1
        self.stats["steps"] += self.block_size
        toks_h, valid_h = jax.device_get((toks, valid))      # the one sync
        self.stats["host_syncs"] += 1
        finished = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            emitted = 0
            for t in range(self.block_size):
                if not valid_h[i, t]:
                    break                 # active mask is monotone per slot
                req.output.append(int(toks_h[i, t]))
                emitted += 1
                self.stats["tokens"] += 1
            self._slot_pos[i] += emitted
            if self.paged:
                self.manager.note_tokens(i, self._slot_pos[i])
            if (len(req.output) >= req.max_new_tokens
                    or (self.eos_id is not None and req.output
                        and req.output[-1] == self.eos_id)):
                req.done.set()
                finished.append(req)
                self.slots[i] = None       # slot recycled for admission
                if self.paged:
                    self.manager.free_slot(i)   # pages back to the pool
                    self._reserved.pop(i, None)
        if self.paged:
            self.stats["kv_pages_in_use"] = self.manager.pages_in_use
            self.stats["kv_pages_hwm"] = self.manager.hwm
            self.kv.record()               # per-tier ledger accounting
            self.state = dataclasses.replace(self.state,
                                             pages=self._idle_pages())
        return finished

    def run_once(self) -> list[Request]:
        """Admit queued requests and serve until every admitted request
        completes; returns the finished ones.  Requests that arrive (or
        overflow the slot count) while serving are admitted mid-stream.
        Non-blocking when idle: empty queue + no live slots returns [].
        """
        finished: list[Request] = []
        self._admit_from_queue(finished)
        while any(r is not None for r in self.slots):
            finished.extend(self.run_block())
            self._admit_from_queue(finished)
        if finished:
            self.stats["batches"] += 1
        return finished

    # ----- accounting --------------------------------------------------------
    def kv_bytes_in_use(self) -> int:
        """Live KV footprint: allocated pages only (paged) or the whole
        dense slab (which is resident regardless of occupancy)."""
        if not self.paged:
            return memory.tree_bytes(self.cache)
        kp = self.cache["k_pages"]
        per_page = self.manager.bytes_per_page(
            kp.shape[3], kp.shape[4], kp.dtype.itemsize,
            num_layers=kp.shape[0])
        return self.manager.pages_in_use * per_page

    def kv_bytes_capacity(self) -> int:
        return memory.tree_bytes(self.cache)

    def tier_stats(self) -> dict:
        """Per-tier residency snapshot (feeds ``BENCH_serve.json``)."""
        return self.mem.ledger.snapshot()