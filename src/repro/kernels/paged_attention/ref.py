"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _is_f8(dtype) -> bool:
    """Single-byte float pool dtype (fp8_e4m3 / fp8_e5m2)."""
    dt = jnp.dtype(dtype)
    return dt.itemsize == 1 and jnp.issubdtype(dt, jnp.floating)


@functools.lru_cache(maxsize=None)
def _f8_lut(dtype_name: str):
    """(256,) fp32 table holding the convert of every fp8 bit pattern —
    the dequant LUT for :func:`to_f32`.  Built host-side (numpy) so the
    cached value is a constant, never a leaked tracer."""
    import numpy as np
    return np.arange(256, dtype=np.uint8).view(
        jnp.dtype(dtype_name)).astype(np.float32)


def gatherable_view(pool: jax.Array) -> jax.Array:
    """uint8 bit-view of an fp8 pool; any other pool unchanged.

    XLA:CPU's gather falls off the fast byte-copy path for float8
    element types (~8x slower than the identical gather on int8/uint8),
    and ``convert_element_type`` f8->f32 over the gathered view is
    likewise scalar — together the source of the fp8 serving throughput
    cliff (server_paged_fp8 at ~0.64x bf16 before this fix).  Gathering
    the same bytes as uint8 and converting through :func:`view_to_f32`'s
    256-entry LUT is bit-identical and restores int8-class speed."""
    if _is_f8(pool.dtype):
        return jax.lax.bitcast_convert_type(pool, jnp.uint8)
    return pool


def to_f32(x: jax.Array) -> jax.Array:
    """fp32 view of gathered pool values.  fp8 goes through the 256-entry
    LUT (bit-identical to ``astype(float32)`` by construction — the LUT
    IS that convert, precomputed over all 256 patterns) instead of the
    scalar ``convert_element_type`` path; everything else casts."""
    if _is_f8(x.dtype):
        return jnp.take(jnp.asarray(_f8_lut(x.dtype.name)),
                        jax.lax.bitcast_convert_type(
                            x, jnp.uint8).astype(jnp.int32), axis=0)
    return x.astype(jnp.float32)


def take_pages(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """``pool[page_table]`` with fp8 pools routed through the uint8
    bit-view (see :func:`gatherable_view`) and bitcast back to the pool
    dtype — bit-identical, gathers at int8-class speed.  The bitcast
    round trip is a metadata op that XLA fuses away; pairing a gathered
    fp8 result with :func:`to_f32` keeps the whole read path off the
    slow fp8 gather/convert kernels."""
    g = gatherable_view(pool)[page_table]
    if g.dtype != pool.dtype:
        g = jax.lax.bitcast_convert_type(g, pool.dtype)
    return g


def gather_pages(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """Materialize the per-sequence view of a page pool.

    pages: (P, page, Hkv, d); page_table: (B, n_pages) int32.
    Returns (B, Hkv, n_pages * page, d) — the cache layout
    :func:`repro.models.layers.decode_attention` expects, with gathered
    position ``i`` holding absolute position ``i`` (pages are in order).
    """
    b, n_pages = page_table.shape
    page, hkv, d = pages.shape[1:]
    g = take_pages(pages, page_table)       # (B, n_pages, page, Hkv, d)
    return g.reshape(b, n_pages * page, hkv, d).transpose(0, 2, 1, 3)


def gather_scales(scales: jax.Array, page_table: jax.Array) -> jax.Array:
    """Materialize the per-sequence view of a per-page scale array.

    scales: (P, page, Hkv) — one dequant scale per (token slot, head);
    page_table: (B, n_pages) int32.  Returns (B, Hkv, n_pages * page),
    aligned position-for-position with :func:`gather_pages`.
    """
    b, n_pages = page_table.shape
    page, hkv = scales.shape[1:]
    g = scales[page_table]                  # (B, n_pages, page, Hkv)
    return g.reshape(b, n_pages * page, hkv).transpose(0, 2, 1)


def paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens,
                        extra_kv=None, k_scales=None, v_scales=None):
    """Decode attention over a paged KV cache.

    q:          (B, Hkv, G, d)       one query token, grouped heads
    k_pages:    (P, page, Hkv, d)    global page pool
    v_pages:    (P, page, Hkv, d)
    page_table: (B, pages_per_seq)   int32 page ids
    seq_lens:   (B,)                 valid tokens per sequence
    extra_kv:   optional current-token (k0, v0), each (B, Hkv, d),
                attended as one extra column past the pooled positions
    k_scales:   optional (P, page, Hkv) dequant scales for a quantized
                pool — multiplied into the fp32 view inline, so the
                full-precision KV never materializes outside this gather
    v_scales:   same, for the value pool
    returns     (B, Hkv, G, d)
    """
    b, hkv, g, d = q.shape
    pages_per_seq = page_table.shape[1]
    page = k_pages.shape[1]

    # fp8 pools gather as a uint8 bit-view and dequantize through the
    # 256-entry convert LUT (bit-identical; see take_pages / to_f32) —
    # the fix for the fp8 serving throughput cliff
    k = take_pages(k_pages, page_table)        # (B, pages, page, Hkv, d)
    v = take_pages(v_pages, page_table)
    k = k.reshape(b, pages_per_seq * page, hkv, d)
    v = v.reshape(b, pages_per_seq * page, hkv, d)
    if k_scales is not None:
        ks = k_scales[page_table].reshape(b, pages_per_seq * page, hkv)
        k = to_f32(k) * ks.astype(jnp.float32)[..., None]
    elif _is_f8(k.dtype):             # scale-less fp8: plain convert
        k = to_f32(k)
    if v_scales is not None:
        vs = v_scales[page_table].reshape(b, pages_per_seq * page, hkv)
        v = to_f32(v) * vs.astype(jnp.float32)[..., None]
    elif _is_f8(v.dtype):
        v = to_f32(v)

    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    pos = jnp.arange(pages_per_seq * page)[None, :]
    valid = pos < seq_lens[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    if extra_kv is not None:
        k0, v0 = extra_kv
        s0 = jnp.einsum("bhgd,bhd->bhg", q.astype(jnp.float32),
                        k0.astype(jnp.float32)) / math.sqrt(d)
        s = jnp.concatenate([s, s0[..., None]], axis=-1)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / p.sum(axis=-1, keepdims=True)
    if extra_kv is not None:
        o = jnp.einsum("bhgs,bshd->bhgd", p[..., :-1],
                       v.astype(jnp.float32))
        o = o + p[..., -1][..., None] * extra_kv[1][:, :, None, :].astype(
            jnp.float32)
    else:
        o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
