"""Serving chaos harness: page-granular preemption bit-identity, tier
fault injection + recovery, the block-pool invariant auditor, and
checkpoint/restart of in-flight serving state.

Every scenario checks the robustness contract: a fault either recovers
to BIT-IDENTICAL tokens (retried transfers, preemption/resume, injected
pool exhaustion, kill-and-restore) or degrades exactly as documented
(victim shed with a structured ``Request.error``, prefix sharing
dropped under pressure, remote offload falling back to local
residency) — and the allocator invariants hold after every scheduling
step (``audit=True`` on every server here)."""
import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.kernels.paged_attention.ops import (BlockManager,
                                               BlockPoolAuditError)
from repro.memory import MemoryOrchestrator, tiers
from repro.memory.tiers import FaultPlan, TierTransferError, fault_plan
from repro.runtime import ft
from repro.runtime.serve import BatchedServer

PAGE = 4
MAX_SEQ = 64
# pool sized so two 8-page worst-case requests fill it and the third
# must preempt: capacity = 18 - 1 (null page) = 17 < 3 * 8
SMALL_POOL = 18


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("qwen2.5-14b").reduced()
    cfg = dataclasses.replace(cfg, remat=False, page_size=PAGE)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _server(tiny_model, **kw):
    model, params = tiny_model
    kw.setdefault("batch_size", 3)
    kw.setdefault("max_seq", MAX_SEQ)
    kw.setdefault("page_size", PAGE)
    kw.setdefault("audit", True)
    return BatchedServer(model, params, **kw)


def _drive(server, reqs, max_rounds=50):
    """run_once until every request completes (or is shed)."""
    finished = []
    for _ in range(max_rounds):
        finished += server.run_once()
        if all(r.done.is_set() for r in reqs):
            return finished
    raise AssertionError(
        f"requests stuck after {max_rounds} rounds: "
        f"{[(r.uid, r.done.is_set()) for r in reqs]}")


def _submit_three(server):
    return [server.submit(np.arange(1, 5, dtype=np.int32),
                          max_new_tokens=24) for _ in range(3)]


# ---------------------------------------------------------------------------
# preemption bit-identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("temp", [0.0, 0.7])
def test_preempted_run_bit_identical(tiny_model, temp):
    """Oversubscribed pool: the third request preempts a victim; the
    victim resumes; every token must match the uncontended run."""
    ref_srv = _server(tiny_model, temperature=temp)
    ref = _submit_three(ref_srv)
    _drive(ref_srv, ref)
    assert ref_srv.stats["preemptions"] == 0

    srv = _server(tiny_model, temperature=temp, num_pages=SMALL_POOL)
    got = _submit_three(srv)
    _drive(srv, got)
    assert srv.stats["preemptions"] >= 1
    assert srv.stats["resumes"] >= 1
    assert srv.stats["sheds"] == 0
    assert srv.stats["audits"] > 0
    for a, b in zip(ref, got):
        assert a.output == b.output, (temp, a.uid, a.output, b.output)
        assert b.error is None


@pytest.mark.parametrize("policy", ["fewest_pages", "lowest_progress"])
def test_preemption_policy_seam(tiny_model, policy):
    ref_srv = _server(tiny_model, temperature=0.7)
    ref = _submit_three(ref_srv)
    _drive(ref_srv, ref)

    srv = _server(tiny_model, temperature=0.7, num_pages=SMALL_POOL,
                  preempt_policy=policy)
    got = _submit_three(srv)
    _drive(srv, got)
    assert srv.stats["preemptions"] >= 1
    assert [r.output for r in ref] == [r.output for r in got]


def test_preemption_with_prefix_sharing_bit_identical(tiny_model):
    """Prefix-shared admissions + preemption: shared pages are stashed
    and restored private, sharing is dropped under pressure — tokens
    must not notice either."""
    sys_toks = np.arange(3, 15, dtype=np.int32)        # 3 whole pages

    def submit_all(server):
        return [server.submit(
            np.concatenate([sys_toks, np.asarray([50 + i, 60 + i],
                                                 np.int32)]),
            max_new_tokens=16) for i in range(3)]

    ref_srv = _server(tiny_model, temperature=0.7, prefix_cache=True)
    ref = submit_all(ref_srv)
    _drive(ref_srv, ref)

    srv = _server(tiny_model, temperature=0.7, prefix_cache=True,
                  num_pages=SMALL_POOL)
    got = submit_all(srv)
    _drive(srv, got)
    assert srv.stats["preemptions"] >= 1
    assert [r.output for r in ref] == [r.output for r in got]


def test_disabled_preemption_still_completes_fifo(tiny_model):
    """preempt=False keeps the old waiting behaviour (and the same
    tokens): the blocked request admits only after reclamation."""
    srv = _server(tiny_model, num_pages=SMALL_POOL, preempt=False)
    reqs = _submit_three(srv)
    _drive(srv, reqs)
    assert srv.stats["preemptions"] == 0
    ref_srv = _server(tiny_model)
    ref = _submit_three(ref_srv)
    _drive(ref_srv, ref)
    assert [r.output for r in ref] == [r.output for r in reqs]


# ---------------------------------------------------------------------------
# fault injection: transfer failures, spikes, pool exhaustion
# ---------------------------------------------------------------------------

def test_transfer_faults_retried_to_identical_tokens(tiny_model):
    ref_srv = _server(tiny_model, temperature=0.7)
    ref = _submit_three(ref_srv)
    _drive(ref_srv, ref)

    srv = _server(tiny_model, temperature=0.7, num_pages=SMALL_POOL)
    got = _submit_three(srv)
    with fault_plan(FaultPlan(fail_first_n=2)):    # swap-out fails twice
        _drive(srv, got)
    assert srv.stats["preemptions"] >= 1
    assert srv.stats["swap_retries"] >= 2          # both failures retried
    assert srv.stats["sheds"] == 0
    assert [r.output for r in ref] == [r.output for r in got]


def test_unrecoverable_swap_fault_sheds_victim_with_structured_error(
        tiny_model):
    srv = _server(tiny_model, num_pages=SMALL_POOL, swap_retries=1)
    reqs = _submit_three(srv)
    with fault_plan(FaultPlan(fail_rate=1.0, seed=7)):
        _drive(srv, reqs)
    shed = [r for r in reqs if r.error is not None]
    assert len(shed) == 1, [r.error for r in reqs]
    err = shed[0].error
    assert err["reason"] == "preempt_swap_failed"
    assert "attempts" in err["detail"]
    assert err["uid"] == shed[0].uid
    assert shed[0].done.is_set()
    assert srv.stats["sheds"] == 1
    for r in reqs:
        if r.error is None:
            assert len(r.output) == 24     # survivors fully served
    # the server survived: it serves new work after the fault clears
    extra = srv.submit(np.asarray([7, 8], np.int32), max_new_tokens=4)
    _drive(srv, [extra])
    assert len(extra.output) == 4 and extra.error is None


def test_latency_spikes_flag_slow_transfers():
    """The serving StragglerMonitor is reused for tier transfers: a
    spiked transfer lands >> 3x the median and is flagged."""
    mon = ft.StragglerMonitor(factor=3.0)
    payload = np.zeros(1024, np.uint8)
    for _ in range(6):
        tiers.transfer_with_retry(lambda: time.sleep(0.002),
                                  what="warm", nbytes=payload.nbytes,
                                  monitor=mon)
    assert mon.flags == 0
    with fault_plan(FaultPlan(spike_first_n=1, spike_s=0.1)):
        tiers.transfer_with_retry(lambda: time.sleep(0.002),
                                  what="spiked", nbytes=payload.nbytes,
                                  monitor=mon)
    assert mon.flags == 1


def test_server_wires_monitor_into_swapper(tiny_model):
    srv = _server(tiny_model, num_pages=SMALL_POOL)
    assert srv.swapper.monitor is srv.transfer_monitor


def test_pool_exhaustion_mid_decode_recovers_bit_identical(tiny_model):
    """Injected mid-decode exhaustion: a dispatch's page growth fails,
    the fault latches, emergency preemption frees a victim, decode
    proceeds, the victim resumes — tokens match the fault-free run."""
    def submit_two(server):
        return [server.submit(np.arange(1, 5, dtype=np.int32),
                              max_new_tokens=24) for _ in range(2)]

    ref_srv = _server(tiny_model, temperature=0.7, batch_size=2)
    ref = submit_two(ref_srv)
    _drive(ref_srv, ref)

    srv = _server(tiny_model, temperature=0.7, batch_size=2,
                  num_pages=SMALL_POOL)
    got = submit_two(srv)
    with fault_plan(FaultPlan(exhaust_at_block=1, exhaust_blocks=2)):
        _drive(srv, got)
    assert srv.stats["pool_faults"] == 1
    assert srv.stats["preemptions"] >= 1       # emergency preemption
    assert srv.stats["resumes"] >= 1
    assert srv.stats["sheds"] == 0
    assert [r.output for r in ref] == [r.output for r in got]


def test_pool_exhaustion_with_single_sequence_sheds(tiny_model):
    """Degradation floor: exhaustion with nothing to preempt FOR the
    blocked slot sheds it with a structured error, not a crash."""
    srv = _server(tiny_model, batch_size=1, num_pages=SMALL_POOL)
    req = srv.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=24)
    with fault_plan(FaultPlan(exhaust_at_block=1, exhaust_blocks=64)):
        _drive(srv, [req])
    assert req.error is not None and req.error["reason"] == "pool_exhausted"
    assert req.error["tokens_emitted"] == len(req.output)
    assert srv.stats["sheds"] == 1
    # server alive after the fault window
    extra = srv.submit(np.asarray([7, 8], np.int32), max_new_tokens=4)
    _drive(srv, [extra])
    assert extra.error is None and len(extra.output) == 4


def test_offload_fault_degrades_to_local_residency():
    """Unrecoverable remote-tier fault while placing the KV pool: the
    orchestrator falls back to local residency (documented degradation)
    and records it, instead of failing placement."""
    cfg = get_config("qwen2.5-14b").reduced().with_pager(
        enabled=True, offload_kv=True)
    m = MemoryOrchestrator.plan(cfg)
    assert type(m.policies["kv_pool"]).__name__ == "OffloadBetweenSteps"
    cache = {"k_pages": np.zeros((4, 2, 2, 2), np.float32),
             "v_pages": np.zeros((4, 2, 2, 2), np.float32)}
    with fault_plan(FaultPlan(fail_first_n=8)):
        placed = m.place_kv_pool(cache)
    assert "kv_pool" in m.degraded
    assert type(m.policies["kv_pool"]).__name__ == "PinLocal"
    assert m.config.offload_kv is False
    assert "degraded" in m.describe()
    np.testing.assert_array_equal(np.asarray(placed["k_pages"]),
                                  cache["k_pages"])
    # subsequent placements go local without touching the faulty tier
    with fault_plan(FaultPlan(fail_rate=1.0)):
        m.place_kv_pool(cache)


# ---------------------------------------------------------------------------
# invariant auditor
# ---------------------------------------------------------------------------

def _manager_with_slots() -> BlockManager:
    m = BlockManager(num_pages=10, page_size=4)
    m.ensure(0, 8)
    m.ensure(1, 12)
    m.note_tokens(0, 7)
    m.note_tokens(1, 9)
    return m


def test_audit_clean_on_healthy_manager():
    m = _manager_with_slots()
    out = m.audit()
    assert out["pages_in_use"] == 5
    assert out["free_pages"] == 4


@pytest.mark.parametrize("corrupt,needle", [
    (lambda m: m._free.append(m._free[0]), "duplicates"),
    (lambda m: m._free.append(m.pages[0][0]), "both free and owned"),
    (lambda m: m.refcount.__setitem__(m.pages[1][0], 2), "refcount"),
    (lambda m: m.pages[0].append(m.pages[0][0]), "twice"),
    (lambda m: m.pages[0].append(0), "null page"),
    (lambda m: m.lens.__setitem__(0, 99), "covers only"),
    (lambda m: setattr(m, "hwm", 0), "hwm"),
])
def test_audit_detects_corruption(corrupt, needle):
    m = _manager_with_slots()
    corrupt(m)
    with pytest.raises(BlockPoolAuditError, match=needle):
        m.audit()


def test_audit_cross_checks_ledger_residency(tiny_model):
    srv = _server(tiny_model)
    req = srv.submit(np.asarray([1, 2, 3], np.int32), max_new_tokens=4)
    _drive(srv, [req])
    srv.kv.audit()                                  # clean
    srv.kv.ledger.record(srv.kv.tier, srv.kv.tensor_class, 1 << 40)
    with pytest.raises(BlockPoolAuditError, match="ledger"):
        srv.kv.audit()


# ---------------------------------------------------------------------------
# checkpoint/restart of in-flight serving state
# ---------------------------------------------------------------------------

def test_kill_and_restore_resumes_bit_identical(tiny_model, tmp_path):
    """Snapshot a server mid-decode, "kill" it, restore into a fresh
    server (disk round trip included): every sequence finishes with the
    tokens the uninterrupted run produced."""
    model, params = tiny_model
    ref_srv = _server(tiny_model, temperature=0.7, num_pages=SMALL_POOL)
    ref = _submit_three(ref_srv)
    _drive(ref_srv, ref)

    srv = _server(tiny_model, temperature=0.7, num_pages=SMALL_POOL)
    reqs = _submit_three(srv)
    early = srv.run_once(max_blocks=1)          # partial progress only
    snap = ft.snapshot_server(srv)
    path = ft.save_server_snapshot(tmp_path / "serve_ckpt", snap)
    del srv                                      # the "crash"

    srv2 = _server(tiny_model, temperature=0.7, num_pages=SMALL_POOL)
    ft.restore_server(srv2, ft.load_server_snapshot(path))
    finished = list(early)
    for _ in range(50):
        finished += srv2.run_once()
        if len(finished) == 3:
            break
    by_uid = {r.uid: r for r in finished}
    assert len(by_uid) == 3
    for a in ref:
        b = by_uid[a.uid]
        assert a.output == b.output, (a.uid, a.output, b.output)
        assert b.error is None
    assert srv2.stats["resumes"] >= 1


def test_restore_rejects_seed_mismatch(tiny_model):
    srv = _server(tiny_model, num_pages=SMALL_POOL)
    srv.submit(np.asarray([1, 2], np.int32), max_new_tokens=4)
    snap = srv.snapshot()
    other = _server(tiny_model, num_pages=SMALL_POOL, seed=1)
    with pytest.raises(ValueError, match="seed"):
        other.restore(snap)


SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import dataclasses
import jax, numpy as np
from repro.configs import get_config, build_model
from repro.launch.mesh import make_serving_mesh
from repro.runtime.serve import BatchedServer

cfg = get_config("qwen2.5-14b").reduced()
cfg = dataclasses.replace(cfg, remat=False, page_size=4)
params = build_model(cfg).init(jax.random.PRNGKey(0))
mesh = make_serving_mesh(model=2)

def serve(num_pages):
    srv = BatchedServer(build_model(cfg), params, batch_size=3, max_seq=64,
                        page_size=4, num_pages=num_pages, temperature=0.7,
                        paged=True, mesh=mesh, audit=True)
    reqs = [srv.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=24)
            for _ in range(3)]
    for _ in range(50):
        srv.run_once()
        if all(r.done.is_set() for r in reqs):
            break
    return [tuple(r.output) for r in reqs], srv

ref, _ = serve(None)                       # uncontended
got, srv = serve(18)                       # oversubscribed -> preemption
assert srv.stats["model_shards"] == 2
assert srv.stats["preemptions"] >= 1, srv.stats
assert srv.stats["resumes"] >= 1, srv.stats
assert srv.stats["sheds"] == 0, srv.stats
assert got == ref, f"sharded preemption diverged:\n  {ref}\n  {got}"
print("SHARDED_PREEMPT_OK")
"""


@pytest.mark.slow
def test_sharded_preemption_bit_identical():
    """Preempt/swap/resume must round-trip a model-sharded block pool
    (the swap gather/scatter crosses the "model" axis) without changing
    a single token."""
    import os
    import subprocess
    import sys
    from pathlib import Path

    src = str(Path(__file__).resolve().parent.parent / "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT, src],
                         capture_output=True, text=True, timeout=600,
                         env=env)
    assert "SHARDED_PREEMPT_OK" in out.stdout, \
        out.stdout[-1500:] + out.stderr[-3000:]


def test_swapper_ledger_accounts_stash_bytes(tiny_model):
    """Preempted KV bytes show up in the remote tier under kv_swap while
    stashed, and drain on resume."""
    srv = _server(tiny_model, num_pages=SMALL_POOL)
    reqs = _submit_three(srv)
    _drive(srv, reqs)
    assert srv.stats["preemptions"] >= 1
    led = srv.mem.ledger
    remote = tiers.REMOTE
    assert led.classes(remote).get("kv_swap", 0) == 0   # drained
    assert led.hwm(remote) > 0                          # but it peaked
