"""Training step: causal LM loss with gradient accumulation, MoE aux loss,
optional int8 gradient compression (error feedback), donation-friendly.

The step is pure and pjit-able; batch arrives sharded over the batch axes,
params per ``model.param_specs()``.  With the FengHuang pager enabled the
stacked layer weights live in the remote tier and are paged per layer by
``paged_scan`` — the same step function, no special casing.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.transformer import vocab_mask_logits
from repro.runtime import optim


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: optim.AdamWConfig = dataclasses.field(default_factory=optim.AdamWConfig)
    accum_steps: int = 1
    moe_aux_weight: float = 0.01
    compress_grads: bool = False
    z_loss: float = 1e-4


LOSS_CHUNK = 512


def _chunk_ce(model, params, hidden, labels, z_loss: float):
    """Cross entropy over one sequence chunk (keeps fp32 logits at
    (B, chunk, V) instead of the full sequence).

    The label pick uses a one-hot contraction instead of take_along_axis so
    GSPMD keeps the vocab axis sharded (partial sum + all-reduce) rather
    than all-gathering the logits."""
    from repro.models import layers as L
    cfg = model.cfg
    logits = L.lm_head(params["embed"], hidden, cfg)
    logits = vocab_mask_logits(logits, cfg.vocab).astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                         logits.ndim - 1)
    onehot = (vocab_ids == labels_safe[..., None]).astype(jnp.float32)
    ll = jnp.sum(logits * onehot, axis=-1)
    nll = ((lse - ll) * mask).sum()
    zl = (jnp.square(lse) * mask).sum() * z_loss
    return nll + zl, mask.sum()


def lm_loss(model, params, batch: dict, *, z_loss: float = 0.0) -> jax.Array:
    """Next-token cross entropy; labels==-1 are masked; padded vocab
    columns masked; VLM patch prefix positions are skipped.  The LM head +
    CE run in sequence chunks so fp32 logits never materialize at
    (B, S, V)."""
    cfg = model.cfg
    extra = {k: v for k, v in batch.items()
             if k in ("patches", "frames")}
    hidden = model.forward_hidden(params, batch["tokens"], extra or None)
    offs = hidden.shape[1] - batch["tokens"].shape[1]
    if offs:                                  # VLM: drop patch positions
        hidden = hidden[:, offs:]
    # predict token t+1 from position t
    hidden = hidden[:, :-1]
    labels = batch["labels"][:, 1:]
    s = hidden.shape[1]
    chunk = min(LOSS_CHUNK, s)
    pad = (-s) % chunk
    if pad:   # pad to a chunk multiple; padded labels are masked (-1)
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        s += pad
    nc = s // chunk
    hs = hidden.reshape(hidden.shape[0], nc, chunk, -1).transpose(1, 0, 2, 3)
    ls = labels.reshape(labels.shape[0], nc, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        h, l = xs
        t, c = _chunk_ce(model, params, h, l, z_loss)
        return (carry[0] + t, carry[1] + c), None

    # checkpoint: recompute chunk logits in backward instead of storing
    # (nc, B, chunk, V) fp32 residuals.
    (total, count), _ = jax.lax.scan(jax.checkpoint(body), (0.0, 0.0),
                                     (hs, ls))
    return total / jnp.maximum(count, 1.0)


def make_train_step(model, tcfg: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch[, err_state]) ->
    (params, opt_state, metrics[, err_state])."""

    def loss_fn(params, micro):
        return lm_loss(model, params, micro, z_loss=tcfg.z_loss)

    def train_step(params, opt_state, batch, err_state=None):
        if tcfg.accum_steps > 1:
            # split the batch into microbatches along batch dim; accumulate
            # grads in fp32 (communication deferred to a single reduction).
            def micro_split(x):
                b = x.shape[0]
                mb = b // tcfg.accum_steps
                return x.reshape(tcfg.accum_steps, mb, *x.shape[1:])

            micros = jax.tree.map(micro_split, batch)

            def accum(carry, micro):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, micro)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(accum, (zeros, 0.0), micros)
            grads = jax.tree.map(lambda g: g / tcfg.accum_steps, gsum)
            loss = lsum / tcfg.accum_steps
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        if tcfg.compress_grads and err_state is not None:
            pairs = jax.tree.map(optim.compressed_grad, grads, err_state)
            grads = jax.tree.map(lambda p: p[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
            err_state = jax.tree.map(lambda p: p[1], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))

        params, opt_state, om = optim.adamw_update(
            tcfg.adamw, params, grads, opt_state)
        metrics = {"loss": loss, **om}
        if err_state is not None:
            return params, opt_state, metrics, err_state
        return params, opt_state, metrics

    return train_step
