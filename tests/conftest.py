"""Shared test fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see
the real single-device CPU backend; multi-device tests subprocess."""
import os
import sys
from pathlib import Path

import pytest

SRC = Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))


@pytest.fixture(scope="session")
def rng():
    import numpy as np
    return np.random.RandomState(0)
