"""Whisper-style encoder-decoder backbone (whisper-base).

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, T_enc, d_model); the encoder is
the transformer part only (bidirectional self-attention + GELU MLP).
Deviation note (DESIGN.md): decoder positions use RoPE instead of learned
absolute embeddings — backbone-only fidelity.

Cross-attention K/V are computed once from the encoder output and live in
the cache — on FengHuang they sit in the remote tier between decode steps
(a natural fit: written once, read every step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.base import BATCH_AXES, ModelConfig, split_keys
from repro.memory import MemoryOrchestrator


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.mem = MemoryOrchestrator.plan(cfg)

    # ----- params -------------------------------------------------------
    def _enc_layer(self, key) -> dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {"attn": L.attn_params(k1, cfg),
                "mlp": L.mlp2_params(k2, cfg),
                "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
                "ln2": jnp.ones((cfg.d_model,), cfg.dtype)}

    def _dec_layer(self, key) -> dict:
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {"attn": L.attn_params(k1, cfg),
                "xattn": L.attn_params(k2, cfg, cross=True),
                "mlp": L.mlp2_params(k3, cfg),
                "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
                "lnx": jnp.ones((cfg.d_model,), cfg.dtype),
                "ln2": jnp.ones((cfg.d_model,), cfg.dtype)}

    def init(self, key) -> dict:
        cfg = self.cfg
        ke, k1, k2 = jax.random.split(key, 3)
        enc_keys = jnp.stack(split_keys(k1, cfg.num_encoder_layers))
        dec_keys = jnp.stack(split_keys(k2, cfg.num_layers))
        return {
            "embed": L.embed_params(ke, cfg),
            "enc_layers": jax.vmap(self._enc_layer)(enc_keys),
            "enc_ln": jnp.ones((cfg.d_model,), cfg.dtype),
            "dec_layers": jax.vmap(self._dec_layer)(dec_keys),
            "ln_f": jnp.ones((cfg.d_model,), cfg.dtype),
        }

    def param_specs(self) -> dict:
        cfg = self.cfg
        return {
            "embed": L.embed_specs(cfg),
            "enc_layers": {"attn": L.attn_specs(cfg), "mlp": L.mlp2_specs(),
                           "ln1": P(None, None), "ln2": P(None, None)},
            "enc_ln": P(None),
            "dec_layers": {"attn": L.attn_specs(cfg),
                           "xattn": L.attn_specs(cfg, cross=True),
                           "mlp": L.mlp2_specs(),
                           "ln1": P(None, None), "lnx": P(None, None),
                           "ln2": P(None, None)},
            "ln_f": P(None),
        }

    # ----- encoder --------------------------------------------------------
    def encode(self, params: dict, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        positions = jnp.arange(frames.shape[1])

        def body(h, lp):
            a = L.attn_forward(lp["attn"],
                               L.rmsnorm(h, lp["ln1"], cfg.norm_eps),
                               positions, cfg, causal=False)
            h = h + a
            h = h + L.mlp2_forward(lp["mlp"],
                                   L.rmsnorm(h, lp["ln2"], cfg.norm_eps))
            return h, None

        h, _ = self.mem.layer_scan(body, frames.astype(cfg.dtype),
                                params["enc_layers"])
        return L.rmsnorm(h, params["enc_ln"], cfg.norm_eps)

    # ----- decoder blocks ---------------------------------------------------
    def _dec_block(self, lp, h, positions, enc_kv):
        cfg = self.cfg
        h = h + L.attn_forward(lp["attn"],
                               L.rmsnorm(h, lp["ln1"], cfg.norm_eps),
                               positions, cfg, causal=True)
        h = h + L.cross_attn_forward(lp["xattn"],
                                     L.rmsnorm(h, lp["lnx"], cfg.norm_eps),
                                     enc_kv, cfg)
        h = h + L.mlp2_forward(lp["mlp"],
                               L.rmsnorm(h, lp["ln2"], cfg.norm_eps))
        return h

    # ----- passes -------------------------------------------------------------
    def forward_hidden(self, params: dict, tokens: jax.Array,
                       extra: dict | None = None) -> jax.Array:
        """Train forward (pre-head).  extra['frames']: (B, T_enc, d)."""
        from repro.runtime.sharding import SEQ_SHARDED_ACTS, maybe_constraint
        cfg = self.cfg
        enc_out = self.encode(params, extra["frames"])
        x = L.embed_lookup(params["embed"], tokens)
        positions = jnp.arange(x.shape[1])

        def body(h, lp):
            h = maybe_constraint(h, SEQ_SHARDED_ACTS)
            def run(h):
                enc_kv = L.cross_kv(lp["xattn"], enc_out, cfg)
                return self._dec_block(lp, h, positions, enc_kv)
            if cfg.remat:
                run = jax.checkpoint(run)
            return run(h), None

        x, _ = self.mem.layer_scan(body, x, params["dec_layers"])
        return L.rmsnorm(x, params["ln_f"], cfg.norm_eps)

    def forward(self, params: dict, tokens: jax.Array,
                extra: dict | None = None) -> jax.Array:
        x = self.forward_hidden(params, tokens, extra)
        return L.lm_head(params["embed"], x, self.cfg)

    # ----- cache ---------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int) -> dict:
        cfg = self.cfg
        kv = (cfg.num_layers, batch, cfg.padded_kv_heads, max_seq,
              cfg.head_dim)
        xkv = (cfg.num_layers, batch, cfg.padded_kv_heads, cfg.encoder_seq,
               cfg.head_dim)
        return {"k": jnp.zeros(kv, cfg.dtype), "v": jnp.zeros(kv, cfg.dtype),
                "xk": jnp.zeros(xkv, cfg.dtype),
                "xv": jnp.zeros(xkv, cfg.dtype)}

    def cache_specs(self) -> dict:
        s = P(None, BATCH_AXES, "model", None, None)
        return {"k": s, "v": s, "xk": s, "xv": s}

    def prefill(self, params: dict, tokens: jax.Array, cache: dict,
                extra: dict | None = None):
        cfg = self.cfg
        enc_out = self.encode(params, extra["frames"])
        x = L.embed_lookup(params["embed"], tokens)
        positions = jnp.arange(x.shape[1])

        def body(h, lp):
            enc_kv = L.cross_kv(lp["xattn"], enc_out, cfg)
            a, (k, v) = L.attn_prefill_kv(
                lp["attn"], L.rmsnorm(h, lp["ln1"], cfg.norm_eps),
                positions, cfg)
            h = h + a
            h = h + L.cross_attn_forward(
                lp["xattn"], L.rmsnorm(h, lp["lnx"], cfg.norm_eps), enc_kv, cfg)
            h = h + L.mlp2_forward(lp["mlp"],
                                   L.rmsnorm(h, lp["ln2"], cfg.norm_eps))
            return h, (L.to_cache_layout(k), L.to_cache_layout(v),
                       L.to_cache_layout(enc_kv[0]),
                       L.to_cache_layout(enc_kv[1]))

        x, (k, v, xk, xv) = self.mem.layer_scan(
            body, x, params["dec_layers"])
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, axis=3),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, axis=3),
            "xk": xk.astype(cache["xk"].dtype),
            "xv": xv.astype(cache["xv"].dtype),
        }
        x = L.rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps)
        return L.lm_head(params["embed"], x, cfg), cache

    def decode_step(self, params: dict, tokens: jax.Array, cache: dict,
                    cur_pos: jax.Array, extra: dict | None = None):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], tokens)

        b = x.shape[0]

        def body(h, lp, cache_layer):
            ck, cv, xk, xv = cache_layer
            a, k0, v0 = L.attn_decode(
                lp["attn"], L.rmsnorm(h, lp["ln1"], cfg.norm_eps),
                ck, cv, cur_pos, cfg)
            h = h + a
            # cross attention: single query against precomputed enc K/V
            q = L.rmsnorm(h, lp["lnx"], cfg.norm_eps)
            hq, hd = cfg.padded_heads, cfg.head_dim
            qh = (q @ lp["xattn"]["wq"]).reshape(b, 1, hq, hd)
            o = L.decode_attention(qh, xk, xv,
                                   jnp.full((b,), xk.shape[2] - 1, jnp.int32))
            h = h + (o.reshape(b, 1, -1) @ lp["xattn"]["wo"])
            h = h + L.mlp2_forward(lp["mlp"],
                                   L.rmsnorm(h, lp["ln2"], cfg.norm_eps))
            return h, (k0, v0)

        # caches read-only in the scan; one batched write afterwards.
        x, (k_new, v_new) = self.mem.layer_scan(
            body, x, params["dec_layers"],
            xs=(cache["k"], cache["v"], cache["xk"], cache["xv"]),
            page_xs=cfg.pager.offload_kv)
        bidx = jnp.arange(b)
        cache = {
            "k": cache["k"].at[:, bidx, :, cur_pos].set(
                k_new.transpose(1, 0, 2, 3).astype(cache["k"].dtype)),
            "v": cache["v"].at[:, bidx, :, cur_pos].set(
                v_new.transpose(1, 0, 2, 3).astype(cache["v"].dtype)),
            "xk": cache["xk"], "xv": cache["xv"],
        }
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return L.lm_head(params["embed"], x, cfg), cache
