"""The N-tier memory hierarchy: ordered local/remote/cold registry with
per-tier bandwidth/latency, tier-edge transfer charging in the ledger,
cold parking of preemption stashes, and the bit-identity contract —
a cold-parked-and-resumed sequence emits exactly the tokens the
uncontended run produced (tier moves never touch the bytes).

Also covers the degenerate-backend contract: on CPU several tiers alias
one host memory kind, but the ledger and policies reason about the
LOGICAL level, so accounting stays per-tier distinct.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.memory import MemoryOrchestrator, tiers
from repro.memory.accounting import MemoryLedger, modeled_transfer_s
from repro.memory.policies import OffloadBetweenSteps, TopKExpertPrefetch
from repro.memory.swap import PageSwapper
from repro.memory.tiers import (COLD, DEFAULT_TIER_LINKS, HIERARCHY, LOCAL,
                                REMOTE, FaultPlan, TierTransferError,
                                fault_plan, registry)
from repro.runtime import ft
from repro.runtime.serve import BatchedServer

PAGE = 4
MAX_SEQ = 64
SMALL_POOL = 18          # oversubscribed: forces preemption (see chaos)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("qwen2.5-14b").reduced()
    cfg = dataclasses.replace(cfg, remat=False, page_size=PAGE)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _server(tiny_model, **kw):
    model, params = tiny_model
    kw.setdefault("batch_size", 3)
    kw.setdefault("max_seq", MAX_SEQ)
    kw.setdefault("page_size", PAGE)
    kw.setdefault("audit", True)
    return BatchedServer(model, params, **kw)


def _drive(server, reqs, max_rounds=50):
    finished = []
    for _ in range(max_rounds):
        finished += server.run_once()
        if all(r.done.is_set() for r in reqs):
            return finished
    raise AssertionError(
        f"requests stuck after {max_rounds} rounds: "
        f"{[(r.uid, r.done.is_set()) for r in reqs]}")


def _submit_three(server):
    return [server.submit(np.arange(1, 5, dtype=np.int32),
                          max_new_tokens=24) for _ in range(3)]


# ---------------------------------------------------------------------------
# registry: ordered hierarchy, per-tier link model, reset/re-resolution
# ---------------------------------------------------------------------------

def test_registry_exposes_ordered_three_tier_hierarchy():
    h = registry().hierarchy()
    assert tuple(t.name for t in h) == HIERARCHY == (LOCAL, REMOTE, COLD)
    for t in h:
        assert t.available, t
        assert t.bandwidth_gbps > 0 and t.latency_us > 0, t
    by = {t.name: t for t in h}
    # the modeled hierarchy is monotone: each level down trades
    # bandwidth for capacity and pays more latency
    assert by[LOCAL].bandwidth_gbps > by[REMOTE].bandwidth_gbps \
        > by[COLD].bandwidth_gbps
    assert by[LOCAL].latency_us < by[REMOTE].latency_us \
        < by[COLD].latency_us


def test_edge_is_bottleneck_bandwidth_plus_summed_latency():
    e = registry().edge(LOCAL, COLD)
    local, cold = registry().tier(LOCAL), registry().tier(COLD)
    assert e.bandwidth_gbps == min(local.bandwidth_gbps, cold.bandwidth_gbps)
    assert e.latency_us == local.latency_us + cold.latency_us
    nb = 1 << 30
    assert e.transfer_s(nb) == modeled_transfer_s(
        nb, bandwidth_gbps=e.bandwidth_gbps, latency_us=e.latency_us)
    # zero bytes still pays the latency floor
    assert e.transfer_s(0) == pytest.approx(e.latency_us * 1e-6)
    assert e.transfer_s(2 * nb) > e.transfer_s(nb)


def test_unknown_tier_name_raises_with_hierarchy():
    with pytest.raises(KeyError, match="hierarchy"):
        registry().tier("nvme")


def test_edge_with_unknown_name_falls_back_to_default_link():
    # ledger charging must never throw on a custom tier label
    e = registry().edge(LOCAL, "nvme")
    assert e.bandwidth_gbps > 0
    assert e.transfer_s(1 << 20) > 0


def test_registry_reset_re_resolves_against_backend():
    r = registry()
    before = [(t.name, t.kind) for t in r.hierarchy()]
    tiers.reset()
    assert r._tiers == {}            # every cached resolution dropped
    after = [(t.name, t.kind) for t in r.hierarchy()]
    # same backend -> same resolution, but freshly computed
    assert after == before
    assert tiers.resolved_cold_kind() == r.cold.kind


def test_cpu_degenerate_tiers_alias_kind_but_account_distinctly():
    """Backends with fewer memory kinds than tiers alias physically but
    stay logically distinct: the ledger keeps separate per-tier lines,
    and ``tiers()`` lists them in hierarchy order."""
    kinds = [tiers.resolved_kind(t) for t in HIERARCHY]
    assert all(k is not None for k in kinds)
    # on CPU remote and cold collapse onto one host kind — that must
    # not collapse the ACCOUNTING
    led = MemoryLedger()
    led.record(COLD, "kv_swap", 300)
    led.record(REMOTE, "kv_swap", 200)
    led.record(LOCAL, "kv_pool", 100)
    assert led.tiers() == [LOCAL, REMOTE, COLD]
    assert [led.in_use(t) for t in HIERARCHY] == [100, 200, 300]
    led.record(COLD, "kv_swap", 0)
    assert led.hwm(COLD) == 300 and led.in_use(COLD) == 0
    assert led.hwm(REMOTE) == 200    # untouched by the cold drain
    snap = led.snapshot()
    assert list(snap) == [LOCAL, REMOTE, COLD]


# ---------------------------------------------------------------------------
# ledger: tier-edge transfer charges through the registry's link model
# ---------------------------------------------------------------------------

def test_charge_transfer_accumulates_bytes_time_and_count():
    led = MemoryLedger()
    nb = 1 << 30
    dt = led.charge_transfer(LOCAL, COLD, nb)
    assert dt == registry().edge(LOCAL, COLD).transfer_s(nb)
    led.charge_transfer(LOCAL, COLD, nb)
    assert led.transferred_bytes(LOCAL, COLD) == 2 * nb
    edge = led.transfers()["local->cold"]
    assert edge["count"] == 2
    assert edge["bytes"] == 2 * nb
    assert edge["modeled_s"] == pytest.approx(2 * dt)
    # edges are directional
    assert led.transferred_bytes(COLD, LOCAL) == 0


def test_charge_transfer_explicit_link_overrides_registry():
    led = MemoryLedger()
    dt = led.charge_transfer(LOCAL, REMOTE, 10**9,
                             bandwidth_gbps=1.0, latency_us=0.0)
    assert dt == pytest.approx(1.0)  # 1 GB over 1 GB/s


def test_cold_edge_is_slower_than_remote_edge():
    """The hierarchy's point: parking pays the flash-bandwidth gap."""
    led = MemoryLedger()
    nb = 1 << 26
    t_remote = led.charge_transfer(LOCAL, REMOTE, nb)
    t_cold = led.charge_transfer(LOCAL, COLD, nb)
    assert t_cold > t_remote
    gap = DEFAULT_TIER_LINKS[REMOTE][0] / DEFAULT_TIER_LINKS[COLD][0]
    assert gap > 10                  # the modeled bandwidth cliff is real


def test_simulator_link_model_shares_the_formula():
    """LinkModel.transfer_time and the ledger charge must route through
    ONE formula (modeled_transfer_s) — measured and simulated transfer
    costs cannot drift apart."""
    from repro.core.latency import LinkModel
    from repro.core.simulator import GB, SystemConfig, fh4

    link = LinkModel(5e-6, 4e12, eff_max=1.0, eff_min=1.0)
    nb = 1 << 28
    assert link.transfer_time(nb) == pytest.approx(modeled_transfer_s(
        nb, bandwidth_gbps=4e12 / GB, latency_us=5.0))
    # the simulator exposes the full hierarchy as link parameters
    links = fh4().tier_links()
    assert list(links) == list(HIERARCHY)
    for bw, lat in links.values():
        assert bw > 0 and lat > 0
    assert links[COLD] == DEFAULT_TIER_LINKS[COLD]


# ---------------------------------------------------------------------------
# swapper: per-tier stash accounting, park/promote moves
# ---------------------------------------------------------------------------

def _tiny_cache():
    shape = (2, 10, PAGE, 2, 4)      # (layers, pages, page, heads, dim)
    k = jnp.arange(np.prod(shape), dtype=jnp.float32).reshape(shape)
    return {"k_pages": k, "v_pages": k + 1.0}


def test_swap_out_to_cold_then_promote_accounts_and_charges():
    led = MemoryLedger()
    sw = PageSwapper(ledger=led)
    cache = _tiny_cache()
    want = np.asarray(cache["k_pages"][:, [2, 5]])

    h = sw.swap_out(cache, [2, 5], tier=COLD)
    nb = h.nbytes
    assert h.tier == COLD and nb > 0
    assert sw.outstanding_bytes == nb
    assert led.in_use(COLD) == nb and led.hwm(COLD) == nb
    assert led.in_use(REMOTE) == 0   # deep preemption skipped remote
    assert led.transferred_bytes(LOCAL, COLD) == nb

    sw.promote(h)                    # cold -> remote (through-remote step)
    assert h.tier == REMOTE and sw.promotes == 1
    assert led.in_use(COLD) == 0 and led.in_use(REMOTE) == nb
    assert led.transferred_bytes(COLD, REMOTE) == nb
    assert led.hwm(COLD) == nb       # the hwm remembers the park

    sw.park(h)                       # and back down
    assert h.tier == COLD and sw.parks == 1
    assert led.transferred_bytes(REMOTE, COLD) == nb

    # a move is accounting + a modeled charge, never a byte rewrite
    sw.promote(h)
    cache = sw.swap_in(cache, [7, 8], h)
    np.testing.assert_array_equal(np.asarray(cache["k_pages"][:, [7, 8]]),
                                  want)
    assert sw.outstanding_bytes == 0
    assert led.in_use(REMOTE) == 0 and led.in_use(COLD) == 0
    assert led.transferred_bytes(REMOTE, LOCAL) == nb


def test_park_same_tier_is_a_no_op_move():
    sw = PageSwapper(ledger=MemoryLedger())
    h = sw.swap_out(_tiny_cache(), [1], tier=COLD)
    before = sw.outstanding_bytes
    sw.park(h)                       # already cold: nothing moves
    assert h.tier == COLD and sw.outstanding_bytes == before
    assert sw.ledger.transferred_bytes(REMOTE, COLD) == 0


def test_park_fault_leaves_stash_in_place():
    led = MemoryLedger()
    sw = PageSwapper(ledger=led, retries=1, backoff_s=0.0)
    h = sw.swap_out(_tiny_cache(), [1, 2])
    assert h.tier == REMOTE
    with fault_plan(FaultPlan(fail_rate=1.0, seed=3)):
        with pytest.raises(TierTransferError):
            sw.park(h)
    assert h.tier == REMOTE          # unmoved
    assert led.in_use(REMOTE) == h.nbytes and led.in_use(COLD) == 0
    assert led.transferred_bytes(REMOTE, COLD) == 0


def test_adopt_respects_handle_tier():
    sw = PageSwapper(ledger=MemoryLedger())
    src = PageSwapper()
    h = src.swap_out(_tiny_cache(), [3], tier=COLD)
    sw.adopt(h)
    assert sw.ledger.in_use(COLD) == h.nbytes
    assert sw.ledger.in_use(REMOTE) == 0
    sw.release(h)
    assert sw.outstanding_bytes == 0


# ---------------------------------------------------------------------------
# policies: the pick_tier seam
# ---------------------------------------------------------------------------

def test_offload_policy_demotes_long_idle_pools():
    p = OffloadBetweenSteps()
    assert p.pick_tier(None) == REMOTE
    assert p.pick_tier({"idle_steps": 0}) == REMOTE
    assert p.pick_tier({"idle_steps": p.cold_after_idle_steps}) == COLD


def test_expert_policy_demotes_rarely_routed_banks():
    p = TopKExpertPrefetch(num_experts=4, top_k=2)
    assert p.pick_tier({"route_fraction": 0.5}) == REMOTE
    assert p.pick_tier({"route_fraction": 0.0}) == COLD
    # 3 hot experts + 1 never routed
    assert p.bank_tiers([100, 100, 100, 0]) == [REMOTE] * 3 + [COLD]


def test_expert_rebalance_moves_ledger_view_not_bytes():
    led = MemoryLedger()
    p = TopKExpertPrefetch(num_experts=4, top_k=2, ledger=led)
    banks = {k: jnp.ones((4, 8), jnp.float32) for k in p.bank_keys}
    nb = sum(4 * 8 * 4 for _ in p.bank_keys)
    per = nb // 4

    chosen = p.rebalance(banks, [100, 100, 100, 0])
    assert chosen[3] == COLD
    assert led.in_use(COLD) == per
    assert led.in_use(REMOTE) == nb - per
    assert led.transferred_bytes(REMOTE, COLD) == per
    # the physical banks are untouched (one stacked array — no retrace)
    assert banks["wi"].shape == (4, 8)

    p.rebalance(banks, [100, 100, 100, 100])    # expert 3 re-warms
    assert led.in_use(COLD) == 0
    assert led.in_use(REMOTE) == nb
    assert led.transferred_bytes(COLD, REMOTE) == per
    assert led.hwm(COLD) == per


# ---------------------------------------------------------------------------
# orchestrator: pick_tier placement + eager degradation recording
# ---------------------------------------------------------------------------

def test_place_uses_pick_tier_and_charges_the_edge():
    m = MemoryOrchestrator.plan(get_config("qwen2.5-14b").reduced())
    m.policies["opt_state"] = OffloadBetweenSteps()
    tree = {"k_pages": np.zeros((2, 8), np.float32),
            "v_pages": np.zeros((2, 8), np.float32)}
    nb = 2 * tree["k_pages"].nbytes
    m.place("opt_state", tree, access_stats={"idle_steps": 10**6})
    assert m.ledger.in_use(COLD) == nb
    assert m.ledger.transferred_bytes(LOCAL, COLD) == nb
    assert "opt_state" not in m.degraded


def test_eager_place_fault_records_degradation():
    """Satellite contract: the generic eager placement fallback records
    ``degraded["<class>"]`` exactly like place_kv_pool does."""
    m = MemoryOrchestrator.plan(get_config("qwen2.5-14b").reduced())
    m.policies["opt_state"] = OffloadBetweenSteps()
    tree = {"k_pages": np.zeros((2, 8), np.float32)}
    with fault_plan(FaultPlan(fail_first_n=16)):
        placed = m.place("opt_state", tree,
                         access_stats={"idle_steps": 10**6})
    assert "opt_state" in m.degraded
    assert "local residency" in m.degraded["opt_state"]
    np.testing.assert_array_equal(np.asarray(placed["k_pages"]),
                                  tree["k_pages"])
    # the fallback residency landed LOCAL, not in the faulty tier
    assert m.ledger.in_use(LOCAL) >= tree["k_pages"].nbytes
    assert m.ledger.in_use(COLD) == 0


# ---------------------------------------------------------------------------
# serving: cold-parked victims resume bit-identically
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("temp", [0.0, 0.7])
def test_deep_preemption_to_cold_bit_identical(tiny_model, temp):
    """cold_park_after_blocks=0: victims stash DIRECTLY in the cold
    tier, promote through remote on resume, and every token matches the
    uncontended run."""
    ref_srv = _server(tiny_model, temperature=temp)
    ref = _submit_three(ref_srv)
    _drive(ref_srv, ref)

    srv = _server(tiny_model, temperature=temp, num_pages=SMALL_POOL,
                  cold_park_after_blocks=0)
    got = _submit_three(srv)
    _drive(srv, got)
    assert srv.stats["preemptions"] >= 1
    assert srv.stats["cold_parks"] >= 1
    assert srv.stats["cold_promotes"] == srv.stats["cold_parks"]
    assert srv.stats["sheds"] == 0
    for a, b in zip(ref, got):
        assert a.output == b.output, (temp, a.uid, a.output, b.output)
        assert b.error is None
    xfers = srv.mem.ledger.transfers()
    assert xfers["local->cold"]["bytes"] > 0
    assert xfers["cold->remote"]["bytes"] > 0
    assert xfers["remote->local"]["bytes"] > 0   # the swap-in leg
    # deep preemption never staged the victim in the remote tier
    assert "local->remote" not in xfers


@pytest.mark.parametrize("temp", [0.0, 0.7])
def test_age_based_park_sweep_bit_identical(tiny_model, temp):
    """cold_park_after_blocks=N>0: stashes start remote and the sweep
    demotes them once they age past N decode blocks — tokens still
    bit-identical."""
    ref_srv = _server(tiny_model, temperature=temp)
    ref = _submit_three(ref_srv)
    _drive(ref_srv, ref)

    srv = _server(tiny_model, temperature=temp, num_pages=SMALL_POOL,
                  cold_park_after_blocks=1)
    got = _submit_three(srv)
    _drive(srv, got)
    assert srv.stats["preemptions"] >= 1
    assert srv.stats["cold_parks"] >= 1, srv.stats
    assert srv.stats["cold_promotes"] == srv.stats["cold_parks"]
    for a, b in zip(ref, got):
        assert a.output == b.output, (temp, a.uid, a.output, b.output)
    xfers = srv.mem.ledger.transfers()
    assert xfers["local->remote"]["bytes"] > 0   # stashed remote first
    assert xfers["remote->cold"]["bytes"] > 0    # then swept down


def test_disabled_cold_parking_means_zero_drift(tiny_model):
    """cold_park_after_blocks=None is the pre-hierarchy behavior: same
    tokens, zero cold-tier traffic."""
    srv = _server(tiny_model, num_pages=SMALL_POOL)
    # the module-scoped model shares ONE orchestrator ledger across tests,
    # so assert no NEW cold traffic rather than a globally clean ledger
    before = {k: v["bytes"] for k, v in srv.mem.ledger.transfers().items()
              if "cold" in k}
    got = _submit_three(srv)
    _drive(srv, got)
    assert srv.stats["preemptions"] >= 1
    assert srv.stats["cold_parks"] == 0
    assert srv.stats["cold_promotes"] == 0
    after = {k: v["bytes"] for k, v in srv.mem.ledger.transfers().items()
             if "cold" in k}
    assert after == before, (before, after)


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8_e4m3"])
def test_cold_park_quantized_bit_identical(kv_dtype):
    """Quantized pools cold-park their stashes (values + bf16 scales)
    byte-verbatim: quantized-vs-quantized stays bit-identical."""
    cfg = get_config("qwen2.5-14b").reduced()
    cfg = dataclasses.replace(cfg, remat=False, page_size=PAGE,
                              kv_dtype=kv_dtype)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qm = (model, params)
    ref_srv = _server(qm, temperature=0.7)
    ref = _submit_three(ref_srv)
    _drive(ref_srv, ref)

    srv = _server(qm, temperature=0.7, num_pages=SMALL_POOL,
                  cold_park_after_blocks=0)
    got = _submit_three(srv)
    _drive(srv, got)
    assert srv.stats["cold_parks"] >= 1
    assert [r.output for r in ref] == [r.output for r in got]


def test_cold_park_with_prefix_sharing_bit_identical(tiny_model):
    """Prefix-shared pages stash to cold and restore private — tokens
    must not notice."""
    sys_toks = np.arange(3, 15, dtype=np.int32)        # 3 whole pages

    def submit_all(server):
        return [server.submit(
            np.concatenate([sys_toks, np.asarray([50 + i, 60 + i],
                                                 np.int32)]),
            max_new_tokens=16) for i in range(3)]

    ref_srv = _server(tiny_model, temperature=0.7, prefix_cache=True)
    ref = submit_all(ref_srv)
    _drive(ref_srv, ref)

    srv = _server(tiny_model, temperature=0.7, prefix_cache=True,
                  num_pages=SMALL_POOL, cold_park_after_blocks=0)
    got = submit_all(srv)
    _drive(srv, got)
    assert srv.stats["preemptions"] >= 1
    assert srv.stats["cold_parks"] >= 1
    assert [r.output for r in ref] == [r.output for r in got]


# ---------------------------------------------------------------------------
# snapshot/restart: the stash's tier round-trips
# ---------------------------------------------------------------------------

def test_snapshot_restore_preserves_cold_tier(tiny_model, tmp_path):
    """A server killed with a cold-parked victim restores the stash in
    the SAME tier and finishes bit-identically (disk round trip
    included)."""
    ref_srv = _server(tiny_model, temperature=0.7, num_pages=SMALL_POOL)
    ref = _submit_three(ref_srv)
    _drive(ref_srv, ref)

    srv = _server(tiny_model, temperature=0.7, num_pages=SMALL_POOL,
                  cold_park_after_blocks=0)
    reqs = _submit_three(srv)
    early = []
    for _ in range(20):
        early += srv.run_once(max_blocks=1)
        if srv._preempted:
            break
    assert srv._preempted, "scenario never preempted"
    assert srv._preempted[0].handle.tier == COLD
    snap = ft.snapshot_server(srv)
    # live slots serialize through a read-out stash (tier remote); the
    # parked victim's entry must carry its COLD tier
    by_tier = [s.get("tier") for s in snap["sequences"] if s.get("tier")]
    assert COLD in by_tier, by_tier
    path = ft.save_server_snapshot(tmp_path / "cold_ckpt", snap)
    del srv                                      # the "crash"

    srv2 = _server(tiny_model, temperature=0.7, num_pages=SMALL_POOL,
                   cold_park_after_blocks=0)
    ft.restore_server(srv2, ft.load_server_snapshot(path))
    assert any(ps.handle.tier == COLD for ps in srv2._preempted)
    finished = list(early)
    for _ in range(50):
        finished += srv2.run_once()
        if len(finished) == 3:
            break
    by_uid = {r.uid: r for r in finished}
    assert len(by_uid) == 3
    for a in ref:
        b = by_uid[a.uid]
        assert a.output == b.output, (a.uid, a.output, b.output)
        assert b.error is None
    # the restored stash promoted through remote on its resume
    assert srv2.stats["cold_promotes"] >= 1


# ---------------------------------------------------------------------------
# tensor-parallel: cold parking across a model-sharded pool
# ---------------------------------------------------------------------------

SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import dataclasses
import jax, numpy as np
from repro.configs import get_config, build_model
from repro.launch.mesh import make_serving_mesh
from repro.runtime.serve import BatchedServer

cfg = get_config("qwen2.5-14b").reduced()
cfg = dataclasses.replace(cfg, remat=False, page_size=4)
params = build_model(cfg).init(jax.random.PRNGKey(0))
mesh = make_serving_mesh(model=2)

def serve(num_pages, cold_park):
    srv = BatchedServer(build_model(cfg), params, batch_size=3, max_seq=64,
                        page_size=4, num_pages=num_pages, temperature=0.7,
                        paged=True, mesh=mesh, audit=True,
                        cold_park_after_blocks=cold_park)
    reqs = [srv.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=24)
            for _ in range(3)]
    for _ in range(50):
        srv.run_once()
        if all(r.done.is_set() for r in reqs):
            break
    return [tuple(r.output) for r in reqs], srv

ref, _ = serve(None, None)                 # uncontended
got, srv = serve(18, 0)                    # oversubscribed -> cold park
assert srv.stats["model_shards"] == 2
assert srv.stats["preemptions"] >= 1, srv.stats
assert srv.stats["cold_parks"] >= 1, srv.stats
assert srv.stats["cold_promotes"] == srv.stats["cold_parks"], srv.stats
assert got == ref, f"sharded cold-park diverged:\n  {ref}\n  {got}"
print("SHARDED_COLD_PARK_OK")
"""


@pytest.mark.slow
def test_sharded_cold_park_bit_identical():
    """Cold park/promote must round-trip a model-sharded block pool
    (the stash gather/scatter crosses the "model" axis) without changing
    a single token."""
    import os
    import subprocess
    import sys
    from pathlib import Path

    src = str(Path(__file__).resolve().parent.parent / "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT, src],
                         env=env, capture_output=True, text=True,
                         timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "SHARDED_COLD_PARK_OK" in out.stdout
