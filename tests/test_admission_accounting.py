"""Property tests for paged admission accounting: worst-case page
reservations (``_worst_pages`` / ``_admission_pages_ready``), the
prefix-sharing eligibility rule (``_shareable_pages``) at page-boundary
and ``max_seq``-clamp edges, and the admission-ordering contract under
preemption churn (FIFO is never overtaken by preemption-freed pages;
victims always resume).  Pure host math — the churn harness drives the
REAL scheduler methods against fakes for the device-touching steps."""
import dataclasses
import functools
import queue as queue_mod

import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 runs without hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs import build_model, get_config
from repro.kernels.paged_attention.ops import BlockManager
from repro.runtime.serve import BatchedServer, Request, _Preempted

MAX_SEQ = 64
PAGE = 4


@functools.lru_cache(maxsize=1)
def _server() -> BatchedServer:
    cfg = get_config("qwen2.5-14b").reduced()
    cfg = dataclasses.replace(cfg, remat=False, page_size=PAGE)
    model = build_model(cfg)
    return BatchedServer(model, model.init(jax.random.PRNGKey(0)),
                         batch_size=2, max_seq=MAX_SEQ, paged=True)


def _valid(plen: int, mnt: int) -> bool:
    """Would submit() accept this (prompt + decode budget fits)?"""
    return plen + max(mnt - 1, 0) <= MAX_SEQ


@given(plen=st.integers(1, MAX_SEQ), mnt=st.integers(0, MAX_SEQ))
@settings(max_examples=60, deadline=None)
def test_worst_pages_covers_every_write_and_respects_max_seq(plen, mnt):
    srv = _server()
    if not _valid(plen, mnt):
        return
    worst = srv._worst_pages(plen, mnt)
    plen_adm = srv._admit_plen(plen, mnt)
    # bucketing only ever pads the prompt, and never past the point
    # where a decode write could land outside the cache
    assert plen_adm >= plen
    assert plen_adm + max(mnt - 1, 0) <= MAX_SEQ or plen_adm == plen
    # the reservation covers the admitted prompt AND the whole decode
    # budget, clamped at max_seq (positions past it are never written)
    lifetime_tokens = min(plen_adm + max(mnt - 1, 0), MAX_SEQ)
    assert worst == srv.manager.pages_for(lifetime_tokens)
    assert worst <= srv.manager.pages_for(MAX_SEQ)      # max_seq clamp
    assert worst >= srv.manager.pages_for(plen)         # prompt fits


@pytest.mark.parametrize("plen,mnt", [
    (PAGE, 0), (PAGE, 1), (2 * PAGE, 0), (2 * PAGE, 1),     # page edges
    (PAGE + 1, 1), (MAX_SEQ, 1), (MAX_SEQ - 1, 2),          # clamp edges
])
def test_worst_pages_boundary_cases(plen, mnt):
    srv = _server()
    worst = srv._worst_pages(plen, mnt)
    plen_adm = srv._admit_plen(plen, mnt)
    assert worst == srv.manager.pages_for(
        min(plen_adm + max(mnt - 1, 0), MAX_SEQ))
    if mnt <= 1:
        # no decode writes beyond the sampled-at-admission token: the
        # reservation is exactly the admitted prompt's pages
        assert worst == srv.manager.pages_for(plen_adm)


@given(reqs=st.lists(st.integers(1, MAX_SEQ), min_size=1, max_size=24))
@settings(max_examples=30, deadline=None)
def test_admission_gate_never_oversubscribes(reqs):
    """Follow the gate exactly as _admit_from_queue does: a request is
    admitted only when its worst case fits beside every live
    reservation — so total reservations can never exceed capacity, and
    an admitted request can never hit mid-decode pool exhaustion."""
    srv = _server()
    srv._reserved = {}
    cap = srv.manager.capacity
    slot = 0
    for plen in reqs:
        mnt = (plen % 7) + 1                   # deterministic budget mix
        if not _valid(plen, mnt):
            continue
        req = Request(uid=slot, prompt=np.zeros(plen, np.int32),
                      max_new_tokens=mnt)
        if srv._admission_pages_ready(req):
            srv._reserved[slot] = srv._worst_pages(plen, mnt)
            slot += 1
        assert sum(srv._reserved.values()) <= cap
        if slot and slot % 5 == 0:             # periodic reclamation
            srv._reserved.pop(min(srv._reserved), None)
    srv._reserved = {}


@given(plen=st.integers(1, MAX_SEQ))
@settings(max_examples=40, deadline=None)
def test_shareable_pages_never_cover_a_written_position(plen):
    """Shared prompt pages must lie strictly before the last prompt
    token: admission always keeps at least one suffix token to prefill,
    and decode's first write (position >= plen) can never land in a
    shared page."""
    srv = _server()
    n = srv._shareable_pages(plen)
    assert n == (plen - 1) // PAGE             # maximal whole pages
    assert n * PAGE <= plen - 1                # excludes the last token
    # decode writes start at position >= plen, strictly past the shared
    # region [0, n*PAGE)
    assert n * PAGE < plen
    if plen % PAGE == 0:
        # page-boundary edge: the final FULL page still stays private
        assert n == plen // PAGE - 1


# ---------------------------------------------------------------------------
# admission ordering under preemption churn
# ---------------------------------------------------------------------------

class _SchedHarness(BatchedServer):
    """The REAL scheduler (``_admit_from_queue`` and the whole victim
    selection / resume-gating machinery run unmodified) over a real
    :class:`BlockManager`, with only the device-touching steps faked as
    host bookkeeping — so admission-ordering properties can be driven
    through thousands of churn schedules without a single dispatch."""

    def __init__(self, *, batch: int = 3, num_pages: int = 12,
                 policy: str = "lru"):
        # deliberately no super().__init__ — no model, no device state;
        # _init_sched_state is the scheduler's OWN definition of the
        # host state it needs, so the harness can never drift from it
        self.paged = True
        self.preempt_enabled = True
        self.preempt_policy = policy
        self.prefix_cache = False
        self.max_seq = MAX_SEQ
        self.batch = batch
        self.page_size = PAGE
        self.manager = BlockManager(num_pages, PAGE)
        self.slots: list[Request | None] = [None] * batch
        self._init_sched_state(batch)
        self.events: list[tuple[str, int]] = []

    # ----- fakes for the device-touching steps -----------------------------
    def _admit(self, req: Request, slot: int) -> bool:
        self._reserved[slot] = self._worst_pages(len(req.prompt),
                                                 req.max_new_tokens)
        plen = self._admit_plen(len(req.prompt), req.max_new_tokens)
        self.manager.ensure(slot, plen)
        self.manager.note_tokens(slot, plen)
        req.pos = plen                               # host-side position
        req.output.append(0)                         # admission token
        self.slots[slot] = req
        self._last_sched[slot] = self._sched_counter
        self._sched_counter += 1
        self.events.append(("admit", req.uid))
        return False

    def _preempt_slot(self, i: int, finished: list[Request]) -> None:
        req = self.slots[i]
        self._preempted.append(_Preempted(req=req, pos=req.pos,
                                          handle=None, key=None))
        self.manager.free_slot(i)
        self._reserved.pop(i, None)
        self.slots[i] = None
        self.events.append(("preempt", req.uid))

    def _resume(self, ps: _Preempted, slot: int,
                finished: list[Request]) -> bool:
        self._reserved[slot] = self._resume_worst(ps)
        try:
            self.manager.ensure(slot, ps.pos)
        except MemoryError:
            self._reserved.pop(slot, None)
            return False
        self.manager.note_tokens(slot, ps.pos)
        self.slots[slot] = ps.req
        self._last_sched[slot] = self._sched_counter
        self._sched_counter += 1
        self.events.append(("resume", ps.req.uid))
        return True

    def _evict_slot(self, i: int) -> None:
        # the real one also deactivates the device slot; host-side the
        # page/reservation release is the whole story
        req = self.slots[i]
        self.manager.free_slot(i)
        self._reserved.pop(i, None)
        self.slots[i] = None
        self._planned[i] = 0
        self.events.append(("evict", req.uid))

    # ----- churn driver -----------------------------------------------------
    def decode_tick(self, finished: list[Request]) -> None:
        """One decode block's worth of host bookkeeping: every live slot
        emits a token (growing its pages on demand, as dispatch does)
        and finished slots reclaim.  Advances the server's block clock —
        deadlines and handoff leases run on it."""
        self.stats["blocks"] += 1
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.pos += 1
            req.output.append(0)
            self.manager.ensure(i, min(req.pos, self.max_seq))
            self.manager.note_tokens(i, min(req.pos, self.max_seq))
            if len(req.output) >= req.max_new_tokens:
                self.manager.free_slot(i)
                self._reserved.pop(i, None)
                self.slots[i] = None
                self._finalize(req, "completed", finished)
                self.events.append(("finish", req.uid))

    def check_invariants(self) -> None:
        self.manager.audit()
        assert sum(self._reserved.values()) <= self.manager.capacity, \
            (self._reserved, self.manager.capacity)
        # every live slot's remaining lifetime is covered by its
        # reservation (the no-mid-decode-exhaustion guarantee)
        for i, req in enumerate(self.slots):
            if req is not None:
                assert len(self.manager.slot_pages(i)) <= self._reserved[i]
        # ...and every in-flight prefill's pages by its pseudo-slot
        # reservation, held in full from the moment it STARTED
        if self.prefill is not None:
            for inf in self.prefill.inflight:
                assert len(self.manager.slot_pages(inf.slot)) \
                    <= self._reserved[inf.slot], (inf.slot, self._reserved)


def _run_churn(shapes: list[tuple[int, int]], schedule: list[int],
               policy: str = "lru") -> _SchedHarness:
    srv = _SchedHarness(policy=policy)
    pending = [Request(uid=u, prompt=np.zeros(p, np.int32),
                       max_new_tokens=m)
               for u, (p, m) in enumerate(shapes)
               if p + max(m - 1, 0) <= MAX_SEQ]
    for r in pending:
        r.pos = 0
    todo = list(pending)
    finished: list[Request] = []
    for op in schedule:
        if op == 0 and todo:
            srv.queue.put(todo.pop(0))
        else:
            srv.decode_tick(finished)
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
    while todo:                               # drain: submit stragglers...
        srv.queue.put(todo.pop(0))
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
    for _ in range(400):                      # ...then decode to done
        if len(finished) == len(pending):
            break
        srv.decode_tick(finished)
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
    assert len(finished) == len(pending), (
        f"starved: {len(finished)}/{len(pending)} finished, "
        f"preempted={[(p.req.uid) for p in srv._preempted]}, "
        f"backlog={[r.uid for r in srv._backlog]}, events={srv.events}")
    return srv


@given(shapes=st.lists(st.tuples(st.integers(1, 12), st.integers(2, 12)),
                       min_size=3, max_size=10),
       schedule=st.lists(st.integers(0, 1), min_size=10, max_size=80))
@settings(max_examples=40, deadline=None)
def test_admission_fifo_never_overtaken_by_preemption(shapes, schedule):
    """Under arbitrary submit/decode interleavings with preemption on,
    first-time admission stays strictly FIFO: pages freed by preempting
    a victim admit the backlog HEAD, never a younger request — and no
    request starves (every victim resumes and finishes)."""
    srv = _run_churn(shapes, schedule)
    first_admits = [uid for kind, uid in srv.events if kind == "admit"]
    assert first_admits == sorted(first_admits), srv.events
    assert len(set(first_admits)) == len(first_admits)
    # a preempted uid always resumes (and may be preempted again, but
    # its resume count keeps up: no victim is left swapped out)
    assert not srv._preempted
    for uid in {u for k, u in srv.events if k == "preempt"}:
        kinds = [k for k, u in srv.events if u == uid]
        assert kinds.count("resume") == kinds.count("preempt"), srv.events
        assert kinds[-1] == "finish"


@given(shapes=st.lists(st.tuples(st.integers(1, 12), st.integers(2, 12)),
                       min_size=3, max_size=8),
       schedule=st.lists(st.integers(0, 1), min_size=10, max_size=60),
       policy=st.sampled_from(["fewest_pages", "lowest_progress"]))
@settings(max_examples=15, deadline=None)
def test_admission_ordering_holds_for_every_victim_policy(shapes, schedule,
                                                          policy):
    """The FIFO/no-starvation contract is policy-independent: victim
    selection changes WHO pays for the head's admission, never who
    admits next."""
    srv = _run_churn(shapes, schedule, policy=policy)
    first_admits = [uid for kind, uid in srv.events if kind == "admit"]
    assert first_admits == sorted(first_admits), srv.events
    assert not srv._preempted


def test_resume_fifo_beats_backlog():
    """A swapped-out victim is older than every queued request: when
    pages free up, the victim resumes BEFORE the backlog head admits."""
    srv = _SchedHarness()
    finished: list[Request] = []
    reqs = [Request(uid=u, prompt=np.zeros(4, np.int32), max_new_tokens=10)
            for u in range(4)]
    for r in reqs:
        r.pos = 0
        srv.queue.put(r)
    srv._admit_from_queue(finished, allow_preempt=True)
    assert [k for k, _ in srv.events].count("admit") >= 2
    # force a preemption for the head, then finish a live slot: the
    # resulting free pages must go to the victim first
    while not any(k == "preempt" for k, _ in srv.events):
        srv.decode_tick(finished)
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
        if len(finished) == len(reqs):
            pytest.skip("pool large enough that nothing preempted")
    victim = next(u for k, u in srv.events if k == "preempt")
    for _ in range(400):
        srv.decode_tick(finished)
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
        if len(finished) == len(reqs):
            break
    ev = srv.events
    resume_at = ev.index(("resume", victim))
    later_admits = [u for k, u in ev[resume_at:] if k == "admit"]
    preempt_at = ev.index(("preempt", victim))
    admits_between = [u for k, u in ev[preempt_at:resume_at] if k == "admit"]
    # only the head the victim was preempted FOR may admit in between
    assert len(admits_between) <= 1, ev
    assert all(u > victim for u in admits_between + later_admits), ev
    assert len(finished) == len(reqs)


# ---------------------------------------------------------------------------
# admission fairness under ASYNC prefill (the disaggregated engine)
# ---------------------------------------------------------------------------

class _HostPrefillEngine:
    """Host-bookkeeping double of :class:`repro.runtime.prefill
    .PrefillEngine` exposing the exact scheduling surface
    ``_async_admission`` drives (``start`` / ``pump_once`` / ``ready``
    / ``inflight`` / ``idle``) over the REAL BlockManager and the REAL
    reservation dict — only the prefill dispatch and the staging
    round-trip are faked."""

    @dataclasses.dataclass
    class _Inflight:
        req: Request
        slot: int
        plen: int
        done: int
        toks: np.ndarray = None          # padded prompt (prefix sharing)

    @dataclasses.dataclass
    class _Handoff:
        req: Request
        plen: int
        token: int
        pslot: int
        lease_expiry_block: int = 0
        handle: object = None            # no staged bytes host-side

    def __init__(self, srv, *, chunk_tokens: int = PAGE, max_inflight=2):
        import collections
        self.srv = srv
        self.chunk_tokens = chunk_tokens
        self.max_inflight = max_inflight
        self.inflight: list[_HostPrefillEngine._Inflight] = []
        self.ready = collections.deque()
        self._rr = 0

    @property
    def idle(self):
        return not self.inflight and not self.ready

    def crash(self) -> None:
        """Mirror of PrefillEngine.crash: in-flight prefills orphan
        their partial pages, staged handoffs keep their leases."""
        srv = self.srv
        for inf in self.inflight:
            srv._orphan_prefills.append((inf.slot, inf.req))
        self.inflight.clear()
        while self.ready:
            srv._orphan_handoffs.append(self.ready.popleft())
        srv.stats["engine_crashes"] += 1
        srv.events.append(("crash", -1))

    def start(self, req: Request) -> None:
        srv = self.srv
        slot = -1000 - req.uid
        srv._reserved[slot] = srv._worst_pages(len(req.prompt),
                                               req.max_new_tokens)
        plen = srv._admit_plen(len(req.prompt), req.max_new_tokens)
        toks = np.zeros((1, plen), np.int32)
        toks[0, plen - len(req.prompt):] = req.prompt
        shared = (srv._shared_prefix_pages(toks, plen)
                  if srv.prefix_cache else [])
        if shared:
            srv.manager.adopt(slot, shared)
            srv.stats["prefix_hits"] += 1
            srv.stats["prefix_shared_pages"] += len(shared)
        self.inflight.append(self._Inflight(req, slot, plen,
                                            len(shared) * PAGE, toks))
        srv.events.append(("start", req.uid))

    def pump_once(self, finished: list) -> bool:
        if not self.inflight:
            return False
        srv = self.srv
        inf = self.inflight[self._rr % len(self.inflight)]
        self._rr += 1
        chunk = min(self.chunk_tokens, inf.plen - inf.done)
        try:
            srv.manager.ensure(inf.slot, inf.done + chunk)
        except MemoryError:
            return False
        inf.done += chunk
        srv.manager.note_tokens(inf.slot, inf.done)
        if inf.done >= inf.plen:
            self.inflight.remove(inf)
            if srv.prefix_cache:
                srv._register_prefix(inf.toks, inf.plen, inf.slot)
            tok = srv.manager.detach_to_handoff(inf.slot)
            self.ready.append(self._Handoff(
                inf.req, inf.plen, tok, inf.slot,
                lease_expiry_block=(srv.stats["blocks"]
                                    + srv.handoff_lease_blocks)))
            srv.events.append(("handoff", inf.req.uid))
        return True


class _AsyncSchedHarness(_SchedHarness):
    """The REAL ``_async_admission`` loop (FIFO starts behind the page
    gate, one pump per round, handoff adoption) over the host engine —
    with only :meth:`_adopt_handoff`'s device splice faked."""

    def __init__(self, *, chunk_tokens: int = PAGE, **kw):
        super().__init__(**kw)
        self.prefill = _HostPrefillEngine(self, chunk_tokens=chunk_tokens)

    def _adopt_handoff(self, h, slot: int, finished: list) -> None:
        self.manager.adopt_from_handoff(slot, h.token)
        self._reserved[slot] = self._reserved.pop(h.pslot)
        h.req.pos = h.plen
        h.req.output.append(0)                       # first token
        self.slots[slot] = h.req
        self._sched_counter += 1
        self._last_sched[slot] = self._sched_counter
        self.events.append(("admit", h.req.uid))


def _run_async_churn(shapes: list[tuple[int, int]], schedule: list[int],
                     **kw) -> _AsyncSchedHarness:
    srv = _AsyncSchedHarness(**kw)
    pending = [Request(uid=u, prompt=np.zeros(p, np.int32),
                       max_new_tokens=m)
               for u, (p, m) in enumerate(shapes)
               if p + max(m - 1, 0) <= MAX_SEQ]
    for r in pending:
        r.pos = 0
    todo = list(pending)
    finished: list[Request] = []
    for op in schedule:
        if op == 0 and todo:
            srv.queue.put(todo.pop(0))
        else:
            srv.decode_tick(finished)
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
    while todo:
        srv.queue.put(todo.pop(0))
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
    for _ in range(600):
        if len(finished) == len(pending):
            break
        srv.decode_tick(finished)
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
    assert len(finished) == len(pending), (
        f"starved: {len(finished)}/{len(pending)} finished, "
        f"inflight={[i.req.uid for i in srv.prefill.inflight]}, "
        f"ready={[h.req.uid for h in srv.prefill.ready]}, "
        f"backlog={[r.uid for r in srv._backlog]}, events={srv.events}")
    return srv


@given(shapes=st.lists(st.tuples(st.integers(1, 12), st.integers(2, 12)),
                       min_size=3, max_size=10),
       schedule=st.lists(st.integers(0, 1), min_size=10, max_size=80))
@settings(max_examples=40, deadline=None)
def test_async_prefill_starts_stay_fifo_and_nothing_starves(shapes,
                                                            schedule):
    """Async-engine admission under churn: prefill STARTS are strictly
    FIFO (the page gate never lets a younger request overtake the
    backlog head), completions may land out of order, every request
    still finishes, and the allocator + reservation invariants hold
    after every step."""
    srv = _run_async_churn(shapes, schedule)
    starts = [uid for kind, uid in srv.events if kind == "start"]
    assert starts == sorted(starts), srv.events
    assert len(set(starts)) == len(starts)
    assert srv.prefill.idle and not srv._preempted
    # every started prefill handed off and adopted exactly once
    for uid in starts:
        kinds = [k for k, u in srv.events if u == uid]
        assert kinds.count("handoff") == 1, srv.events
        assert kinds.count("admit") == 1, srv.events


def test_out_of_order_completion_cannot_starve_earlier_start():
    """With decode work pending (one chunk per scheduling round), a
    long prompt starts prefilling FIRST; a short one behind it
    completes first and adopts the only free slot — the long prompt's
    worst-case reservation (held since its start) must survive the
    overtaking completion, so it always finishes."""
    srv = _AsyncSchedHarness(batch=2, num_pages=40, chunk_tokens=PAGE)
    finished: list[Request] = []
    # a steady decoder keeps decode dispatchable for the whole churn —
    # otherwise the idle-burst path batches both prefills before any
    # adoption and there is no overtaking to observe
    steady = Request(uid=9, prompt=np.zeros(2, np.int32),
                     max_new_tokens=40)
    steady.pos = 0
    srv.queue.put(steady)
    srv._admit_from_queue(finished, allow_preempt=True)
    srv.check_invariants()
    assert ("admit", 9) in srv.events and srv._can_dispatch()
    long_req = Request(uid=0, prompt=np.zeros(24, np.int32),
                       max_new_tokens=4)
    short_req = Request(uid=1, prompt=np.zeros(4, np.int32),
                        max_new_tokens=4)
    for r in (long_req, short_req):
        r.pos = 0
        srv.queue.put(r)
    long_pslot, long_worst = -1000, srv._worst_pages(24, 4)
    # one chunk advances per round while decode is pending; the short
    # prompt (1 chunk) completes long before the long one (6 chunks)
    for _ in range(40):
        if ("admit", 1) in srv.events:
            break
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
        srv.decode_tick(finished)
    starts = [u for k, u in srv.events if k == "start"]
    assert starts == [9, 0, 1]                     # FIFO starts
    # the short prompt overtook the long one to the handoff AND the
    # slot...
    assert ("handoff", 1) in srv.events
    assert ("handoff", 0) not in srv.events    # long still mid-prefill
    assert ("admit", 1) in srv.events
    assert ("admit", 0) not in srv.events
    # ...but the long prompt's start-time reservation is still pinned
    # under its pseudo-slot at full worst case — the overtaker spent
    # its own budget, not the head's
    assert srv._reserved.get(long_pslot) == long_worst
    while ("admit", 0) not in srv.events:
        assert srv._reserved.get(long_pslot) == long_worst
        srv.decode_tick(finished)
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
    for _ in range(200):
        if len(finished) == 3:
            break
        srv.decode_tick(finished)
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
    assert {r.uid for r in finished} == {0, 1, 9}, srv.events


@given(shapes=st.lists(st.tuples(st.integers(1, 12), st.integers(2, 12)),
                       min_size=3, max_size=8),
       schedule=st.lists(st.integers(0, 1), min_size=10, max_size=60),
       policy=st.sampled_from(["fewest_pages", "lowest_progress"]))
@settings(max_examples=15, deadline=None)
def test_async_prefill_fairness_holds_under_preemption(shapes, schedule,
                                                       policy):
    """Preemption churn + async engine: victim selection changes who
    pays for the head's pages, never the FIFO start order — and every
    victim resumes."""
    srv = _run_async_churn(shapes, schedule, policy=policy)
    starts = [uid for kind, uid in srv.events if kind == "start"]
    assert starts == sorted(starts), srv.events
    assert not srv._preempted
    for uid in {u for k, u in srv.events if k == "preempt"}:
        kinds = [k for k, u in srv.events if u == uid]
        assert kinds.count("resume") == kinds.count("preempt"), srv.events


# ---------------------------------------------------------------------------
# request lifecycle: engine crashes, handoff leases, deadlines, overload
# ---------------------------------------------------------------------------

def _assert_fully_reclaimed(srv) -> None:
    """Zero-leak postcondition after a full drain: allocator audit
    clean, no page allocated anywhere (handoffs included), no dangling
    reservation or crash-recovery state, pending demand view empty."""
    srv.manager.audit()
    assert srv.manager.pages_in_use == 0, srv.manager.pages
    assert srv.manager.handoff_pages == 0
    assert not srv._reserved, srv._reserved
    assert not srv._orphan_prefills and not srv._orphan_handoffs
    assert srv._pending_count == 0 and srv._pending_pages == 0


def _drive_to_drain(srv, pending, finished, rounds=800) -> None:
    for _ in range(rounds):
        if all(r.done.is_set() for r in pending):
            break
        srv.decode_tick(finished)
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
    assert all(r.done.is_set() for r in pending), (
        f"wedged: {[r.uid for r in pending if not r.done.is_set()]}, "
        f"events={srv.events}")


@given(shapes=st.lists(st.tuples(st.integers(1, 12), st.integers(2, 12)),
                       min_size=3, max_size=8),
       schedule=st.lists(st.integers(0, 1), min_size=6, max_size=40),
       crash_round=st.integers(0, 45),
       lease=st.integers(1, 8),
       share=st.booleans())
@settings(max_examples=40, deadline=None)
def test_prefill_crash_reclaims_requeues_and_leaks_nothing(
        shapes, schedule, crash_round, lease, share):
    """Crash the prefill engine at an arbitrary churn point — mid-chunk
    prefills and staged (possibly prefix-sharing) handoffs alike.  The
    watchdog must reclaim every orphaned page (partial prefills at
    once, staged handoffs after their lease) and requeue the victims;
    every request still finishes, with the allocator audit clean after
    every step and zero pages/reservations/pending leaked at the end."""
    srv = _AsyncSchedHarness()
    srv.prefix_cache = share
    srv.handoff_lease_blocks = lease
    pending = [Request(uid=u, prompt=np.arange(p, dtype=np.int32) % 7,
                       max_new_tokens=m)
               for u, (p, m) in enumerate(shapes)
               if p + max(m - 1, 0) <= MAX_SEQ]
    for r in pending:
        r.pos = 0
    todo = list(pending)
    finished: list[Request] = []
    for rnd, op in enumerate(schedule + [1] * (crash_round + 1)):
        if rnd == crash_round:
            srv.prefill.crash()
        if op == 0 and todo:
            srv.queue.put(todo.pop(0))
        else:
            srv.decode_tick(finished)
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
    while todo:
        srv.queue.put(todo.pop(0))
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
    _drive_to_drain(srv, pending, finished)
    for r in pending:       # a crash sheds nothing: every victim retried
        assert r.error is None, r.error
    if ("crash", -1) in srv.events:
        assert srv.stats["engine_crashes"] == 1
    _assert_fully_reclaimed(srv)


def test_lease_expiry_reclaims_staged_handoff_and_retries():
    """A handoff staged while every decode slot is busy must not pin
    its pool pages forever: once its lease runs out the watchdog
    releases the registry entry and requeues the victim, which later
    admits normally and finishes."""
    srv = _AsyncSchedHarness(batch=2, num_pages=40)
    srv.handoff_lease_blocks = 3
    finished: list[Request] = []
    # two long decoders occupy both slots for many blocks
    busy = [Request(uid=u, prompt=np.zeros(2, np.int32), max_new_tokens=30)
            for u in (0, 1)]
    late = Request(uid=2, prompt=np.zeros(4, np.int32), max_new_tokens=4)
    for r in busy + [late]:
        r.pos = 0
    for r in busy:
        srv.queue.put(r)
    srv._admit_from_queue(finished, allow_preempt=True)
    assert all(s is not None for s in srv.slots)
    srv.queue.put(late)
    # pump the prefill to a staged handoff (no free slot to adopt into),
    # then sit past the lease: the watchdog must reclaim + requeue
    for _ in range(10):
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
        if srv.stats["lease_reclaims"]:
            break
        srv.decode_tick(finished)
    assert srv.stats["lease_reclaims"] >= 1, srv.events
    assert srv.stats["crash_requeues"] >= 1
    assert srv.manager.handoff_pages == 0       # registry entry released
    _drive_to_drain(srv, busy + [late], finished)
    assert late.error is None and len(late.output) == late.max_new_tokens
    _assert_fully_reclaimed(srv)


def test_lease_reclaim_of_prefix_sharing_handoff_keeps_sharer_pages():
    """Lease-expiry x prefix-sharing: reclaiming an orphaned handoff
    whose leading pages are SHARED only drops the handoff's reference —
    the live sharer keeps decoding on intact pages (audit-verified)."""
    srv = _AsyncSchedHarness(batch=2, num_pages=40)
    srv.prefix_cache = True
    srv.handoff_lease_blocks = 2
    finished: list[Request] = []
    prompt = np.arange(3 * PAGE, dtype=np.int32)    # 2 shareable pages
    first = Request(uid=0, prompt=prompt.copy(), max_new_tokens=24)
    second = Request(uid=1, prompt=prompt.copy(), max_new_tokens=4)
    blocker = Request(uid=2, prompt=np.zeros(2, np.int32),
                      max_new_tokens=24)
    for r in (first, second, blocker):
        r.pos = 0
    # first publishes its prefix pages and decodes; blocker takes the
    # other slot so second's handoff has nowhere to land
    for r in (first, blocker):
        srv.queue.put(r)
    for _ in range(6):
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
        if all(s is not None for s in srv.slots):
            break
        srv.decode_tick(finished)
    assert all(s is not None for s in srv.slots)
    srv.queue.put(second)
    for _ in range(12):
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
        if srv.stats["lease_reclaims"]:
            break
        srv.decode_tick(finished)
    assert srv.stats["lease_reclaims"] >= 1, srv.events
    # the handoff really adopted first's published pages, and the
    # reclaim gave back only the handoff's reference — first is still
    # live on intact pages
    assert srv.stats["prefix_hits"] >= 1, srv.events
    assert first in srv.slots
    srv.manager.audit()
    _drive_to_drain(srv, [first, second, blocker], finished)
    assert all(r.error is None for r in (first, second, blocker))
    _assert_fully_reclaimed(srv)


@given(shapes=st.lists(st.tuples(st.integers(1, 12), st.integers(2, 12)),
                       min_size=3, max_size=8),
       schedule=st.lists(st.integers(0, 1), min_size=6, max_size=40),
       deadlines=st.lists(st.one_of(st.none(), st.integers(0, 12)),
                          min_size=8, max_size=8),
       asynchronous=st.booleans())
@settings(max_examples=40, deadline=None)
def test_deadline_expiry_at_any_stage_reclaims_everything(
        shapes, schedule, deadlines, asynchronous):
    """Random tight deadlines across random churn hit requests at every
    lifecycle stage — backlogged, mid-prefill, staged handoff, live
    decode slot, preempted.  Every request must terminate (expired or
    served), every expiry must carry the structured error, and the
    allocator must end fully reclaimed."""
    srv = (_AsyncSchedHarness() if asynchronous else _SchedHarness())
    pending = [Request(uid=u, prompt=np.zeros(p, np.int32),
                       max_new_tokens=m)
               for u, (p, m) in enumerate(shapes)
               if p + max(m - 1, 0) <= MAX_SEQ]
    for i, r in enumerate(pending):
        r.pos = 0
        r.deadline_blocks = deadlines[i % len(deadlines)]
        r.submitted_block = 0
    todo = list(pending)
    finished: list[Request] = []
    for op in schedule:
        if op == 0 and todo:
            srv.queue.put(todo.pop(0))
        else:
            srv.decode_tick(finished)
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
    while todo:
        srv.queue.put(todo.pop(0))
        srv._admit_from_queue(finished, allow_preempt=True)
        srv.check_invariants()
    _drive_to_drain(srv, pending, finished)
    for r in pending:
        if r.outcome == "expired":
            assert r.error is not None
            assert r.error["reason"] == "deadline_expired"
        else:
            assert r.error is None
    _assert_fully_reclaimed(srv)


def test_overload_gate_rejects_fast_and_counts_outcomes():
    """submit() under admission control: beyond ``max_pending`` /
    ``overload_factor`` requests come back instantly with
    ``outcome == "rejected"`` and a structured error; the admitted ones
    all complete and the outcome counters add up."""
    srv = _SchedHarness(num_pages=12)
    srv.max_pending = 3
    srv.overload_factor = 1.5
    reqs = [srv.submit(np.zeros(4, np.int32), max_new_tokens=4)
            for _ in range(10)]
    rejected = [r for r in reqs if r.outcome == "rejected"]
    admitted = [r for r in reqs if r.outcome != "rejected"]
    assert rejected and admitted
    for r in rejected:
        assert r.done.is_set()
        assert r.error["reason"] == "admission_rejected"
        assert not r.output
    for r in admitted:        # host-side position for the churn driver
        r.pos = 0
    finished: list[Request] = []
    srv._admit_from_queue(finished, allow_preempt=True)
    _drive_to_drain(srv, admitted, finished)
    assert srv.stats["rejected"] == len(rejected)
    assert all(r.error is None for r in admitted)
    _assert_fully_reclaimed(srv)
    # headroom restored: a fresh request is accepted again
    again = srv.submit(np.zeros(4, np.int32), max_new_tokens=4)
    assert again.outcome != "rejected"
