"""granite-moe-3b-a800m: 32L d=1536 24H (GQA kv=8) per-expert d_ff=512,
vocab=49155, MoE 40 experts top-8 (padded to 48 for the EP axis; the 8
dummy experts are router-masked) [hf:ibm-granite/granite-3.0 family]."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab=49155, head_dim=64,
    num_experts=40, top_k=8,
)
