"""Streamed matmul — the FengHuang Tensor Prefetcher at kernel granularity.

The weight matrix lives in HBM (the kernel-level "remote tier"); BlockSpec
tiling streams (bk, bn) weight tiles through VMEM while the MXU consumes
the previous tile — Pallas' implicit grid pipeline plays the paging
stream, double-buffering tiles exactly like ``repro.memory`` double-buffers
layers.  Accumulation runs in an fp32 VMEM scratch across the K grid
dimension.

Block shapes are MXU-aligned (multiples of 128 on the matmul dims).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def streamed_matmul(x: jax.Array, w: jax.Array, *,
                    bm: int = 256, bk: int = 512, bn: int = 256,
                    interpret: bool = False) -> jax.Array:
    """x: (M, K) @ w: (K, N) -> (M, N).

    Requires M % bm == K % bk == N % bn == 0 (ops.py pads otherwise).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
