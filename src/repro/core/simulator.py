"""FengHuang discrete-event simulator (§4.1.3).

Replays an operator dependency graph (``core.graphs``) on a modelled system:

* a **compute stream** executing operators at a roofline-with-MFU rate,
* a **paging stream** (the Tensor Prefetcher) bringing pageable tensors from
  the FengHuang remote tier into local memory with a lookahead window ``w``
  (paper uses w=1: each node triggers the prefetch of its successor), and
* **collectives** costed by the fabric model of ``core.latency``
  (FengHuang shared-memory one-shot vs NVLink ring).

The simulator also accounts the peak *local* memory footprint — weights/KV
resident in the paging window plus pinned tensors and activations — which
reproduces Table 4.3 (10–20 GB instead of 144 GB per GPU).

Calibration constants (``MfuModel``, ``local_efficiency``) are the free
parameters of the paper's methodology ("we apply a scaling coefficient …
similar to empirical NVLink behaviour"); they are documented in
EXPERIMENTS.md and swept in tests.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core import hw, latency
from repro.core.graphs import Node
from repro.memory import accounting

GB = 1e9
TB = 1e12


@dataclasses.dataclass(frozen=True)
class MfuModel:
    """Saturating matmul-efficiency model (compute-bound ops only).

    mfu(M, K, N) = mfu_max * (1 - exp(-N/knee_n)) * M/(M+knee_m)

    Smaller per-GPU output shards (larger TP slices) get lower MFU — the
    mechanism by which the paper's FH4 (TP=4, fatter shards) closes most of
    the aggregate-FLOPs gap against Baseline8 (TP=8) on prefill.  Memory-
    bound ops (decode GEMVs) never see this curve; they run at the
    bandwidth roofline (see ``exec_time``).
    """

    mfu_max: float = 0.82
    knee_m: float = 64.0
    knee_n: float = 8192.0
    attention_mfu: float = 0.40   # flash-attention prefill efficiency

    def matmul(self, m: float, k: float, n: float) -> float:
        del k
        return (self.mfu_max
                * (1.0 - math.exp(-n / self.knee_n))
                * (m / (m + self.knee_m)))


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    """A simulated node (Baseline8 / FH4-1.5xM / FH4-2.0xM)."""

    name: str
    num_gpus: int
    peak_flops: float                 # per GPU
    local_bw: float                   # bytes/s per GPU
    fabric: str                       # 'nvlink' | 'fh'
    fabric_bw: float                  # bytes/s per GPU
    paged: bool = False
    remote_bw: float = 0.0            # bytes/s per GPU (FengHuang crossbar)
    lookahead: int = 1
    local_efficiency: float = 0.60    # achieved fraction of local HBM bw
    mfu: MfuModel = dataclasses.field(default_factory=MfuModel)
    kernel_overhead_s: float = 4e-6

    def remote_link(self) -> latency.LinkModel:
        return latency.LinkModel(
            fixed_latency_s=hw.PAPER_READ_LATENCY_NS * 1e-9,
            bandwidth_Bps=self.remote_bw,
            eff_max=0.95, eff_min=0.25, eff_knee_bytes=512 * 1024.0)

    def fabric_link(self) -> latency.LinkModel:
        if self.fabric == "fh":
            return latency.make_fh_link(self.fabric_bw)
        return latency.make_nvlink(self.fabric_bw)

    def tier_links(self) -> dict[str, tuple[float, float]]:
        """Registry-style per-tier link view ``{tier: (bandwidth_gbps,
        latency_us)}``: local/remote from this node's modeled hardware,
        cold from the registry's default (High-Bandwidth-Flash) link —
        the same (bandwidth, latency) vocabulary
        :data:`repro.memory.tiers.DEFAULT_TIER_LINKS` carries, and the
        same :func:`~repro.memory.accounting.modeled_transfer_s` formula
        (via ``LinkModel.transfer_time``) prices both.  This is what
        keeps the simulator's per-tier costs and the live ledger's
        tier-edge charges one code path."""
        from repro.memory.tiers import COLD, DEFAULT_TIER_LINKS, LOCAL, REMOTE
        return {
            LOCAL: (self.local_bw / GB,
                    hw.PAPER_READ_LATENCY_NS * 1e-3),
            REMOTE: (self.remote_bw / GB,
                     hw.PAPER_READ_LATENCY_NS * 1e-3),
            COLD: DEFAULT_TIER_LINKS[COLD],
        }


def baseline8() -> SystemConfig:
    """8x H200 + NVLink 4.0 (Table 4.1/4.2)."""
    return SystemConfig(
        name="Baseline8", num_gpus=8,
        peak_flops=hw.PAPER_H200_BF16_TFLOPS * 1e12,
        local_bw=hw.PAPER_H200_HBM_BW_TBPS * TB,
        fabric="nvlink", fabric_bw=hw.PAPER_NVLINK_BW_GBPS * GB,
        paged=False)


def fh4(local_scale: float = 1.5, remote_bw_tbps: float = 4.0,
        lookahead: int = 12) -> SystemConfig:
    """FH4-{1.5,2.0}xM: 4 GPUs @1.33x H200 compute, scaled local HBM,
    FengHuang TAB fabric + remote tier at `remote_bw_tbps` per GPU.

    ``lookahead`` is in *operator* nodes.  The paper's w=1 is in units of its
    Nsight trace nodes (fused kernel groups ~ one transformer sub-layer);
    twelve operator nodes ~ two of our layers, which keeps the same ~2-layer
    resident window (Table 4.3) while restoring the full paging/compute
    overlap the paper's simulator exhibits.
    """
    return SystemConfig(
        name=f"FH4-{local_scale}xM@{remote_bw_tbps}T", num_gpus=4,
        peak_flops=hw.PAPER_H200_BF16_TFLOPS * 1e12 * hw.PAPER_FH_COMPUTE_SCALE,
        local_bw=hw.PAPER_H200_HBM_BW_TBPS * TB * local_scale,
        fabric="fh", fabric_bw=remote_bw_tbps * TB,
        paged=True, remote_bw=remote_bw_tbps * TB, lookahead=lookahead,
        # §3.1: FH local memory is "tuned to workload characteristics for
        # efficient caching" — a small working set streamed sequentially
        # sustains a higher fraction of peak than baseline fine-grained
        # kernel access (0.60, the measured MBU of inference servers).
        local_efficiency=0.85)


@dataclasses.dataclass
class SimResult:
    elapsed_s: float
    compute_busy_s: float
    paging_busy_s: float
    collective_s: float
    paging_exposed_s: float        # time compute stalled waiting on pages
    peak_paged_window_bytes: float
    peak_local_bytes: float        # window + pinned + activations
    num_nodes: int

    def summary(self) -> dict:
        return dataclasses.asdict(self)


def exec_time(node: Node, sys: SystemConfig) -> float:
    """Roofline-with-MFU execution time for a non-collective node."""
    if node.kind == "collective":
        kind, payload = node.collective
        return latency.collective_time_s(kind, sys.fabric, payload,
                                         sys.num_gpus, sys.fabric_link())
    mem_t = node.local_bytes / (sys.local_bw * sys.local_efficiency)
    if node.flops <= 0:
        return mem_t + sys.kernel_overhead_s
    if node.kind == "attention":
        eff = sys.mfu.attention_mfu
    elif node.matmul_dims is not None:
        eff = sys.mfu.matmul(*node.matmul_dims)
    else:
        eff = sys.mfu.mfu_max
    # Roofline: the op runs at whichever limit is slower.  The MFU derate
    # applies to the compute term at every size (skinny TP shards are
    # inefficient); memory-bound GEMVs are floored by the bandwidth term
    # because their derated compute term is tiny anyway.
    comp_t = node.flops / (sys.peak_flops * eff)
    return max(comp_t, mem_t) + sys.kernel_overhead_s


def simulate(nodes: Sequence[Node], sys: SystemConfig,
             *, pinned_bytes: float = 0.0,
             activation_bytes: float = 0.0,
             warm_window: bool = False) -> SimResult:
    """Schedule `nodes` on the compute + paging streams.

    warm_window=True models steady-state decode, where the first `w` pages
    were prefetched during the previous token's tail (their cost is charged
    to that token — symmetric in steady state).
    """
    n = len(nodes)
    page_done = [0.0] * n
    node_start = [0.0] * n
    paging_t = 0.0
    paging_busy = 0.0
    issued = 0
    remote = sys.remote_link() if sys.paged else None

    def issue_up_to(limit: int, trigger: float) -> None:
        nonlocal paging_t, paging_busy, issued
        while issued <= min(limit, n - 1):
            nd = nodes[issued]
            if sys.paged and nd.pageable_bytes > 0:
                start = max(paging_t, trigger)
                dur = remote.transfer_time(nd.pageable_bytes)
                page_done[issued] = start + dur
                paging_t = start + dur
                paging_busy += dur
            else:
                page_done[issued] = 0.0
            issued += 1

    w = max(0, sys.lookahead)
    # Prime the initial window.  Steady-state decode: free (overlapped with
    # the previous token); cold start (prefill): pages serialize from t=0.
    issue_up_to(w, 0.0)
    if warm_window:
        for i in range(min(w + 1, n)):
            page_done[i] = 0.0

    compute_t = 0.0
    compute_busy = 0.0
    collective_t = 0.0
    paging_exposed = 0.0
    peak_window = 0.0

    for j, nd in enumerate(nodes):
        # degenerate windows (w=0): the page for node j must exist before
        # the node can wait on it — issue it now, triggered by "compute is
        # here" (demand paging).
        issue_up_to(j, compute_t)
        start = max(compute_t, page_done[j])
        paging_exposed += max(0.0, page_done[j] - compute_t)
        dur = exec_time(nd, sys)
        node_start[j] = start
        compute_t = start + dur
        if nd.kind == "collective":
            collective_t += dur
        else:
            compute_busy += dur
        issue_up_to(j + w, start)
        # resident pageable window: nodes [j, j+w] (executing + prefetched)
        if sys.paged:
            window_bytes = sum(nodes[i].pageable_bytes
                               for i in range(j, min(j + w + 1, n)))
            peak_window = max(peak_window, window_bytes)

    return SimResult(
        elapsed_s=compute_t,
        compute_busy_s=compute_busy,
        paging_busy_s=paging_busy,
        collective_s=collective_t,
        paging_exposed_s=paging_exposed,
        peak_paged_window_bytes=peak_window,
        # shared with the live runtime's ledger math (repro.memory):
        # simulated and measured Table 4.3 numbers use one formula
        peak_local_bytes=accounting.peak_local_bytes(
            peak_window, pinned_bytes, activation_bytes),
        num_nodes=n,
    )


# ---------------------------------------------------------------------------
# Workload-level driver: TTFT / TPOT / E2E (Figure 4.1) + local capacity
# (Table 4.3).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InferenceTask:
    name: str
    prompt_len: int
    gen_len: int
    batch: int = 8


QA_TASK = InferenceTask("qa", prompt_len=4096, gen_len=1024)
REASONING_TASK = InferenceTask("reasoning", prompt_len=512, gen_len=16384)


def run_workload(cfg, task: InferenceTask, sys: SystemConfig,
                 *, page_kv: bool = True) -> dict:
    from repro.core import graphs as G

    tp = sys.num_gpus
    prefill = G.build_graph(cfg, "prefill", batch=task.batch,
                            prompt_len=task.prompt_len, tp=tp,
                            paged=sys.paged, page_kv=page_kv)
    mid_ctx = task.prompt_len + task.gen_len // 2
    decode = G.build_graph(cfg, "decode", batch=task.batch,
                           prompt_len=task.prompt_len, ctx_len=mid_ctx,
                           tp=tp, paged=sys.paged, page_kv=page_kv)

    # pinned local tensors: embeddings + lm head shard (+ KV if not paged)
    pinned = cfg.embedding_params * G.BYTES_PER_PARAM / tp
    act = task.batch * task.prompt_len * cfg.d_model * G.BYTES_PER_PARAM * 4 / tp
    act_dec = task.batch * cfg.d_model * G.BYTES_PER_PARAM * 16 / tp
    kv_total = (2 * task.batch * (task.prompt_len + task.gen_len)
                * cfg.num_kv_heads * cfg.head_dim * cfg.num_layers
                * G.BYTES_PER_PARAM / tp)
    if not page_kv:
        pinned += kv_total

    r_prefill = simulate(prefill, sys, pinned_bytes=pinned,
                         activation_bytes=act, warm_window=False)
    r_decode = simulate(decode, sys, pinned_bytes=pinned,
                        activation_bytes=act_dec, warm_window=True)
    ttft = r_prefill.elapsed_s
    tpot = r_decode.elapsed_s
    e2e = ttft + max(0, task.gen_len - 1) * tpot
    return {
        "system": sys.name, "workload": cfg.name, "task": task.name,
        "ttft_s": ttft, "tpot_s": tpot, "e2e_s": e2e,
        "prefill": r_prefill.summary(), "decode": r_decode.summary(),
        "peak_local_gb": max(r_prefill.peak_local_bytes,
                             r_decode.peak_local_bytes) / GB,
        "kv_total_gb": kv_total / GB,
    }
