"""§3.3.3 speed-up analysis: exact paper numbers + model properties."""
import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 runs without hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import analysis, hw, latency


def test_paper_headline_numbers():
    h = analysis.paper_headline_numbers(8)
    assert h["enabler1_latency_bound"] == 14.0
    assert h["enabler1_bandwidth_bound"] == 1.75
    assert h["enabler2_bandwidth_bound"] == pytest.approx(8.89, abs=0.01)
    assert h["overall_latency_bound"] == 70.0
    assert h["overall_bandwidth_bound"] == pytest.approx(15.56, abs=0.01)


def test_exact_component_ratios():
    r = analysis.speedup_report(8)
    assert r.enabler2_latency_bound_read == pytest.approx(1000 / 220)
    assert r.enabler2_latency_bound_write == pytest.approx(500 / 90)
    assert r.enabler1_latency_bound == 14


@given(n=st.integers(min_value=2, max_value=64))
@settings(max_examples=30, deadline=None)
def test_enabler1_structure(n):
    r = analysis.speedup_report(n)
    # ring does 2(N-1) transfers; FH always 1
    assert r.enabler1_latency_bound == 2 * (n - 1)
    # bandwidth-bound data ratio 2(N-1)/N in [1, 2)
    assert 1.0 <= r.enabler1_bandwidth_bound < 2.0
    # overall speedups grow monotonically with N
    r2 = analysis.speedup_report(n + 1)
    assert r2.overall_latency_bound > r.overall_latency_bound


def test_table_3_1_totals():
    t = latency.table_3_1_totals_ns()
    assert t["read"] == 220
    assert t["write"] == 90
    assert t["atomic_completion"] == 40


@given(size=st.floats(min_value=1.0, max_value=1e12))
@settings(max_examples=40, deadline=None)
def test_latency_equations(size):
    bw = 4.0e12
    r = latency.fh_read_latency_s(size, bw)
    w = latency.fh_write_latency_s(size, bw)
    wa = latency.fh_write_accumulate_latency_s(size, bw)
    assert r == pytest.approx(220e-9 + size / bw)
    assert w == pytest.approx(90e-9 + size / bw)
    assert wa == w
    assert latency.fh_completion_notification_latency_s() == 40e-9


@given(size=st.floats(min_value=1.0, max_value=1e11),
       n=st.integers(min_value=2, max_value=16))
@settings(max_examples=40, deadline=None)
def test_fh_collectives_beat_ring(size, n):
    """With the paper's constants, FengHuang allreduce is faster than the
    NVLink ring at every size and GPU count."""
    fh = latency.fh_allreduce_time_s(size, n)
    ring = latency.nvlink_ring_allreduce_time_s(size, n)
    assert fh < ring


@given(a=st.floats(min_value=1.0, max_value=1e9))
@settings(max_examples=30, deadline=None)
def test_efficiency_curve_monotone(a):
    link = latency.LinkModel(0.0, 4e12)
    assert link.efficiency(a) <= link.efficiency(a * 2) + 1e-12
    assert latency.LinkModel(0.0, 4e12).transfer_time(a) < \
        latency.LinkModel(0.0, 4e12).transfer_time(a * 2)


def test_collective_dispatch_covers_all():
    for fabric in ("fh", "nvlink"):
        for kind in latency.COLLECTIVES:
            t = latency.collective_time_s(kind, fabric, 1 << 20, 8)
            assert t > 0
    with pytest.raises(ValueError):
        latency.collective_time_s("bogus", "fh", 1, 8)
