"""Serving runtime: prefill/decode step functions and a batched server
with continuous-batching-lite semantics.

serve_step == one decode step for the whole batch against the KV cache —
the function the decode_* dry-run shapes lower.  Sampling is greedy or
temperature-based; padded vocab columns are masked.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import vocab_mask_logits


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    output: list = dataclasses.field(default_factory=list)


def sample(logits: jax.Array, vocab: int, temperature: float,
           key: jax.Array) -> jax.Array:
    """logits: (B, 1, V) -> (B, 1) token ids."""
    logits = vocab_mask_logits(logits, vocab).astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1).astype(jnp.int32)


def make_prefill_step(model) -> Callable:
    def prefill_step(params, tokens, cache, extra=None):
        logits, cache = model.prefill(params, tokens, cache, extra)
        return logits, cache
    return prefill_step


def make_serve_step(model, *, temperature: float = 0.0) -> Callable:
    """One decode step: (params, tokens (B,1), cache, cur_pos, key) ->
    (next_tokens (B,1), logits, cache)."""
    vocab = model.cfg.vocab

    def serve_step(params, tokens, cache, cur_pos, key):
        logits, cache = model.decode_step(params, tokens, cache, cur_pos)
        nxt = sample(logits, vocab, temperature, key)
        return nxt, logits, cache
    return serve_step


class BatchedServer:
    """Minimal batched inference server (single process, CPU demo scale).

    Requests accumulate into fixed-size batches (padding with idle slots),
    prefill runs per batch, then the decode loop emits one token per step
    for every live slot — the paper's inference-serving shape.
    """

    def __init__(self, model, params, *, batch_size: int = 4,
                 max_seq: int = 256, temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self._uid = 0
        self.prefill_step = jax.jit(make_prefill_step(model))
        self.serve_step = jax.jit(make_serve_step(model,
                                                  temperature=temperature))
        self.key = jax.random.PRNGKey(seed)
        self.stats = {"steps": 0, "tokens": 0, "batches": 0}

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> Request:
        self._uid += 1
        req = Request(self._uid, np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens)
        self.queue.put(req)
        return req

    def _next_batch(self) -> list[Request]:
        reqs = [self.queue.get()]
        while len(reqs) < self.batch:
            try:
                reqs.append(self.queue.get_nowait())
            except queue.Empty:
                break
        return reqs

    def run_once(self) -> list[Request]:
        """Serve one batch to completion; returns the finished requests."""
        reqs = self._next_batch()
        n = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((self.batch, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        cache = self.model.init_cache(self.batch, self.max_seq)
        logits, cache = self.prefill_step(self.params, jnp.asarray(toks),
                                          cache)
        self.key, k = jax.random.split(self.key)
        cur = sample(logits, self.model.cfg.vocab, 0.0, k)
        for i, r in enumerate(reqs):
            r.output.append(int(cur[i, 0]))
        max_new = max(r.max_new_tokens for r in reqs)
        pos = jnp.full((self.batch,), plen, jnp.int32)
        for step in range(max_new - 1):
            self.key, k = jax.random.split(self.key)
            cur, logits, cache = self.serve_step(self.params, cur, cache,
                                                 pos, k)
            pos = pos + 1
            self.stats["steps"] += 1
            for i, r in enumerate(reqs):
                if len(r.output) < r.max_new_tokens:
                    r.output.append(int(cur[i, 0]))
                    self.stats["tokens"] += 1
        for r in reqs:
            r.done.set()
        self.stats["batches"] += 1
        return reqs
