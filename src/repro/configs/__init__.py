"""Architecture registry: ``get_config(id)`` / ``build_model(cfg)``.

The ten assigned architectures plus the paper's own simulator workloads
(gpt3-175b / grok-1 / qwen3-235b, which live in ``repro.core.graphs`` as
analytical configs and here as runnable ``ModelConfig``s for completeness).
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.base import ModelConfig

ARCH_IDS = (
    "qwen2.5-14b", "qwen3-14b", "minicpm-2b", "starcoder2-15b",
    "recurrentgemma-9b", "xlstm-125m", "whisper-base",
    "moonshot-v1-16b-a3b", "granite-moe-3b-a800m", "llava-next-34b",
)

_MODULES = {
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen3-14b": "qwen3_14b",
    "minicpm-2b": "minicpm_2b",
    "starcoder2-15b": "starcoder2_15b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "xlstm-125m": "xlstm_125m",
    "whisper-base": "whisper_base",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "llava-next-34b": "llava_next_34b",
    # paper workloads, runnable form
    "gpt3-175b": "gpt3_175b",
    "grok-1": "grok_1",
    "qwen3-235b": "qwen3_235b",
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def build_model(cfg: ModelConfig):
    """Instantiate the model class for a config's family."""
    if cfg.family in ("dense",):
        from repro.models.transformer import DenseLM
        return DenseLM(cfg)
    if cfg.family == "vlm":
        from repro.models.vlm import VLM
        return VLM(cfg)
    if cfg.family == "moe":
        from repro.models.moe import MoELM
        return MoELM(cfg)
    if cfg.family == "hybrid":
        from repro.models.hybrid import HybridLM
        return HybridLM(cfg)
    if cfg.family == "ssm":
        from repro.models.ssm import XLSTM
        return XLSTM(cfg)
    if cfg.family == "encdec":
        from repro.models.encdec import EncDecLM
        return EncDecLM(cfg)
    raise ValueError(cfg.family)


def get_model(arch_id: str, **overrides):
    cfg = get_config(arch_id)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return build_model(cfg), cfg


# Sub-quadratic families that support the long_500k shape.
SUBQUADRATIC = {"recurrentgemma-9b", "xlstm-125m"}
