"""Paged decode attention — FengHuang KV paging at kernel granularity.

The KV cache lives as fixed-size pages in a global HBM pool (the kernel's
"remote tier"); the page table is **scalar-prefetched**
(``PrefetchScalarGridSpec``) so the BlockSpec index_map can look up which
physical page to DMA into VMEM for each grid step — the hardware analogue
of the paper's Tensor Prefetcher: the next page's fetch is issued by the
Mosaic pipeline while the current page is being reduced.

Grid: (batch, kv_heads, pages_per_seq); online-softmax state in VMEM
scratch across the page dimension.

``extra_kv`` is the serving hot path's contract with the decode layer
scan: the pool holds strictly-past tokens (masked to ``pos <
seq_lens[b]``) and the CURRENT token's (k, v) joins as one extra
online-softmax column folded in at the final grid step — so the pool is
read-only inside the scan and the new token is written once, batched over
layers, afterwards.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(page_table_ref, seq_lens_ref,      # scalar-prefetch refs
            q_ref, k_ref, v_ref, *rest,
            page: int, n_pages: int, scale: float, has_extra: bool,
            has_scales: bool):
    if has_scales:
        ks_ref, vs_ref, *rest = rest
    if has_extra:
        k0_ref, v0_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    p_idx = pl.program_id(2)

    @pl.when(p_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                               # (G, d)
    k = k_ref[0, :, 0, :]                         # (page, d)
    v = v_ref[0, :, 0, :]
    if has_scales:
        # fused dequant: the quantized page is widened and rescaled in
        # VMEM right before the dots — full-precision KV never exists
        # outside this (page, d) tile
        q = q.astype(jnp.float32)
        k = k.astype(jnp.float32) * ks_ref[0, :, 0].astype(
            jnp.float32)[:, None]
        v = v.astype(jnp.float32) * vs_ref[0, :, 0].astype(
            jnp.float32)[:, None]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = p_idx * page + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)                    # (G, page)
    valid = pos < seq_lens_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(p_idx == n_pages - 1)
    def _flush():
        m_p, l_p, acc_p = m_ref[...], l_ref[...], acc_ref[...]
        if has_extra:
            # current token's (k, v): one more online-softmax column.  A
            # seq_len==0 slot gets alpha = exp(NEG_INF - s0) == 0, which
            # exactly zeroes the garbage accumulated from masked pages.
            k0 = k0_ref[0]                        # (1, d)
            v0 = v0_ref[0]
            if has_scales:
                # extra_kv stays full precision (it is the CURRENT
                # token, never pooled); only q was widened above
                k0 = k0.astype(jnp.float32)
            s0 = jax.lax.dot_general(
                q, k0, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale   # (G, 1)
            m_f = jnp.maximum(m_p, s0)
            alpha = jnp.exp(m_p - m_f)
            p0 = jnp.exp(s0 - m_f)
            l_p = l_p * alpha + p0
            acc_p = acc_p * alpha + p0 * v0.astype(jnp.float32)
        o_ref[0, 0] = (acc_p /
                       jnp.maximum(l_p, 1e-30)).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_table: jax.Array, seq_lens: jax.Array, *,
                    extra_kv: tuple[jax.Array, jax.Array] | None = None,
                    k_scales: jax.Array | None = None,
                    v_scales: jax.Array | None = None,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, G, d); pages: (P, page, Hkv, d);
    page_table: (B, n_pages) int32; seq_lens: (B,) int32;
    extra_kv: optional current-token (k0, v0), each (B, Hkv, d), attended
    in addition to the first ``seq_lens`` pooled positions;
    k_scales/v_scales: optional (P, page, Hkv) dequant scales for a
    quantized pool — DMA'd per page next to the KV tiles and multiplied
    into the fp32 widening inside the online-softmax loop.
    Returns (B, Hkv, G, d)."""
    b, hkv, g, d = q.shape
    n_pages = page_table.shape[1]
    if n_pages < 1:
        raise ValueError("page_table must map at least one page per row")
    if (k_scales is None) != (v_scales is None):
        raise ValueError("k_scales and v_scales must be given together")
    page = k_pages.shape[1]
    scale = 1.0 / math.sqrt(d)
    has_extra = extra_kv is not None
    has_scales = k_scales is not None

    in_specs = [
        pl.BlockSpec((1, 1, g, d), lambda bb, h, p, pt, sl: (bb, h, 0, 0)),
        # the page table drives which physical page is DMA'd
        pl.BlockSpec((1, page, 1, d),
                     lambda bb, h, p, pt, sl: (pt[bb, p], 0, h, 0)),
        pl.BlockSpec((1, page, 1, d),
                     lambda bb, h, p, pt, sl: (pt[bb, p], 0, h, 0)),
    ]
    inputs = [page_table, seq_lens, q, k_pages, v_pages]
    if has_scales:
        in_specs += [
            pl.BlockSpec((1, page, 1),
                         lambda bb, h, p, pt, sl: (pt[bb, p], 0, h)),
            pl.BlockSpec((1, page, 1),
                         lambda bb, h, p, pt, sl: (pt[bb, p], 0, h)),
        ]
        inputs += [k_scales, v_scales]
    if has_extra:
        in_specs += [
            pl.BlockSpec((1, 1, d), lambda bb, h, p, pt, sl: (bb, h, 0)),
            pl.BlockSpec((1, 1, d), lambda bb, h, p, pt, sl: (bb, h, 0)),
        ]
        inputs += [extra_kv[0], extra_kv[1]]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bb, h, p, pt, sl: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, page=page, n_pages=n_pages, scale=scale,
                          has_extra=has_extra, has_scales=has_scales),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(*inputs)
