"""Schema assertion for ``BENCH_serve.json`` — keeps the serving perf
record machine-readable as the benchmark evolves (CI gate).

    python benchmarks/check_bench_schema.py [path]

Asserts the top-level keys, the ``kv_memory`` sub-schema, and the
per-tier residency block (every tier must carry ``in_use_bytes`` /
``hwm_bytes`` / ``by_class``).  Exits nonzero with a readable message on
any violation.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

TOP_KEYS = {
    "model", "batch", "prompt", "new_tokens", "block_size", "max_seq",
    "tokens_per_s", "speedup_block_vs_per_token",
    "paged_vs_dense_tokens_identical", "kv_memory", "tiers",
    "attention_scaling",
}
TOKENS_PER_S_KEYS = {"per_token_dense", "block_dense", "server_dense",
                     "server_paged"}
KV_MEMORY_KEYS = {
    "page_size", "dense_slab_bytes", "paged_pool_capacity_bytes",
    "paged_hwm_bytes", "peak_live_tokens", "bytes_per_active_token_dense",
    "bytes_per_active_token_paged", "local_kv_reduction_vs_dense",
    "fragmentation_hwm_bound",
}
TIER_KEYS = {"in_use_bytes", "hwm_bytes", "capacity_bytes", "by_class"}


def check(path: Path) -> list[str]:
    errors: list[str] = []
    try:
        bench = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"cannot load {path}: {e}"]

    missing = TOP_KEYS - bench.keys()
    if missing:
        errors.append(f"missing top-level keys: {sorted(missing)}")
    if not TOKENS_PER_S_KEYS <= bench.get("tokens_per_s", {}).keys():
        errors.append(
            f"tokens_per_s must contain {sorted(TOKENS_PER_S_KEYS)}, got "
            f"{sorted(bench.get('tokens_per_s', {}))}")
    km_missing = KV_MEMORY_KEYS - bench.get("kv_memory", {}).keys()
    if km_missing:
        errors.append(f"missing kv_memory keys: {sorted(km_missing)}")

    tiers = bench.get("tiers", {})
    if not isinstance(tiers, dict) or not tiers:
        errors.append("tiers must be a non-empty per-tier mapping")
    for name, t in (tiers.items() if isinstance(tiers, dict) else ()):
        tk_missing = TIER_KEYS - (t.keys() if isinstance(t, dict) else set())
        if tk_missing:
            errors.append(f"tier '{name}' missing {sorted(tk_missing)}")
        elif not isinstance(t["by_class"], dict):
            errors.append(f"tier '{name}' by_class must be a mapping")
        else:
            for field in ("in_use_bytes", "hwm_bytes", "capacity_bytes"):
                if not isinstance(t[field], int) or t[field] < 0:
                    errors.append(
                        f"tier '{name}' {field} must be a non-negative "
                        f"int, got {t[field]!r}")
    if isinstance(tiers, dict) and "local" not in tiers:
        errors.append("tiers must include the 'local' tier")
    return errors


def main() -> None:
    path = Path(sys.argv[1] if len(sys.argv) > 1 else "BENCH_serve.json")
    errors = check(path)
    if errors:
        for e in errors:
            print(f"BENCH schema violation: {e}", file=sys.stderr)
        raise SystemExit(1)
    print(f"{path}: schema OK "
          f"(tiers: {sorted(json.loads(path.read_text())['tiers'])})")


if __name__ == "__main__":
    main()
