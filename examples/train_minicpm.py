"""Train a ~100M-parameter MiniCPM-family model for a few hundred steps
with the full production stack: WSD schedule, gradient accumulation,
fault-tolerant loop with checkpoints, prefetching data pipeline.

    PYTHONPATH=src python examples/train_minicpm.py --steps 300
"""
import argparse
import dataclasses
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, build_model
from repro.data.pipeline import DataConfig, PrefetchingLoader, SyntheticLM
from repro.runtime import optim
from repro.runtime.ft import FTConfig, FaultTolerantLoop
from repro.runtime.train import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=384)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    # ~100M-parameter MiniCPM-family config (WSD schedule per the paper)
    cfg = get_config("minicpm-2b").reduced(
        num_layers=args.layers, d_model=args.d_model,
        num_heads=6, num_kv_heads=6, d_ff=args.d_model * 4, vocab=32768,
        head_dim=args.d_model // 6)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] minicpm-family: {n/1e6:.1f}M params, "
          f"{cfg.num_layers}L d={cfg.d_model}")

    tcfg = TrainConfig(
        adamw=optim.AdamWConfig(lr=6e-3, schedule="wsd", warmup_steps=20,
                                total_steps=args.steps, decay_fraction=0.2),
        accum_steps=2)
    step_fn = jax.jit(make_train_step(model, tcfg))
    opt = optim.init_opt_state(params)
    dcfg = DataConfig(batch=args.batch, seq=args.seq, vocab=cfg.vocab)
    loader = PrefetchingLoader(SyntheticLM(dcfg), dcfg)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="minicpm_ckpt_")
    losses = []

    def ft_step(state, i):
        p, o = state
        batch = {k: jnp.asarray(v) for k, v in next(loader).items()}
        p, o, m = step_fn(p, o, batch)
        losses.append(float(m["loss"]))
        if i % 25 == 0:
            print(f"[train] step {i:4d} loss {losses[-1]:.4f} "
                  f"lr {float(m['lr']):.2e} gnorm {float(m['grad_norm']):.2f}")
        return (p, o), m

    loop = FaultTolerantLoop(
        FTConfig(ckpt_dir=ckpt_dir, ckpt_every=100, async_save=True), ft_step)
    try:
        (params, opt), end = loop.run((params, opt), num_steps=args.steps)
    finally:
        loader.close()
    print(f"[train] done at step {end}; loss {np.mean(losses[:10]):.3f} -> "
          f"{np.mean(losses[-10:]):.3f}; checkpoints in {ckpt_dir}; "
          f"straggler flags {loop.monitor.flags}, "
          f"backup batches {loader.backup_batches}")
    assert np.mean(losses[-10:]) < np.mean(losses[:10])
    print("[train] OK")


if __name__ == "__main__":
    main()
