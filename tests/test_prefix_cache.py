"""Prefix caching: per-page refcounts, the prompt-prefix index,
copy-on-write divergence, and bit-identical shared-vs-unshared serving."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.kernels.paged_attention.ops import BlockManager
from repro.runtime.serve import BatchedServer

PAGE = 4


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("qwen2.5-14b").reduced()
    cfg = dataclasses.replace(cfg, remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


# ---------------------------------------------------------------------------
# BlockManager refcount + prefix index invariants
# ---------------------------------------------------------------------------

def test_adopt_refcounts_and_shared_free():
    mgr = BlockManager(num_pages=9, page_size=PAGE)
    owner = mgr.ensure(0, 3 * PAGE)               # 3 pages, rc=1 each
    key = b"prefix-bytes"
    mgr.register_prefix(key, owner[0])
    assert mgr.lookup_prefix(key) == owner[0]

    mgr.adopt(1, owner[:2])                       # slot 1 shares 2 pages
    mgr.ensure(1, 3 * PAGE)                       # + 1 private page
    assert mgr.pages[1][:2] == owner[:2]
    assert mgr.refcount[owner[0]] == mgr.refcount[owner[1]] == 2
    assert mgr.refcount[owner[2]] == 1
    # shared pages consume no extra pool capacity
    assert mgr.pages_in_use == 4
    assert mgr.shared_pages == 2

    # eviction of one sharer never frees pages still referenced
    mgr.free_slot(0)
    assert mgr.refcount[owner[0]] == 1
    assert owner[0] not in mgr._free and owner[1] not in mgr._free
    assert owner[2] in mgr._free                  # rc hit 0: reclaimed
    assert mgr.lookup_prefix(key) == owner[0]     # index entry survives

    # last owner gone: pages reclaimed AND the index entry with them
    mgr.free_slot(1)
    assert mgr.pages_in_use == 0
    assert mgr.free_pages == mgr.capacity
    assert mgr.lookup_prefix(key) is None
    assert not mgr.refcount


def test_adopt_guards():
    mgr = BlockManager(num_pages=5, page_size=PAGE)
    pages = mgr.ensure(0, PAGE)
    mgr.ensure(1, PAGE)
    with pytest.raises(ValueError, match="must lead"):
        mgr.adopt(1, pages)                       # slot 1 already owns pages
    mgr.free_slot(0)
    with pytest.raises(ValueError, match="not live"):
        mgr.adopt(2, pages)                       # page was reclaimed
    with pytest.raises(ValueError, match="not live"):
        mgr.register_prefix(b"k", pages[0])


# ---------------------------------------------------------------------------
# server end-to-end: physical sharing + copy-on-write divergence
# ---------------------------------------------------------------------------

def _prompts(n: int, shared: int = 12, unique: int = 2):
    base = np.arange(1, shared + 1, dtype=np.int32)
    return [np.concatenate([base, np.full(unique, 100 + i, np.int32)])
            for i in range(n)]


def test_shared_prefix_maps_identical_physical_pages(tiny_model):
    model, params = tiny_model
    server = BatchedServer(model, params, batch_size=3, max_seq=64,
                           block_size=4, page_size=PAGE)
    for p in _prompts(3):
        server.submit(p, max_new_tokens=8)
    finished: list = []
    server._admit_from_queue(finished)            # all three live at once
    mgr = server.manager
    plen = server._admit_plen(14, 8)              # 14-token prompt -> bucket
    n_share = server._shareable_pages(plen)
    assert n_share >= 1
    tables = [mgr.slot_pages(i) for i in range(3)]
    for t in tables[1:]:
        # identical physical leading pages, refcounted once per sharer
        assert t[:n_share] == tables[0][:n_share]
    for p in tables[0][:n_share]:
        assert mgr.refcount[p] == 3
    # copy-on-write divergence: everything past the shared prefix is
    # private — the first partial page is never shared
    tails = [tuple(t[n_share:]) for t in tables]
    assert len(set().union(*map(set, tails))) == sum(map(len, tails))
    assert server.stats["prefix_hits"] == 2
    # draining the batch returns every page exactly once
    server.run_once()
    assert mgr.pages_in_use == 0 and mgr.free_pages == mgr.capacity
    assert not mgr.refcount


def test_evicting_one_sharer_keeps_neighbours_correct(tiny_model):
    """The short sharer finishes (its refcounts drop) while the long
    sharer keeps decoding from the same physical prefix pages — outputs
    must match a solo run of the long request."""
    model, params = tiny_model
    prompts = _prompts(2)

    def serve(reqs_spec, batch):
        server = BatchedServer(model, params, batch_size=batch, max_seq=64,
                               block_size=4, page_size=PAGE)
        reqs = [server.submit(p, max_new_tokens=n) for p, n in reqs_spec]
        server.run_once()
        return server, [tuple(r.output) for r in reqs]

    server, (long_out, short_out) = serve(
        [(prompts[0], 16), (prompts[1], 4)], batch=2)
    assert server.stats["prefix_hits"] == 1
    solo, (solo_out,) = serve([(prompts[0], 16)], batch=1)
    assert long_out == solo_out
    assert server.manager.pages_in_use == 0


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_prefix_cached_tokens_bit_identical(tiny_model, temperature):
    model, params = tiny_model

    def serve(prefix_cache):
        server = BatchedServer(model, params, batch_size=3, max_seq=64,
                               block_size=4, page_size=PAGE,
                               temperature=temperature,
                               prefix_cache=prefix_cache)
        reqs = [server.submit(p, max_new_tokens=8) for p in _prompts(3)]
        server.run_once()
        return server, [tuple(r.output) for r in reqs]

    shared, out_s = serve(True)
    unshared, out_u = serve(False)
    assert out_s == out_u
    assert shared.stats["prefix_hits"] > 0
    assert unshared.stats["prefix_hits"] == 0
    # physical residency dropped by the shared pages
    assert shared.manager.hwm < unshared.manager.hwm
