"""Benchmark: §3.3.2 — TAB one-shot vs NVLink-ring collectives, measured on
a real (host-device) mesh.

Demonstrates Enabler 1 structurally: the ring allreduce lowers to 2(N-1)
collective-permute steps in the HLO while the TAB schedule is a single
all-reduce/psum op.  Wall-clock on forced CPU devices is not a performance
claim; the HLO op counts are the reproducible artifact.

Run standalone (needs 8 host devices):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.collectives
"""
from __future__ import annotations

import functools
import os
import re
import subprocess
import sys
import time


def _inner() -> list[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P, AxisType
    from repro.core import tab

    n = 8
    mesh = jax.make_mesh((n,), ("model",), axis_types=(AxisType.Auto,))
    rows = []
    x = jnp.asarray(np.random.RandomState(0).randn(n * 256, 256), jnp.float32)

    for sched in ("tab", "ring"):
        f = jax.jit(jax.shard_map(
            functools.partial(tab.allreduce, axis_name="model",
                              schedule=sched),
            mesh=mesh, in_specs=P("model"), out_specs=P("model"),
            check_vma=False))
        f(x).block_until_ready()
        t0 = time.perf_counter()
        iters = 20
        for _ in range(iters):
            out = f(x)
        out.block_until_ready()
        us = (time.perf_counter() - t0) / iters * 1e6
        hlo = jax.jit(jax.shard_map(
            functools.partial(tab.allreduce, axis_name="model",
                              schedule=sched),
            mesh=mesh, in_specs=P("model"), out_specs=P("model"),
            check_vma=False)).lower(x).compile().as_text()
        # trip-count-aware: the ring's permutes live inside fori_loops
        from repro.launch.hlo_cost import module_cost
        counts = module_cost(hlo)["collective_counts"]
        n_perm = int(counts.get("collective-permute", 0))
        n_ar = int(counts.get("all-reduce", 0))
        rows.append(f"collective_allreduce_{sched},{us:.1f},"
                    f"permute_steps={n_perm} allreduce_ops={n_ar} "
                    f"(ring expects 2(N-1)={2*(n-1)} steps, tab expects 1 op)")
    return rows


def run() -> list[str]:
    if os.environ.get("REPRO_COLLECTIVES_INNER") == "1":
        return _inner()
    # re-exec with 8 host devices
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    env["REPRO_COLLECTIVES_INNER"] = "1"
    env.setdefault("PYTHONPATH", "src")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.collectives"],
        capture_output=True, text=True, env=env, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    rows = [l for l in out.stdout.splitlines() if l.startswith("collective")]
    if not rows:
        rows = [f"collective_allreduce,0,SUBPROCESS_FAILED: "
                f"{out.stderr[-200:]}"]
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
