"""jit'd public wrapper for the streamed matmul (padding + dispatch)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.streamed_matmul.kernel import streamed_matmul
from repro.kernels.streamed_matmul.ref import streamed_matmul_ref


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "bn", "interpret"))
def matmul(x: jax.Array, w: jax.Array, *, bm: int = 256, bk: int = 512,
           bn: int = 256, interpret: bool = False) -> jax.Array:
    """Padded, jit'd streamed matmul; shapes need not be block-aligned.

    Shapes are validated at trace time: operands must be 2-D, non-empty
    and contraction-compatible.  (The old ``min(bm, m) or 1`` clamp
    silently turned an empty operand into a degenerate 1-wide block and
    returned garbage-shaped output instead of erroring.)
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(
            f"streamed matmul takes 2-D operands, got x{x.shape} w{w.shape}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(
            f"contraction mismatch: x{x.shape} @ w{w.shape}")
    if m == 0 or k == 0 or n == 0:
        raise ValueError(
            f"streamed matmul requires non-empty operands, got "
            f"x{x.shape} @ w{w.shape}")
    # explicit clamp: block sizes never exceed the (now known-positive)
    # dims, so tiny shapes stream as a single block
    bm_, bk_, bn_ = min(bm, m), min(bk, k), min(bn, n)
    xp = _pad_to(x, bm_, bk_)
    wp = _pad_to(w, bk_, bn_)
    out = streamed_matmul(xp, wp, bm=bm_, bk=bk_, bn=bn_,
                          interpret=interpret)
    return out[:m, :n]


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return streamed_matmul_ref(x, w)
