"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct inputs (no allocation), record
memory_analysis / cost_analysis / per-collective traffic for the roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40-cell sweep
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Results land in experiments/dryrun/<arch>__<shape>__<mesh>[__paged].json.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as config_registry
from repro.configs import ARCH_IDS, SUBQUADRATIC, build_model, get_config
from repro.launch.mesh import make_production_mesh
from repro.runtime import optim
from repro.runtime.sharding import batch_spec, named_shardings, resolve_spec
from repro.runtime.train import TrainConfig, make_train_step
from repro.runtime.serve import make_serve_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def _fit_spec(spec: P, shape, mesh) -> P:
    """Drop axis entries that don't divide the dim (e.g. batch=1 cells)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, entry in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for n in names:
            total *= sizes.get(n, 1)
        if shape[i] % total == 0 and shape[i] >= total:
            out.append(entry)
        else:
            out.append(None)
    return P(*out)


def sds(shape, dtype, mesh, spec):
    resolved = _fit_spec(resolve_spec(spec, mesh), shape, mesh)
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, resolved))


def abstract_params(model, mesh, *, paged: bool):
    """ShapeDtypeStructs for params with production shardings attached."""
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    shardings = named_shardings(model.param_specs(), mesh,
                                pageable_remote=paged)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def abstract_cache(model, mesh, batch, seq):
    shapes = jax.eval_shape(lambda: model.init_cache(batch, seq))
    shardings = named_shardings(model.cache_specs(), mesh)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=NamedSharding(mesh, _fit_spec(sh.spec, s.shape, mesh))),
        shapes, shardings)


def input_specs(arch: str, shape_name: str, mesh, *, paged: bool = False,
                kv_quant: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = get_config(arch)
    if paged:
        cfg = cfg.with_pager(enabled=True, lookahead=1)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    model = build_model(cfg)
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    bspec = batch_spec(mesh)
    params = abstract_params(model, mesh, paged=paged)

    extra = {}
    if cfg.family == "encdec":
        extra["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), jnp.float32,
                              mesh, P(bspec[0], None, None))
    if cfg.family == "vlm":
        extra["patches"] = sds((b, cfg.num_patches, cfg.d_model), jnp.float32,
                               mesh, P(bspec[0], None, None))

    if info["kind"] == "train":
        text = s - cfg.num_patches if cfg.family == "vlm" else s
        batch = {
            "tokens": sds((b, text), jnp.int32, mesh, P(bspec[0], None)),
            "labels": sds((b, text), jnp.int32, mesh, P(bspec[0], None)),
            **extra,
        }
        opt_shapes = jax.eval_shape(optim.init_opt_state, params)
        opt_sharding = optim.opt_state_specs(model.param_specs())
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        data_ax = sizes.get("data", 1)

        def zero1(sh, sp):
            """ZeRO-1: moments additionally sharded over 'data' on the
            first free dim that divides."""
            spec = list(resolve_spec(sp, mesh)) + \
                [None] * (len(sh.shape) - len(sp))
            if sh.dtype == jnp.float32 and "data" in sizes:
                for i, (dim, entry) in enumerate(zip(sh.shape, spec)):
                    if entry is None and dim % data_ax == 0 and dim >= data_ax:
                        spec[i] = "data"
                        break
            return jax.ShapeDtypeStruct(
                sh.shape, sh.dtype,
                sharding=NamedSharding(mesh, P(*spec)))

        opt = jax.tree.map(
            zero1, opt_shapes,
            jax.tree.map(lambda s_: s_, opt_sharding,
                         is_leaf=lambda x: isinstance(x, P)),
            is_leaf=lambda x: hasattr(x, "shape"))
        return model, cfg, dict(kind="train", params=params, opt=opt,
                                batch=batch)
    if info["kind"] == "prefill":
        text = s - cfg.num_patches if cfg.family == "vlm" else s
        tokens = sds((b, text), jnp.int32, mesh, P(bspec[0], None))
        cache = abstract_cache(model, mesh, b, s)
        return model, cfg, dict(kind="prefill", params=params, tokens=tokens,
                                cache=cache, extra=extra)
    # decode
    tokens = sds((b, 1), jnp.int32, mesh, P(bspec[0], None))
    cache = abstract_cache(model, mesh, b, s)
    cur_pos = sds((b,), jnp.int32, mesh, P(bspec[0]))
    key = jax.ShapeDtypeStruct((2,), jnp.uint32,
                               sharding=NamedSharding(mesh, P()))
    return model, cfg, dict(kind="decode", params=params, tokens=tokens,
                            cache=cache, cur_pos=cur_pos, key=key,
                            extra=extra)


COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*= \(?([a-z0-9_]+)\[([0-9,]*)\]")
SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
               "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-operand bytes of every collective op in the HLO."""
    out: dict[str, float] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(
            r".*= ((?:\([^)]*\)|[a-z0-9_]+\[[0-9,]*\][^ ]*)) "
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)", line)
        if not m:
            continue
        sig, op = m.group(1), m.group(2)
        total = 0
        for dt, dims in SHAPE_RE.findall(sig):
            nbytes = DTYPE_BYTES.get(dt)
            if nbytes is None:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * nbytes
        out[op] = out.get(op, 0.0) + total
        count[op] = count.get(op, 0) + 1
    return {"bytes": out, "counts": count,
            "total_bytes": float(sum(out.values()))}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             paged: bool = False, kv_quant: bool = False,
             save: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = (f"{arch}__{shape_name}__{mesh_name}" + ("__paged" if paged else "")
           + ("__kvq" if kv_quant else ""))
    info = SHAPES[shape_name]

    cfg0 = get_config(arch)
    if shape_name == "long_500k" and arch not in SUBQUADRATIC:
        result = {"cell": tag, "status": "skipped",
                  "reason": "full quadratic attention at 512k context "
                            "(DESIGN.md long_500k policy)"}
        if save:
            _save(tag, result)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.monotonic()
    model, cfg, spec = input_specs(arch, shape_name, mesh, paged=paged,
                                   kv_quant=kv_quant)

    # jax.set_mesh (not `with mesh:`) sets the ambient mesh that
    # with_sharding_constraint(P(...)) and get_abstract_mesh() observe.
    with jax.set_mesh(mesh):
        if spec["kind"] == "train":
            # microbatching: larger models train with gradient accumulation
            # so per-microbatch activations fit HBM (standard production
            # knob; communication is deferred to one reduction).
            accum = 2 if cfg.d_model >= 5120 else 1
            tstep = make_train_step(model, TrainConfig(accum_steps=accum))
            lowered = jax.jit(tstep, donate_argnums=(0, 1)).lower(
                spec["params"], spec["opt"], spec["batch"])
        elif spec["kind"] == "prefill":
            def prefill(params, tokens, cache, extra):
                return model.prefill(params, tokens, cache, extra or None)
            lowered = jax.jit(prefill, donate_argnums=(2,)).lower(
                spec["params"], spec["tokens"], spec["cache"], spec["extra"])
        else:
            sstep = make_serve_step(model)
            def serve(params, tokens, cache, cur_pos, key, extra):
                del extra
                return sstep(params, tokens, cache, cur_pos, key)
            lowered = jax.jit(serve, donate_argnums=(2,)).lower(
                spec["params"], spec["tokens"], spec["cache"],
                spec["cur_pos"], spec["key"], spec["extra"])
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    from repro.launch.hlo_cost import module_cost
    walked = module_cost(hlo)   # trip-count-aware per-device costs

    result = {
        "cell": tag, "status": "ok", "arch": arch, "shape": shape_name,
        "mesh": mesh_name, "paged": paged,
        "devices": int(np.prod(mesh.devices.shape)),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_device_bytes": (ma.argument_size_in_bytes +
                                  ma.output_size_in_bytes +
                                  ma.temp_size_in_bytes -
                                  ma.alias_size_in_bytes),
            "host_argument_bytes": ma.host_argument_size_in_bytes,
            "host_temp_bytes": ma.host_temp_size_in_bytes,
        },
        "cost": {
            # trip-count-aware walker (see hlo_cost.py); XLA's own numbers
            # kept for reference — they count while bodies once.
            "flops": walked["flops"],
            "bytes_accessed": walked["bytes"],
            "transcendentals": walked["transcendentals"],
            "xla_flops": ca.get("flops", 0.0),
            "xla_bytes_accessed": ca.get("bytes accessed", 0.0),
        },
        "collectives": {
            "bytes": walked["collective_bytes"],
            "counts": walked["collective_counts"],
            "total_bytes": walked["collective_total_bytes"],
            "once_per_loop": coll,
        },
    }
    if save:
        _save(tag, result)
    return result


def _save(tag: str, result: dict):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    (RESULTS_DIR / f"{tag}.json").write_text(json.dumps(result, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="FengHuang configuration: weights in the remote "
                         "tier, paged per layer")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (dense family)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = list(ARCH_IDS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    failures = 0
    for arch, shape, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        tag = (f"{arch}__{shape}__{mesh_name}"
               + ("__paged" if args.paged else "")
               + ("__kvq" if args.kv_quant else ""))
        if args.skip_existing and (RESULTS_DIR / f"{tag}.json").exists():
            prev = json.loads((RESULTS_DIR / f"{tag}.json").read_text())
            if prev.get("status") in ("ok", "skipped"):
                print(f"[skip] {tag} (cached {prev['status']})")
                continue
        try:
            r = run_cell(arch, shape, multi_pod=mp, paged=args.paged,
                         kv_quant=args.kv_quant)
            if r["status"] == "ok":
                peak = r["memory"]["peak_device_bytes"] / 2**30
                print(f"[ok]   {tag}: peak {peak:.2f} GiB/dev, "
                      f"flops {r['cost']['flops']:.3e}, "
                      f"coll {r['collectives']['total_bytes']:.3e} B, "
                      f"compile {r['compile_s']:.1f}s")
            else:
                print(f"[skip] {tag}: {r['reason']}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:400]}")
            _save(tag, {"cell": tag, "status": "failed",
                        "error": f"{type(e).__name__}: {str(e)[:2000]}",
                        "traceback": traceback.format_exc()[-4000:]})
    print(f"done: {len(cells) - failures}/{len(cells)} cells passed")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
