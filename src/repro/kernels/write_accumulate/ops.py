"""jit'd wrapper for the TAB write-accumulate (arbitrary pytree shapes)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.write_accumulate.kernel import write_accumulate
from repro.kernels.write_accumulate.ref import write_accumulate_ref


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def accumulate(shards: jax.Array, *, block: int = 512,
               interpret: bool = False) -> jax.Array:
    """shards: (N, ...) -> (...) sum; flattens, pads, dispatches."""
    n = shards.shape[0]
    orig_shape = shards.shape[1:]
    flat = shards.reshape(n, -1)
    size = flat.shape[1]
    cols = min(512, size)
    pad = (-size) % cols
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    rows = flat.shape[1] // cols
    x = flat.reshape(n, rows, cols)
    blk = min(block, rows)
    while rows % blk:
        blk -= 1
    out = write_accumulate(x, block=blk, interpret=interpret)
    return out.reshape(-1)[:size].reshape(orig_shape)


def accumulate_ref(shards: jax.Array) -> jax.Array:
    return write_accumulate_ref(shards)
