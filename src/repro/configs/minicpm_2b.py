"""minicpm-2b: 40L d=2304 36H (MHA kv=36) d_ff=5760 vocab=122753.
Llama-like arch; trained with the WSD schedule (see runtime.optim.wsd)
[arXiv:2404.06395]."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab=122753, head_dim=64,
    tie_embeddings=True,
)

TRAIN_SCHEDULE = "wsd"   # picked up by runtime.optim when training this arch
