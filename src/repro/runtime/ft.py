"""Fault tolerance: checkpointed training loop with restart-on-failure,
straggler detection, and elastic mesh degradation.

Single-process semantics (this container), cluster-shaped structure: the
loop is written against abstract callbacks (``make_step``, ``remesh``) so a
multi-host deployment plugs in jax.distributed initialization + real
failure detection without touching the loop.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

from repro.runtime import checkpoint

log = logging.getLogger("repro.ft")


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 10
    keep: int = 3
    max_restarts: int = 3
    straggler_factor: float = 3.0   # step slower than factor x median => flag
    async_save: bool = True


class StragglerMonitor:
    """Tracks step durations; flags outliers (the signal a cluster runtime
    would use to trigger backup workers / re-scheduling)."""

    def __init__(self, factor: float = 3.0, window: int = 50):
        self.factor = factor
        self.window = window
        self.durations: list[float] = []
        self.flags = 0

    def observe(self, seconds: float) -> bool:
        self.durations.append(seconds)
        if len(self.durations) > self.window:
            self.durations.pop(0)
        d = sorted(self.durations)
        n = len(d)
        # true median for BOTH parities: the old d[n // 2] overshoots on
        # even-length windows (upper of the two middle elements), which
        # under-flagged stragglers whenever half the window was slow
        med = d[n // 2] if n % 2 else 0.5 * (d[n // 2 - 1] + d[n // 2])
        is_straggler = n >= 5 and seconds > self.factor * med
        if is_straggler:
            self.flags += 1
        return is_straggler


class FaultTolerantLoop:
    """Run a training step function with checkpoint/restart.

    ``state`` is an arbitrary pytree (params, opt state, ...).  On an
    exception from ``step_fn`` the loop restores the latest checkpoint and
    replays from there (deterministic data makes the replay exact).  After
    ``max_restarts`` consecutive failures it calls ``on_degrade`` — the
    elastic-scaling hook (e.g. rebuild a smaller mesh and reshard via
    ``checkpoint.restore(..., mesh=new_mesh)``).
    """

    def __init__(self, cfg: FTConfig, step_fn: Callable[[Any, int], Any],
                 *, on_degrade: Callable[[], Any] | None = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.on_degrade = on_degrade
        self.monitor = StragglerMonitor(cfg.straggler_factor)
        self.restarts = 0
        self.metrics_log: list[dict] = []

    def run(self, state: Any, *, start_step: int = 0,
            num_steps: int = 100) -> tuple[Any, int]:
        step = start_step
        consecutive_failures = 0
        pending_save = None
        while step < start_step + num_steps:
            t0 = time.monotonic()
            try:
                state, metrics = self.step_fn(state, step)
            except Exception as e:  # noqa: BLE001 - the whole point
                log.warning("step %d failed: %r", step, e)
                self.restarts += 1
                consecutive_failures += 1
                if consecutive_failures > self.cfg.max_restarts:
                    if self.on_degrade is not None:
                        log.warning("degrading after %d failures",
                                    consecutive_failures)
                        state = self.on_degrade()
                        consecutive_failures = 0
                        continue
                    raise
                try:
                    state, step = checkpoint.restore(
                        self.cfg.ckpt_dir, state)
                    log.warning("restored checkpoint at step %d", step)
                except FileNotFoundError:
                    log.warning("no checkpoint; retrying step %d", step)
                continue
            consecutive_failures = 0
            dt = time.monotonic() - t0
            if self.monitor.observe(dt):
                log.warning("straggler step %d: %.3fs", step, dt)
            self.metrics_log.append(
                {"step": step, "dt": dt, **jax_scalarize(metrics)})
            step += 1
            if step % self.cfg.ckpt_every == 0:
                if self.cfg.async_save:
                    pending_save = checkpoint.save_async(
                        self.cfg.ckpt_dir, step, state, keep=self.cfg.keep)
                else:
                    checkpoint.save(self.cfg.ckpt_dir, step, state,
                                    keep=self.cfg.keep)
        if pending_save is not None:
            pending_save.join(timeout=30.0)
        return state, step


def jax_scalarize(metrics: dict) -> dict:
    out = {}
    for k, v in metrics.items():
        try:
            out[k] = float(v)
        except (TypeError, ValueError):
            pass
    return out


# ---------------------------------------------------------------------------
# Serving checkpoint/restart: persist a BatchedServer's in-flight state
# ---------------------------------------------------------------------------

def snapshot_server(server) -> dict:
    """Capture a server's in-flight serving state (see
    ``BatchedServer.snapshot``): every live / preempted / queued
    sequence with its partial output, position and KV pages.  Under
    async prefill (``prefill_async=True``) completed-but-unadopted
    KV handoffs are serialized too (their staged remote-tier pages
    ride along like preemption stashes), so a server killed
    mid-handoff restores and finishes bit-identically.  Call between
    ``run_once`` calls (no block in flight)."""
    return server.snapshot()


def restore_server(server, snap: dict) -> None:
    """Rehydrate a snapshot into a freshly constructed server (same
    model/params/config).  In-flight sequences come back as swapped-out
    stashes and resume page-granularly; queued ones rejoin the backlog."""
    server.restore(snap)


def save_server_snapshot(path, snap: dict):
    """Persist a server snapshot to ``<path>/`` (``arrays.npz`` +
    ``manifest.json``, atomic via the checkpoint module's tmp-rename
    idiom) so a crashed server *process* can restore."""
    import json
    import shutil
    from pathlib import Path

    import numpy as np

    path = Path(path)
    tmp = path.parent / f".tmp_{path.name}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    arrays: dict = {}
    seqs = []
    for i, s in enumerate(snap["sequences"]):
        entry = {k: s[k] for k in ("uid", "max_new_tokens", "output", "pos")}
        # request-lifecycle metadata (arrival block, SLA deadline): a
        # restarted server rebases these so remaining TTLs carry over
        for k in ("submitted_block", "deadline_blocks"):
            if s.get(k) is not None:
                entry[k] = int(s[k])
        # hierarchy level of the stash (remote / cold): a restored
        # server re-adopts it in the SAME tier it was parked in
        if s.get("tier") is not None:
            entry["tier"] = str(s["tier"])
        arrays[f"seq{i}_prompt"] = np.asarray(s["prompt"], np.int32)
        if s["pos"]:
            # quantized pools persist their dequant scales alongside the
            # values so a restored server resumes bit-identically
            for pool in ("k", "v", "k_scale", "v_scale"):
                if pool not in s:
                    continue
                arr = np.asarray(s[pool])
                entry[f"{pool}_dtype"] = arr.dtype.name
                arrays[f"seq{i}_{pool}"] = checkpoint._storage_view(arr)
        seqs.append(entry)
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {k: snap[k] for k in snap if k != "sequences"}
    manifest["sequences"] = seqs
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if path.exists():
        shutil.rmtree(path)
    tmp.rename(path)
    return path


def load_server_snapshot(path) -> dict:
    """Load a snapshot written by :func:`save_server_snapshot`."""
    import json
    from pathlib import Path

    import numpy as np

    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "arrays.npz")
    snap = {k: v for k, v in manifest.items() if k != "sequences"}
    snap["sequences"] = []
    for i, entry in enumerate(manifest["sequences"]):
        s = dict(entry)
        s["prompt"] = data[f"seq{i}_prompt"]
        if s["pos"]:
            for pool in ("k", "v", "k_scale", "v_scale"):
                if f"{pool}_dtype" not in s:
                    continue
                s[pool] = checkpoint._unstorage_view(
                    data[f"seq{i}_{pool}"], s.pop(f"{pool}_dtype"))
        snap["sequences"].append(s)
    return snap
