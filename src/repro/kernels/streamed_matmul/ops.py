"""jit'd public wrapper for the streamed matmul (padding + dispatch)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.streamed_matmul.kernel import streamed_matmul
from repro.kernels.streamed_matmul.ref import streamed_matmul_ref


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "bn", "interpret"))
def matmul(x: jax.Array, w: jax.Array, *, bm: int = 256, bk: int = 512,
           bn: int = 256, interpret: bool = False) -> jax.Array:
    """Padded, jit'd streamed matmul; shapes need not be block-aligned."""
    m, k = x.shape
    _, n = w.shape
    bm_, bk_, bn_ = min(bm, m) or 1, min(bk, k) or 1, min(bn, n) or 1
    xp = _pad_to(x, bm_, bk_)
    wp = _pad_to(w, bk_, bn_)
    out = streamed_matmul(xp, wp, bm=bm_, bk=bk_, bn=bn_,
                          interpret=interpret)
    return out[:m, :n]


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return streamed_matmul_ref(x, w)
