"""Benchmark: Pallas kernels vs their jnp oracles (interpret mode on CPU —
functional timings, not TPU performance claims) + static VMEM-footprint
derivations for the TPU target block shapes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hw


def _time(fn, *args, iters=3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list[str]:
    rows = []
    rng = np.random.RandomState(0)

    from repro.kernels.streamed_matmul import ops as sm
    x = jnp.asarray(rng.randn(256, 512), jnp.float32)
    w = jnp.asarray(rng.randn(512, 256), jnp.float32)
    us = _time(lambda a, b: sm.matmul(a, b, bm=128, bk=128, bn=128,
                                      interpret=True), x, w)
    err = float(jnp.abs(sm.matmul(x, w, bm=128, bk=128, bn=128,
                                  interpret=True) -
                        sm.matmul_ref(x, w)).max())
    vmem = (128 * 128 + 128 * 128) * 4 + 128 * 128 * 4
    rows.append(f"kernel_streamed_matmul,{us:.0f},maxerr={err:.2e} "
                f"vmem_block={vmem/1024:.0f}KiB "
                f"(fits {hw.TPU_V5E.vmem_capacity//2**20}MiB VMEM)")

    from repro.kernels.flash_attention import ops as fa
    q = jnp.asarray(rng.randn(1, 128, 4, 64), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(1, 128, 2, 64), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(1, 128, 2, 64), jnp.float32)
    us = _time(lambda a, b, c: fa.attention(a, b, c, bq=64, bk=64,
                                            interpret=True), q, k, v)
    err = float(jnp.abs(fa.attention(q, k, v, bq=64, bk=64, interpret=True) -
                        fa.attention_ref(q, k, v)).max())
    rows.append(f"kernel_flash_attention,{us:.0f},maxerr={err:.2e} "
                f"blocks=(64,64) online-softmax")

    from repro.kernels.paged_attention import ops as pa
    kp = jnp.asarray(rng.randn(16, 8, 2, 64), jnp.float32) * 0.3
    vp = jnp.asarray(rng.randn(16, 8, 2, 64), jnp.float32)
    qq = jnp.asarray(rng.randn(2, 2, 2, 64), jnp.float32) * 0.3
    table = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 0]], jnp.int32)
    lens = jnp.asarray([30, 20], jnp.int32)
    us = _time(lambda *a: pa.attend(*a, interpret=True),
               qq, kp, vp, table, lens)
    err = float(jnp.abs(pa.attend(qq, kp, vp, table, lens, interpret=True) -
                        pa.attend_ref(qq, kp, vp, table, lens)).max())
    rows.append(f"kernel_paged_attention,{us:.0f},maxerr={err:.2e} "
                f"scalar-prefetched page table")

    from repro.kernels.write_accumulate import ops as wa
    sh = jnp.asarray(rng.randn(8, 64, 512), jnp.float32)
    us = _time(lambda a: wa.accumulate(a, interpret=True), sh)
    err = float(jnp.abs(wa.accumulate(sh, interpret=True) -
                        wa.accumulate_ref(sh)).max())
    rows.append(f"kernel_write_accumulate,{us:.0f},maxerr={err:.2e} "
                f"TAB line-rate reduction emulation")
    return rows
