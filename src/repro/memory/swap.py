"""PageSwapper: batched KV-page transfers between the device block pool
and the remote tier — the mechanism behind page-granular preemption.

Swapping a victim sequence out gathers its live pages from the stacked
device pools in ONE batched take per pool, moves the bytes to the remote
tier (host-resident stash on every backend; on CPU local == remote and
the copy degenerates to a host copy with identical semantics), and hands
back an opaque :class:`SwapHandle`.  Swapping back in scatters the
stashed pages into freshly allocated page ids with one donated dispatch
per pool pair — bucketed to a power-of-two page count so executables
stay O(log pool) over a server's lifetime.

Every transfer is a *fallible, bounded-latency* operation: it runs
through :func:`repro.memory.tiers.transfer_with_retry` (fault-injection
checkpoint, retry with exponential backoff, timeout) and reports its
duration to an optional :class:`repro.runtime.ft.StragglerMonitor` so
slow tier transfers are flagged.  Stashed bytes are ledger-accounted in
the remote tier under the ``kv_swap`` tensor class.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.memory import tiers
from repro.memory.accounting import MemoryLedger


@dataclasses.dataclass
class SwapHandle:
    """Remote-tier stash of one sequence's KV pages (host arrays).

    Quantized pools stash their per-slot dequant scales alongside the
    values (``k_scale``/``v_scale``, (L, n, page, Hkv)) so a restore is
    byte-for-byte the pages that were swapped out — the quantized
    preemption bit-identity contract."""

    page_count: int
    k: np.ndarray            # (L, n, page, Hkv, hd)
    v: np.ndarray
    nbytes: int
    k_scale: np.ndarray | None = None
    v_scale: np.ndarray | None = None


def _bucket_pages(n: int, quantum: int = 4) -> int:
    b = quantum
    while b < n:
        b *= 2
    return b


class PageSwapper:
    """Batched swap-out/swap-in of block-pool KV pages.

    One instance per server; ``retries``/``backoff_s``/``timeout_s``
    parameterize the transfer contract and ``monitor`` (a
    ``StragglerMonitor``) flags slow transfers.  The swap-in scatter is
    jitted with the pool donated, so restores splice into the live cache
    without copying it.
    """

    tensor_class = "kv_swap"

    def __init__(self, *, ledger: MemoryLedger | None = None,
                 tier: str = tiers.REMOTE, retries: int = 3,
                 backoff_s: float = 0.001, timeout_s: float | None = None,
                 monitor=None):
        self.ledger = ledger
        self.tier = tier
        self.retries = retries
        self.backoff_s = backoff_s
        self.timeout_s = timeout_s
        self.monitor = monitor
        self.swap_outs = 0
        self.swap_ins = 0
        self.retry_attempts = 0      # failed attempts that were retried
        self._stash_bytes = 0
        self._stash_hwm = 0
        self._scatter = jax.jit(self._scatter_fn, donate_argnums=(0,))

    # ----- ledger ------------------------------------------------------------
    def _record(self) -> None:
        if self.ledger is not None:
            self.ledger.record(self.tier, self.tensor_class,
                               self._stash_bytes)
            # the stash arena grows on demand: its provisioned capacity
            # is the largest footprint it ever held, keeping the tier's
            # hwm <= capacity invariant auditable
            self._stash_hwm = max(self._stash_hwm, self._stash_bytes)
            self.ledger.record_capacity(self.tier, self.tensor_class,
                                        self._stash_hwm)

    def _transfer(self, fn, *, what: str, nbytes: int):
        before = (tiers.active_fault_plan().failures
                  if tiers.active_fault_plan() else 0)
        try:
            return tiers.transfer_with_retry(
                fn, what=what, nbytes=nbytes, retries=self.retries,
                backoff_s=self.backoff_s, timeout_s=self.timeout_s,
                monitor=self.monitor)
        finally:
            plan = tiers.active_fault_plan()
            if plan is not None:
                self.retry_attempts += plan.failures - before

    # ----- swap out ----------------------------------------------------------
    def swap_out(self, cache: dict, page_ids: list[int]) -> SwapHandle:
        """Gather ``page_ids`` from the stacked pools and stash them in
        the remote tier; raises :class:`tiers.TierTransferError` after
        the retry budget is exhausted (the caller's degradation policy —
        shed the victim — takes over)."""
        pids = jnp.asarray(page_ids, jnp.int32)
        grab = [jnp.take(cache["k_pages"], pids, axis=1),
                jnp.take(cache["v_pages"], pids, axis=1)]
        quant = "k_scale" in cache
        if quant:
            grab += [jnp.take(cache["k_scale"], pids, axis=1),
                     jnp.take(cache["v_scale"], pids, axis=1)]
        # per-array bytes: a quantized stash mixes int8/fp8 values with
        # bf16 scales, so a single shared itemsize would misaccount
        nbytes = sum(a.size * a.dtype.itemsize for a in grab)

        def pull():
            return [np.asarray(a) for a in jax.device_get(grab)]

        host = self._transfer(pull, what="kv_swap_out", nbytes=nbytes)
        self.swap_outs += 1
        self._stash_bytes += nbytes
        self._record()
        return SwapHandle(page_count=len(page_ids), k=host[0], v=host[1],
                          nbytes=nbytes,
                          k_scale=host[2] if quant else None,
                          v_scale=host[3] if quant else None)

    # ----- swap in -----------------------------------------------------------
    def _scatter_fn(self, cache: dict, pids: jax.Array, k: jax.Array,
                    v: jax.Array, k_scale: jax.Array | None = None,
                    v_scale: jax.Array | None = None) -> dict:
        from repro.runtime.sharding import maybe_constraint
        from jax.sharding import PartitionSpec as P
        k = maybe_constraint(k, P(None, None, None, "model", None))
        v = maybe_constraint(v, P(None, None, None, "model", None))
        out = dict(cache)
        out["k_pages"] = cache["k_pages"].at[:, pids].set(
            k.astype(cache["k_pages"].dtype))
        out["v_pages"] = cache["v_pages"].at[:, pids].set(
            v.astype(cache["v_pages"].dtype))
        if k_scale is not None:
            sc = P(None, None, None, "model")
            out["k_scale"] = cache["k_scale"].at[:, pids].set(
                maybe_constraint(k_scale, sc))
            out["v_scale"] = cache["v_scale"].at[:, pids].set(
                maybe_constraint(v_scale, sc))
        return out

    def swap_in(self, cache: dict, page_ids: list[int],
                handle: SwapHandle) -> dict:
        """Scatter a stash back into freshly allocated ``page_ids`` (same
        order as the swap-out) and release the stash.  Returns the new
        cache; the old one is donated.  Padding entries (bucketed width)
        point at the null page 0, which no live table ever reads."""
        if len(page_ids) != handle.page_count:
            raise ValueError(f"swap_in got {len(page_ids)} pages for a "
                             f"{handle.page_count}-page stash")
        n = handle.page_count
        cap = _bucket_pages(max(n, 1))
        pids = np.zeros(cap, np.int32)
        pids[:n] = page_ids
        pad = ((0, 0), (0, cap - n)) + ((0, 0),) * (handle.k.ndim - 2)
        k = np.pad(handle.k, pad)
        v = np.pad(handle.v, pad)
        scales = ()
        if handle.k_scale is not None:
            spad = pad[:-1]
            scales = (jnp.asarray(np.pad(handle.k_scale, spad)),
                      jnp.asarray(np.pad(handle.v_scale, spad)))

        def push():
            return self._scatter(cache, jnp.asarray(pids), jnp.asarray(k),
                                 jnp.asarray(v), *scales)

        new_cache = self._transfer(push, what="kv_swap_in",
                                   nbytes=handle.nbytes)
        self.swap_ins += 1
        self.release(handle)
        return new_cache

    def adopt(self, handle: SwapHandle) -> None:
        """Account for a stash produced elsewhere (snapshot restore): the
        bytes join this swapper's remote-tier ledger line as if it had
        swapped them out itself."""
        self._stash_bytes += handle.nbytes
        self._record()

    def release(self, handle: SwapHandle) -> None:
        """Drop a stash without restoring it (victim shed / restore into
        a snapshot)."""
        if handle.nbytes:
            self._stash_bytes -= handle.nbytes
            handle.nbytes = 0
            self._record()
