"""jit'd wrappers for paged decode attention + block-pool management.

Two halves of the FengHuang block-pool KV cache:

* :func:`attend` / :func:`attend_ref` — the kernel-side read path.  The
  Pallas kernel (scalar-prefetched page tables) serves TPU; the gather
  oracle is the jittable fallback everywhere else.  Pick once per backend
  with :func:`use_pallas_kernel`.
* :class:`BlockManager` — the host-side allocator.  It owns ONLY the
  bookkeeping (free list, per-slot page lists, lengths, accounting); the
  stacked ``(L, P, page, Hkv, hd)`` device pools live in the serving
  cache and are donated through every dispatch, with all KV writes done
  on device as batched scatters (one per decode step covering every
  layer and slot, one per prefill covering the whole prompt chunk).

Page 0 is the reserved **null page**: table padding and the write slots
of idle/finished sequences point at it, so garbage reads are masked by
``seq_lens`` and garbage writes land where no sequence ever looks.
"""
from __future__ import annotations

import functools

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import (gather_pages, gather_scales,
                                               paged_attention_ref)

# Logical specs for the block pool under tensor parallelism: the KV-heads
# axis is "model"-sharded (each device owns its head shard of EVERY
# page), page ids and per-slot tables are replicated host bookkeeping.
# Quantized pools carry per-(token-slot, head) dequant scales that shard
# exactly like their pages (head axis on "model").
POOL_SPEC = P(None, None, "model", None)                 # (P, page, Hkv, hd)
STACKED_POOL_SPEC = P(None, None, None, "model", None)   # (L, P, ...)
SCALE_SPEC = P(None, None, "model")                      # (P, page, Hkv)
STACKED_SCALE_SPEC = P(None, None, None, "model")        # (L, P, page, Hkv)
GATHERED_KV_SPEC = P(None, "model", None, None)          # (B, Hkv, n*pg, hd)
GATHERED_SCALE_SPEC = P(None, "model", None)             # (B, Hkv, n*pg)
PAGE_TABLE_SPEC = P()                                    # replicated


def gather_pages_sharded(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """:func:`gather_pages` with the KV-heads axis constrained to stay
    "model"-sharded: the gather indexes only the (replicated) page axis,
    so under a mesh each device materializes just its head shard of the
    per-sequence view — no cross-device KV movement on the decode read
    path.  Outside a mesh context the constraint is a no-op."""
    from repro.runtime.sharding import maybe_constraint
    return maybe_constraint(gather_pages(pages, page_table),
                            GATHERED_KV_SPEC)


def gather_scales_sharded(scales: jax.Array,
                          page_table: jax.Array) -> jax.Array:
    """:func:`gather_scales` with the head axis kept "model"-sharded,
    mirroring :func:`gather_pages_sharded` for the dequant scales."""
    from repro.runtime.sharding import maybe_constraint
    return maybe_constraint(gather_scales(scales, page_table),
                            GATHERED_SCALE_SPEC)


@functools.lru_cache(maxsize=None)
def use_pallas_kernel() -> bool:
    """Backend selection for the serving hot path, resolved once: the
    Mosaic kernel needs a TPU; everywhere else the gather-based oracle is
    the jittable (and bit-compatible) implementation."""
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - no backend at all
        return False


@functools.partial(jax.jit, static_argnames=("interpret",))
def attend(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
           page_table: jax.Array, seq_lens: jax.Array,
           extra_kv: tuple[jax.Array, jax.Array] | None = None,
           k_scales: jax.Array | None = None,
           v_scales: jax.Array | None = None, *,
           interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, G, d) single decode token -> (B, Hkv, G, d)."""
    return paged_attention(q, k_pages, v_pages, page_table, seq_lens,
                           extra_kv=extra_kv, k_scales=k_scales,
                           v_scales=v_scales, interpret=interpret)


def attend_ref(q, k_pages, v_pages, page_table, seq_lens, extra_kv=None,
               k_scales=None, v_scales=None):
    return paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens,
                               extra_kv=extra_kv, k_scales=k_scales,
                               v_scales=v_scales)


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to map ``tokens`` positions."""
    return -(-tokens // page_size)


class BlockPoolAuditError(AssertionError):
    """An invariant of the block-pool bookkeeping is violated (refcount
    drift, free-list corruption, table/pool inconsistency)."""


class BlockManager:
    """Host-side page allocator for the device-resident block pool.

    Sequences (keyed by serving slot) own ordered lists of fixed-size
    pages from a global pool — the FengHuang remote tier holds the pool;
    per-sequence page tables are the Tensor Prefetcher's routing
    metadata.  Allocation happens at block boundaries (a slot is grown to
    cover its next decode block in one call); reclamation returns a
    finished slot's pages to the free list in LIFO order so hot pages are
    reused first.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the null page)")
        if page_size < 1:
            raise ValueError("page_size must be positive")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free = list(range(num_pages - 1, 0, -1))  # page 0 = null page
        self.pages: dict[int, list[int]] = {}
        self.lens: dict[int, int] = {}
        self.hwm = 0                    # pages-in-use high-water mark
        # prefix caching: pages shared across slots carry a refcount and
        # (for prompt-prefix pages) an entry in the prefix index keyed by
        # the exact token bytes they cover.  A page returns to the free
        # list only when its last owner releases it.
        self.refcount: dict[int, int] = {}
        self._prefix_index: dict[bytes, int] = {}
        self._page_key: dict[int, bytes] = {}
        # prefill->decode handoffs: pages detached from a prefill slot
        # and parked under an opaque token until a decode slot adopts
        # them.  Handoff pages are owned by NO slot but stay refcounted
        # (the handoff IS the owner) — audit() treats each in-flight
        # handoff as a pseudo-slot.
        self._handoffs: dict[int, tuple[list[int], int]] = {}
        self._next_handoff = 1

    # ----- capacity ---------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Allocatable pages (the null page is never handed out)."""
        return self.num_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.capacity - len(self._free)

    def pages_for(self, tokens: int) -> int:
        return pages_for(tokens, self.page_size)

    def can_fit(self, slot: int, tokens: int) -> bool:
        """Would :meth:`ensure`'ing ``tokens`` for ``slot`` succeed now?"""
        have = len(self.pages.get(slot, ()))
        return self.pages_for(tokens) - have <= len(self._free)

    # ----- allocate / reclaim ----------------------------------------------
    def ensure(self, slot: int, tokens: int) -> list[int]:
        """Grow ``slot`` so positions ``[0, tokens)`` are mapped; returns
        the newly allocated page ids (possibly empty).  Raises
        ``MemoryError`` when the pool cannot cover the growth."""
        table = self.pages.setdefault(slot, [])
        need = self.pages_for(tokens) - len(table)
        if need > len(self._free):
            raise MemoryError(
                f"page pool exhausted: slot {slot} needs {need} more "
                f"page(s) for {tokens} tokens, {len(self._free)} free of "
                f"{self.capacity}")
        new = [self._free.pop() for _ in range(max(need, 0))]
        for p in new:
            self.refcount[p] = 1
        table.extend(new)
        self.hwm = max(self.hwm, self.pages_in_use)
        return new

    def adopt(self, slot: int, page_ids: list[int]) -> None:
        """Map ``slot``'s leading table entries onto already-allocated
        pages (prompt-prefix sharing): each adopted page's refcount rises
        by one and NO pool page is consumed.  Only valid on a fresh slot
        — adopted pages must precede any privately allocated ones so the
        table stays position-ordered."""
        table = self.pages.setdefault(slot, [])
        if table:
            raise ValueError(
                f"slot {slot} already owns pages; prefix pages must lead")
        for p in page_ids:
            if self.refcount.get(p, 0) < 1:
                raise ValueError(f"page {p} is not live; cannot adopt")
            self.refcount[p] += 1
        table.extend(page_ids)

    def note_tokens(self, slot: int, tokens: int) -> None:
        """Record that ``slot`` now holds ``tokens`` written positions
        (drives the fragmentation accounting; monotone per slot)."""
        self.lens[slot] = max(self.lens.get(slot, 0), tokens)

    def _release_pages(self, page_ids: list[int]) -> None:
        """Drop one reference from each page (reverse order so LIFO
        reuse favors hot pages).  Pages still referenced by another
        sharer survive; a page whose last reference drops returns to the
        free list and leaves the prefix index."""
        for p in reversed(page_ids):
            rc = self.refcount.get(p, 1) - 1
            if rc > 0:
                self.refcount[p] = rc
                continue
            self.refcount.pop(p, None)
            self._free.append(p)
            key = self._page_key.pop(p, None)
            if key is not None:
                self._prefix_index.pop(key, None)

    def free_slot(self, slot: int) -> None:
        """Release every page owned by ``slot`` (EOS / eviction)."""
        self._release_pages(self.pages.pop(slot, []))
        self.lens.pop(slot, None)

    # ----- prefill->decode handoffs ------------------------------------------
    def detach_to_handoff(self, slot: int) -> int:
        """Detach ``slot``'s pages into a handoff token: the slot
        disappears, its pages keep their refcounts (ownership moves to
        the handoff), and the returned token later rebinds them to a
        decode slot via :meth:`adopt_from_handoff`.  This is the
        allocator half of the prefill->decode page handoff — no page is
        copied, freed, or reallocated across the engine boundary."""
        if slot not in self.pages:
            raise KeyError(f"slot {slot} owns no pages to hand off")
        token = self._next_handoff
        self._next_handoff += 1
        self._handoffs[token] = (self.pages.pop(slot),
                                 self.lens.pop(slot, 0))
        return token

    def adopt_from_handoff(self, slot: int, token: int) -> list[int]:
        """Rebind a handoff's pages to a fresh decode ``slot`` (refcounts
        unchanged — ownership transfers back from the handoff).  Returns
        the page ids, now ``slot``'s table."""
        if token not in self._handoffs:
            raise KeyError(f"unknown handoff token {token}")
        if self.pages.get(slot):
            raise ValueError(
                f"slot {slot} already owns pages; cannot adopt handoff")
        pages, tokens = self._handoffs.pop(token)
        self.pages[slot] = pages
        if tokens:
            self.note_tokens(slot, tokens)
        return list(pages)

    def release_handoff(self, token: int) -> None:
        """Drop an in-flight handoff without adopting it (shed /
        restore-into-snapshot): its pages lose the handoff's reference
        exactly like :meth:`free_slot` releases a slot's."""
        pages, _ = self._handoffs.pop(token, ([], 0))
        self._release_pages(pages)

    @property
    def handoff_pages(self) -> int:
        """Pages currently parked in prefill->decode handoffs."""
        return sum(len(p) for p, _ in self._handoffs.values())

    # ----- prompt-prefix index ----------------------------------------------
    def register_prefix(self, key: bytes, page_id: int) -> None:
        """Publish a fully written prompt page under the exact token
        bytes it covers (position-dependent: the key is the whole padded
        prompt up to and including this page).  First writer wins; the
        entry lives exactly as long as the page has owners."""
        if key in self._prefix_index:
            return
        if self.refcount.get(page_id, 0) < 1:
            raise ValueError(f"page {page_id} is not live; cannot index")
        self._prefix_index[key] = page_id
        self._page_key[page_id] = key

    def lookup_prefix(self, key: bytes) -> int | None:
        return self._prefix_index.get(key)

    @property
    def shared_pages(self) -> int:
        """Logical pages served by sharing beyond their physical count
        (sum of refcount - 1 over multiply-owned pages)."""
        return sum(rc - 1 for rc in self.refcount.values() if rc > 1)

    # ----- tables -----------------------------------------------------------
    def slot_pages(self, slot: int) -> list[int]:
        return list(self.pages.get(slot, ()))

    def max_slot_pages(self) -> int:
        return max((len(t) for t in self.pages.values()), default=0)

    def table(self, slots: list[int], n_pages: int) -> np.ndarray:
        """(len(slots), n_pages) int32 page table, null-page padded."""
        out = np.zeros((len(slots), n_pages), np.int32)
        for i, s in enumerate(slots):
            t = self.pages.get(s, [])[:n_pages]
            out[i, : len(t)] = t
        return out

    # ----- invariants -------------------------------------------------------
    def audit(self) -> dict:
        """Cross-check every allocator invariant; raises
        :class:`BlockPoolAuditError` on the first violation, returns a
        summary dict when clean.

        Invariants: the null page is never owned or free-listed; free
        pages are unique, in range, and disjoint from every table; a
        slot's table holds no duplicate pages; each live page's refcount
        equals its owner count across tables; free + allocated ==
        capacity; the prefix index and its page->key inverse agree and
        only reference live pages; recorded lengths fit their tables;
        the high-water mark bounds current occupancy.  In-flight
        prefill->decode handoffs participate as pseudo-slots (owned by
        no slot, refcounted by the handoff).  Called after every decode
        block in the server's audit mode — the race/corruption detector
        for the whole paged stack."""
        def fail(msg: str):
            raise BlockPoolAuditError(f"block-pool audit: {msg}")

        free = self._free
        free_set = set(free)
        if len(free_set) != len(free):
            fail(f"free list holds duplicates ({len(free) - len(free_set)})")
        bad = [p for p in free_set if not 1 <= p < self.num_pages]
        if bad:
            fail(f"free list holds out-of-range/null pages {sorted(bad)}")
        owners: dict[int, int] = {}
        # in-flight prefill->decode handoffs are pseudo-slots: their
        # pages are owned by no slot but must stay refcounted, in range,
        # and disjoint from the free list until adopted or released
        tables = list(self.pages.items()) + [
            (f"handoff:{tok}", pages)
            for tok, (pages, _) in self._handoffs.items()]
        for slot, table in tables:
            if len(set(table)) != len(table):
                fail(f"slot {slot} maps a page twice: {table}")
            for p in table:
                if not 1 <= p < self.num_pages:
                    fail(f"slot {slot} maps out-of-range/null page {p}")
                if p in free_set:
                    fail(f"page {p} is both free and owned by slot {slot}")
                owners[p] = owners.get(p, 0) + 1
        if set(self.refcount) != set(owners):
            fail(f"refcount keys {sorted(self.refcount)} != allocated "
                 f"pages {sorted(owners)}")
        for p, rc in self.refcount.items():
            if rc != owners[p]:
                fail(f"page {p} refcount {rc} != owner count {owners[p]}")
        if len(free) + len(owners) != self.capacity:
            fail(f"{len(free)} free + {len(owners)} allocated != "
                 f"capacity {self.capacity}")
        for key, p in self._prefix_index.items():
            if self._page_key.get(p) != key:
                fail(f"prefix index maps {key!r} -> page {p} but the "
                     f"inverse disagrees")
            if self.refcount.get(p, 0) < 1:
                fail(f"prefix index references dead page {p}")
        for p, key in self._page_key.items():
            if self._prefix_index.get(key) != p:
                fail(f"page-key inverse {p} -> {key!r} missing from the "
                     f"prefix index")
        for slot, n in self.lens.items():
            cover = len(self.pages.get(slot, ())) * self.page_size
            if n > cover:
                fail(f"slot {slot} records {n} tokens but its table "
                     f"covers only {cover}")
        for tok, (pages, n) in self._handoffs.items():
            if n > len(pages) * self.page_size:
                fail(f"handoff {tok} records {n} tokens but covers only "
                     f"{len(pages) * self.page_size}")
        if self.hwm < self.pages_in_use:
            fail(f"hwm {self.hwm} < pages in use {self.pages_in_use}")
        if self.hwm > self.capacity:
            fail(f"hwm {self.hwm} > capacity {self.capacity} (occupancy "
                 f"exceeded the provisioned pool)")
        return {"pages_in_use": self.pages_in_use,
                "free_pages": len(free), "slots": len(self.pages),
                "shared_pages": self.shared_pages,
                "handoff_pages": self.handoff_pages}

    # ----- accounting -------------------------------------------------------
    def bytes_per_page(self, kv_heads: int, head_dim: int,
                       itemsize: int = 2, num_layers: int = 1,
                       scale_itemsize: int = 0) -> int:
        """Bytes ONE page occupies across both pools and all layers.

        ``scale_itemsize`` > 0 adds the per-(token-slot, head) dequant
        scale storage of a quantized pool (one scale per position per KV
        head per pool), so quantized accounting charges TRUE bytes —
        scales included — and ``capacity_reduction`` stays comparable."""
        return (2 * num_layers * self.page_size * kv_heads
                * (head_dim * itemsize + scale_itemsize))

    def fragmentation(self) -> float:
        """Fraction of in-use page slots holding no live token (tail
        waste of partially filled last pages).  With prefix sharing the
        logical token count can exceed the physical slot count (that is
        the point), so the result is clamped at 0."""
        in_use = self.pages_in_use * self.page_size
        if not in_use:
            return 0.0
        live = sum(min(self.lens.get(s, 0), len(t) * self.page_size)
                   for s, t in self.pages.items())
        return max(0.0, 1.0 - live / in_use)


# The deprecated host-driven ``PagePool`` wrapper that used to live here
# is gone; host-side pool experiments go through
# ``repro.memory.policies.BlockPoolResidency`` (same BlockManager
# bookkeeping, batched ``append_block`` writes, ledger accounting).
