"""Mixture-of-Experts LM (moonshot-v1-16b-a3b: 64e top-6; granite-moe-3b:
40e top-8 padded to 48) with expert parallelism over the model axis.

Dispatch is GShard-style capacity-based scatter/gather:

    route -> top_k -> position-in-expert (cumsum) -> scatter to (E, C, d)
    -> grouped expert GEMMs (E-sharded = expert parallelism) -> gather back

which keeps HLO memory at O(T·k·d + E·C·d) (no T×E×C dispatch tensors) and
makes the expert GEMM flops exactly 2·E·C·d·f — the quantity the roofline
needs.  Under GSPMD the (E, C, d) buffers shard over the model axis and the
scatter/gather lower to the all-to-all pattern of Fig 3.6 — on FengHuang
those are single shared-memory hops (tab schedule).

FengHuang fit (DESIGN.md §4): inactive experts never leave the remote tier;
with paging enabled the per-layer expert bank pages through local memory
while other layers compute — the paper's §2.1 motivation verbatim.

With ``PagerPolicy.page_experts`` the banks go one step further: they
stay at rest in the remote tier even while their layer computes, and
:func:`moe_ffn_topk` pages in only the rows the router selects (the
``TopKExpertPrefetch`` residency policy) — resident expert bytes drop to
``(tokens·top_k + 1) / num_experts`` of the dense bank, the
capacity-bound regime where disaggregated-memory designs pay off most.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.base import ModelConfig, dense_init
from repro.models.transformer import DenseLM


def capacity(tokens: int, num_experts: int, top_k: int, factor: float) -> int:
    c = int(math.ceil(tokens * top_k * factor / num_experts))
    return max(4, ((c + 3) // 4) * 4)


def moe_params(key, cfg: ModelConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    e, d, f = cfg.padded_experts, cfg.d_model, cfg.d_ff
    return {
        "router": dense_init(k1, (d, e), jnp.float32),
        "wi": dense_init(k2, (e, d, f), cfg.dtype),
        "wg": dense_init(k3, (e, d, f), cfg.dtype),
        "wo": dense_init(k4, (e, f, d), cfg.dtype),
    }


def moe_specs() -> dict:
    return {
        "router": P(None, None, None),
        "wi": P(None, "model", None, None),
        "wg": P(None, "model", None, None),
        "wo": P(None, "model", None, None),
    }


def moe_ffn(p: dict, x: jax.Array, cfg: ModelConfig,
            return_aux: bool = False):
    """x: (B, S, d) -> (B, S, d)[, aux_loss]."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.padded_experts, cfg.top_k
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    # mask padded experts
    col = jnp.arange(e)
    logits = jnp.where(col[None, :] < cfg.num_experts, logits, L.NEG_INF)
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_i = jax.lax.top_k(gates, k)                   # (T, k)
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

    cap = capacity(t, cfg.num_experts, k, cfg.capacity_factor)
    # position of each (token, choice) within its expert queue
    oh = jax.nn.one_hot(top_i, e, dtype=jnp.int32)           # (T, k, E)
    flat = oh.reshape(t * k, e)
    pos = (jnp.cumsum(flat, axis=0) - 1)                     # (T*k, E)
    pos_in_e = jnp.take_along_axis(
        pos.reshape(t, k, e), top_i[..., None], axis=-1)[..., 0]  # (T, k)
    keep = pos_in_e < cap
    safe_pos = jnp.where(keep, pos_in_e, cap - 1)

    # scatter tokens to (E, C, d) — expert parallelism: E over the model
    # axis.  Explicit constraints keep the dispatch/combine as
    # scatter/gather against E-sharded buffers with a single (T, d)
    # partial-sum reduction, instead of all-reducing the k-expanded
    # (T*k, d) tensor (§Perf iteration B: ~6x less MoE wire traffic).
    from repro.runtime.sharding import maybe_constraint
    from jax.sharding import PartitionSpec as P

    buf = jnp.zeros((e, cap, d), x.dtype)
    ei = top_i.reshape(-1)
    pi = safe_pos.reshape(-1)
    src = jnp.repeat(xt, k, axis=0) * keep.reshape(-1, 1).astype(x.dtype)
    buf = buf.at[ei, pi].add(src)
    buf = maybe_constraint(buf, P("model", None, None))

    # expert GEMMs (EP over model axis)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    out_e = jnp.einsum("ecf,efd->ecd", h, p["wo"])           # (E, C, d)
    out_e = maybe_constraint(out_e, P("model", None, None))

    # gather back and combine with gates
    gathered = out_e[ei, pi]                                 # (T*k, d)
    w = (top_g.reshape(-1) * keep.reshape(-1)).astype(x.dtype)
    combined = (gathered * w[:, None]).reshape(t, k, d).sum(axis=1)
    out = combined.reshape(b, s, d)
    from repro.models.base import BATCH_AXES
    out = maybe_constraint(out, P(BATCH_AXES, "model", None))

    if not return_aux:
        return out
    # GShard load-balance loss: E * sum_e f_e * P_e
    me = gates.mean(axis=0)                                  # (E,)
    ce = (jnp.sum(jax.nn.one_hot(top_i, e), axis=(0, 1)) /
          jnp.maximum(t * k, 1))
    aux = cfg.num_experts * jnp.sum(me * ce)
    return out, aux


# ---------------------------------------------------------------------------
# All-to-all expert parallelism (§Perf iteration D — the paper's Fig 3.6
# AllToAll pattern).  Tokens are seq-sharded; each device routes its local
# tokens, exchanges per-expert queues with the expert owners via two
# all-to-alls (wire ~= T*k*d per device instead of all-reducing E-sharded
# (E, C, d) buffers), runs its local experts' GEMMs, and combines locally.
# ---------------------------------------------------------------------------

def _moe_ep_available(cfg: ModelConfig, s: int) -> bool:
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:   # pragma: no cover
        return False
    if am is None or getattr(am, "empty", True):
        return False
    sizes = dict(zip(am.axis_names, am.axis_sizes))
    tp = sizes.get("model", 1)
    return (tp > 1 and s % tp == 0 and cfg.padded_experts % tp == 0)


def moe_ffn_ep(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """shard_map EP MoE.  x: (B, S, d) with S divisible by the model axis."""
    from repro.models.base import BATCH_AXES
    am = jax.sharding.get_abstract_mesh()
    sizes = dict(zip(am.axis_names, am.axis_sizes))
    tp = sizes["model"]
    e, k, d = cfg.padded_experts, cfg.top_k, cfg.d_model
    batch_axes = tuple(a for a in BATCH_AXES if a in sizes)

    def local(xs, router, wi, wg, wo):
        b_loc, s_loc, _ = xs.shape
        t = b_loc * s_loc
        xt = xs.reshape(t, d)
        logits = xt.astype(jnp.float32) @ router
        col = jnp.arange(e)
        logits = jnp.where(col[None, :] < cfg.num_experts, logits, L_NEG)
        gates = jax.nn.softmax(logits, axis=-1)
        top_g, top_i = jax.lax.top_k(gates, k)
        top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

        cap = capacity(t, cfg.num_experts, k, cfg.capacity_factor)
        oh = jax.nn.one_hot(top_i, e, dtype=jnp.int32)
        pos = jnp.cumsum(oh.reshape(t * k, e), axis=0) - 1
        pos_in_e = jnp.take_along_axis(
            pos.reshape(t, k, e), top_i[..., None], axis=-1)[..., 0]
        keep = pos_in_e < cap
        safe_pos = jnp.where(keep, pos_in_e, cap - 1)
        ei = top_i.reshape(-1)
        pi = safe_pos.reshape(-1)
        src = jnp.repeat(xt, k, axis=0) * keep.reshape(-1, 1).astype(xs.dtype)
        buf = jnp.zeros((e, cap, d), xs.dtype).at[ei, pi].add(src)

        # ship queues to the expert owners (TAB AllToAll on FengHuang)
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                 tiled=True)        # (E/tp, tp*cap, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
            jnp.einsum("ecd,edf->ecf", buf, wi)
        out_e = jnp.einsum("ecf,efd->ecd", h, wo)   # (E/tp, tp*cap, d)
        out_e = jax.lax.all_to_all(out_e, "model", split_axis=1,
                                   concat_axis=0, tiled=True)  # (E, cap, d)

        gathered = out_e[ei, pi]
        w = (top_g.reshape(-1) * keep.reshape(-1)).astype(xs.dtype)
        combined = (gathered * w[:, None]).reshape(t, k, d).sum(axis=1)
        return combined.reshape(b_loc, s_loc, d)

    from jax.sharding import PartitionSpec as P
    return jax.shard_map(
        local, mesh=am,
        in_specs=(P(batch_axes or None, "model", None),   # x seq-sharded
                  P(None, None),                          # router replicated
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(batch_axes or None, "model", None),
        check_vma=False,
    )(x, p["router"], p["wi"], p["wg"], p["wo"])


L_NEG = -1e30


# ---------------------------------------------------------------------------
# Expert-tier paging (repro.memory TopKExpertPrefetch policy): banks at
# rest in the remote tier, only routed rows paged in.
# ---------------------------------------------------------------------------

def moe_ffn_topk(p: dict, x: jax.Array, cfg: ModelConfig, mem) -> jax.Array:
    """MoE FFN that touches only the routed experts.

    x: (B, S, d).  Routing (logits -> softmax -> top-k -> capacity keep)
    is identical to :func:`moe_ffn`; the expert GEMMs are computed
    per-(token, choice) against ``tokens x k`` gathered bank rows
    (``mem.gather_experts`` — a page-in of just those rows when the
    banks live in the remote tier) instead of dense (E, C, d) buffers.
    Single-device path: expert parallelism keeps the EP all-to-all
    route; this one exists so expert weights can stay remote.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.padded_experts, cfg.top_k
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    col = jnp.arange(e)
    logits = jnp.where(col[None, :] < cfg.num_experts, logits, L.NEG_INF)
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_i = jax.lax.top_k(gates, k)                   # (T, k)
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

    # capacity keep — bit-compatible with the dense dispatch's dropping
    cap = capacity(t, cfg.num_experts, k, cfg.capacity_factor)
    oh = jax.nn.one_hot(top_i, e, dtype=jnp.int32)
    pos = jnp.cumsum(oh.reshape(t * k, e), axis=0) - 1
    pos_in_e = jnp.take_along_axis(
        pos.reshape(t, k, e), top_i[..., None], axis=-1)[..., 0]
    keep = pos_in_e < cap                                     # (T, k)

    ids = top_i.reshape(-1)                                   # (T*k,)
    rows = mem.gather_experts(p, ids)      # each (T*k, d, f) / (T*k, f, d)
    x_rep = jnp.repeat(xt, k, axis=0)                         # (T*k, d)
    h = jax.nn.silu(jnp.einsum("td,tdf->tf", x_rep, rows["wg"])) * \
        jnp.einsum("td,tdf->tf", x_rep, rows["wi"])
    out_tok = jnp.einsum("tf,tfd->td", h, rows["wo"])         # (T*k, d)
    w = (top_g.reshape(-1) * keep.reshape(-1)).astype(x.dtype)
    combined = (out_tok.astype(x.dtype) * w[:, None]).reshape(t, k, d) \
        .sum(axis=1)
    return combined.reshape(b, s, d)


class MoELM(DenseLM):
    """DenseLM with the FFN swapped for a top-k expert bank."""

    def init_layer(self, key) -> dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "attn": L.attn_params(k1, cfg),
            "moe": moe_params(k2, cfg),
            "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
            "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
        }

    def layer_specs(self) -> dict:
        return {
            "attn": L.attn_specs(self.cfg),
            "moe": moe_specs(),
            "ln1": P(None, None), "ln2": P(None, None),
        }

    def ffn(self, lp: dict, x: jax.Array, *,
            gather_tp: bool = False) -> jax.Array:
        # ``gather_tp`` is the dense-family all-gather-TP knob; the MoE
        # combine already sums expert outputs in replicated f32, so the
        # flag has nothing extra to gather here.
        # expert paging first: banks are at rest in the remote tier, so
        # the dense (E, C, d) dispatch would drag the whole bank through
        # local memory — gather only the routed rows instead.  (EP over a
        # live mesh supersedes it: sharded banks ARE distributed memory.)
        if self.mem.expert_policy is not None \
                and not _moe_ep_available(self.cfg, x.shape[1]):
            return moe_ffn_topk(lp["moe"], x, self.cfg, self.mem)
        if _moe_ep_available(self.cfg, x.shape[1]):
            return moe_ffn_ep(lp["moe"], x, self.cfg)
        return moe_ffn(lp["moe"], x, self.cfg)
