"""Flash attention (prefill) Pallas kernel.

Grid: (batch*heads, num_q_blocks, num_kv_blocks) with the online-softmax
state (m, l, acc) held in VMEM scratch across the innermost (kv) grid
dimension.  Q/K/V blocks stream HBM->VMEM via BlockSpec tiling — on TPU
the Mosaic pipeline double-buffers them, the kernel-level expression of
the FengHuang paging stream.

Causal masking skips nothing structurally (blocks beyond the diagonal are
masked, not skipped) — the kernel stays grid-static; the jnp path in
``models.layers`` handles dynamic skipping for the huge-prefill case.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            n_kv: int, q_offset: int, kv_valid: int):
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (bq, d)
    k = k_ref[0]                                   # (bk, d)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (bq, bk)

    q_pos = q_offset + q_idx * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    k_pos = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < kv_valid            # padded KV rows never attend
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kv_idx == n_kv - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    bq: int = 256, bk: int = 256, q_offset: int = 0,
                    kv_valid: int | None = None,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, d); k, v: (BH, Sk, d) -> (BH, Sq, d).

    Heads are folded into the leading dim (ops.py does the fold and the
    GQA group expansion).  Sq % bq == Sk % bk == 0 required.
    """
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    n_kv = sk // bk
    grid = (bh, sq // bq, n_kv)
    scale = 1.0 / math.sqrt(d)
    kv_valid = sk if kv_valid is None else kv_valid

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, n_kv=n_kv,
                          q_offset=q_offset, kv_valid=kv_valid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
