"""whisper-base: 6L(dec)+6L(enc) d=512 8H d_ff=2048 vocab=51865; enc-dec
with conv/mel frontend STUBBED (precomputed frame embeddings)
[arXiv:2212.04356].  Full attention => long_500k skipped."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab=51865, head_dim=64,
    num_encoder_layers=6, encoder_seq=1500,
)
