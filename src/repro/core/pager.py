"""TensorPager — FengHuang two-tier memory orchestration in JAX (§3.2).

Maps the paper's local/remote split onto JAX memory spaces:

* **remote tier**  = ``memory_kind="pinned_host"`` (host DRAM behind the
  DMA engine — the TAB-attached LPDDR6 pool in the paper's node),
* **local tier**   = ``memory_kind="device"`` (HBM).

The *Tensor Prefetcher* becomes :func:`paged_scan`: a scan over stacked
per-layer weights whose carry holds a **double buffer** — iteration *i*
computes layer *i* from the already-fetched buffer while the fetch of layer
*i+1* is issued *before* the compute, so XLA's async copy-start/copy-done
pair (the "paging stream") overlaps the transfer with layer *i*'s compute.
Peak device residency is 2 layers of weights + activations, which is the
paper's Table 4.3 result (10–20 GB instead of 144 GB).

Everything degrades gracefully: with ``enabled=False`` (or on backends
without host memory spaces) the transform is a plain ``lax.scan`` over
device-resident weights, so models are paging-agnostic.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

REMOTE_KIND = "pinned_host"
LOCAL_KIND = "device"

# Host-side kinds that can back the FengHuang remote tier, best first.
# GPU/TPU expose "pinned_host"; the CPU backend only has "unpinned_host"
# (where local == remote, so paging degenerates to the identity — the
# semantics stay intact and tests exercise the full transform).
_HOST_KINDS = ("pinned_host", "unpinned_host")

try:  # public since jax 0.5
    from jax.sharding import TransferToMemoryKind as _TransferToMemoryKind
except ImportError:  # pragma: no cover - version specific
    try:
        from jax._src.sharding_impls import (
            TransferToMemoryKind as _TransferToMemoryKind)
    except ImportError:
        _TransferToMemoryKind = None


@functools.lru_cache(maxsize=None)
def _memory_kinds() -> frozenset:
    try:
        dev = jax.devices()[0]
        return frozenset(m.kind for m in dev.addressable_memories())
    except Exception:  # pragma: no cover - platform specific
        return frozenset()


def resolved_remote_kind() -> str | None:
    """The memory kind backing the remote tier on this backend."""
    for kind in _HOST_KINDS:
        if kind in _memory_kinds():
            return kind
    return None


def resolved_local_kind() -> str | None:
    """The memory kind backing the local tier on this backend."""
    if LOCAL_KIND in _memory_kinds():
        return LOCAL_KIND
    try:
        return jax.devices()[0].default_memory().kind
    except Exception:  # pragma: no cover - platform specific
        return None


@dataclasses.dataclass(frozen=True)
class PagerConfig:
    """FengHuang paging policy.

    enabled          — page weights through the remote tier.
    lookahead        — prefetch window in layers (paper w=1).  Only w=1 is
                       materialized as an explicit double buffer; deeper
                       windows are left to XLA's scheduler, which may hoist
                       further copy-starts.
    offload_kv       — keep the KV cache in the remote tier between steps,
                       paging per-layer pages in during attention.
    donate_evicted   — donate the consumed buffer (eviction is implicit:
                       the buffer is dead after the layer computes).
    """

    enabled: bool = False
    lookahead: int = 1
    offload_kv: bool = False
    donate_evicted: bool = True


def supports_memory_spaces() -> bool:
    """True if the backend exposes a host memory kind the remote tier can
    live in (distinct from HBM on GPU/TPU; aliased with it on CPU)."""
    return resolved_remote_kind() is not None


def remote_sharding(mesh, pspec: P) -> NamedSharding:
    """NamedSharding in the FengHuang remote tier."""
    return NamedSharding(mesh, pspec, memory_kind=REMOTE_KIND)


def local_sharding(mesh, pspec: P) -> NamedSharding:
    return NamedSharding(mesh, pspec, memory_kind=LOCAL_KIND)


def to_remote(tree: Any, mesh, pspec_tree: Any) -> Any:
    """Move a pytree of arrays into the remote tier (sharded)."""
    return jax.tree.map(
        lambda x, ps: jax.device_put(x, remote_sharding(mesh, ps)),
        tree, pspec_tree)


def _put_kind(x: jax.Array, kind: str | None) -> jax.Array:
    if kind is None:
        return x
    if isinstance(x, jax.core.Tracer):
        if _TransferToMemoryKind is None:  # pragma: no cover - old jax
            return x
        return jax.device_put(x, _TransferToMemoryKind(kind))
    return jax.device_put(x, x.sharding.with_memory_kind(kind))


def page_in(tree: Any) -> Any:
    """Fetch a pytree from the remote tier into local (device) memory.

    Traceable: inside jit this lowers to an async H2D copy that XLA
    schedules concurrently with unrelated compute (the paging stream).
    """
    return jax.tree.map(lambda x: _put_kind(x, resolved_local_kind()), tree)


def page_out(tree: Any) -> Any:
    """Evict a pytree to the remote tier (write-back)."""
    return jax.tree.map(lambda x: _put_kind(x, resolved_remote_kind()), tree)


def host_put(tree: Any) -> Any:
    """Eagerly place a pytree in the remote tier (single-device helper for
    examples/tests; sharded placement goes through :func:`to_remote`)."""
    return jax.tree.map(lambda x: _put_kind(jnp.asarray(x),
                                            resolved_remote_kind()), tree)


def place_kv_pool(cache: Any, config: PagerConfig) -> Any:
    """Residency policy for the block-pool paged KV cache.

    With ``offload_kv`` the stacked ``(L, P, page, Hkv, hd)`` page pools
    live in the FengHuang remote tier between dispatches — decode pages
    exactly one layer's pool through local memory at a time (the
    ``paged_scan_cache`` carry) — while the small leaves (page tables,
    lengths) stay local.  Without it the pool is device-resident and the
    call is the identity."""
    if not (config.enabled and config.offload_kv):
        return cache
    pool_keys = ("k_pages", "v_pages")
    return {k: (host_put(v) if k in pool_keys else v)
            for k, v in cache.items()}


def donating_jit(fn: Callable, *, donate_argnums: tuple[int, ...] = (),
                 config: PagerConfig | None = None, **jit_kwargs) -> Callable:
    """``jax.jit`` with the FengHuang donation contract.

    The serving hot path hands its KV cache and decode state to every
    dispatch and never touches the old buffers again — exactly the
    "consumed double buffer" the pager's eviction policy describes.
    Donating them lets XLA alias input and output so the cache is updated
    in place instead of copied once per dispatch.  ``config.donate_evicted
    = False`` turns the aliasing off (debug mode: old buffers stay live).
    """
    if config is not None and not config.donate_evicted:
        donate_argnums = ()
    return jax.jit(fn, donate_argnums=donate_argnums, **jit_kwargs)


def _index_layer(stacked: Any, i) -> Any:
    """Slice layer ``i`` out of a stacked (L, ...) pytree (stays in its
    current memory space)."""
    return jax.tree.map(
        lambda x: jax.lax.dynamic_index_in_dim(x, i, 0, keepdims=False),
        stacked)


def paged_scan(
    body: Callable[..., tuple[Any, Any]],
    carry: Any,
    stacked_weights: Any,
    xs: Any = None,
    *,
    config: PagerConfig,
    length: int | None = None,
    unroll: int = 1,
    page_xs: bool = False,
) -> tuple[Any, Any]:
    """FengHuang-paged scan over layers.

    ``body(carry, layer_weights[, x]) -> (carry, out)`` — layer weights
    arrive in the local tier.  With paging enabled, ``stacked_weights`` is
    expected to live in the remote tier; the double-buffered carry implements
    the lookahead-1 Tensor Prefetcher.  Differentiable (the transfers are
    linear), so the same transform serves training.

    ``xs`` is an optional extra per-layer input (e.g. the KV-cache slice for
    this layer).  With ``page_xs=True`` it is paged in alongside the weights
    and the per-layer output ``out`` is written back to the remote tier
    (FengHuang KV paging).
    """
    if length is None:
        length = jax.tree.leaves(stacked_weights)[0].shape[0]

    if not config.enabled:
        if xs is None:
            return jax.lax.scan(body, carry, stacked_weights, unroll=unroll)
        return jax.lax.scan(lambda c, wx: body(c, wx[0], wx[1]), carry,
                            (stacked_weights, xs), unroll=unroll)

    def fetch(i):
        return page_in(_index_layer(stacked_weights, i))

    last = length - 1
    w0 = fetch(0)

    def step(state, i):
        inner_carry, w_cur = state
        # Issue the prefetch of layer i+1 BEFORE the compute of layer i so
        # the copy-start precedes the matmuls in program order; XLA overlaps.
        w_next = fetch(jnp.minimum(i + 1, last))
        if xs is None:
            inner_carry, out = body(inner_carry, w_cur)
        else:
            x = _index_layer(xs, i)
            if page_xs:
                x = page_in(x)
            inner_carry, out = body(inner_carry, w_cur, x)
            if page_xs:
                out = page_out(out)
        return (inner_carry, w_next), out

    (carry, _), outs = jax.lax.scan(step, (carry, w0), jnp.arange(length),
                                    unroll=unroll)
    return carry, outs


def paged_scan_cache(
    body: Callable[..., tuple[Any, Any]],
    carry: Any,
    stacked_weights: Any,
    cache: Any,
    *,
    config: PagerConfig,
    length: int | None = None,
) -> tuple[Any, Any]:
    """Layer scan with the (stacked) cache threaded through the CARRY.

    ``body(carry, layer_weights, cache_layer) -> (carry, new_cache_layer)``.

    Unlike passing the cache as scan xs/ys — which makes XLA materialize a
    second full-size stacked buffer and copy the untouched layers every
    iteration — the carried buffer is updated in place with a
    dynamic-update-slice (while-loop state aliases input/output), so
    per-layer traffic is just that layer's slice.  With
    ``config.offload_kv`` the slice pages through the FengHuang remote
    tier (page-in before attention, write-back after).
    """
    if length is None:
        length = jax.tree.leaves(stacked_weights)[0].shape[0]
    last = length - 1

    def fetch(i):
        w = _index_layer(stacked_weights, i)
        return page_in(w) if config.enabled else w

    def update(buf, i, new_layer):
        return jax.tree.map(
            lambda b, u: jax.lax.dynamic_update_index_in_dim(
                b, u.astype(b.dtype), i, 0),
            buf, new_layer)

    if not config.enabled:
        def step(state, i):
            inner, cache_buf = state
            cl = _index_layer(cache_buf, i)
            inner, new_cl = body(inner, fetch(i), cl)
            return (inner, update(cache_buf, i, new_cl)), None

        (carry, cache), _ = jax.lax.scan(step, (carry, cache),
                                         jnp.arange(length))
        return carry, cache

    w0 = fetch(0)

    def step(state, i):
        inner, cache_buf, w_cur = state
        w_next = fetch(jnp.minimum(i + 1, last))    # lookahead-1 prefetch
        cl = _index_layer(cache_buf, i)
        if config.offload_kv:
            cl = page_in(cl)
        inner, new_cl = body(inner, w_cur, cl)
        if config.offload_kv:
            new_cl = page_out(new_cl)
        return (inner, update(cache_buf, i, new_cl), w_next), None

    (carry, cache, _), _ = jax.lax.scan(step, (carry, cache, w0),
                                        jnp.arange(length))
    return carry, cache


def paged_map(fn: Callable[[Any], Any], stacked: Any, *,
              config: PagerConfig) -> Any:
    """Apply ``fn`` per layer with paging (utility for cache init etc.)."""
    def body(carry, w):
        return carry, fn(w)
    _, outs = paged_scan(body, (), stacked, config=config)
    return outs


# ---------------------------------------------------------------------------
# Host-side memory accounting (mirrors the simulator's Table 4.3 logic for
# real pytrees).
# ---------------------------------------------------------------------------

def tree_bytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def resident_window_bytes(stacked_weights: Any, lookahead: int = 1) -> int:
    """Peak local bytes the pager keeps resident: (1 + lookahead) layers."""
    leaves = jax.tree.leaves(stacked_weights)
    if not leaves:
        return 0
    num_layers = leaves[0].shape[0]
    per_layer = tree_bytes(stacked_weights) // max(num_layers, 1)
    return (1 + max(lookahead, 0)) * per_layer
