"""Benchmark: Figure 4.1 — TTFT / TPOT / E2E for GPT-3, Grok-1, Qwen3-235B
(+ Qwen3-R reasoning) on Baseline8 vs FH4-{1.5,2.0}xM across the remote
bandwidth sweep, via the FengHuang simulator.

Also emits the validation summary against the paper's §4.2 claims.
"""
from __future__ import annotations

import time

from repro.core import graphs as G
from repro.core import hw, simulator as S


def run() -> list[str]:
    rows = []
    base = S.baseline8()
    t0 = time.perf_counter()
    base_results = {}
    for name, cfg in G.PAPER_WORKLOADS.items():
        base_results[name] = S.run_workload(cfg, S.QA_TASK, base)
    base_results["qwen3-235b-R"] = S.run_workload(
        G.QWEN3_235B, S.REASONING_TASK, base)

    for name, cfg in G.PAPER_WORKLOADS.items():
        rb = base_results[name]
        for scale in (1.5, 2.0):
            for bw in hw.PAPER_REMOTE_BW_SWEEP_TBPS:
                rf = S.run_workload(cfg, S.QA_TASK, S.fh4(scale, bw))
                us = (time.perf_counter() - t0) * 1e6
                rows.append(
                    f"fig41_{name}_fh4-{scale}xM@{bw}T,{us:.0f},"
                    f"ttft={rf['ttft_s']*1e3:.1f}ms"
                    f"({(1-rf['ttft_s']/rb['ttft_s'])*100:+.1f}%)"
                    f" tpot={rf['tpot_s']*1e3:.2f}ms"
                    f"({(1-rf['tpot_s']/rb['tpot_s'])*100:+.1f}%)"
                    f" e2e={rf['e2e_s']:.1f}s"
                    f"({(1-rf['e2e_s']/rb['e2e_s'])*100:+.1f}%)")
        rows.append(
            f"fig41_{name}_baseline8,0,"
            f"ttft={rb['ttft_s']*1e3:.1f}ms tpot={rb['tpot_s']*1e3:.2f}ms "
            f"e2e={rb['e2e_s']:.1f}s")

    # reasoning workload (Qwen3-R)
    rbR = base_results["qwen3-235b-R"]
    for bw in hw.PAPER_REMOTE_BW_SWEEP_TBPS:
        rf = S.run_workload(G.QWEN3_235B, S.REASONING_TASK, S.fh4(1.5, bw))
        us = (time.perf_counter() - t0) * 1e6
        rows.append(f"fig41_qwen3-R_fh4-1.5xM@{bw}T,{us:.0f},"
                    f"e2e={rf['e2e_s']:.1f}s"
                    f"({(1-rf['e2e_s']/rbR['e2e_s'])*100:+.1f}%)")

    # §4.2 claim validation
    claims = []
    for name, cfg in G.PAPER_WORKLOADS.items():
        rb = base_results[name]
        rf40 = S.run_workload(cfg, S.QA_TASK, S.fh4(1.5, 4.0))
        rf48 = S.run_workload(cfg, S.QA_TASK, S.fh4(1.5, 4.8))
        rf64 = S.run_workload(cfg, S.QA_TASK, S.fh4(1.5, 6.4))
        ttft_gain = (1 - rf40["ttft_s"] / rb["ttft_s"]) * 100
        claims.append((f"claim_ttft_{name}",
                       f"FH beats baseline TTFT: {ttft_gain:+.1f}% "
                       f"(paper: gpt3 +32.5 grok +8.4 qwen3 +28.9)",
                       ttft_gain > 0))
        tpot_trend = rf64["tpot_s"] < rf40["tpot_s"] * 1.001
        claims.append((f"claim_tpot_trend_{name}",
                       f"TPOT improves 4.0->6.4 TB/s: "
                       f"{rf40['tpot_s']*1e3:.1f}->{rf64['tpot_s']*1e3:.1f}ms",
                       tpot_trend))
        e2e_comp = abs(1 - rf48["e2e_s"] / rb["e2e_s"]) < 0.30
        claims.append((f"claim_e2e_comparable_{name}",
                       f"E2E within 30% of baseline at 4.8 TB/s: "
                       f"{(1-rf48['e2e_s']/rb['e2e_s'])*100:+.1f}%",
                       e2e_comp))
    for name, msg, ok in claims:
        rows.append(f"{name},0,{msg} [{'OK' if ok else 'MISS'}]")
    return rows
