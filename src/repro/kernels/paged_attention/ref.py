"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gather_pages(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """Materialize the per-sequence view of a page pool.

    pages: (P, page, Hkv, d); page_table: (B, n_pages) int32.
    Returns (B, Hkv, n_pages * page, d) — the cache layout
    :func:`repro.models.layers.decode_attention` expects, with gathered
    position ``i`` holding absolute position ``i`` (pages are in order).
    """
    b, n_pages = page_table.shape
    page, hkv, d = pages.shape[1:]
    g = pages[page_table]                   # (B, n_pages, page, Hkv, d)
    return g.reshape(b, n_pages * page, hkv, d).transpose(0, 2, 1, 3)


def gather_scales(scales: jax.Array, page_table: jax.Array) -> jax.Array:
    """Materialize the per-sequence view of a per-page scale array.

    scales: (P, page, Hkv) — one dequant scale per (token slot, head);
    page_table: (B, n_pages) int32.  Returns (B, Hkv, n_pages * page),
    aligned position-for-position with :func:`gather_pages`.
    """
    b, n_pages = page_table.shape
    page, hkv = scales.shape[1:]
    g = scales[page_table]                  # (B, n_pages, page, Hkv)
    return g.reshape(b, n_pages * page, hkv).transpose(0, 2, 1)


def paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens,
                        extra_kv=None, k_scales=None, v_scales=None):
    """Decode attention over a paged KV cache.

    q:          (B, Hkv, G, d)       one query token, grouped heads
    k_pages:    (P, page, Hkv, d)    global page pool
    v_pages:    (P, page, Hkv, d)
    page_table: (B, pages_per_seq)   int32 page ids
    seq_lens:   (B,)                 valid tokens per sequence
    extra_kv:   optional current-token (k0, v0), each (B, Hkv, d),
                attended as one extra column past the pooled positions
    k_scales:   optional (P, page, Hkv) dequant scales for a quantized
                pool — multiplied into the fp32 view inline, so the
                full-precision KV never materializes outside this gather
    v_scales:   same, for the value pool
    returns     (B, Hkv, G, d)
    """
    b, hkv, g, d = q.shape
    pages_per_seq = page_table.shape[1]
    page = k_pages.shape[1]

    k = k_pages[page_table]          # (B, pages, page, Hkv, d)
    v = v_pages[page_table]
    k = k.reshape(b, pages_per_seq * page, hkv, d)
    v = v.reshape(b, pages_per_seq * page, hkv, d)
    if k_scales is not None:
        ks = k_scales[page_table].reshape(b, pages_per_seq * page, hkv)
        k = k.astype(jnp.float32) * ks.astype(jnp.float32)[..., None]
    if v_scales is not None:
        vs = v_scales[page_table].reshape(b, pages_per_seq * page, hkv)
        v = v.astype(jnp.float32) * vs.astype(jnp.float32)[..., None]

    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    pos = jnp.arange(pages_per_seq * page)[None, :]
    valid = pos < seq_lens[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    if extra_kv is not None:
        k0, v0 = extra_kv
        s0 = jnp.einsum("bhgd,bhd->bhg", q.astype(jnp.float32),
                        k0.astype(jnp.float32)) / math.sqrt(d)
        s = jnp.concatenate([s, s0[..., None]], axis=-1)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / p.sum(axis=-1, keepdims=True)
    if extra_kv is not None:
        o = jnp.einsum("bhgs,bshd->bhgd", p[..., :-1],
                       v.astype(jnp.float32))
        o = o + p[..., -1][..., None] * extra_kv[1][:, :, None, :].astype(
            jnp.float32)
    else:
        o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
