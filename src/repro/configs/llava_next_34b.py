"""llava-next-34b: 60L d=7168 56H (GQA kv=8) d_ff=20480 vocab=64000; VLM
backbone with anyres vision tower STUBBED (576 precomputed patch
embeddings prepended) [hf:llava-hf/llava-v1.6 family]."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab=64000, head_dim=128,
    num_patches=576,
)
