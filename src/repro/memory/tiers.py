"""Memory-tier registry: the FengHuang hierarchy resolved per backend.

Maps the paper's multi-tier shared-memory hierarchy onto JAX memory
kinds:

* **local tier**  = ``memory_kind="device"`` (HBM),
* **remote tier** = the best host-side kind the backend exposes —
  ``pinned_host`` (host DRAM behind the DMA engine; the TAB-attached
  LPDDR6 pool in the paper's node) on GPU/TPU, ``unpinned_host`` on the
  CPU backend (where local == remote, so paging degenerates to the
  identity while keeping every transform's semantics intact).

Resolution is cached **per backend** in a :class:`TierRegistry` — unlike
the old module-level ``lru_cache`` in ``core.pager`` it is invalidated
by :func:`reset` (used by tests and by anything that swaps the default
backend mid-process, e.g. ``jax.config.update("jax_platform_name", …)``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# Canonical tier names used across policies, the ledger and BENCH JSON.
LOCAL = "local"
REMOTE = "remote"

LOCAL_KIND = "device"
REMOTE_KIND = "pinned_host"

# Host-side kinds that can back the FengHuang remote tier, best first.
_HOST_KINDS = ("pinned_host", "unpinned_host")

try:  # public since jax 0.5
    from jax.sharding import TransferToMemoryKind as _TransferToMemoryKind
except ImportError:  # pragma: no cover - version specific
    try:
        from jax._src.sharding_impls import (
            TransferToMemoryKind as _TransferToMemoryKind)
    except ImportError:
        _TransferToMemoryKind = None


@dataclasses.dataclass(frozen=True)
class Tier:
    """One level of the hierarchy: a logical name bound to the memory
    kind that backs it on the current backend (None = unavailable)."""

    name: str
    kind: str | None

    @property
    def available(self) -> bool:
        return self.kind is not None


class TierRegistry:
    """Backend-scoped tier resolution.

    ``registry().local`` / ``.remote`` resolve lazily against the
    *current* default backend and are re-resolved after :func:`reset`
    or when the default backend changes — fixing the stale module-level
    ``lru_cache`` the old ``core.pager`` carried."""

    def __init__(self) -> None:
        self._tiers: dict[str, dict[str, Tier]] = {}

    def _backend(self) -> str:
        try:
            return jax.default_backend()
        except Exception:  # pragma: no cover - no backend at all
            return "<none>"

    def _resolve(self, backend: str) -> dict[str, Tier]:
        try:
            kinds = frozenset(
                m.kind for m in jax.devices()[0].addressable_memories())
        except Exception:  # pragma: no cover - platform specific
            kinds = frozenset()
        local = LOCAL_KIND if LOCAL_KIND in kinds else None
        if local is None:
            try:
                local = jax.devices()[0].default_memory().kind
            except Exception:  # pragma: no cover - platform specific
                local = None
        remote = next((k for k in _HOST_KINDS if k in kinds), None)
        return {LOCAL: Tier(LOCAL, local), REMOTE: Tier(REMOTE, remote)}

    def tiers(self) -> dict[str, Tier]:
        backend = self._backend()
        if backend not in self._tiers:
            self._tiers[backend] = self._resolve(backend)
        return self._tiers[backend]

    @property
    def local(self) -> Tier:
        return self.tiers()[LOCAL]

    @property
    def remote(self) -> Tier:
        return self.tiers()[REMOTE]

    def reset(self) -> None:
        """Drop every cached resolution (tests; backend swaps)."""
        self._tiers.clear()


_REGISTRY = TierRegistry()


def registry() -> TierRegistry:
    return _REGISTRY


def reset() -> None:
    """Invalidate the process-wide tier registry."""
    _REGISTRY.reset()


def resolved_local_kind() -> str | None:
    """The memory kind backing the local tier on this backend."""
    return _REGISTRY.local.kind


def resolved_remote_kind() -> str | None:
    """The memory kind backing the remote tier on this backend."""
    return _REGISTRY.remote.kind


def supports_memory_spaces() -> bool:
    """True if the backend exposes a host memory kind the remote tier can
    live in (distinct from HBM on GPU/TPU; aliased with it on CPU)."""
    return _REGISTRY.remote.available


# ---------------------------------------------------------------------------
# Placement primitives
# ---------------------------------------------------------------------------

def tier_sharding(mesh, pspec: P, tier: str) -> NamedSharding:
    """NamedSharding placing data in ``tier`` (``LOCAL``/``REMOTE``) with
    the memory kind the *current backend* actually exposes — resolved
    through the registry, never hardcoded.  A ``None`` kind (tier not
    backed on this platform) falls back to the backend default, so CPU —
    where local == remote == ``unpinned_host`` — degenerates cleanly."""
    kind = _REGISTRY.tiers().get(tier, Tier(tier, None)).kind
    return NamedSharding(mesh, pspec, memory_kind=kind)


def remote_sharding(mesh, pspec: P) -> NamedSharding:
    """NamedSharding in the FengHuang remote tier."""
    return tier_sharding(mesh, pspec, REMOTE)


def local_sharding(mesh, pspec: P) -> NamedSharding:
    return tier_sharding(mesh, pspec, LOCAL)


def to_remote(tree: Any, mesh, pspec_tree: Any) -> Any:
    """Move a pytree of arrays into the remote tier (sharded)."""
    return jax.tree.map(
        lambda x, ps: jax.device_put(x, remote_sharding(mesh, ps)),
        tree, pspec_tree)


def _put_kind(x: jax.Array, kind: str | None) -> jax.Array:
    if kind is None:
        return x
    if isinstance(x, jax.core.Tracer):
        if _TransferToMemoryKind is None:  # pragma: no cover - old jax
            return x
        return jax.device_put(x, _TransferToMemoryKind(kind))
    return jax.device_put(x, x.sharding.with_memory_kind(kind))


def page_in(tree: Any) -> Any:
    """Fetch a pytree from the remote tier into local (device) memory.

    Traceable: inside jit this lowers to an async H2D copy that XLA
    schedules concurrently with unrelated compute (the paging stream).
    """
    return jax.tree.map(lambda x: _put_kind(x, resolved_local_kind()), tree)


def page_out(tree: Any) -> Any:
    """Evict a pytree to the remote tier (write-back)."""
    return jax.tree.map(lambda x: _put_kind(x, resolved_remote_kind()), tree)


def host_put(tree: Any) -> Any:
    """Eagerly place a pytree in the remote tier (single-device helper for
    examples/tests; sharded placement goes through :func:`to_remote`)."""
    return jax.tree.map(lambda x: _put_kind(jnp.asarray(x),
                                            resolved_remote_kind()), tree)
