"""Benchmark: serving hot path — seed-style host-driven per-token decode
vs the fused on-device block loop, and dense-slab KV vs the block-pool
paged cache (§Perf iterations D + E).

The per-token baseline reproduces the seed ``BatchedServer.run_once``
anti-pattern exactly: one ``serve_step`` dispatch per token plus a
``int(cur[i, 0])`` host sync per slot per step.  The block path is one
dispatch and one host sync per ``BLOCK`` tokens.  The paged path serves
the same requests from the device-resident page pool: identical tokens,
KV bytes proportional to live tokens instead of ``batch × max_seq``, and
per-step attention reads that scale with the actual sequence length.

The paged server row exercises the full pipeline: device-resident page
tables updated by per-block deltas, double-buffered dispatch (up to two
blocks in flight), and the prefix-cache row serves a shared-system-prompt
batch where leading prompt pages are physically shared across requests.

Emits human-readable CSV rows AND writes ``BENCH_serve.json`` (cwd) with
machine-readable tokens/s, KV-bytes-per-active-token, pipeline counters
(``compiles`` / ``host_syncs`` / ``table_rebuilds``), a peak-occupancy
per-tier residency snapshot and attention cost-vs-seq-len numbers so CI
can track the perf trajectory.  ``SERVE_BENCH_SMOKE=1`` trims the repeat
count for CI smoke runs.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import build_model, get_config
from repro.launch.mesh import make_serving_mesh, serving_model_shards
from repro.memory import capacity_reduction, tree_bytes
from repro.models.base import DecodeState
from repro.runtime.serve import (BatchedServer, _bucket, make_decode_loop,
                                 make_prefill_step, make_serve_step, sample)
from repro.runtime.sharding import collective_bytes_by_axis, mesh_axis_sizes

BATCH = 4
PROMPT = 8
NEW_TOKENS = 64
BLOCK = 32
MAX_SEQ = 384
SMOKE = os.environ.get("SERVE_BENCH_SMOKE", "") == "1"
REPEATS = 3 if SMOKE else 7   # timing = min over repeats (dispatch noise)
SYS_PROMPT = 48               # shared system-prompt tokens (prefix bench)
USER_PROMPT = 8               # per-request unique suffix tokens
PREFIX_NEW_TOKENS = 32
JSON_PATH = Path("BENCH_serve.json")


def _counted(fn, counter: dict):
    def wrapped(*a, **k):
        counter["n"] += 1
        return fn(*a, **k)
    return wrapped


def _setup():
    cfg = get_config("qwen2.5-14b").reduced(num_layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (BATCH, PROMPT), 0,
                                 cfg.vocab)
    return model, params, prompts


def _prefill(model, params, prompts):
    cache = model.init_cache(BATCH, MAX_SEQ)
    logits, cache = jax.jit(make_prefill_step(model))(params, prompts, cache)
    cur = sample(logits, model.cfg.vocab, 0.0, jax.random.PRNGKey(0))
    return cur, cache


def _per_token(model, params, prompts) -> tuple[float, int, int, list]:
    """Seed-style loop: dispatch + per-slot host sync every token."""
    dispatches = {"n": 0}
    sstep = _counted(jax.jit(make_serve_step(model)), dispatches)

    def once():
        cur, cache = _prefill(model, params, prompts)
        key = jax.random.PRNGKey(7)
        pos = jnp.full((BATCH,), PROMPT, jnp.int32)
        outs = [[] for _ in range(BATCH)]
        syncs = 0
        t0 = time.perf_counter()
        for _ in range(NEW_TOKENS):
            key, k = jax.random.split(key)
            cur, _, cache = sstep(params, cur, cache, pos, k)
            pos = pos + 1
            for i in range(BATCH):
                outs[i].append(int(cur[i, 0]))    # the seed's per-slot sync
                syncs += 1
        return time.perf_counter() - t0, syncs, outs

    once()                                        # warm the compile cache
    dispatches["n"] = 0
    runs = [once() for _ in range(REPEATS)]
    dt, syncs, outs = min(runs, key=lambda r: r[0])
    return dt, dispatches["n"] // REPEATS, syncs, outs


def _block_decode(model, params, prompts) -> tuple[float, int, int, list]:
    """Fused loop: one dispatch + one host sync per BLOCK tokens."""
    dispatches = {"n": 0}
    loop = _counted(make_decode_loop(model, block_size=BLOCK), dispatches)

    def once():
        cur, cache = _prefill(model, params, prompts)
        state = DecodeState(tokens=cur,
                            pos=jnp.full((BATCH,), PROMPT, jnp.int32),
                            active=jnp.ones((BATCH,), bool),
                            remaining=jnp.full((BATCH,), NEW_TOKENS,
                                               jnp.int32),
                            key=jax.random.PRNGKey(7))
        outs = [[] for _ in range(BATCH)]
        syncs = 0
        t0 = time.perf_counter()
        for _ in range(NEW_TOKENS // BLOCK):
            toks, valid, cache, state = loop(params, cache, state)
            blk = np.asarray(jax.device_get(toks))   # ONE sync per block
            syncs += 1
            for i in range(BATCH):
                outs[i].extend(int(t) for t in blk[i])
        return time.perf_counter() - t0, syncs, outs

    once()                                        # warm (donates warm bufs)
    dispatches["n"] = 0
    runs = [once() for _ in range(REPEATS)]
    dt, syncs, outs = min(runs, key=lambda r: r[0])
    return dt, dispatches["n"] // REPEATS, syncs, outs


def _measure_rounds(servers: list, submit_all) -> tuple[list[float], list]:
    """Warm every server, then run REPEATS measurement rounds with the
    servers INTERLEAVED (a noisy scheduling window hits every variant
    instead of biasing whichever happened to be measured then); per
    server, the timing is the min over rounds and the outputs come from
    the last round."""
    for s in servers:
        submit_all(s)
        s.run_once()                              # warm every compile
    dts = [float("inf")] * len(servers)
    outs: list = [None] * len(servers)
    for _ in range(REPEATS):
        for i, s in enumerate(servers):
            reqs = submit_all(s)
            t0 = time.perf_counter()
            s.run_once()
            dts[i] = min(dts[i], time.perf_counter() - t0)
            outs[i] = [tuple(r.output) for r in reqs]
    return dts, outs


def _serve_requests(cfg, params):
    """Serve BATCH identical-shape requests through five interleaved
    servers: dense slab, bf16 block pool, the int8 / fp8 quantized
    page pools (same requests, same params — kv_dtype only changes the
    pool storage), and the disaggregated bf16 server (async prefill
    engine + handoff adoption) on the same steady workload.  Returns
    ``(dts, outs, servers)`` in that order.  Each server gets a FRESH
    model: a server reports through its model's orchestrator ledger, and
    two live servers on one model would share (and overwrite) one
    kv_pool residency class."""
    def submit_all(server):
        rng = np.random.RandomState(5)
        return [server.submit(rng.randint(0, cfg.vocab, PROMPT)
                              .astype(np.int32),
                              max_new_tokens=NEW_TOKENS)
                for _ in range(BATCH)]

    variants = [
        (cfg, {"paged": False}),
        (cfg, {"paged": True}),
        (dataclasses.replace(cfg, kv_dtype="int8"), {"paged": True}),
        (dataclasses.replace(cfg, kv_dtype="fp8_e4m3"), {"paged": True}),
        (cfg, {"paged": True, "prefill_async": True,
               "prefill_chunk_tokens": BLOCK}),
    ]
    servers = [BatchedServer(build_model(c), params, batch_size=BATCH,
                             max_seq=MAX_SEQ, block_size=BLOCK, **kw)
               for c, kw in variants]
    dts, outs = _measure_rounds(servers, submit_all)
    return dts, outs, servers


def _kv_logit_err(cfg, params, prompts) -> dict:
    """Max |Δlogit| of ONE decode step reading a quantized pool vs the
    bf16 pool.  Prefill attends the full-precision activations on the
    fly (its logits are bit-identical across kv dtypes) and the fed
    token comes from the bf16 argmax, so the difference isolates exactly
    the KV-pool quantization error seen by decode."""
    page = cfg.page_size
    n = -(-(PROMPT + 1) // page)
    pages = jnp.asarray(
        1 + np.arange(BATCH * n, dtype=np.int32).reshape(BATCH, n))
    pos = jnp.full((BATCH,), PROMPT, jnp.int32)

    def step_logits(c):
        m = build_model(c)
        cache = m.init_paged_cache(1 + BATCH * n)
        logits, cache = jax.jit(m.prefill_paged)(params, prompts, cache,
                                                 pages)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        out, _ = jax.jit(m.decode_step)(params, cur, cache, pos, None,
                                        pages)
        return np.asarray(out, np.float32)

    ref = step_logits(cfg)
    return {kd: float(np.max(np.abs(
                step_logits(dataclasses.replace(cfg, kv_dtype=kd)) - ref)))
            for kd in ("int8", "fp8_e4m3")}


def _greedy_match_rate(out_q, out_ref, horizon: int | None = None) -> float:
    """Position-wise token agreement between a quantized server's greedy
    outputs and the bf16 paged reference (same requests, same budgets).
    ``horizon`` restricts the comparison to each request's first N
    tokens: greedy decoding cascades (one flipped argmax rewrites the
    rest of the sequence), so the short-horizon rate is the stable
    readout of KV fidelity while the full-horizon rate mostly measures
    how early the first flip happened."""
    total = same = 0
    for rq, rr in zip(out_q, out_ref):
        for tq, tr in zip(rq[:horizon], rr[:horizon]):
            total += 1
            same += int(tq == tr)
    return same / max(total, 1)


def _kv_quant_block(cfg, params, prompts, servers, dts, outs,
                    peak_tokens) -> dict:
    """Machine-readable KV-precision record: per-dtype effective bytes
    per active token (scales INCLUDED — true bytes, Table-4.3
    comparable), throughput vs the interleaved bf16 paged row, greedy
    token agreement, and the one-step max |Δlogit|."""
    srv_paged, srv_q8, srv_fp8 = servers[1:4]
    dt_paged, dt_q8, dt_fp8 = dts[1:4]
    out_paged, out_q8, out_fp8 = outs[1:4]
    total = BATCH * NEW_TOKENS
    err = _kv_logit_err(cfg, params, prompts)

    def per_page(srv):
        return srv.kv_bytes_capacity() // srv.num_pages

    bpt_bf16 = srv_paged.manager.hwm * per_page(srv_paged) / peak_tokens
    block = {"bytes_per_active_token_bf16": round(bpt_bf16)}
    for kd, srv, dt, out in (("int8", srv_q8, dt_q8, out_q8),
                             ("fp8_e4m3", srv_fp8, dt_fp8, out_fp8)):
        hwm_bytes = srv.manager.hwm * per_page(srv)
        bpt = hwm_bytes / peak_tokens
        block[kd] = {
            "tokens_per_s": round(total / dt, 1),
            "pool_capacity_bytes": srv.kv_bytes_capacity(),
            "kv_hwm_bytes": hwm_bytes,
            "bytes_per_active_token": round(bpt),
            "bytes_ratio_vs_bf16": round(bpt / bpt_bf16, 4),
            # same pool budget holds 1/ratio times the tokens — the
            # "doubling effective pool capacity" headline
            "capacity_gain_vs_bf16": round(bpt_bf16 / bpt, 2),
            "greedy_match_rate_vs_bf16": round(
                _greedy_match_rate(out, out_paged), 4),
            "greedy_match_rate_first8": round(
                _greedy_match_rate(out, out_paged, horizon=8), 4),
            "max_abs_logit_err": round(err[kd], 5),
        }
    return block


def _serve_prefix(cfg, params):
    """Shared-system-prompt scenario: BATCH requests whose padded
    prompts agree on their leading whole pages.  With the prefix cache
    on, those pages are physically shared (refcounted) and admission
    prefills only each request's suffix; tokens must stay bit-identical
    to the unshared server.  Returns the machine-readable comparison."""
    sys_toks = np.random.RandomState(11).randint(
        0, cfg.vocab, SYS_PROMPT).astype(np.int32)

    def submit_all(server):
        return [server.submit(
            np.concatenate([sys_toks,
                            np.full(USER_PROMPT, 100 + i, np.int32)]),
            max_new_tokens=PREFIX_NEW_TOKENS) for i in range(BATCH)]

    srv_s, srv_u = (BatchedServer(build_model(cfg), params,
                                  batch_size=BATCH, max_seq=MAX_SEQ,
                                  block_size=BLOCK, paged=True,
                                  prefix_cache=pc)
                    for pc in (True, False))
    (dt_s, dt_u), (out_s, out_u) = _measure_rounds([srv_s, srv_u],
                                                   submit_all)
    assert out_s == out_u, \
        "prefix-cached serving must emit identical tokens to unshared"
    assert srv_s.stats["prefix_hits"] > 0, "prefix cache never hit"

    per_page = srv_s.kv_bytes_capacity() // srv_s.num_pages
    plen = srv_s._admit_plen(SYS_PROMPT + USER_PROMPT, PREFIX_NEW_TOKENS)
    peak_tokens = BATCH * (plen + PREFIX_NEW_TOKENS - 1)
    hwm_s, hwm_u = srv_s.manager.hwm, srv_u.manager.hwm
    total = BATCH * PREFIX_NEW_TOKENS
    return {
        "sys_prompt": SYS_PROMPT, "user_prompt": USER_PROMPT,
        "new_tokens": PREFIX_NEW_TOKENS,
        "prefix_hits": srv_s.stats["prefix_hits"],
        "shared_pages": srv_s.stats["prefix_shared_pages"],
        "tokens_per_s_shared": round(total / dt_s, 1),
        "tokens_per_s_unshared": round(total / dt_u, 1),
        "kv_hwm_bytes_shared": hwm_s * per_page,
        "kv_hwm_bytes_unshared": hwm_u * per_page,
        "bytes_per_active_token_shared": round(hwm_s * per_page
                                               / peak_tokens),
        "bytes_per_active_token_unshared": round(hwm_u * per_page
                                                 / peak_tokens),
        "residency_reduction_vs_unshared": round(
            capacity_reduction(hwm_s, hwm_u), 3),
        "tokens_identical_to_unshared": True,
    }


def _serve_sharded(cfg, params, out_paged) -> dict:
    """Tensor-parallel serving row: the paged server on a ``"model"``
    mesh over however many local devices exist (2+ under the forced
    multi-device CI job, a degenerate 1-shard mesh on one device — the
    mesh code path runs either way).  Tokens must be bit-identical to
    the single-device paged server; the decode executable's collective
    traffic is attributed per mesh axis, and the ledger snapshot is
    per-shard (what ONE device holds)."""
    shards = serving_model_shards(8, cfg.padded_heads, cfg.padded_kv_heads,
                                  cfg.d_ff, cfg.padded_vocab)
    mesh = make_serving_mesh(model=shards)

    def submit_all(server):
        rng = np.random.RandomState(5)
        return [server.submit(rng.randint(0, cfg.vocab, PROMPT)
                              .astype(np.int32),
                              max_new_tokens=NEW_TOKENS)
                for _ in range(BATCH)]

    srv = BatchedServer(build_model(cfg), params, batch_size=BATCH,
                        max_seq=MAX_SEQ, block_size=BLOCK, paged=True,
                        mesh=mesh)
    (dt,), (outs,) = _measure_rounds([srv], submit_all)
    assert outs == out_paged, \
        "sharded serving must emit identical tokens to single-device"
    # wire traffic from the live decode executable: the scan body appears
    # ONCE in the HLO, so the parsed bytes cover one decode STEP (every
    # layer, the whole batch); a block dispatch runs BLOCK steps and
    # emits BATCH tokens per step
    with srv._mesh_ctx():
        hlo = srv._decode_loop.lower(srv.params, srv.cache, srv.state,
                                     None).compile().as_text()
    per_step = collective_bytes_by_axis(hlo, mesh)
    total = BATCH * NEW_TOKENS

    # opt-in Megatron row-parallel placement (deterministic=False): wo
    # stays contraction-sharded and the per-layer all-gather becomes a
    # partial-sum all-reduce.  Tokens may drift from the all-gather row
    # once shards >= 2 (reduction-order ambiguity in bf16), so identity
    # is recorded, not asserted; the collective-bytes row lands next to
    # the all-gather one for a like-for-like wire-traffic comparison.
    srv_rp = BatchedServer(build_model(cfg), params, batch_size=BATCH,
                           max_seq=MAX_SEQ, block_size=BLOCK, paged=True,
                           mesh=mesh, deterministic=False)
    (dt_rp,), (out_rp,) = _measure_rounds([srv_rp], submit_all)
    with srv_rp._mesh_ctx():
        hlo_rp = srv_rp._decode_loop.lower(
            srv_rp.params, srv_rp.cache, srv_rp.state,
            None).compile().as_text()
    rp_step = collective_bytes_by_axis(hlo_rp, mesh)

    return {
        "devices": jax.device_count(),
        "model_shards": shards,
        "mesh_axes": mesh_axis_sizes(mesh),
        "tokens_per_s_sharded": round(total / dt, 1),
        "tokens_identical_to_single_device": True,
        "collective_bytes_per_step_by_axis": per_step,
        "collective_bytes_per_token_by_axis": {
            axis: round(b / BATCH) for axis, b in per_step.items()},
        "tiers_peak_per_shard": srv.tier_stats_peak(),
        "row_parallel": {
            "deterministic": False,
            "tokens_per_s_sharded": round(total / dt_rp, 1),
            "tokens_identical_to_single_device": out_rp == out_paged,
            "collective_bytes_per_step_by_axis": rp_step,
            "collective_bytes_per_token_by_axis": {
                axis: round(b / BATCH) for axis, b in rp_step.items()},
        },
    }


PREEMPT_MAX_SEQ = 96
PREEMPT_BLOCK = 8
PREEMPT_PAGE = 4
PREEMPT_POOL = 39             # capacity 38: two hogs reserve 36 of it
HOG_NEW_TOKENS = 64
SHORT_NEW_TOKENS = 8
N_HOGS, N_SHORTS = 2, 4


def _serve_preemption(cfg, params) -> dict:
    """Deep-queue memory-pressure scenario: two long "hog" requests
    reserve nearly the whole (deliberately small) page pool, then four
    short requests queue behind them.  Without preemption the shorts
    stall until a hog drains its full decode budget; with page-granular
    preemption a hog is swapped to the remote tier, the shorts admit and
    finish, and the hog resumes — every token bit-identical to an
    uncontended big-pool run.  Returns the machine-readable comparison
    (admission-wait-in-blocks with/without preemption is the headline)."""
    def submit_all(server):
        rng = np.random.RandomState(13)
        reqs = [server.submit(rng.randint(0, cfg.vocab, PROMPT)
                              .astype(np.int32),
                              max_new_tokens=HOG_NEW_TOKENS)
                for _ in range(N_HOGS)]
        reqs += [server.submit(rng.randint(0, cfg.vocab, PROMPT)
                               .astype(np.int32),
                               max_new_tokens=SHORT_NEW_TOKENS)
                 for _ in range(N_SHORTS)]
        return reqs

    def serve(preempt: bool, num_pages: int):
        srv = BatchedServer(build_model(cfg), params, batch_size=3,
                            max_seq=PREEMPT_MAX_SEQ, block_size=PREEMPT_BLOCK,
                            paged=True, page_size=PREEMPT_PAGE,
                            num_pages=num_pages, preempt=preempt, audit=True)
        reqs = submit_all(srv)
        t0 = time.perf_counter()
        srv.run_once()
        dt = time.perf_counter() - t0
        assert all(r.done.is_set() and r.error is None for r in reqs), \
            [(r.uid, r.error) for r in reqs]
        shorts = reqs[N_HOGS:]
        wait = max(r.admitted_at_block for r in shorts)
        return [tuple(r.output) for r in reqs], wait, dt, srv

    out_ref, _, _, _ = serve(True, None)               # uncontended pool
    out_p, wait_p, dt_p, srv_p = serve(True, PREEMPT_POOL)
    out_n, wait_n, dt_n, srv_n = serve(False, PREEMPT_POOL)
    assert out_p == out_ref, \
        "preempted serving must emit identical tokens to uncontended"
    assert out_n == out_ref, \
        "waiting (no-preempt) serving must emit identical tokens too"
    assert srv_p.stats["preemptions"] >= 1, srv_p.stats
    assert srv_p.stats["resumes"] >= 1, srv_p.stats
    assert srv_p.stats["sheds"] == 0 and srv_n.stats["sheds"] == 0
    assert srv_p.stats["audits"] > 0
    assert wait_p < wait_n, (wait_p, wait_n)
    return {
        "policy": srv_p.preempt_policy,
        "num_pages": PREEMPT_POOL, "page_size": PREEMPT_PAGE,
        "hogs": N_HOGS, "shorts": N_SHORTS,
        "hog_new_tokens": HOG_NEW_TOKENS,
        "short_new_tokens": SHORT_NEW_TOKENS,
        "preemptions": srv_p.stats["preemptions"],
        "resumes": srv_p.stats["resumes"],
        "sheds": srv_p.stats["sheds"],
        "preempted_pages": srv_p.stats["preempted_pages"],
        "swap_retries": srv_p.stats["swap_retries"],
        "audits": srv_p.stats["audits"],
        "max_admission_wait_blocks_preempt": wait_p,
        "max_admission_wait_blocks_no_preempt": wait_n,
        "admission_wait_reduction": round(1 - wait_p / max(wait_n, 1), 3),
        "drain_s_preempt": round(dt_p, 3),
        "drain_s_no_preempt": round(dt_n, 3),
        "tokens_identical_to_uncontended": True,
    }


COLD_BIG_NEW = 88             # worst-case 24 pages: shortfall evicts BOTH hogs


def _serve_cold_park(cfg, params) -> dict:
    """Deep-preemption cold-parking scenario: two hog requests reserve
    nearly the whole small pool, then one big request arrives whose
    worst-case reservation exceeds what evicting a single hog frees —
    both hogs are stashed in ONE preemption round.  Without cold parking
    both stashes sit in the remote tier simultaneously (remote hwm = two
    stashes); with ``cold_park_after_blocks=0`` victims swap straight to
    the cold tier and only transit remote one at a time on the
    promote-through-remote resume path (remote hwm = one stash).  Tokens
    stay bit-identical to an uncontended big-pool run in every config —
    tier moves never touch bytes."""
    def submit_all(server):
        rng = np.random.RandomState(29)
        reqs = [server.submit(rng.randint(0, cfg.vocab, PROMPT)
                              .astype(np.int32),
                              max_new_tokens=HOG_NEW_TOKENS)
                for _ in range(N_HOGS)]
        reqs.append(server.submit(rng.randint(0, cfg.vocab, PROMPT)
                                  .astype(np.int32),
                                  max_new_tokens=COLD_BIG_NEW))
        return reqs

    def serve(cold_park: int | None, num_pages: int | None):
        srv = BatchedServer(build_model(cfg), params, batch_size=3,
                            max_seq=PREEMPT_MAX_SEQ, block_size=PREEMPT_BLOCK,
                            paged=True, page_size=PREEMPT_PAGE,
                            num_pages=num_pages, preempt=True, audit=True,
                            cold_park_after_blocks=cold_park)
        reqs = submit_all(srv)
        t0 = time.perf_counter()
        srv.run_once()
        dt = time.perf_counter() - t0
        assert all(r.done.is_set() and r.error is None for r in reqs), \
            [(r.uid, r.error) for r in reqs]
        return [tuple(r.output) for r in reqs], dt, srv

    out_ref, _, _ = serve(None, None)                  # uncontended pool
    out_n, dt_n, srv_n = serve(None, PREEMPT_POOL)     # remote-only stashes
    out_c, dt_c, srv_c = serve(0, PREEMPT_POOL)        # park straight to cold
    assert out_n == out_ref, \
        "remote-stash serving must emit identical tokens to uncontended"
    assert out_c == out_ref, \
        "cold-parked serving must emit identical tokens to uncontended"
    assert srv_n.stats["cold_parks"] == 0, srv_n.stats
    assert srv_c.stats["cold_parks"] >= 2, srv_c.stats
    assert srv_c.stats["cold_promotes"] == srv_c.stats["cold_parks"], \
        srv_c.stats
    hwm_n = srv_n.mem.ledger.snapshot()["remote"]["hwm_bytes"]
    hwm_c = srv_c.mem.ledger.snapshot()["remote"]["hwm_bytes"]
    assert 0 < hwm_c < hwm_n, (hwm_c, hwm_n)
    xfers = srv_c.mem.ledger.transfers()
    assert xfers.get("local->cold", {}).get("bytes", 0) > 0, xfers
    assert xfers.get("cold->remote", {}).get("bytes", 0) > 0, xfers
    return {
        "num_pages": PREEMPT_POOL, "page_size": PREEMPT_PAGE,
        "hogs": N_HOGS, "hog_new_tokens": HOG_NEW_TOKENS,
        "big_new_tokens": COLD_BIG_NEW,
        "preemptions": srv_c.stats["preemptions"],
        "cold_parks": srv_c.stats["cold_parks"],
        "cold_promotes": srv_c.stats["cold_promotes"],
        "remote_hwm_bytes_no_park": hwm_n,
        "remote_hwm_bytes_cold_park": hwm_c,
        "remote_hwm_reduction": round(1 - hwm_c / max(hwm_n, 1), 3),
        # modeled tier-edge traffic of the cold-park run: bytes, modeled
        # transfer seconds and move count per hierarchy edge
        "transfers_cold_park": xfers,
        "drain_s_no_park": round(dt_n, 3),
        "drain_s_cold_park": round(dt_c, 3),
        "tokens_identical_to_uncontended": True,
    }


DISAGG_LONG_PROMPT = 128      # the mid-stream arrival that stalls decode
DISAGG_LONG_NEW = 8
DISAGG_N_LONG = 2
# staggered steady budgets: slots free at different blocks, so the long
# prompts really do arrive MID-STREAM beside live decoders
DISAGG_STEADY_NEW = (32, 64, 96, 96)


def _serve_disagg(cfg, params) -> dict:
    """Prefill/decode interference scenario: a steady decode batch with
    two long prompts arriving mid-stream (they admit as slots free while
    the rest of the batch is still decoding).  Monolithic admission
    prefills each 128-token prompt in ONE synchronous dispatch between
    decode blocks — a multi-block stall for every live slot; the
    disaggregated server chunks the same prefill through the async
    engine and hands the pages off, bounding the worst-case stall to
    ``ceil(chunk / block)`` = 1 block.  Tokens must be bit-identical at
    temperature 0.0 AND 0.7; the chunk-size sweep records the
    stall-vs-overhead trade-off (smaller chunks = shorter stalls, more
    dispatches)."""
    def submit_all(server):
        rng = np.random.RandomState(17)
        reqs = [server.submit(rng.randint(0, cfg.vocab, PROMPT)
                              .astype(np.int32), max_new_tokens=m)
                for m in DISAGG_STEADY_NEW]
        reqs += [server.submit(rng.randint(0, cfg.vocab, DISAGG_LONG_PROMPT)
                               .astype(np.int32),
                               max_new_tokens=DISAGG_LONG_NEW)
                 for _ in range(DISAGG_N_LONG)]
        return reqs

    def serve(disagg: bool, temp: float, chunk: int = BLOCK):
        kw = dict(batch_size=BATCH, max_seq=MAX_SEQ, block_size=BLOCK,
                  paged=True, temperature=temp)
        if disagg:
            kw.update(prefill_async=True, prefill_chunk_tokens=chunk)
        srv = BatchedServer(build_model(cfg), params, **kw)
        reqs = submit_all(srv)
        t0 = time.perf_counter()
        srv.run_once()
        dt = time.perf_counter() - t0
        assert all(r.done.is_set() and r.error is None for r in reqs), \
            [(r.uid, r.error) for r in reqs]
        if disagg:
            srv.manager.audit()
            assert srv.manager.handoff_pages == 0, "leaked handoff pages"
        return [tuple(r.output) for r in reqs], srv, dt

    out_m0, srv_m0, dt_m = serve(False, 0.0)
    out_d0, srv_d0, dt_d = serve(True, 0.0)
    out_m7, _, _ = serve(False, 0.7)
    out_d7, _, _ = serve(True, 0.7)
    stall_mono = srv_m0.stats["decode_stall_blocks_max"]
    stall_dis = srv_d0.stats["decode_stall_blocks_max"]
    assert out_d0 == out_m0, "disaggregated serving diverged at temp 0.0"
    assert out_d7 == out_m7, "disaggregated serving diverged at temp 0.7"
    assert stall_mono >= 3, (stall_mono, srv_m0.stats)
    assert stall_dis <= 1, (stall_dis, srv_d0.stats)
    # chunk-size trade-off: stall bound vs prefill dispatch count
    sweep = {}
    for chunk in (BLOCK, 2 * BLOCK, DISAGG_LONG_PROMPT):
        _, srv_c, dt_c = serve(True, 0.0, chunk=chunk)
        sweep[str(chunk)] = {
            "decode_stall_blocks_max": srv_c.stats[
                "decode_stall_blocks_max"],
            "prefill_chunks": srv_c.stats["prefill_chunks"],
            "ttft_p50_blocks": srv_c.stats["ttft_p50_blocks"],
            "ttft_p99_blocks": srv_c.stats["ttft_p99_blocks"],
            "drain_s": round(dt_c, 3),
        }
    return {
        "steady_new_tokens": list(DISAGG_STEADY_NEW),
        "long_prompt": DISAGG_LONG_PROMPT,
        "long_new_tokens": DISAGG_LONG_NEW,
        "n_long": DISAGG_N_LONG,
        "prefill_chunk_tokens": srv_d0.prefill.chunk_tokens,
        "handoffs": srv_d0.stats["handoffs"],
        "prefill_chunks": srv_d0.stats["prefill_chunks"],
        "decode_stall_blocks_max_monolithic": stall_mono,
        "decode_stall_blocks_max_disagg": stall_dis,
        "decode_stall_blocks_total_monolithic": srv_m0.stats[
            "decode_stall_blocks_total"],
        "decode_stall_blocks_total_disagg": srv_d0.stats[
            "decode_stall_blocks_total"],
        "ttft_p50_blocks_monolithic": srv_m0.stats["ttft_p50_blocks"],
        "ttft_p50_blocks_disagg": srv_d0.stats["ttft_p50_blocks"],
        "ttft_p99_blocks_monolithic": srv_m0.stats["ttft_p99_blocks"],
        "ttft_p99_blocks_disagg": srv_d0.stats["ttft_p99_blocks"],
        "drain_s_monolithic": round(dt_m, 3),
        "drain_s_disagg": round(dt_d, 3),
        "tokens_identical_t0": out_d0 == out_m0,
        "tokens_identical_t07": out_d7 == out_m7,
        "chunk_sweep": sweep,
    }


OVERLOAD_PAGE = 4
OVERLOAD_BLOCK = 8
OVERLOAD_MAX_SEQ = 96
OVERLOAD_NEW = 24
# worst case per request: bucketed 8-token prompt + 23 decode tokens =
# 31 tokens = 8 pages; capacity 16 = exactly two live slots' worth
OVERLOAD_POOL = 17
OVERLOAD_STEADY = 10          # steady offers beyond the two SLA probes
OVERLOAD_MAX_PENDING = 4
OVERLOAD_FACTOR = 2.0
OVERLOAD_DEADLINE = 1         # blocks: the probes cannot finish in time
# admitted-p99-TTFT ceiling (block units) for the CONTROLLED server:
# max_pending bounds the backlog to one slot-generation behind the
# live batch, so first tokens land within a few request drains
OVERLOAD_TTFT_CEIL = 12.0


def _serve_overload(cfg, params) -> dict:
    """Overload admission-control scenario: twelve requests hit a
    two-slot server whose pool holds exactly two worst cases.  The
    UNCONTROLLED server queues everything — every request eventually
    serves, but admitted tail TTFT grows with queue depth.  The
    CONTROLLED server (``max_pending`` + ``overload_factor``) rejects
    the uncredible offers at submit time with a structured error and
    keeps the admitted tail bounded.  Two probes carry a 1-block SLA
    deadline and must come back ``expired`` (cancelled mid-decode, pages
    reclaimed).  Every terminal outcome is counted and the counts must
    sum to the offered load; both pools drain to zero pages."""
    def offer(server):
        rng = np.random.RandomState(23)
        reqs = [server.submit(rng.randint(0, cfg.vocab, PROMPT)
                              .astype(np.int32),
                              max_new_tokens=OVERLOAD_NEW,
                              deadline_blocks=OVERLOAD_DEADLINE)
                for _ in range(2)]
        reqs += [server.submit(rng.randint(0, cfg.vocab, PROMPT)
                               .astype(np.int32),
                               max_new_tokens=OVERLOAD_NEW)
                 for _ in range(OVERLOAD_STEADY)]
        return reqs

    def serve(controlled: bool):
        kw = dict(batch_size=2, max_seq=OVERLOAD_MAX_SEQ,
                  block_size=OVERLOAD_BLOCK, paged=True,
                  page_size=OVERLOAD_PAGE, num_pages=OVERLOAD_POOL,
                  audit=True)
        if controlled:
            kw.update(max_pending=OVERLOAD_MAX_PENDING,
                      overload_factor=OVERLOAD_FACTOR)
        srv = BatchedServer(build_model(cfg), params, **kw)
        reqs = offer(srv)
        t0 = time.perf_counter()
        for _ in range(200):
            srv.run_once()
            if all(r.done.is_set() for r in reqs):
                break
        dt = time.perf_counter() - t0
        assert all(r.done.is_set() for r in reqs), "overload run stuck"
        srv.manager.audit()
        s = srv.stats
        counts = {o: sum(1 for r in reqs if r.outcome == o)
                  for o in ("completed", "rejected", "expired", "shed")}
        assert sum(counts.values()) == len(reqs), (counts, len(reqs))
        assert counts["completed"] == s["completed"]
        assert counts["rejected"] == s["rejected"]
        assert counts["expired"] == s["expired"]
        return reqs, srv, dt, counts

    offered = 2 + OVERLOAD_STEADY
    reqs_c, srv_c, dt_c, counts_c = serve(True)
    reqs_u, srv_u, dt_u, counts_u = serve(False)
    assert counts_c["rejected"] >= 1, counts_c
    assert counts_c["completed"] >= 1, counts_c
    assert counts_c["expired"] >= 1, counts_c
    assert counts_u["rejected"] == 0, counts_u
    for r in reqs_c:
        if r.outcome == "rejected":
            assert r.error["reason"] == "admission_rejected", r.error
            assert len(r.output) == 0
    p99_c = srv_c.stats["ttft_p99_blocks"]
    p99_u = srv_u.stats["ttft_p99_blocks"]
    assert p99_c <= OVERLOAD_TTFT_CEIL < p99_u, (p99_c, p99_u)
    assert srv_c.manager.pages_in_use == 0
    assert srv_u.manager.pages_in_use == 0

    def side(srv, counts, dt):
        return {
            "completed": counts["completed"],
            "rejected": counts["rejected"],
            "expired": counts["expired"],
            "sheds": counts["shed"],
            "admitted_ttft_p50_blocks": srv.stats["ttft_p50_blocks"],
            "admitted_ttft_p99_blocks": srv.stats["ttft_p99_blocks"],
            "e2e_p50_blocks": srv.stats["e2e_p50_blocks"],
            "e2e_p99_blocks": srv.stats["e2e_p99_blocks"],
            "audits": srv.stats["audits"],
            "leaked_pages": srv.manager.pages_in_use,
            "drain_s": round(dt, 3),
        }

    return {
        "offered": offered, "batch": 2,
        "num_pages": OVERLOAD_POOL, "page_size": OVERLOAD_PAGE,
        "new_tokens": OVERLOAD_NEW,
        "max_pending": OVERLOAD_MAX_PENDING,
        "overload_factor": OVERLOAD_FACTOR,
        "sla_probes": 2, "deadline_blocks": OVERLOAD_DEADLINE,
        "ttft_p99_bound_blocks": OVERLOAD_TTFT_CEIL,
        "controlled": side(srv_c, counts_c, dt_c),
        "uncontrolled": side(srv_u, counts_u, dt_u),
        "p99_ttft_bounded": p99_c <= OVERLOAD_TTFT_CEIL,
    }


def _attention_scaling(model) -> dict:
    """Per-decode-step attention read cost at several live sequence
    lengths: the dense slab always scans max_seq columns; the paged path
    reads only the (power-of-two bucketed) pages covering the live
    length.  FLOPs/token = 2 dots x 2 FLOPs/MAC x Hq x hd x columns."""
    cfg = model.cfg
    hq, hd, page = cfg.padded_heads, cfg.head_dim, cfg.page_size
    out = {}
    for s in (16, 32, 64, 128, 256, 384):
        if s > MAX_SEQ:
            continue
        paged_cols = _bucket(-(-s // page), 1) * page
        out[str(s)] = {
            "dense_cols": MAX_SEQ,
            "paged_cols": paged_cols,
            "dense_attn_flops_per_tok": 4 * hq * hd * MAX_SEQ,
            "paged_attn_flops_per_tok": 4 * hq * hd * paged_cols,
        }
    return out


def run() -> list[str]:
    model, params, prompts = _setup()
    cfg = model.cfg
    total = BATCH * NEW_TOKENS

    dt_old, disp_old, sync_old, outs_old = _per_token(model, params, prompts)
    dt_new, disp_new, sync_new, outs_new = _block_decode(
        model, params, prompts)
    assert outs_old == outs_new, "block decode must match per-token decode"
    assert disp_old == NEW_TOKENS                  # 1 dispatch / token
    assert disp_new == NEW_TOKENS // BLOCK         # 1 dispatch / block
    assert sync_new == NEW_TOKENS // BLOCK         # 1 host sync / block

    dts, outs, servers = _serve_requests(cfg, params)
    dt_dense, dt_paged, dt_q8, dt_fp8, dt_disagg = dts
    out_dense, out_paged, out_q8, out_fp8, out_disagg = outs
    srv_dense, srv_paged = servers[:2]
    assert out_paged == out_dense, \
        "paged serving must emit identical tokens to the dense cache"
    assert out_disagg == out_paged, \
        "disaggregated serving must emit identical tokens to monolithic"
    prefix = _serve_prefix(cfg, params)
    sharded = _serve_sharded(cfg, params, out_paged)
    preemption = _serve_preemption(cfg, params)
    cold_park = _serve_cold_park(cfg, params)
    disagg = _serve_disagg(cfg, params)
    overload = _serve_overload(cfg, params)

    mgr = srv_paged.manager
    bytes_per_page = srv_paged.kv_bytes_capacity() // (mgr.num_pages)
    dense_slab = tree_bytes(srv_dense.cache)
    hwm_bytes = mgr.hwm * bytes_per_page
    # every slot was live simultaneously: peak tokens = admitted prompt
    # length + the full decode budget, per slot
    peak_tokens = BATCH * (srv_paged._admit_plen(PROMPT, NEW_TOKENS)
                           + NEW_TOKENS - 1)

    tps_old, tps_new = total / dt_old, total / dt_new
    tps_dense, tps_paged = total / dt_dense, total / dt_paged
    tps_q8, tps_fp8 = total / dt_q8, total / dt_fp8
    tps_disagg = total / dt_disagg
    kvq = _kv_quant_block(cfg, params, prompts, servers, dts, outs,
                          peak_tokens)

    bench = {
        "model": cfg.name,
        "batch": BATCH, "prompt": PROMPT, "new_tokens": NEW_TOKENS,
        "block_size": BLOCK, "max_seq": MAX_SEQ,
        "tokens_per_s": {
            "per_token_dense": round(tps_old, 1),
            "block_dense": round(tps_new, 1),
            "server_dense": round(tps_dense, 1),
            "server_paged": round(tps_paged, 1),
            "server_paged_q8": round(tps_q8, 1),
            "server_paged_fp8": round(tps_fp8, 1),
            "server_disagg": round(tps_disagg, 1),
        },
        "speedup_block_vs_per_token": round(tps_new / tps_old, 2),
        "paged_vs_dense_tokens_identical": True,
        "kv_memory": {
            "page_size": mgr.page_size,
            "dense_slab_bytes": dense_slab,
            "paged_pool_capacity_bytes": srv_paged.kv_bytes_capacity(),
            "paged_hwm_bytes": hwm_bytes,
            "peak_live_tokens": peak_tokens,
            "bytes_per_active_token_dense": round(dense_slab / peak_tokens),
            "bytes_per_active_token_paged": round(hwm_bytes / peak_tokens),
            # same capacity_reduction the Table 4.3 simulator reports
            "local_kv_reduction_vs_dense": round(
                capacity_reduction(hwm_bytes, dense_slab), 3),
            "fragmentation_hwm_bound": round(
                1 - peak_tokens / (mgr.hwm * mgr.page_size), 3),
        },
        # serving-pipeline counters: executables compiled across the hot
        # path's jit entry points (the O(log) bucketing claim), host
        # syncs (one per harvested block), and page-table maintenance
        # traffic (full rebuilds vs steady-state delta entries)
        "pipeline": {
            "enabled": srv_paged.pipeline,
            "max_inflight": srv_paged.max_inflight,
            "compiles": srv_paged.stats["compiles"],
            "host_syncs": srv_paged.stats["host_syncs"],
            "dispatches": srv_paged.stats["dispatches"],
            "table_rebuilds": srv_paged.stats["table_rebuilds"],
            "table_delta_entries": srv_paged.stats["table_delta_entries"],
        },
        # quantized page pools: int8 / fp8 values + per-(slot, head)
        # bf16 scales, dequant fused into the pool reads.  Effective
        # bytes per active token (scales included) vs the bf16 pool,
        # greedy agreement and the one-step logit error — the gated
        # KV-precision trade-off record.
        "kv_quant": kvq,
        "prefix_cache": prefix,
        # tensor-parallel serving: mesh shape, tokens/s, bit-identity to
        # the single-device server, per-axis collective bytes of one
        # decode block, and the per-shard residency snapshot
        "sharded": sharded,
        # memory-pressure robustness: the deep-queue scenario above —
        # page-granular preemption admits the queued shorts orders of
        # magnitude earlier than waiting on hog reclamation, with
        # bit-identical tokens and a clean allocator audit every block
        "preemption": preemption,
        # cold-tier parking under deep preemption: with
        # cold_park_after_blocks=0 both victims of a two-victim round
        # swap straight to the cold tier and only transit remote one at
        # a time on resume — the remote-tier high-water mark halves
        # while every token stays bit-identical
        "cold_park": cold_park,
        # disaggregated prefill/decode: mid-stream long-prompt arrivals
        # stall monolithic decode for whole-prompt prefills; the async
        # engine bounds the stall to one chunk with bit-identical tokens
        # at temp 0.0 and 0.7 (steady throughput lands in tokens_per_s
        # as server_disagg, interleave-measured against server_paged)
        "disagg": disagg,
        # overload admission control: a 6x-oversubscribed offered load
        # against the same two-slot pool with and without the gate —
        # structured rejections and SLA expiries keep the admitted
        # p99 TTFT bounded while the uncontrolled queue's tail grows
        # with queue depth
        "overload": overload,
        # per-tier residency from the orchestrator's ledger: every tier
        # carries in_use_bytes / hwm_bytes / by_class (schema-checked in
        # CI).  ``tiers`` is the drained end state; ``tiers_peak`` is the
        # mid-flight snapshot at peak pool occupancy, where the kv_pool
        # class is non-degenerate.
        "tiers": srv_paged.tier_stats(),
        "tiers_peak": srv_paged.tier_stats_peak(),
        # tier-edge transfer ledger of the headline paged server: bytes
        # moved, modeled seconds (bandwidth/latency link model shared
        # with the Table-4.3 simulator) and move count per edge
        "transfers": srv_paged.mem.ledger.transfers(),
        "attention_scaling": _attention_scaling(model),
    }
    JSON_PATH.write_text(json.dumps(bench, indent=2) + "\n")

    km = bench["kv_memory"]
    pl = bench["pipeline"]
    ov_c, ov_u = overload["controlled"], overload["uncontrolled"]
    rp = sharded["row_parallel"]
    rp_tps = rp["tokens_per_s_sharded"]
    rp_bytes = sum(rp["collective_bytes_per_token_by_axis"].values())
    rows = [
        f"serve_per_token,{dt_old / NEW_TOKENS * 1e6:.0f},"
        f"tok_s={tps_old:.0f} dispatches_per_step="
        f"{disp_old / NEW_TOKENS:.3f} syncs_per_tok={sync_old / total:.3f}",
        f"serve_block{BLOCK},{dt_new / NEW_TOKENS * 1e6:.0f},"
        f"tok_s={tps_new:.0f} dispatches_per_step="
        f"{disp_new / NEW_TOKENS:.3f} syncs_per_tok={sync_new / total:.3f}"
        f" speedup={tps_new / tps_old:.2f}x",
        f"serve_paged,{dt_paged / NEW_TOKENS * 1e6:.0f},"
        f"tok_s={tps_paged:.0f} vs_dense={tps_paged / tps_dense:.2f}x"
        f" kv_hwm_bytes={km['paged_hwm_bytes']}"
        f" dense_slab_bytes={km['dense_slab_bytes']}"
        f" kv_reduction={km['local_kv_reduction_vs_dense']:.1%}"
        f" compiles={pl['compiles']} table_rebuilds={pl['table_rebuilds']}"
        f" identical_tokens=True json={JSON_PATH.name}",
        f"server_paged_q8,{dt_q8 / NEW_TOKENS * 1e6:.0f},"
        f"tok_s={tps_q8:.0f} vs_bf16_paged={tps_q8 / tps_paged:.2f}x"
        f" bytes_ratio={kvq['int8']['bytes_ratio_vs_bf16']:.3f}"
        f" capacity_gain={kvq['int8']['capacity_gain_vs_bf16']:.2f}x"
        f" greedy_match={kvq['int8']['greedy_match_rate_vs_bf16']:.3f}"
        f" max_dlogit={kvq['int8']['max_abs_logit_err']:.4f}",
        f"server_paged_fp8,{dt_fp8 / NEW_TOKENS * 1e6:.0f},"
        f"tok_s={tps_fp8:.0f} vs_bf16_paged={tps_fp8 / tps_paged:.2f}x"
        f" bytes_ratio={kvq['fp8_e4m3']['bytes_ratio_vs_bf16']:.3f}"
        f" capacity_gain={kvq['fp8_e4m3']['capacity_gain_vs_bf16']:.2f}x"
        f" greedy_match={kvq['fp8_e4m3']['greedy_match_rate_vs_bf16']:.3f}"
        f" max_dlogit={kvq['fp8_e4m3']['max_abs_logit_err']:.4f}",
        f"serve_prefix_cache,"
        f"{BATCH / prefix['tokens_per_s_shared'] * 1e6:.0f},"
        f"tok_s={prefix['tokens_per_s_shared']:.0f}"
        f" shared_pages={prefix['shared_pages']}"
        f" kv_hwm_shared={prefix['kv_hwm_bytes_shared']}"
        f" kv_hwm_unshared={prefix['kv_hwm_bytes_unshared']}"
        f" residency_reduction="
        f"{prefix['residency_reduction_vs_unshared']:.1%}"
        f" identical_tokens=True",
        f"server_sharded,"
        f"{BATCH / sharded['tokens_per_s_sharded'] * 1e6:.0f},"
        f"tok_s={sharded['tokens_per_s_sharded']:.0f}"
        f" model_shards={sharded['model_shards']}"
        f" devices={sharded['devices']}"
        f" collective_B_per_tok="
        f"{sum(sharded['collective_bytes_per_token_by_axis'].values())}"
        f" identical_tokens=True",
        f"server_rowparallel,{BATCH / rp_tps * 1e6:.0f},"
        f"tok_s={rp_tps:.0f}"
        f" deterministic=False collective_B_per_tok={rp_bytes}",
        f"serve_preemption,"
        f"{preemption['drain_s_preempt'] * 1e6:.0f},"
        f"preemptions={preemption['preemptions']}"
        f" resumes={preemption['resumes']}"
        f" short_wait_blocks={preemption['max_admission_wait_blocks_preempt']}"
        f" vs_no_preempt="
        f"{preemption['max_admission_wait_blocks_no_preempt']}"
        f" wait_reduction={preemption['admission_wait_reduction']:.1%}"
        f" audits={preemption['audits']} identical_tokens=True",
        f"serve_cold_park,"
        f"{cold_park['drain_s_cold_park'] * 1e6:.0f},"
        f"cold_parks={cold_park['cold_parks']}"
        f" cold_promotes={cold_park['cold_promotes']}"
        f" remote_hwm_cold={cold_park['remote_hwm_bytes_cold_park']}"
        f" vs_no_park={cold_park['remote_hwm_bytes_no_park']}"
        f" remote_hwm_reduction={cold_park['remote_hwm_reduction']:.1%}"
        f" identical_tokens=True",
        f"server_disagg,{dt_disagg / NEW_TOKENS * 1e6:.0f},"
        f"tok_s={tps_disagg:.0f}"
        f" vs_paged={tps_disagg / tps_paged:.2f}x"
        f" stall_blocks={disagg['decode_stall_blocks_max_disagg']}"
        f" vs_monolithic={disagg['decode_stall_blocks_max_monolithic']}"
        f" handoffs={disagg['handoffs']}"
        f" chunks={disagg['prefill_chunks']}"
        f" ttft_p50={disagg['ttft_p50_blocks_disagg']}"
        f" identical_tokens=True",
        f"serve_overload,{ov_c['drain_s'] * 1e6:.0f},"
        f"offered={overload['offered']}"
        f" completed={ov_c['completed']}"
        f" rejected={ov_c['rejected']}"
        f" expired={ov_c['expired']}"
        f" ttft_p99_admitted={ov_c['admitted_ttft_p99_blocks']}"
        f" vs_uncontrolled={ov_u['admitted_ttft_p99_blocks']}"
        f" bound={overload['ttft_p99_bound_blocks']}"
        f" leaked_pages={ov_c['leaked_pages']}",
        _continuous(model, params),
    ]
    return rows


def _continuous(model, params) -> str:
    server = BatchedServer(model, params, batch_size=2, max_seq=MAX_SEQ,
                           block_size=8)
    server.submit(np.arange(1, 6, dtype=np.int32), max_new_tokens=32)
    server.submit(np.arange(6, 9, dtype=np.int32), max_new_tokens=8)
    server.submit(np.arange(9, 11, dtype=np.int32), max_new_tokens=8)
    t0 = time.perf_counter()
    done = server.run_once()
    us = (time.perf_counter() - t0) * 1e6
    s = server.stats
    assert s["batches"] == 1 and len(done) == 3, (s, done)
    return (f"serve_continuous_batching,{us:.0f},"
            f"reqs={len(done)} slots=2 batches={s['batches']} "
            f"admitted_mid_stream={s['admitted'] - 2} "
            f"tok_per_dispatch={s['tokens'] / max(s['dispatches'], 1):.1f}")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    for row in run():
        print(row)
