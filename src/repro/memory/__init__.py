"""repro.memory — the FengHuang memory-orchestration subsystem.

One place for everything the paper calls *memory orchestration*:

* :mod:`repro.memory.tiers` — backend-resolved N-tier registry (the
  ordered ``local``/``remote``/``cold`` hierarchy with per-tier modeled
  bandwidth/latency) and the placement primitives (``page_in`` /
  ``page_out`` / ``eager_to_tier`` / ``host_put`` / sharded variants).
* :mod:`repro.memory.policies` — the :class:`ResidencyPolicy` seam and
  its concrete policies (``PinLocal``, ``DoubleBufferPrefetch``,
  ``BlockPoolResidency``, ``OffloadBetweenSteps``,
  ``TopKExpertPrefetch``) plus :class:`PagerConfig`.
* :mod:`repro.memory.orchestrator` — :class:`MemoryOrchestrator`, which
  binds tensor classes to policies and owns the paged scan transforms
  and the donation contract; ``MemoryOrchestrator.plan(cfg)`` is the one
  entry point models, the server, benchmarks and examples use.
* :mod:`repro.memory.accounting` — per-tier byte accounting (ledger,
  high-water marks, fragmentation) shared between the live runtime and
  the Table 4.3 simulator, so measured and simulated capacity reduction
  go through one code path.
* :mod:`repro.memory.swap` — :class:`PageSwapper`, batched KV-page
  transfers between the device block pool and the remote tier (the
  mechanism behind page-granular preemption), riding the fault-injected
  retrying transfer contract in :mod:`repro.memory.tiers`
  (:class:`FaultPlan` / :func:`transfer_with_retry`).

The ``repro.core.pager`` re-export shim promised for one release is
gone; import from here.
"""
from repro.memory.accounting import (MemoryLedger, capacity_reduction,
                                     modeled_transfer_s, paged_window_bytes,
                                     peak_local_bytes, resident_window_bytes,
                                     tree_bytes)
from repro.memory.orchestrator import (MemoryOrchestrator, donating_jit,
                                       paged_map, paged_scan,
                                       paged_scan_cache)
from repro.memory.policies import (BlockPoolResidency, DoubleBufferPrefetch,
                                   OffloadBetweenSteps, PagerConfig, PinLocal,
                                   ResidencyPolicy, TopKExpertPrefetch)
from repro.memory.tiers import (COLD, HIERARCHY, LOCAL, REMOTE, FaultPlan,
                                Tier, TierEdge, TierTransferError,
                                active_fault_plan, eager_to_remote,
                                eager_to_tier, fault_plan, host_put,
                                install_fault_plan, local_sharding, page_in,
                                page_out, remote_sharding, reset,
                                resolved_cold_kind, resolved_kind,
                                resolved_local_kind, resolved_remote_kind,
                                supports_memory_spaces, tier_sharding,
                                to_remote, transfer_with_retry)
from repro.memory.swap import PageSwapper, SwapHandle

__all__ = [
    "MemoryLedger", "capacity_reduction", "modeled_transfer_s",
    "paged_window_bytes", "peak_local_bytes", "resident_window_bytes",
    "tree_bytes",
    "MemoryOrchestrator", "donating_jit", "paged_map", "paged_scan",
    "paged_scan_cache",
    "BlockPoolResidency", "DoubleBufferPrefetch", "OffloadBetweenSteps",
    "PagerConfig", "PinLocal", "ResidencyPolicy", "TopKExpertPrefetch",
    "PageSwapper", "SwapHandle",
    "FaultPlan", "TierTransferError", "active_fault_plan", "fault_plan",
    "install_fault_plan", "transfer_with_retry",
    "COLD", "HIERARCHY", "LOCAL", "REMOTE", "Tier", "TierEdge",
    "eager_to_remote", "eager_to_tier", "host_put", "local_sharding",
    "page_in", "page_out", "remote_sharding", "reset", "resolved_cold_kind",
    "resolved_kind", "resolved_local_kind", "resolved_remote_kind",
    "supports_memory_spaces", "tier_sharding", "to_remote",
]
