"""Block-pool paged KV cache: kernel edge cases, BlockManager invariants,
and end-to-end paged-vs-dense serving parity."""
import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.kernels.paged_attention import ops as pa
from repro.kernels.paged_attention.ops import BlockManager
from repro.kernels.paged_attention.ref import gather_pages
from repro.memory import BlockPoolResidency
from repro.models.base import DecodeState
from repro.models.layers import decode_attention, paged_decode_attention
from repro.models.transformer import decode_loop
from repro.runtime.serve import BatchedServer

RNG = np.random.RandomState(7)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("qwen2.5-14b").reduced()
    cfg = dataclasses.replace(cfg, remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


# ---------------------------------------------------------------------------
# kernel edge cases: kernel (interpret) vs gather oracle vs dense attention
# ---------------------------------------------------------------------------

def _pool(b, npages, page, hkv, d, dtype=jnp.float32):
    pool = 1 + b * npages
    kp = jnp.asarray(RNG.randn(pool, page, hkv, d), dtype) * 0.3
    vp = jnp.asarray(RNG.randn(pool, page, hkv, d), dtype)
    table = jnp.asarray(1 + np.arange(b * npages).reshape(b, npages),
                        jnp.int32)
    return kp, vp, table


@pytest.mark.parametrize("lens", [
    (5, 11),      # partial last page on both rows
    (0, 12),      # empty slot next to a live one
    (8, 16),      # exact page boundaries
])
@pytest.mark.parametrize("g", [1, 3])       # GQA group of 1 and > 1
def test_paged_kernel_edge_cases(lens, g):
    b, hkv, d, page, npages = 2, 2, 16, 8, 2
    kp, vp, table = _pool(b, npages, page, hkv, d)
    q = jnp.asarray(RNG.randn(b, hkv, g, d), jnp.float32) * 0.3
    seq_lens = jnp.asarray(lens, jnp.int32)
    k0 = jnp.asarray(RNG.randn(b, hkv, d), jnp.float32) * 0.3
    v0 = jnp.asarray(RNG.randn(b, hkv, d), jnp.float32)

    out = pa.attend(q, kp, vp, table, seq_lens, (k0, v0), interpret=True)
    ref = pa.attend_ref(q, kp, vp, table, seq_lens, (k0, v0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # ... and against the DENSE decode path over the gathered view — the
    # parity that makes paged serving bit-compatible with the dense cache
    hq = hkv * g
    qd = q.reshape(b, 1, hq, d)
    kd, vd = gather_pages(kp, table), gather_pages(vp, table)
    dense = decode_attention(qd, kd, vd, seq_lens, extra_kv=(k0, v0))
    np.testing.assert_allclose(
        np.asarray(out).reshape(b, 1, hq, d), np.asarray(dense),
        atol=2e-5, rtol=2e-5)


def test_paged_kernel_null_page_padding():
    """Table columns past a sequence's pages map the null page 0; its
    contents must never leak into the output."""
    b, hkv, g, d, page = 1, 2, 2, 16, 8
    kp, vp, table = _pool(b, 3, page, hkv, d)
    # poison the null page, then point the last table column at it
    kp = kp.at[0].set(100.0)
    vp = vp.at[0].set(-100.0)
    table = table.at[0, 2].set(0)
    q = jnp.asarray(RNG.randn(b, hkv, g, d), jnp.float32) * 0.3
    k0 = jnp.asarray(RNG.randn(b, hkv, d), jnp.float32) * 0.3
    v0 = jnp.asarray(RNG.randn(b, hkv, d), jnp.float32)
    seq_lens = jnp.asarray([13], jnp.int32)       # inside the real pages

    out = pa.attend(q, kp, vp, table, seq_lens, (k0, v0), interpret=True)
    short = pa.attend(q, kp, vp, table[:, :2], seq_lens, (k0, v0),
                      interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(short),
                               atol=2e-5, rtol=2e-5)
    assert np.abs(np.asarray(out)).max() < 50


def test_paged_kernel_seq_len_zero_with_self_column():
    """A fresh slot (seq_len 0) must attend ONLY the current token."""
    b, hkv, g, d, page = 1, 2, 2, 16, 8
    kp, vp, table = _pool(b, 2, page, hkv, d)
    q = jnp.asarray(RNG.randn(b, hkv, g, d), jnp.float32) * 0.3
    k0 = jnp.asarray(RNG.randn(b, hkv, d), jnp.float32) * 0.3
    v0 = jnp.asarray(RNG.randn(b, hkv, d), jnp.float32)
    out = pa.attend(q, kp, vp, table, jnp.asarray([0], jnp.int32),
                    (k0, v0), interpret=True)
    # softmax over a single column == that column's value
    np.testing.assert_allclose(
        np.asarray(out), np.broadcast_to(np.asarray(v0)[:, :, None, :],
                                         (b, hkv, g, d)),
        atol=2e-5, rtol=2e-5)


def test_paged_decode_attention_backend_routing():
    """Kernel (interpret) and gather fallback agree through the layer-level
    entry point, q in (B, 1, Hq, hd) layout."""
    b, hkv, g, d, page = 2, 2, 2, 16, 8
    kp, vp, table = _pool(b, 2, page, hkv, d)
    q = jnp.asarray(RNG.randn(b, 1, hkv * g, d), jnp.float32) * 0.3
    k0 = jnp.asarray(RNG.randn(b, hkv, d), jnp.float32) * 0.3
    v0 = jnp.asarray(RNG.randn(b, hkv, d), jnp.float32)
    cur = jnp.asarray([7, 15], jnp.int32)
    a = paged_decode_attention(q, kp, vp, table, cur, (k0, v0),
                               use_kernel=False)
    k = paged_decode_attention(q, kp, vp, table, cur, (k0, v0),
                               use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(k),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# BlockManager invariants
# ---------------------------------------------------------------------------

def test_block_manager_alloc_free_reuse_churn():
    mgr = BlockManager(num_pages=17, page_size=4)
    rng = random.Random(0)
    live: dict[int, int] = {}       # slot -> tokens
    for step in range(200):
        if live and (rng.random() < 0.4 or len(live) >= 4):
            slot = rng.choice(list(live))
            mgr.free_slot(slot)
            del live[slot]
        else:
            slot = rng.randrange(8)
            if slot in live:
                tokens = live[slot] + rng.randrange(1, 9)
            else:
                tokens = rng.randrange(1, 17)
            if mgr.pages_for(tokens) - len(mgr.slot_pages(slot)) \
                    > mgr.free_pages:
                continue
            mgr.ensure(slot, tokens)
            mgr.note_tokens(slot, tokens)
            live[slot] = tokens
        # invariants: no double ownership, null page never allocated,
        # conservation, coverage
        owned = [p for t in mgr.pages.values() for p in t]
        assert len(owned) == len(set(owned))
        assert 0 not in owned and 0 not in mgr._free
        assert len(owned) + mgr.free_pages == mgr.capacity
        for slot, tokens in live.items():
            assert len(mgr.slot_pages(slot)) >= mgr.pages_for(tokens)
        assert 0.0 <= mgr.fragmentation() < 1.0
    for slot in list(live):
        mgr.free_slot(slot)
    assert mgr.free_pages == mgr.capacity and mgr.pages_in_use == 0
    assert mgr.hwm > 0


def test_block_manager_exhaustion_and_null_page():
    mgr = BlockManager(num_pages=3, page_size=4)
    mgr.ensure(0, 8)                        # both allocatable pages
    with pytest.raises(MemoryError, match="exhausted"):
        mgr.ensure(1, 1)
    assert mgr.can_fit(0, 8) and not mgr.can_fit(1, 1)
    tab = mgr.table([0, 1], 3)
    assert tab.shape == (2, 3)
    assert tab[1].tolist() == [0, 0, 0]     # unallocated -> null page
    assert tab[0, 2] == 0                   # width padding -> null page


def test_block_pool_residency_batched_append():
    """Host-side BlockPoolResidency pools (the deleted PagePool's role):
    chunked append_block == one append_block, page-boundary crossing."""
    kw = dict(num_pages=8, page_size=4, kv_heads=2, head_dim=8)
    a = BlockPoolResidency(**kw)
    b = BlockPoolResidency(**kw)
    a.alloc_seq(1)
    b.alloc_seq(1)
    blk_k = jnp.asarray(RNG.randn(6, 2, 8), jnp.bfloat16)
    blk_v = jnp.asarray(RNG.randn(6, 2, 8), jnp.bfloat16)
    for lo, hi in ((0, 2), (2, 3), (3, 6)):      # three uneven chunks
        a.append_block(1, blk_k[lo:hi], blk_v[lo:hi])
    b.append_block(1, blk_k, blk_v)
    assert a.manager.lens[1] == b.manager.lens[1] == 6
    assert a.manager.pages[1] == b.manager.pages[1]
    np.testing.assert_array_equal(np.asarray(a.k, np.float32),
                                  np.asarray(b.k, np.float32))
    assert a.batch_lens([1]).tolist() == [6]
    assert a.batch_tables([1], 3).shape == (1, 3)
    a.free_seq(1)
    assert 1 not in a.manager.pages


# ---------------------------------------------------------------------------
# model-level parity: paged prefill/decode vs the dense cache path
# ---------------------------------------------------------------------------

def _dense_and_paged(model, params, batch, plen, max_seq, steps, page=16):
    cfg = model.cfg
    prompts = jax.random.randint(jax.random.PRNGKey(3), (batch, plen), 0,
                                 cfg.vocab)
    cache_d = model.init_cache(batch, max_seq)
    lg_d, cache_d = jax.jit(lambda p, t, c: model.prefill(p, t, c))(
        params, prompts, cache_d)

    mgr = BlockManager(1 + batch * (-(-max_seq // page)), page)
    cache_p = model.init_paged_cache(mgr.num_pages, page)
    for i in range(batch):
        mgr.ensure(i, plen + steps)
    n_prompt = mgr.pages_for(plen)
    prompt_pages = jnp.asarray(
        [mgr.slot_pages(i)[:n_prompt] for i in range(batch)], jnp.int32)
    lg_p, cache_p = jax.jit(lambda p, t, c, pg: model.prefill_paged(
        p, t, c, pg))(params, prompts, cache_p, prompt_pages)
    table = jnp.asarray(mgr.table(list(range(batch)),
                                  mgr.max_slot_pages()), jnp.int32)
    return (lg_d, cache_d), (lg_p, cache_p, table)


def test_paged_prefill_matches_dense(tiny_model):
    model, params = tiny_model
    (lg_d, cache_d), (lg_p, cache_p, table) = _dense_and_paged(
        model, params, batch=2, plen=8, max_seq=64, steps=6)
    np.testing.assert_array_equal(np.asarray(lg_d), np.asarray(lg_p))
    # every layer's pages hold exactly the dense cache's prompt KV
    for l in range(model.cfg.num_layers):
        kg = gather_pages(cache_p["k_pages"][l], table)
        np.testing.assert_array_equal(
            np.asarray(kg[:, :, :8], np.float32),
            np.asarray(cache_d["k"][l][:, :, :8], np.float32))


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_paged_decode_loop_matches_dense(tiny_model, temperature):
    """Greedy AND sampled parity: the paged pool emits bit-identical
    tokens to the dense cache under the same PRNG folding."""
    model, params = tiny_model
    batch, plen, steps = 2, 8, 6
    (lg_d, cache_d), (lg_p, cache_p, table) = _dense_and_paged(
        model, params, batch, plen, max_seq=64, steps=steps)
    cur = jnp.argmax(np.asarray(lg_d), axis=-1).astype(jnp.int32)
    common = dict(tokens=cur, pos=jnp.full((batch,), plen, jnp.int32),
                  active=jnp.ones((batch,), bool),
                  remaining=jnp.full((batch,), steps, jnp.int32),
                  key=jax.random.PRNGKey(7))
    run = jax.jit(lambda p, c, s: decode_loop(
        model, p, c, s, num_steps=steps, temperature=temperature))
    t_d, v_d, _, _ = run(params, cache_d, DecodeState(**common))
    t_p, v_p, _, _ = run(params, cache_p, DecodeState(**common, pages=table))
    np.testing.assert_array_equal(np.asarray(t_d), np.asarray(t_p))
    np.testing.assert_array_equal(np.asarray(v_d), np.asarray(v_p))


# ---------------------------------------------------------------------------
# server end-to-end
# ---------------------------------------------------------------------------

def test_server_paged_matches_dense_server(tiny_model):
    model, params = tiny_model
    prompts = [np.asarray([3, 1, 4, 1, 5], np.int32),
               np.asarray([9, 10], np.int32),
               np.asarray([6], np.int32)]

    def serve(paged):
        server = BatchedServer(model, params, batch_size=2, max_seq=64,
                               block_size=4, paged=paged)
        reqs = [server.submit(p, max_new_tokens=n)
                for p, n in zip(prompts, (9, 5, 7))]
        server.run_once()
        return server, [tuple(r.output) for r in reqs]

    sp, out_p = serve(True)
    sd, out_d = serve(False)
    assert sp.paged and not sd.paged
    assert out_p == out_d
    # continuous batching stayed intact and every page was reclaimed
    assert sp.stats["admitted"] == 3 and sp.stats["batches"] == 1
    assert sp.manager.pages_in_use == 0
    assert sp.manager.free_pages == sp.manager.capacity
    assert sp.stats["kv_pages_hwm"] > 0
    assert sp.kv_bytes_in_use() == 0


def test_server_paged_footprint_tracks_live_tokens(tiny_model):
    """KV pages consumed scale with actual tokens, not batch x max_seq."""
    model, params = tiny_model
    server = BatchedServer(model, params, batch_size=4, max_seq=256,
                           block_size=4, page_size=16)
    server.submit(np.asarray([1, 2, 3], np.int32), max_new_tokens=8)
    server.submit(np.asarray([4, 5], np.int32), max_new_tokens=8)
    server.run_once()
    # 2 slots x (8-token prompt bucket + 8 decode) = 1 page each; the
    # dense cache would hold 4 x 256 tokens = 64 pages worth
    assert server.manager.hwm <= 2
    dense_tokens = 4 * 256
    live_tokens = 2 * 16
    assert server.manager.hwm * 16 <= 2 * live_tokens
    assert server.kv_bytes_capacity() \
        == server.num_pages * 16 * model.cfg.padded_kv_heads \
        * model.cfg.head_dim * 2 * model.cfg.num_layers * 2
    assert dense_tokens // 16 == 64       # the slab the pool replaced


def test_server_paged_admission_backpressure(tiny_model):
    """A pool smaller than the worst case of two concurrent requests
    serializes them via admission instead of dying mid-decode."""
    model, params = tiny_model
    server = BatchedServer(model, params, batch_size=2, max_seq=32,
                           num_pages=4, page_size=8)
    a = server.submit(np.arange(1, 9, dtype=np.int32), max_new_tokens=16)
    b = server.submit(np.arange(9, 17, dtype=np.int32), max_new_tokens=16)
    done = server.run_once()
    assert {r.uid for r in done} == {a.uid, b.uid}
    assert len(a.output) == len(b.output) == 16
    assert server.manager.hwm <= server.manager.capacity
    assert server.manager.free_pages == server.manager.capacity
    # oversized-for-the-pool requests are rejected up front
    with pytest.raises(ValueError, match="KV pages"):
        server.submit(np.arange(1, 17, dtype=np.int32), max_new_tokens=17)


def test_evicted_slot_ghost_writes_never_corrupt_reused_pages(tiny_model):
    """A slot that finishes early keeps being executed (inactive, frozen
    position) by every later dispatch; its page-table row must be
    re-pointed at the null page so those ghost writes can never land in
    its freed pages once a live neighbour's growth reuses them (LIFO
    free order makes reuse immediate).  Tiny pages + blocks maximise
    page churn after the eviction."""
    model, params = tiny_model
    long_prompt = np.asarray([3, 1, 4, 1, 5], np.int32)

    def long_output(with_neighbour):
        server = BatchedServer(model, params, batch_size=2, max_seq=64,
                               block_size=2, page_size=2)
        req = server.submit(long_prompt, max_new_tokens=24)
        if with_neighbour:     # finishes after one block, pages reused
            server.submit(np.asarray([9, 10], np.int32), max_new_tokens=2)
        server.run_once()
        return tuple(req.output)

    assert long_output(True) == long_output(False)


def test_server_paged_offload_kv(tiny_model):
    """offload_kv composes: the pool rides the scan carry through the
    remote tier and still emits identical tokens."""
    model, params = tiny_model
    ocfg = model.cfg.with_pager(enabled=True, offload_kv=True)
    omodel = build_model(ocfg)
    prompt = np.asarray([3, 1, 4], np.int32)

    def run(m):
        server = BatchedServer(m, params, batch_size=2, max_seq=64)
        r = server.submit(prompt, max_new_tokens=8)
        server.run_once()
        return r.output

    assert run(omodel) == run(model)