"""Serving runtime: batched server end-to-end + sampling semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, build_model
from repro.runtime.serve import BatchedServer, sample


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("qwen2.5-14b").reduced()
    cfg = dataclasses.replace(cfg, remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_sample_greedy_masks_padded_vocab():
    logits = jnp.zeros((2, 1, 512))
    # put the max in the PADDED region — must never be sampled
    logits = logits.at[:, :, 500:].set(100.0)
    toks = sample(logits, vocab=500, temperature=0.0,
                  key=jax.random.PRNGKey(0))
    assert int(toks.max()) < 500


def test_server_serves_batch(tiny_model):
    model, params = tiny_model
    server = BatchedServer(model, params, batch_size=2, max_seq=64)
    r1 = server.submit(np.asarray([5, 6, 7], np.int32), max_new_tokens=6)
    r2 = server.submit(np.asarray([9, 10], np.int32), max_new_tokens=6)
    done = server.run_once()
    assert {r.uid for r in done} == {r1.uid, r2.uid}
    assert len(r1.output) == 6 and len(r2.output) == 6
    assert all(0 <= t < model.cfg.vocab for t in r1.output)
    assert server.stats["tokens"] > 0


def test_server_greedy_deterministic(tiny_model):
    model, params = tiny_model
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    outs = []
    for _ in range(2):
        server = BatchedServer(model, params, batch_size=1, max_seq=64)
        r = server.submit(prompt, max_new_tokens=8)
        server.run_once()
        outs.append(tuple(r.output))
    assert outs[0] == outs[1]
