"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only <name>]

Emits ``name,us_per_call,derived`` CSV rows:
  * speedup_analysis — §3.3.3 (70x latency-bound / 15.56x bandwidth-bound)
  * latency_model    — Table 3.1 + Eq 3.1-3.4 / 4.1
  * workloads        — Figure 4.1 TTFT/TPOT/E2E sweep + §4.2 claim checks
  * local_memory     — Table 4.3 local-capacity requirements
  * collectives      — §3.3.2 TAB vs ring on a real device mesh
  * kernels_bench    — Pallas kernels vs oracles
  * roofline         — deliverable (g) per-cell terms (reads dry-run JSONs)
  * serve_bench      — serving hot path: per-token loop vs fused block
                       decode vs block-pool paged KV; also writes the
                       machine-readable ``BENCH_serve.json`` (tokens/s,
                       KV bytes per active token, attention FLOPs/token
                       vs seq len) that CI tracks
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = ("speedup_analysis", "latency_model", "workloads", "local_memory",
           "collectives", "kernels_bench", "roofline", "serve_bench")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=MODULES)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failed = []
    for name in MODULES:
        if args.only and name != args.only:
            continue
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row in mod.run():
                print(row)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name},0,FAILED {type(e).__name__}: {str(e)[:160]}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
