"""§3.3.3 — Theoretical analysis of FengHuang speed-up over NVLink.

Reproduces the paper's two-enabler decomposition exactly:

  Enabler 1 (reduced data movement):
      latency-bound:    2(N-1) / 1          = 14x   at N=8
      bandwidth-bound:  (2(N-1) * T/N) / T  = 1.75x at N=8
  Enabler 2 (superior link performance):
      latency-bound:    1000/220 (read) or 500/90 (write)  ~= 5x
      bandwidth-bound:  4000/450 = 8.89x
  Overall:
      latency-bound:    14 * 5    = 70x
      bandwidth-bound:  1.75 * 8.89 ~= 15.56x
"""
from __future__ import annotations

import dataclasses

from repro.core import hw


@dataclasses.dataclass(frozen=True)
class SpeedupReport:
    n_gpus: int
    enabler1_latency_bound: float
    enabler1_bandwidth_bound: float
    enabler2_latency_bound_read: float
    enabler2_latency_bound_write: float
    enabler2_latency_bound: float
    enabler2_bandwidth_bound: float
    overall_latency_bound: float
    overall_bandwidth_bound: float

    def as_rows(self) -> list[tuple[str, float]]:
        return [
            ("enabler1_latency_bound", self.enabler1_latency_bound),
            ("enabler1_bandwidth_bound", self.enabler1_bandwidth_bound),
            ("enabler2_latency_bound", self.enabler2_latency_bound),
            ("enabler2_bandwidth_bound", self.enabler2_bandwidth_bound),
            ("overall_latency_bound", self.overall_latency_bound),
            ("overall_bandwidth_bound", self.overall_bandwidth_bound),
        ]


def num_transfers_nvlink_ring(n_gpus: int) -> int:
    """Ring allreduce: 2(N-1) sequential transfer steps."""
    return 2 * (n_gpus - 1)


def num_transfers_fenghuang(n_gpus: int) -> int:
    """Shared-memory write-accumulate: a single transfer per GPU."""
    del n_gpus
    return 1


def data_moved_per_gpu_nvlink(tensor_bytes: float, n_gpus: int) -> float:
    """Ring allreduce moves 2(N-1) * T/N bytes per GPU."""
    return 2 * (n_gpus - 1) * tensor_bytes / n_gpus


def data_moved_per_gpu_fenghuang(tensor_bytes: float, n_gpus: int) -> float:
    """FengHuang write-accumulates the full tensor once per GPU."""
    del n_gpus
    return tensor_bytes


def speedup_report(
    n_gpus: int = 8,
    *,
    nvlink_read_ns: float = hw.PAPER_NVLINK_READ_LATENCY_NS,
    nvlink_write_ns: float = hw.PAPER_NVLINK_WRITE_LATENCY_NS,
    fh_read_ns: float = hw.PAPER_READ_LATENCY_NS,
    fh_write_ns: float = hw.PAPER_WRITE_LATENCY_NS,
    nvlink_bw_gbps: float = hw.PAPER_NVLINK_BW_GBPS,
    fh_bw_gbps: float = hw.PAPER_FH_EFFECTIVE_BW_GBPS,
) -> SpeedupReport:
    n = n_gpus
    e1_lat = num_transfers_nvlink_ring(n) / num_transfers_fenghuang(n)
    e1_bw = data_moved_per_gpu_nvlink(1.0, n) / data_moved_per_gpu_fenghuang(1.0, n)

    e2_lat_read = nvlink_read_ns / fh_read_ns
    e2_lat_write = nvlink_write_ns / fh_write_ns
    # The paper rounds "1000/220 or 500/90 ~= 5x"; we keep the exact
    # component ratios and use the paper's quoted 5x for the headline product
    # only when asked for the rounded figures (see tests).
    e2_lat = min(e2_lat_read, e2_lat_write)  # conservative: 1000/220 = 4.545
    e2_bw = fh_bw_gbps / nvlink_bw_gbps

    return SpeedupReport(
        n_gpus=n,
        enabler1_latency_bound=e1_lat,
        enabler1_bandwidth_bound=e1_bw,
        enabler2_latency_bound_read=e2_lat_read,
        enabler2_latency_bound_write=e2_lat_write,
        enabler2_latency_bound=e2_lat,
        enabler2_bandwidth_bound=e2_bw,
        overall_latency_bound=e1_lat * e2_lat,
        overall_bandwidth_bound=e1_bw * e2_bw,
    )


def paper_headline_numbers(n_gpus: int = 8) -> dict:
    """The rounded figures the paper quotes (14x, 1.75x, ~5x, 8.89x, 70x, 15.56x)."""
    n = n_gpus
    e1_lat = 2 * (n - 1)
    e1_bw = 2 * (n - 1) / n
    e2_lat = 5.0                      # paper rounds 1000/220 ~ 500/90 to 5x
    e2_bw = hw.PAPER_FH_EFFECTIVE_BW_GBPS / hw.PAPER_NVLINK_BW_GBPS  # 8.89x
    return {
        "enabler1_latency_bound": float(e1_lat),       # 14
        "enabler1_bandwidth_bound": float(e1_bw),      # 1.75
        "enabler2_latency_bound": e2_lat,              # 5
        "enabler2_bandwidth_bound": round(e2_bw, 2),   # 8.89
        "overall_latency_bound": float(e1_lat * e2_lat),              # 70
        "overall_bandwidth_bound": round(e1_bw * e2_bw, 2),           # 15.56
    }
