"""repro.memory subsystem: tier registry scoping/reset, the orchestrator's
policy matrix, accounting parity between the live ledger and the Table 4.3
simulator, and expert-paging residency/churn."""
import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import memory
from repro.configs import build_model, get_config
from repro.core import simulator as S
from repro.core.graphs import Node
from repro.memory import (MemoryLedger, MemoryOrchestrator, TopKExpertPrefetch,
                          accounting, tiers)
from repro.memory.policies import (DoubleBufferPrefetch, OffloadBetweenSteps,
                                   PagerConfig, PinLocal)

RNG = np.random.RandomState(11)


# ---------------------------------------------------------------------------
# tier registry: per-backend scoping + reset()
# ---------------------------------------------------------------------------

def test_registry_resolves_current_backend():
    reg = tiers.registry()
    assert reg.local.available
    assert reg.remote.available == memory.supports_memory_spaces()
    # CPU backend: remote degenerates to unpinned_host, local aliases it
    if jax.default_backend() == "cpu":
        assert reg.remote.kind == "unpinned_host"


def test_registry_rescopes_after_backend_change(monkeypatch):
    """The old lru_cache went stale if the backend changed mid-process;
    the registry is keyed per backend and re-resolves after reset()."""
    reg = tiers.TierRegistry()
    real = reg.tiers()                      # resolve the real backend once
    monkeypatch.setattr(reg, "_backend", lambda: "fake-tpu")
    monkeypatch.setattr(
        reg, "_resolve",
        lambda backend: {tiers.LOCAL: tiers.Tier(tiers.LOCAL, "device"),
                         tiers.REMOTE: tiers.Tier(tiers.REMOTE,
                                                  "pinned_host")})
    # a NEW backend resolves fresh even without reset (per-backend key)
    assert reg.remote.kind == "pinned_host"
    assert reg.tiers() is not real
    # reset drops every cached resolution
    reg.reset()
    assert reg._tiers == {}


def test_module_reset_invalidates_process_registry():
    before = tiers.registry().tiers()
    memory.reset()
    after = tiers.registry().tiers()
    assert before is not after              # re-resolved, same content
    assert before == after


# ---------------------------------------------------------------------------
# orchestrator: policy matrix + placement
# ---------------------------------------------------------------------------

def test_plan_policy_matrix():
    base = get_config("qwen2.5-14b").reduced()
    assert isinstance(MemoryOrchestrator.plan(base)
                      .policies["layer_weights"], PinLocal)

    m = MemoryOrchestrator.plan(base.with_pager(enabled=True, lookahead=2))
    assert isinstance(m.policies["layer_weights"], DoubleBufferPrefetch)
    assert m.policies["layer_weights"].lookahead == 2
    assert isinstance(m.policies["kv_pool"], PinLocal)
    assert m.expert_policy is None and m.weights_fetch_filter() is None

    m = MemoryOrchestrator.plan(base.with_pager(enabled=True,
                                                offload_kv=True))
    assert isinstance(m.policies["kv_pool"], OffloadBetweenSteps)

    moe = get_config("granite-moe-3b-a800m").reduced()
    m = MemoryOrchestrator.plan(moe.with_pager(enabled=True,
                                               page_experts=True))
    ep = m.expert_policy
    assert isinstance(ep, TopKExpertPrefetch)
    assert (ep.num_experts, ep.top_k) == (moe.num_experts, moe.top_k)
    flt = m.weights_fetch_filter()
    assert not flt("['moe']['wi']") and flt("['moe']['router']")
    assert flt("['attn']['wq']")
    # page_experts on an expert-free family is a no-op
    assert MemoryOrchestrator.plan(
        base.with_pager(page_experts=True)).expert_policy is None


def test_place_layer_weights_roundtrips_and_accounts():
    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              remat=False, dtype=jnp.float32)
    model = build_model(cfg.with_pager(enabled=True))
    params = model.init(jax.random.PRNGKey(0))
    placed = model.mem.place_layer_weights(params["layers"])
    led = model.mem.ledger
    total = accounting.tree_bytes(params["layers"])
    assert led.classes(tiers.REMOTE)["layer_weights"] == total
    assert led.classes(tiers.LOCAL)["layer_weights_window"] == \
        accounting.resident_window_bytes(params["layers"], 1)
    # placement preserves values (CPU: remote == host memory)
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(placed)[0]),
        np.asarray(jax.tree.leaves(params["layers"])[0]))


def test_place_kv_pool_follows_policy():
    cache = {"k_pages": jnp.zeros((2, 4, 4, 2, 8)),
             "v_pages": jnp.zeros((2, 4, 4, 2, 8)), "meta": jnp.zeros((3,))}
    m = MemoryOrchestrator(PagerConfig())          # PinLocal: identity
    assert m.place_kv_pool(cache)["k_pages"] is cache["k_pages"]
    assert m.ledger.capacity(tiers.LOCAL) == accounting.tree_bytes(cache)
    assert m.ledger.in_use(tiers.LOCAL) == 0       # capacity != residency

    m = MemoryOrchestrator(
        PagerConfig(enabled=True, offload_kv=True),
        {"kv_pool": OffloadBetweenSteps()})
    placed = m.place_kv_pool(cache)
    assert placed["meta"] is cache["meta"]         # small leaves stay put
    assert m.ledger.capacity(tiers.REMOTE) == accounting.tree_bytes(cache)
    np.testing.assert_array_equal(np.asarray(placed["k_pages"]),
                                  np.asarray(cache["k_pages"]))


# ---------------------------------------------------------------------------
# accounting: ledger semantics + parity with the Table 4.3 simulator
# ---------------------------------------------------------------------------

def test_ledger_residency_and_hwm():
    led = MemoryLedger()
    led.record("local", "a", 100)
    led.record("local", "b", 50)
    assert led.in_use("local") == 150 and led.hwm("local") == 150
    led.record("local", "a", 10)           # residency is state, not a sum
    assert led.in_use("local") == 60 and led.hwm("local") == 150
    led.release("local", "b")
    assert led.in_use("local") == 10
    snap = led.snapshot()
    assert snap["local"]["hwm_bytes"] == 150
    assert snap["local"]["by_class"] == {"a": 10}


def test_window_accounting_matches_simulator_peak():
    """Parity: the live pager's resident-window accounting and the
    discrete-event simulator's peak paged window agree for a stream of
    equal-size pageable layers — both reduce to paged_window_bytes."""
    stacked = {"w": jnp.zeros((6, 32, 16), jnp.float32),
               "b": jnp.zeros((6, 16), jnp.float32)}
    per_layer = accounting.tree_bytes(stacked) // 6
    for lookahead in (1, 2):
        measured = accounting.resident_window_bytes(stacked, lookahead)
        nodes = [Node(f"l{i}", "matmul", flops=1e6, local_bytes=per_layer,
                      pageable_bytes=per_layer) for i in range(6)]
        sys = dataclasses.replace(S.fh4(), lookahead=lookahead)
        sim = S.simulate(nodes, sys)
        assert sim.peak_paged_window_bytes == pytest.approx(measured)
        assert measured == accounting.paged_window_bytes(per_layer,
                                                         lookahead)


def test_peak_local_formula_shared_with_simulator():
    """run_workload's Table 4.3 peak goes through accounting.peak_local_
    bytes: window + pinned + activations, nothing else."""
    nodes = [Node(f"l{i}", "matmul", flops=1e6, local_bytes=1e3,
                  pageable_bytes=2e3) for i in range(4)]
    sim = S.simulate(nodes, S.fh4(), pinned_bytes=7e3, activation_bytes=5e2)
    assert sim.peak_local_bytes == pytest.approx(accounting.peak_local_bytes(
        sim.peak_paged_window_bytes, 7e3, 5e2))
    # and the reduction helper is the shared claim formula
    assert accounting.capacity_reduction(10.0, 144.0) == \
        pytest.approx(1 - 10.0 / 144.0)
    assert accounting.capacity_reduction(10.0, 0.0) == 0.0


def test_demo_model_hwm_matches_table43_prediction():
    """The ledger's measured local high-water mark for the demo model's
    paged weights matches the simulator-side prediction (same equal-layer
    window formula Table 4.3 is built on) within tolerance: stacked
    layers are homogeneous, so measured window == (1+w) * mean layer."""
    cfg = get_config("qwen2.5-14b").reduced(num_layers=4)
    model = build_model(cfg.with_pager(enabled=True, lookahead=1))
    params = model.init(jax.random.PRNGKey(0))
    model.mem.place_layer_weights(params["layers"])
    measured = model.mem.ledger.hwm(tiers.LOCAL)
    per_layer = accounting.tree_bytes(params["layers"]) / cfg.num_layers
    predicted = accounting.paged_window_bytes(per_layer, 1)
    assert measured == pytest.approx(predicted, rel=0.01)


# ---------------------------------------------------------------------------
# expert paging: gather semantics, residency bound, churn
# ---------------------------------------------------------------------------

def _banks(e=8, d=16, f=32, dtype=jnp.float32):
    return {"router": jnp.asarray(RNG.randn(d, e), jnp.float32),
            "wi": jnp.asarray(RNG.randn(e, d, f), dtype),
            "wg": jnp.asarray(RNG.randn(e, d, f), dtype),
            "wo": jnp.asarray(RNG.randn(e, f, d), dtype)}


def test_expert_gather_rows_and_residency_bound():
    banks = _banks()
    led = MemoryLedger()
    ep = TopKExpertPrefetch(num_experts=8, top_k=2, ledger=led)
    placed = ep.place({k: banks[k] for k in ep.bank_keys})
    assert led.classes(tiers.REMOTE)["expert_weights"] == \
        accounting.tree_bytes({k: banks[k] for k in ep.bank_keys})
    ids = jnp.asarray([3, 5], jnp.int32)           # one token, top-2
    rows = ep.gather(placed, ids)
    for k in ep.bank_keys:
        np.testing.assert_array_equal(np.asarray(rows[k]),
                                      np.asarray(banks[k][np.asarray(ids)]))
    bank_bytes = accounting.tree_bytes(
        {k: banks[k] for k in ep.bank_keys})
    resident = led.classes(tiers.LOCAL)["expert_weights"]
    assert resident == ep.resident_bytes(banks, 2)
    assert resident <= (ep.top_k + 1) / ep.num_experts * bank_bytes


def test_expert_residency_churn():
    """Random routing churn: recorded residency always respects the
    (rows + 1)/E bound and caps at the full bank + staging."""
    banks = _banks()
    led = MemoryLedger()
    ep = TopKExpertPrefetch(num_experts=8, top_k=2, ledger=led)
    bank_bytes = accounting.tree_bytes({k: banks[k] for k in ep.bank_keys})
    row_bytes = bank_bytes // 8
    rng = random.Random(3)
    for _ in range(50):
        n = rng.randrange(1, 24)                   # tokens*k rows requested
        ids = jnp.asarray([rng.randrange(8) for _ in range(n)], jnp.int32)
        ep.gather(banks, ids)
        resident = led.classes(tiers.LOCAL)["expert_weights"]
        assert resident == (min(n, 8) + 1) * row_bytes
        assert resident <= bank_bytes + row_bytes     # full bank + staging
    assert led.hwm(tiers.LOCAL) <= (8 + 1) * row_bytes


def test_moe_topk_ffn_matches_dense_dispatch():
    """The gathered routed-expert FFN == the dense (E, C, d) dispatch
    (same routing, same keep mask) for decode-shaped inputs."""
    from repro.models.moe import moe_ffn, moe_ffn_topk
    cfg = dataclasses.replace(get_config("granite-moe-3b-a800m").reduced(),
                              dtype=jnp.float32)
    model = build_model(cfg.with_pager(enabled=True, page_experts=True))
    p = model.init_layer(jax.random.PRNGKey(2))["moe"]
    for b, s in ((2, 1), (1, 4)):
        x = jnp.asarray(RNG.randn(b, s, cfg.d_model), jnp.float32) * 0.3
        dense = moe_ffn(p, x, cfg)
        gathered = moe_ffn_topk(p, x, cfg, model.mem)
        np.testing.assert_allclose(np.asarray(gathered), np.asarray(dense),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("temperature,enabled", [(0.0, True), (0.7, True),
                                                 (0.0, False)])
def test_moe_server_expert_paging_matches_dense(temperature, enabled):
    """End-to-end: a served MoE model with expert banks at rest in the
    remote tier emits the same tokens as the dense-bank baseline — with
    the layer-weight pager on AND off (at-rest banks must not stream
    through the disabled path's plain scan either)."""
    from repro.runtime.serve import BatchedServer
    cfg = dataclasses.replace(get_config("granite-moe-3b-a800m").reduced(),
                              remat=False)
    prompt = np.asarray([3, 1, 4, 1], np.int32)

    def serve(model, params):
        server = BatchedServer(model, params, batch_size=1, max_seq=64,
                               block_size=4, temperature=temperature)
        r = server.submit(prompt, max_new_tokens=8)
        server.run_once()
        return r.output, server

    base = build_model(cfg)
    params = base.init(jax.random.PRNGKey(0))
    out_d, _ = serve(base, params)

    emodel = build_model(cfg.with_pager(enabled=enabled, page_experts=True))
    eparams = dict(params)
    eparams["layers"] = emodel.mem.place_layer_weights(params["layers"])
    out_p, server = serve(emodel, eparams)
    assert out_p == out_d
    # resident expert rows bounded by (B*k + 1 staging) rows per bank
    led = emodel.mem.ledger
    per_layer_bank = led.classes(tiers.REMOTE)["expert_weights"] \
        // cfg.num_layers
    bound = (cfg.top_k + 1) / cfg.padded_experts
    assert led.classes(tiers.LOCAL)["expert_weights"] <= \
        bound * per_layer_bank + 1


# ---------------------------------------------------------------------------
# repro.memory is the one import surface (the core.pager shim is gone)
# ---------------------------------------------------------------------------

def test_memory_exports_the_pager_surface():
    with pytest.raises(ImportError):
        from repro.core import pager  # noqa: F401 - removed after one release
    for name in ("paged_scan", "paged_scan_cache", "donating_jit",
                 "tree_bytes", "host_put", "page_in", "page_out",
                 "supports_memory_spaces", "resident_window_bytes",
                 "PagerConfig", "PageSwapper", "FaultPlan",
                 "transfer_with_retry"):
        assert hasattr(memory, name), name
    assert memory.host_put is tiers.host_put
    assert memory.PagerConfig is PagerConfig
