"""Production meshes.

Functions (not module constants) so importing never touches jax device
state — the dry-run sets XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: meshes carry explicit axis types
    from jax.sharding import AxisType

    def _axis_kw(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # older jax: every axis is Auto implicitly
    def _axis_kw(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_smoke_mesh():
    """Single-device mesh for CPU smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"), **_axis_kw(2))


def make_host_mesh(data: int = 1, model: int = 1):
    """Arbitrary small mesh from forced host devices (tests)."""
    return jax.make_mesh((data, model), ("data", "model"), **_axis_kw(2))


def make_serving_mesh(model: int = 1, data: int = 1):
    """Tensor-parallel serving mesh: ``model`` shards for weights/KV
    heads, ``data`` replicas for batch sharding.  ``model=1`` is a valid
    degenerate mesh (the sharded serving path on a single device)."""
    return make_host_mesh(data=data, model=model)


def serving_model_shards(max_shards: int, *heads: int) -> int:
    """Largest tensor-parallel degree <= ``max_shards`` (and the local
    device count) that divides every padded head count in ``heads`` —
    how benches and examples pick a mesh for whatever devices exist."""
    limit = max(1, min(max_shards, jax.device_count()))
    for m in range(limit, 0, -1):
        if all(h % m == 0 for h in heads):
            return m
    return 1
