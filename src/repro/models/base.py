"""Model zoo foundations: configs, parameter specs, dtype policy.

Pure-functional JAX models.  Parameters are nested dicts of arrays; every
init function has a twin returning the matching pytree of
``PartitionSpec`` so the runtime can shard params for any mesh.

Divisibility policy (documented in DESIGN.md §4): the tensor-parallel mesh
axis is 16, so head counts / expert counts / vocab are **padded** to the
next multiple of the relevant quantum; KV heads are **replicated** up to
the axis size when smaller.  Padding overhead is charged in the roofline.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Literal

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# Logical mesh axis names (resolved by runtime.sharding for single/multi-pod).
BATCH_AXES = ("pod", "data")   # batch dim is sharded over these (if present)
MODEL_AXIS = "model"

VOCAB_QUANTUM = 256            # vocab padded to a multiple of this
DEFAULT_TP = 16                # production model-axis size


def pad_to(n: int, q: int) -> int:
    return ((n + q - 1) // q) * q


@dataclasses.dataclass
class DecodeState:
    """Per-slot decoding state threaded through the fused decode loop.

    One instance covers the whole serving batch; every field is a device
    array so a block of decode steps runs without a host round trip.

    Donation contract: a decode loop *consumes* its ``(cache, state)``
    arguments.  Callers jit the loop with ``donate_argnums`` on both (see
    :func:`repro.memory.donating_jit`) so XLA aliases the KV cache and
    state buffers in place; the donated inputs are dead after the call and
    must not be reused.
    """

    tokens: jax.Array     # (B, 1) int32 — last sampled token per slot
    pos: jax.Array        # (B,)  int32 — absolute position the next decode
                          #        step writes (== tokens seen so far; with
                          #        a paged cache this IS the per-slot
                          #        seq_lens the page kernel masks against)
    active: jax.Array     # (B,)  bool  — slot is mid-generation
    remaining: jax.Array  # (B,)  int32 — decode tokens still owed
    key: jax.Array        # PRNG key, split once per decode step
    pages: jax.Array | None = None
                          # (B, n_pages) int32 — block-pool KV page table
                          #        (None = dense per-slot cache).
                          #        PERSISTENT device state: the host
                          #        keeps a byte-exact mirror and applies
                          #        per-block deltas inside the decode
                          #        dispatch, re-transferring the whole
                          #        (power-of-two bucketed) table only on
                          #        width changes.  Column padding and
                          #        idle slots map the null page 0.
    slot_keys: jax.Array | None = None
                          # (B, 2) uint32 — per-slot PRNG keys (None =
                          #        legacy batch-wide split).  With
                          #        per-slot keys the token at sequence
                          #        position q is sampled from
                          #        fold_in(slot_key, q): sampling depends
                          #        only on the request's own key and
                          #        position, never on the batch-wide step
                          #        count — so preemption/resume, block
                          #        boundaries and neighbour interleaving
                          #        cannot perturb a request's tokens.

    @classmethod
    def init(cls, batch: int, key: jax.Array,
             pages: jax.Array | None = None,
             slot_keys: jax.Array | None = None) -> "DecodeState":
        """All-idle state: every slot is a no-op until admission."""
        return cls(tokens=jnp.zeros((batch, 1), jnp.int32),
                   pos=jnp.zeros((batch,), jnp.int32),
                   active=jnp.zeros((batch,), bool),
                   remaining=jnp.zeros((batch,), jnp.int32),
                   key=key, pages=pages, slot_keys=slot_keys)


jax.tree_util.register_dataclass(
    DecodeState,
    data_fields=["tokens", "pos", "active", "remaining", "key", "pages",
                 "slot_keys"],
    meta_fields=[])


@dataclasses.dataclass(frozen=True)
class PagerPolicy:
    """FengHuang paging policy carried in the model config (resolved into
    a residency-policy matrix by ``repro.memory.MemoryOrchestrator.plan``).

    ``page_experts`` keeps MoE expert banks at rest in the remote tier
    and pages in only the routed (top-k) rows per decode block — no-op
    for families without experts."""
    enabled: bool = False
    lookahead: int = 1
    offload_kv: bool = False
    page_experts: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Superset config covering every assigned architecture family."""

    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "encdec", "vlm"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128

    # attention options
    qkv_bias: bool = False           # qwen2.5
    qk_norm: bool = False            # qwen3
    rope_theta: float = 10000.0
    sliding_window: int = 0          # 0 = full attention
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # hybrid (recurrentgemma): pattern of block kinds, e.g. ("rec","rec","att")
    block_pattern: tuple[str, ...] = ()
    rglru_conv_width: int = 4

    # ssm (xlstm): alternating mlstm/slstm
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0

    # encdec (whisper)
    num_encoder_layers: int = 0
    encoder_seq: int = 1500          # precomputed frame embeddings (stub)

    # vlm (llava)
    num_patches: int = 576           # anyres patch embeddings (stub)

    # numerics / system
    dtype: Any = jnp.bfloat16
    kv_quant: bool = False           # int8 KV cache (per-token-per-head scale)
    kv_dtype: str | None = None      # paged-pool KV precision: None = cfg.dtype
                                     # (full precision), "int8" or "fp8_e4m3"
                                     # store quantized pages + per-token-slot
                                     # per-head scales alongside the pool
    page_size: int = 16              # tokens per KV page (block-pool serving)
    norm_eps: float = 1e-6
    tp: int = DEFAULT_TP             # model-axis size the config targets
    pager: PagerPolicy = dataclasses.field(default_factory=PagerPolicy)
    collective_schedule: Literal["tab", "ring"] = "tab"
    # attention implementation for prefill/train
    q_block: int = 512
    kv_block: int = 512
    # decode layer-scan unroll: >1 trades compile time for fewer per-
    # iteration loop ops on the decode hot path (CPU demo: big win for
    # shallow models; deep prod stacks keep 1)
    decode_unroll: int = 1
    # remat policy for train
    remat: bool = True

    # ---------- padded dims -------------------------------------------------
    @property
    def padded_heads(self) -> int:
        return pad_to(self.num_heads, self.tp)

    @property
    def padded_kv_heads(self) -> int:
        if self.num_kv_heads >= self.tp:
            return pad_to(self.num_kv_heads, self.tp)
        return self.tp  # replicate small KV-head counts up to the axis

    @property
    def kv_repeat(self) -> int:
        """How many times each true KV head is replicated."""
        return self.padded_kv_heads // math.gcd(self.padded_kv_heads,
                                                self.num_kv_heads) \
            if self.num_kv_heads else 1

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab, VOCAB_QUANTUM)

    @property
    def padded_experts(self) -> int:
        return pad_to(self.num_experts, self.tp) if self.num_experts else 0

    @property
    def q_per_kv(self) -> int:
        return self.padded_heads // self.padded_kv_heads

    def with_pager(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, pager=PagerPolicy(**kw))

    # ---------- paged-pool KV precision -------------------------------------
    #: quantized page-pool dtypes -> (jnp dtype, quantization clip range).
    #: fp8_e4m3 uses the finite max of float8_e4m3fn (448); int8 the
    #: symmetric signed range.  Scales are always stored bf16.
    KV_DTYPES = {"int8": (jnp.int8, 127.0),
                 "fp8_e4m3": (jnp.float8_e4m3fn, 448.0)}

    @property
    def kv_quantized(self) -> bool:
        """True when the paged page pools hold quantized KV."""
        return self.kv_dtype is not None

    def kv_pool_dtype(self):
        """The jnp dtype paged KV pools are allocated with."""
        if self.kv_dtype is None:
            return self.dtype
        try:
            return self.KV_DTYPES[self.kv_dtype][0]
        except KeyError:
            raise ValueError(
                f"unknown kv_dtype {self.kv_dtype!r}; expected one of "
                f"{sorted(self.KV_DTYPES)}") from None

    def kv_qmax(self) -> float:
        """Symmetric clip range of the quantized pool dtype."""
        if self.kv_dtype is None:
            raise ValueError("kv_qmax is only defined for quantized KV")
        return self.KV_DTYPES[self.kv_dtype][1]

    def assert_mesh_compatible(self, axis_sizes: dict) -> None:
        """Fail fast when a serving mesh cannot shard this config.

        The ``"model"`` axis shards attention heads, KV heads (and hence
        the page pools' head axis), the MLP hidden dim and the padded
        vocab; any non-divisible dimension would silently fall back to
        replication mid-model, so reject the mesh up front instead.
        """
        m = int(axis_sizes.get("model", 1))
        if m <= 1:
            return
        if self.num_experts:
            raise ValueError(
                f"config {self.name} cannot shard over model={m}: "
                f"expert-parallel serving of MoE banks is not wired yet "
                f"(the all-gather-TP determinism contract does not cover "
                f"the expert combine; see ROADMAP open items)")
        bad = {name: v for name, v in (
            ("padded_heads", self.padded_heads),
            ("padded_kv_heads", self.padded_kv_heads),
            ("padded_vocab", self.padded_vocab),
            ("d_ff", self.d_ff),
        ) if v and v % m}
        if bad:
            raise ValueError(
                f"config {self.name} cannot shard over model={m}: "
                f"non-divisible dims {bad}")

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            num_layers=min(self.num_layers, 2 if not self.block_pattern
                           else len(self.block_pattern)),
            d_model=128, num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 2,
            d_ff=256 if self.d_ff else 0, vocab=512, head_dim=32, tp=1,
            encoder_seq=16, num_patches=8, sliding_window=(
                8 if self.sliding_window else 0),
        )
        if self.num_experts:
            # high capacity factor => no token dropping at smoke scale, so
            # decode matches teacher forcing exactly (capacity-based MoE
            # drops differently for different batch shapes by design).
            small.update(num_experts=4, top_k=min(self.top_k, 2),
                         capacity_factor=8.0)
        if self.block_pattern:
            small.update(block_pattern=self.block_pattern[:3] or ("rec", "rec", "att"))
        if self.num_encoder_layers:
            small.update(num_encoder_layers=2)
        small.update(overrides)
        return dataclasses.replace(self, name=self.name + "-smoke", **small)


# ---------------------------------------------------------------------------
# Param init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(shape, dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def spec_like(tree: Any, spec_fn) -> Any:
    """Build a PartitionSpec pytree parallel to ``tree``."""
    return jax.tree.map(spec_fn, tree)
