"""xLSTM (sLSTM + mLSTM blocks) — arXiv:2405.04517, structurally faithful:

* **mLSTM**: matrix memory C_t (per head, hd x hd), exponential input gate,
  sigmoid forget gate with log-domain stabilizer m_t; recurrence
      C_t = f C_{t-1} + i v k^T,  n_t = f n_{t-1} + i k,
      h_t = (C_t q) / max(|n_t . q|, 1)
  Fully state-space: O(1) decode state => long_500k runs.
* **sLSTM**: scalar memory with exponential gating, normalizer and
  stabilizer states, per-head block-diagonal recurrent matrices; the
  recurrence depends on h_{t-1} through the gates, so it scans sequentially
  (per paper).

``d_ff == 0`` in the assigned config: the blocks carry their own
projections (mlstm_proj_factor up-projection / slstm post-MLP).

Head alignment policy: heads are *subdivided* to the tensor-parallel axis
(4 -> 16 on the production mesh) — identical parameter count, finer head
granularity — so all per-head state shards over the model axis.  KV paging
is inapplicable (no KV cache — DESIGN.md §Arch-applicability); weight
paging applies unchanged.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.base import ModelConfig, dense_init
from repro.models.hybrid import BlockKinds, GroupedLM


TIME_CHUNK = 128


def chunked_time_scan(step, carry, length: int, chunk: int = TIME_CHUNK):
    """scan over t=0..length-1 with chunk-boundary checkpointing.

    BPTT over a plain ``lax.scan`` of length S stores per-step residuals
    (O(S) memory).  Nesting scans and checkpointing the inner chunk stores
    only O(S/chunk) chunk carries + O(chunk) transient recompute — the
    standard chunkwise-recurrent training trick (xLSTM appendix).
    """
    ts = jnp.arange(length)
    chunk = min(chunk, length)
    if length % chunk:
        return jax.lax.scan(step, carry, ts)
    tsc = ts.reshape(-1, chunk)

    def inner(c, tchunk):
        return jax.lax.scan(step, c, tchunk)

    inner_ckpt = jax.checkpoint(inner)

    def outer(c, tchunk):
        return inner_ckpt(c, tchunk)

    carry, ys = jax.lax.scan(outer, carry, tsc)
    ys = jax.tree.map(lambda y: y.reshape((length,) + y.shape[2:]), ys)
    return carry, ys


def mlstm_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    """(dp, nh, hd) for the mLSTM inner space."""
    nh = cfg.padded_heads
    dp = int(cfg.d_model * cfg.mlstm_proj_factor)
    dp = ((dp + nh - 1) // nh) * nh
    return dp, nh, dp // nh


def slstm_dims(cfg: ModelConfig) -> tuple[int, int]:
    """(nh, hd) for the sLSTM space (nh*hd == d_model)."""
    nh = cfg.padded_heads
    assert cfg.d_model % nh == 0, (cfg.d_model, nh)
    return nh, cfg.d_model // nh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_params(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dp, nh, hd = mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "ln": jnp.ones((d,), cfg.dtype),
        "w_up": dense_init(ks[0], (d, 2 * dp), cfg.dtype),
        "w_q": dense_init(ks[1], (dp, dp), cfg.dtype),
        "w_k": dense_init(ks[2], (dp, dp), cfg.dtype),
        "w_v": dense_init(ks[3], (dp, dp), cfg.dtype),
        "w_i": dense_init(ks[4], (dp, nh), cfg.dtype),
        "b_i": jnp.zeros((nh,), jnp.float32),
        "w_f": dense_init(ks[5], (dp, nh), cfg.dtype),
        "b_f": jnp.full((nh,), 3.0, jnp.float32),   # forget-open init
        "gn": jnp.ones((dp,), cfg.dtype),
        "w_down": dense_init(ks[6], (dp, d), cfg.dtype),
    }


def mlstm_specs() -> dict:
    return {
        "ln": P(None, None), "w_up": P(None, None, "model"),
        "w_q": P(None, None, "model"), "w_k": P(None, None, "model"),
        "w_v": P(None, None, "model"),
        "w_i": P(None, None, "model"), "b_i": P(None, "model"),
        "w_f": P(None, None, "model"), "b_f": P(None, "model"),
        "gn": P(None, "model"), "w_down": P(None, "model", None),
    }


def mlstm_seq(p: dict, x: jax.Array, cfg: ModelConfig, state=None):
    """Sequential (scan) mLSTM over a full sequence.  x: (B,S,d) normed."""
    dp, nh, hd = mlstm_dims(cfg)
    b, s, _ = x.shape
    up = x @ p["w_up"]
    z, gate = jnp.split(up, 2, axis=-1)                      # (B,S,dp) each
    q = (z @ p["w_q"]).reshape(b, s, nh, hd) / math.sqrt(hd)
    k = (z @ p["w_k"]).reshape(b, s, nh, hd) / math.sqrt(hd)
    v = (z @ p["w_v"]).reshape(b, s, nh, hd)
    log_i = (z @ p["w_i"]).astype(jnp.float32) + p["b_i"]    # (B,S,nh)
    log_f = jax.nn.log_sigmoid(
        (z @ p["w_f"]).astype(jnp.float32) + p["b_f"])

    if state is None:
        C0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, nh, hd), jnp.float32)
        m0 = jnp.full((b, nh), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def step(carry, t):
        C, n, m = carry
        qt = q[:, t].astype(jnp.float32)
        kt = k[:, t].astype(jnp.float32)
        vt = v[:, t].astype(jnp.float32)
        li, lf = log_i[:, t], log_f[:, t]
        m_new = jnp.maximum(lf + m, li)
        i_ = jnp.exp(li - m_new)
        f_ = jnp.exp(lf + m - m_new)
        C = f_[..., None, None] * C + i_[..., None, None] * \
            jnp.einsum("bhd,bhe->bhde", vt, kt)
        n = f_[..., None] * n + i_[..., None] * kt
        hq = jnp.einsum("bhde,bhe->bhd", C, qt)
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt)), 1.0)
        h = hq / denom[..., None]
        return (C, n, m_new), h.astype(x.dtype)

    (C, n, m), hs = chunked_time_scan(step, (C0, n0, m0), s)
    hs = hs.transpose(1, 0, 2, 3).reshape(b, s, dp)
    out = (L.rmsnorm(hs, p["gn"], 1e-6) * gate) @ p["w_down"]
    return out, {"C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_params(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nh, hd = slstm_dims(cfg)
    pf = cfg.slstm_proj_factor
    dp = max(64, int(round(d * pf / 64)) * 64)
    ks = jax.random.split(key, 7)
    return {
        "ln": jnp.ones((d,), cfg.dtype),
        "w_in": dense_init(ks[0], (d, 4 * nh * hd), cfg.dtype),
        "r_z": dense_init(ks[1], (nh, hd, hd), cfg.dtype),
        "r_i": dense_init(ks[2], (nh, hd, hd), cfg.dtype),
        "r_f": dense_init(ks[3], (nh, hd, hd), cfg.dtype),
        "r_o": dense_init(ks[4], (nh, hd, hd), cfg.dtype),
        "b": jnp.zeros((4, nh, hd), jnp.float32),
        "gn": jnp.ones((nh * hd,), cfg.dtype),
        "w_up": dense_init(ks[5], (nh * hd, dp), cfg.dtype),
        "w_down": dense_init(ks[6], (dp, d), cfg.dtype),
    }


def slstm_specs() -> dict:
    return {
        "ln": P(None, None), "w_in": P(None, None, "model"),
        "r_z": P(None, "model", None, None), "r_i": P(None, "model", None, None),
        "r_f": P(None, "model", None, None), "r_o": P(None, "model", None, None),
        "b": P(None, None, "model", None),
        "gn": P(None, "model"),
        "w_up": P(None, "model", None), "w_down": P(None, None, None),
    }


def slstm_seq(p: dict, x: jax.Array, cfg: ModelConfig, state=None):
    nh, hd = slstm_dims(cfg)
    b, s, _ = x.shape
    zifo = (x @ p["w_in"]).reshape(b, s, 4, nh, hd)
    if state is None:
        c0 = jnp.zeros((b, nh, hd), jnp.float32)
        n0 = jnp.zeros((b, nh, hd), jnp.float32)
        h0 = jnp.zeros((b, nh, hd), jnp.float32)
        m0 = jnp.full((b, nh, hd), -1e30, jnp.float32)
    else:
        c0, n0, h0, m0 = state["c"], state["n"], state["h"], state["m"]

    bias = p["b"]

    def rec(h, r):   # (b,nh,hd) x (nh,hd,hd) -> (b,nh,hd)
        return jnp.einsum("bhd,hde->bhe", h, r.astype(jnp.float32))

    def step(carry, t):
        c, n, h, m = carry
        z_in = zifo[:, t, 0].astype(jnp.float32) + bias[0]
        i_in = zifo[:, t, 1].astype(jnp.float32) + bias[1]
        f_in = zifo[:, t, 2].astype(jnp.float32) + bias[2]
        o_in = zifo[:, t, 3].astype(jnp.float32) + bias[3]
        z = jnp.tanh(z_in + rec(h, p["r_z"]))
        log_i = i_in + rec(h, p["r_i"])
        log_f = jax.nn.log_sigmoid(f_in + rec(h, p["r_f"]))
        o = jax.nn.sigmoid(o_in + rec(h, p["r_o"]))
        m_new = jnp.maximum(log_f + m, log_i)
        i_ = jnp.exp(log_i - m_new)
        f_ = jnp.exp(log_f + m - m_new)
        c = f_ * c + i_ * z
        n = f_ * n + i_
        h_new = o * c / jnp.maximum(n, 1.0)
        return (c, n, h_new, m_new), h_new

    (c, n, h, m), hs = chunked_time_scan(step, (c0, n0, h0, m0), s)
    hs = hs.transpose(1, 0, 2, 3).reshape(b, s, nh * hd).astype(x.dtype)
    hs = L.rmsnorm(hs, p["gn"], 1e-6)
    out = jax.nn.gelu(hs @ p["w_up"]) @ p["w_down"]
    return out, {"c": c, "n": n, "h": h, "m": m}


# ---------------------------------------------------------------------------
# Block kinds + model
# ---------------------------------------------------------------------------

class XLSTMKinds(BlockKinds):
    def init_block(self, key, kind: str) -> dict:
        if kind == "m":
            return {"mlstm": mlstm_params(key, self.cfg)}
        if kind == "s":
            return {"slstm": slstm_params(key, self.cfg)}
        return super().init_block(key, kind)

    def block_specs(self, kind: str) -> dict:
        if kind == "m":
            return {"mlstm": mlstm_specs()}
        if kind == "s":
            return {"slstm": slstm_specs()}
        return super().block_specs(kind)

    def init_state(self, kind: str, batch: int, max_seq: int):
        cfg = self.cfg
        if kind == "m":
            _, nh, hd = mlstm_dims(cfg)
            return {"C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
                    "n": jnp.zeros((batch, nh, hd), jnp.float32),
                    "m": jnp.full((batch, nh), -1e30, jnp.float32)}
        if kind == "s":
            nh, hd = slstm_dims(cfg)
            return {"c": jnp.zeros((batch, nh, hd), jnp.float32),
                    "n": jnp.zeros((batch, nh, hd), jnp.float32),
                    "h": jnp.zeros((batch, nh, hd), jnp.float32),
                    "m": jnp.full((batch, nh, hd), -1e30, jnp.float32)}
        return super().init_state(kind, batch, max_seq)

    def state_specs(self, kind: str):
        from repro.models.base import BATCH_AXES
        if kind == "m":
            return {"C": P(None, BATCH_AXES, "model", None, None),
                    "n": P(None, BATCH_AXES, "model", None),
                    "m": P(None, BATCH_AXES, "model")}
        if kind == "s":
            s = P(None, BATCH_AXES, "model", None)
            return {"c": s, "n": s, "h": s, "m": s}
        return super().state_specs(kind)

    def train(self, kind: str, p: dict, x, positions):
        cfg = self.cfg
        if kind == "m":
            o, _ = mlstm_seq(p["mlstm"],
                             L.rmsnorm(x, p["mlstm"]["ln"], cfg.norm_eps), cfg)
            return x + o
        if kind == "s":
            o, _ = slstm_seq(p["slstm"],
                             L.rmsnorm(x, p["slstm"]["ln"], cfg.norm_eps), cfg)
            return x + o
        return super().train(kind, p, x, positions)

    def prefill(self, kind: str, p: dict, x, positions, state):
        cfg = self.cfg
        if kind == "m":
            o, st = mlstm_seq(p["mlstm"],
                              L.rmsnorm(x, p["mlstm"]["ln"], cfg.norm_eps), cfg)
            return x + o, st
        if kind == "s":
            o, st = slstm_seq(p["slstm"],
                              L.rmsnorm(x, p["slstm"]["ln"], cfg.norm_eps), cfg)
            return x + o, st
        return super().prefill(kind, p, x, positions, state)

    def decode(self, kind: str, p: dict, x, state, cur_pos):
        cfg = self.cfg
        if kind == "m":
            o, st = mlstm_seq(p["mlstm"],
                              L.rmsnorm(x, p["mlstm"]["ln"], cfg.norm_eps),
                              cfg, state)
            return x + o, st
        if kind == "s":
            o, st = slstm_seq(p["slstm"],
                              L.rmsnorm(x, p["slstm"]["ln"], cfg.norm_eps),
                              cfg, state)
            return x + o, st
        return super().decode(kind, p, x, state, cur_pos)


class XLSTM(GroupedLM):
    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg, XLSTMKinds(cfg))
