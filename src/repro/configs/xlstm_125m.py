"""xlstm-125m: 12L d=768 4H d_ff=0 vocab=50304; sLSTM + mLSTM blocks in
pattern (m,m,m,s) x 3 (3:1 m:s ratio; the paper's xLSTM[7:1] rounded to a
12-layer tiling) [arXiv:2405.04517].  O(1) state => long_500k runs."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab=50304,
    block_pattern=("m", "m", "m", "s"),
)
