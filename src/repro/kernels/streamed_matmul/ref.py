"""Pure-jnp oracle for the streamed matmul."""
from __future__ import annotations

import jax.numpy as jnp


def streamed_matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (M, K), w: (K, N) -> (M, N) in fp32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
