"""Benchmark: Table 4.3 — FengHuang local-memory capacity requirement per
workload (paper: GPT-3 10 GB, Grok-1 18 GB, Qwen3 20 GB, Qwen3-R 20 GB vs
144 GB resident on Baseline8 — the '93% local memory reduction' headline).

The reduction is computed through ``repro.memory.accounting`` — the SAME
``capacity_reduction`` the serving runtime's measured numbers go through
(see ``benchmarks/serve_bench.py``), so simulated and measured claims
are comparable by construction.
"""
from __future__ import annotations

import time

from repro.core import graphs as G
from repro.core import hw, simulator as S
from repro.memory import accounting

PAPER_TABLE_4_3_GB = {"gpt3-175b": 10, "grok-1": 18,
                      "qwen3-235b": 20, "qwen3-235b-R": 20}


def run() -> list[str]:
    rows = []
    t0 = time.perf_counter()
    sysfh = S.fh4(1.5, 4.0)
    cases = [(n, c, S.QA_TASK) for n, c in G.PAPER_WORKLOADS.items()]
    cases.append(("qwen3-235b-R", G.QWEN3_235B, S.REASONING_TASK))
    for name, cfg, task in cases:
        r = S.run_workload(cfg, task, sysfh)
        us = (time.perf_counter() - t0) * 1e6
        paper = PAPER_TABLE_4_3_GB[name if task is S.QA_TASK or
                                   name.endswith("-R") else name]
        reduction = accounting.capacity_reduction(
            r["peak_local_gb"], hw.PAPER_H200_HBM_CAP_GB) * 100
        rows.append(
            f"table43_{name},{us:.0f},peak_local={r['peak_local_gb']:.1f}GB"
            f" (paper {paper}GB; vs 144GB resident: -{reduction:.1f}%)")
    return rows
