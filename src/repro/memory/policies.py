"""Residency policies: the pluggable seam between tensor classes and the
tier hierarchy.

A :class:`ResidencyPolicy` answers two questions for one *tensor class*
(stacked layer weights, a dense KV cache, a block-pool KV pool, expert
banks): **where does it live at rest** (``tier`` + ``place``) and **how
does it move through local memory while computing** (policy-specific:
the double-buffered prefetch window, the scan-carry offload, the
block-pool page tables, the routed-expert gather).  ``pick_tier``
is the access-frequency face of the first question: given observed
access stats, a policy may answer with a *colder* hierarchy level than
its home tier (long-idle pools and rarely-routed expert banks demote
to ``cold``).  The
:class:`~repro.memory.orchestrator.MemoryOrchestrator` binds classes to
policies and owns the scan transforms the policies ride.

Concrete policies:

* :class:`PinLocal` — default; tensors stay in local HBM.
* :class:`DoubleBufferPrefetch` — stacked layer weights at rest in the
  remote tier, paged per layer with a lookahead-w double buffer (the
  paper's Tensor Prefetcher, w=1 materialized).
* :class:`OffloadBetweenSteps` — KV pools parked in the remote tier
  between dispatches, one layer's slice local at a time in the scan
  carry.
* :class:`BlockPoolResidency` — block-pool paged KV: wraps
  :class:`~repro.kernels.paged_attention.ops.BlockManager` bookkeeping
  (free list / tables / lengths / refcounts / hwm / fragmentation) and
  reports through the shared ledger — prefix-shared pages count once, so
  the ``kv_pool`` class reflects physical residency; optionally owns
  host-side pools for host-driven experiments (the role the deleted
  ``PagePool`` played).
* :class:`TopKExpertPrefetch` — MoE expert banks at rest in the remote
  tier; only the rows routing selects are paged in per decode block.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.ops import BlockManager
from repro.memory import tiers
from repro.memory.accounting import MemoryLedger, tree_bytes


@dataclasses.dataclass(frozen=True)
class PagerConfig:
    """FengHuang paging policy knobs (the per-model policy matrix).

    enabled          — page stacked layer weights through the remote tier.
    lookahead        — prefetch window in layers (paper w=1).  Only w=1 is
                       materialized as an explicit double buffer; deeper
                       windows are left to XLA's scheduler, which may hoist
                       further copy-starts.
    offload_kv       — keep the KV cache in the remote tier between steps,
                       paging per-layer pages in during attention.
    page_experts     — MoE expert banks live in the remote tier; decode
                       pages in only the routed (top-k) expert rows.
    donate_evicted   — donate the consumed buffer (eviction is implicit:
                       the buffer is dead after the layer computes).
    """

    enabled: bool = False
    lookahead: int = 1
    offload_kv: bool = False
    page_experts: bool = False
    donate_evicted: bool = True


@runtime_checkable
class ResidencyPolicy(Protocol):
    """Where a tensor class lives at rest, and how it is placed there.

    ``sharding(mesh, spec)`` is the mesh-aware face of the same answer:
    a :class:`~jax.sharding.NamedSharding` carrying BOTH the partition
    spec and the policy's tier resolved to the memory kind the current
    backend exposes — policies emit NamedShardings, never bare kinds.
    """

    tier: str

    def place(self, tree: Any) -> Any:
        """Move ``tree`` into the policy's home tier (eager)."""
        ...

    def sharding(self, mesh, spec):
        """NamedSharding placing one leaf in the policy's tier."""
        ...

    def pick_tier(self, access_stats: dict | None = None) -> str:
        """Hierarchy level this class should occupy given how it is
        being accessed (``access_stats`` keys are policy-specific:
        ``idle_steps`` for between-step offload, ``route_fraction`` for
        expert banks).  The home ``tier`` when stats are absent or
        unremarkable; a colder tier when access frequency justifies the
        bandwidth gap."""
        ...


@dataclasses.dataclass(frozen=True)
class PinLocal:
    """Default policy: device-resident, placement is the identity."""

    tier: str = tiers.LOCAL

    def place(self, tree: Any) -> Any:
        return tree

    def sharding(self, mesh, spec):
        return tiers.tier_sharding(mesh, spec, self.tier)

    def pick_tier(self, access_stats: dict | None = None) -> str:
        return self.tier


@dataclasses.dataclass(frozen=True)
class DoubleBufferPrefetch:
    """Stacked layer weights at rest in the remote tier, streamed through
    a (1 + lookahead)-layer local window by the paged layer scan."""

    lookahead: int = 1
    tier: str = tiers.REMOTE

    def place(self, tree: Any) -> Any:
        return tiers.host_put(tree)

    def sharding(self, mesh, spec):
        return tiers.tier_sharding(mesh, spec, self.tier)

    def pick_tier(self, access_stats: dict | None = None) -> str:
        # the prefetch window touches every layer every step — layer
        # weights never go colder than their home tier
        return self.tier


@dataclasses.dataclass(frozen=True)
class OffloadBetweenSteps:
    """KV pools at rest in the remote tier between dispatches; decode
    pages one layer's pool through local memory at a time (the scan
    carry of ``paged_scan_cache``).  Small leaves (page tables, lengths)
    stay local — only ``pool_keys`` move."""

    pool_keys: tuple[str, ...] = ("k_pages", "v_pages", "k_scale", "v_scale")
    tier: str = tiers.REMOTE
    # a pool untouched for this many steps (a long-idle prefix page set,
    # a parked conversation) belongs in the cold tier
    cold_after_idle_steps: int = 64

    def place(self, tree: Any) -> Any:
        return {k: (tiers.host_put(v) if k in self.pool_keys else v)
                for k, v in tree.items()}

    def sharding(self, mesh, spec, *, key: str | None = None):
        """Pool leaves live remote; bookkeeping leaves stay local."""
        tier = self.tier if (key is None or key in self.pool_keys) \
            else tiers.LOCAL
        return tiers.tier_sharding(mesh, spec, tier)

    def pick_tier(self, access_stats: dict | None = None) -> str:
        """Access-frequency placement: a pool idle for
        ``cold_after_idle_steps`` dispatches demotes to cold (it pays
        the flash-bandwidth gap once on resume instead of holding remote
        capacity every step it is not read)."""
        if (access_stats
                and access_stats.get("idle_steps", 0)
                >= self.cold_after_idle_steps):
            return tiers.COLD
        return self.tier


class BlockPoolResidency:
    """Block-pool paged KV residency.

    Wraps the host-side :class:`BlockManager` (allocation happens at
    block boundaries, reclamation on EOS/eviction) and reports live
    pool bytes into the shared :class:`MemoryLedger`.  The stacked
    device pools normally live in the serving cache and are donated
    through every dispatch; pass ``kv_heads``/``head_dim`` to own small
    host-side pools instead (host-driven experiments and tests), written
    with :meth:`append_block` — ONE batched scatter per block of tokens.
    """

    tensor_class = "kv_pool"

    def __init__(self, num_pages: int, page_size: int, *,
                 kv_heads: int | None = None, head_dim: int | None = None,
                 dtype=jnp.bfloat16, bytes_per_page: int | None = None,
                 tier: str = tiers.LOCAL,
                 ledger: MemoryLedger | None = None,
                 shard_factor: int = 1):
        self.manager = BlockManager(num_pages, page_size)
        self.page_size = page_size
        self.tier = tier
        self.ledger = ledger
        # model-axis shards of the device pools: the kv-heads axis is
        # "model"-sharded under tensor parallelism, so ONE device holds
        # 1/shard_factor of every page's bytes — ledger residency is
        # recorded per shard (comparable to the per-GPU simulator)
        self.shard_factor = max(int(shard_factor), 1)
        self._bytes_per_page = bytes_per_page
        self.k = self.v = None
        if kv_heads is not None and head_dim is not None:
            self.k = jnp.zeros((num_pages, page_size, kv_heads, head_dim),
                               dtype)
            self.v = jnp.zeros((num_pages, page_size, kv_heads, head_dim),
                               dtype)
            if bytes_per_page is None:
                self._bytes_per_page = self.manager.bytes_per_page(
                    kv_heads, head_dim, jnp.dtype(dtype).itemsize)

    def place(self, tree: Any) -> Any:
        return tree

    def sharding(self, mesh, spec):
        return tiers.tier_sharding(mesh, spec, self.tier)

    def pick_tier(self, access_stats: dict | None = None) -> str:
        # the live pool is read every attention step; only its
        # preemption stashes move down-hierarchy (PageSwapper.park)
        return self.tier

    def bind_kv_shape(self, kv_heads: int, head_dim: int, itemsize: int,
                      num_layers: int = 1, scale_itemsize: int = 0) -> None:
        """Derive per-page bytes from the served cache's shape (single
        source: :meth:`BlockManager.bytes_per_page`).  Quantized pools
        pass ``scale_itemsize`` (bf16 scales -> 2) so the ledger's
        ``kv_pool`` line reports TRUE quantized bytes, scales included —
        keeping ``capacity_reduction`` Table-4.3-comparable."""
        self._bytes_per_page = self.manager.bytes_per_page(
            kv_heads, head_dim, itemsize, num_layers=num_layers,
            scale_itemsize=scale_itemsize)

    # ----- bookkeeping (delegated) -----------------------------------------
    @property
    def capacity(self) -> int:
        return self.manager.capacity

    @property
    def pages_in_use(self) -> int:
        return self.manager.pages_in_use

    @property
    def hwm(self) -> int:
        return self.manager.hwm

    @property
    def shared_pages(self) -> int:
        """Logical pages served beyond their physical count by prompt-
        prefix sharing (refcounted pages count once toward residency —
        the ledger's ``kv_pool`` class shrinks by exactly this times the
        page bytes under a shared system prompt)."""
        return self.manager.shared_pages

    def fragmentation(self) -> float:
        return self.manager.fragmentation()

    def record(self) -> None:
        """Push the pool's live footprint into the ledger (per shard:
        heads-sharded pools hold 1/shard_factor of each page per device)."""
        if self.ledger is not None and self._bytes_per_page:
            self.ledger.record(self.tier, self.tensor_class,
                               self.manager.pages_in_use
                               * self._bytes_per_page
                               // self.shard_factor)

    def audit(self) -> dict:
        """Full invariant audit: the manager's allocator checks
        (:meth:`BlockManager.audit`) plus the ledger cross-check — the
        recorded ``kv_pool`` residency must equal the physical
        pages-in-use times per-page bytes (per shard).  Only meaningful
        right after :meth:`record`; callers audit at block boundaries
        where that holds."""
        summary = self.manager.audit()
        if self.ledger is not None and self._bytes_per_page:
            want = (self.manager.pages_in_use * self._bytes_per_page
                    // self.shard_factor)
            got = self.ledger.classes(self.tier).get(self.tensor_class)
            if got is not None and got != want:
                from repro.kernels.paged_attention.ops import \
                    BlockPoolAuditError
                raise BlockPoolAuditError(
                    f"ledger residency drift: {self.tier}/"
                    f"{self.tensor_class} records {got} bytes but "
                    f"{self.manager.pages_in_use} live pages x "
                    f"{self._bytes_per_page} bytes / {self.shard_factor} "
                    f"shard(s) = {want}")
        return summary

    # ----- host-side pools (experiments/tests) ------------------------------
    def alloc_seq(self, uid: int) -> None:
        self.manager.pages.setdefault(uid, [])
        self.manager.lens.setdefault(uid, 0)

    def append_block(self, uid: int, k_blk: jax.Array,
                     v_blk: jax.Array) -> None:
        """k_blk/v_blk: (T, kv_heads, head_dim) — T tokens appended with a
        single batched scatter per pool."""
        if self.k is None:
            raise ValueError("host-side pools not initialised; construct "
                             "with kv_heads/head_dim")
        t = k_blk.shape[0]
        pos0 = self.manager.lens.get(uid, 0)
        self.manager.ensure(uid, pos0 + t)
        table = jnp.asarray(self.manager.pages[uid], jnp.int32)
        pos = pos0 + jnp.arange(t)
        pids = table[pos // self.page_size]
        slots = pos % self.page_size
        self.k = self.k.at[pids, slots].set(k_blk.astype(self.k.dtype))
        self.v = self.v.at[pids, slots].set(v_blk.astype(self.v.dtype))
        self.manager.lens[uid] = pos0 + t
        self.record()

    def free_seq(self, uid: int) -> None:
        self.manager.free_slot(uid)
        self.record()

    def batch_tables(self, uids: list[int], n_pages: int) -> jax.Array:
        return jnp.asarray(self.manager.table(uids, n_pages), jnp.int32)

    def batch_lens(self, uids: list[int]) -> jax.Array:
        return jnp.asarray([self.manager.lens.get(u, 0) for u in uids],
                           jnp.int32)


@dataclasses.dataclass
class TopKExpertPrefetch:
    """MoE expert paging: banks at rest in the remote tier, only routed
    rows local.

    The expert banks (``wi``/``wg``/``wo``, each with a leading expert
    axis) are the workload class where disaggregated memory pays off
    most: a top-k router touches k of E experts per token, so decode
    needs only ``tokens x k`` rows (+ one in-flight staging row per
    bank) in local memory — ``(top_k + 1) / num_experts`` of the dense
    footprint for single-slot decode.  Routing is data-dependent, so
    unlike layer weights there is no lookahead window: the gather *is*
    the prefetch, issued as soon as the router's top-k lands.
    """

    num_experts: int
    top_k: int
    bank_keys: tuple[str, ...] = ("wi", "wg", "wo")
    tier: str = tiers.REMOTE
    # an expert routed to fewer than this fraction of tokens earns cold
    # residency (rarely-read, read-mostly: the High-Bandwidth-Flash
    # tenant profile)
    cold_route_fraction: float = 0.02
    ledger: MemoryLedger | None = None
    tensor_class = "expert_weights"

    def matches(self, path: str) -> bool:
        """Leaf-path selector for expert-bank leaves inside a stacked
        layer pytree (``...['moe']['wi']`` etc.)."""
        return "moe" in path and any(path.endswith(f"['{k}']")
                                     for k in self.bank_keys)

    def place(self, tree: Any) -> Any:
        if self.ledger is not None:
            nb = tree_bytes(tree)
            self.ledger.record(self.tier, self.tensor_class, nb)
            self.ledger.record_capacity(self.tier, self.tensor_class, nb)
        return tiers.host_put(tree)

    def sharding(self, mesh, spec):
        return tiers.tier_sharding(mesh, spec, self.tier)

    def pick_tier(self, access_stats: dict | None = None) -> str:
        """Access-frequency placement: ``route_fraction`` (this bank's
        share of routed tokens) below ``cold_route_fraction`` -> cold."""
        if (access_stats is not None
                and access_stats.get("route_fraction", 1.0)
                < self.cold_route_fraction):
            return tiers.COLD
        return self.tier

    def bank_tiers(self, route_counts) -> list[str]:
        """Per-expert tier choice from observed routing counts (one
        count per expert): expert e's share of total routes drives
        :meth:`pick_tier`."""
        counts = [int(c) for c in route_counts]
        total = max(sum(counts), 1)
        return [self.pick_tier({"route_fraction": c / total})
                for c in counts]

    def rebalance(self, banks: dict, route_counts) -> list[str]:
        """Re-split the ledger's ``expert_weights`` residency between
        the home tier and cold from observed routing, charging the tier
        edge for every expert bank that moved since the last rebalance.

        The physical banks stay ONE stacked array per key (a per-expert
        physical split would retrace the routed gather); what moves is
        the hierarchy's *view* — residency lines and modeled transfer
        charges.  The gather reads the same array either way, so routed
        outputs are bit-identical by construction."""
        chosen = self.bank_tiers(route_counts)
        cold = {i for i, t in enumerate(chosen) if t == tiers.COLD}
        if self.ledger is not None:
            nb = tree_bytes({k: banks[k] for k in self.bank_keys
                             if k in banks})
            per = nb // max(self.num_experts, 1)
            prev = getattr(self, "_cold_experts", set())
            for _ in cold - prev:
                self.ledger.charge_transfer(self.tier, tiers.COLD, per)
            for _ in prev - cold:
                self.ledger.charge_transfer(tiers.COLD, self.tier, per)
            cold_b = per * len(cold)
            self.ledger.record(self.tier, self.tensor_class, nb - cold_b)
            self.ledger.record(tiers.COLD, self.tensor_class, cold_b)
            self._cold_cap = max(getattr(self, "_cold_cap", 0), cold_b)
            self.ledger.record_capacity(tiers.COLD, self.tensor_class,
                                        self._cold_cap)
        self._cold_experts = cold
        return chosen

    def resident_bytes(self, banks: dict, num_rows: int) -> int:
        """Local bytes the gather keeps resident: ``num_rows`` routed
        rows + 1 staging row per bank (the in-flight fetch)."""
        total = 0
        for k in self.bank_keys:
            bank = banks[k]
            row = tree_bytes(bank) // max(bank.shape[0], 1)
            total += (min(num_rows, bank.shape[0]) + 1) * row
        return total

    def gather(self, banks: dict, ids: jax.Array) -> dict:
        """Page in the routed expert rows: ``ids`` (N,) expert indices
        (duplicates fine — XLA gathers each row once per reference).
        Returns ``{key: (N, ...)}`` local-resident rows.  Residency is
        shape-derived, so it is recorded at trace time."""
        n = int(ids.shape[0])
        if self.ledger is not None:
            nb = self.resident_bytes(banks, n)
            self.ledger.record(tiers.LOCAL, self.tensor_class, nb)
            # gather staging is provisioned at its largest routed set
            cap = max(getattr(self, "_local_cap", 0), nb)
            self._local_cap = cap
            self.ledger.record_capacity(tiers.LOCAL, self.tensor_class, cap)
        return {k: tiers.page_in(jnp.take(banks[k], ids, axis=0))
                for k in self.bank_keys}
