"""Optimizers & schedules: AdamW with ZeRO-1-ready state layout, cosine and
WSD (Warmup-Stable-Decay, MiniCPM arXiv:2404.06395) schedules, optional
int8 gradient compression with error feedback.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"        # 'cosine' | 'wsd' | 'const'
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_fraction: float = 0.1     # WSD: last 10% of steps decay


def schedule_value(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "const":
        return cfg.lr * warm
    if cfg.schedule == "cosine":
        frac = jnp.clip((s - cfg.warmup_steps) /
                        jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    if cfg.schedule == "wsd":
        # Warmup -> Stable (lr) -> Decay (last decay_fraction of steps,
        # exponential-to-~0.1x as in MiniCPM).
        decay_start = cfg.total_steps * (1.0 - cfg.decay_fraction)
        in_decay = jnp.clip((s - decay_start) /
                            jnp.maximum(cfg.total_steps - decay_start, 1),
                            0.0, 1.0)
        return cfg.lr * warm * jnp.power(0.1, in_decay)
    raise ValueError(cfg.schedule)


def init_opt_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def opt_state_specs(param_specs: Any) -> dict:
    """Optimizer moments inherit the param sharding; with a 'data' axis in
    the mesh the caller may extend these for ZeRO-1."""
    from jax.sharding import PartitionSpec as P
    return {
        "step": P(),
        "m": param_specs,
        "v": param_specs,
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any,
                 state: dict) -> tuple[Any, dict, dict]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule_value(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [t[0] for t in new])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in new])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"step": step, "m": new_m, "v": new_v}, metrics


# ---------------------------------------------------------------------------
# Gradient compression (int8 + error feedback) — distributed-optimization
# trick for bandwidth-bound data parallelism.
# ---------------------------------------------------------------------------

def compress_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_grad(g: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Quantize g+err to int8; return (dequantized grad, new error)."""
    total = g.astype(jnp.float32) + err
    q, scale = compress_int8(total)
    deq = decompress_int8(q, scale)
    return deq, total - deq
