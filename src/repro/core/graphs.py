"""Operator dependency graphs for the paper's evaluation workloads (§4.1).

The FengHuang paper evaluates by replaying an operator dependency graph
extracted from Nsight traces.  We rebuild that graph analytically from the
model architecture: for each of GPT-3 175B (dense), Grok-1 (8e top-2 MoE) and
Qwen3-235B (128e top-8 fine-grained MoE) we emit the per-layer operator
sequence for a *prefill* pass and a *decode* step under tensor parallelism,
annotated with FLOPs, local-memory traffic, pageable (remote-tier) bytes and
collective traffic.  ``core.simulator`` then schedules these nodes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Literal

BYTES_PER_PARAM = 2.0  # fp16/bf16 inference


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """Architecture description of a paper workload."""

    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int                      # per-expert FFN hidden dim
    vocab: int
    num_experts: int = 1           # 1 => dense
    top_k: int = 1
    tied_embeddings: bool = False

    # -- parameter counts (per layer / total), in parameters -----------------
    @property
    def attn_params(self) -> float:
        q = self.d_model * self.num_heads * self.head_dim
        kv = 2 * self.d_model * self.num_kv_heads * self.head_dim
        o = self.num_heads * self.head_dim * self.d_model
        return q + kv + o

    @property
    def expert_params(self) -> float:
        # gated FFN (SwiGLU-style): up, gate, down
        return 3 * self.d_model * self.d_ff

    @property
    def ffn_params_per_layer(self) -> float:
        return self.num_experts * self.expert_params

    @property
    def layer_params(self) -> float:
        return self.attn_params + self.ffn_params_per_layer + 2 * self.d_model

    @property
    def embedding_params(self) -> float:
        n = self.vocab * self.d_model
        return n if self.tied_embeddings else 2 * n

    @property
    def total_params(self) -> float:
        return self.num_layers * self.layer_params + self.embedding_params

    @property
    def active_params_per_token(self) -> float:
        active_ffn = self.top_k * self.expert_params
        per_layer = self.attn_params + active_ffn + 2 * self.d_model
        return self.num_layers * per_layer + self.embedding_params


# Paper workloads (§4.1.2).  Grok-1: 314B, 8 experts top-2; Qwen3-235B:
# fine-grained 128 experts top-8 (DeepSeek-style).  GPT-3: classic dense.
GPT3_175B = WorkloadConfig(
    name="gpt3-175b", num_layers=96, d_model=12288, num_heads=96,
    num_kv_heads=96, head_dim=128, d_ff=4 * 12288 // 2, vocab=50257,
)
# NOTE: gpt3 uses a non-gated 4*d FFN (2 matrices).  We model it as a gated
# FFN with d_ff chosen so 3*d*d_ff == 2*d*(4d)  =>  d_ff = 8d/3.
GPT3_175B = dataclasses.replace(GPT3_175B, d_ff=int(8 * 12288 / 3))

GROK_1 = WorkloadConfig(
    name="grok-1", num_layers=64, d_model=6144, num_heads=48,
    num_kv_heads=8, head_dim=128, d_ff=32768, vocab=131072,
    num_experts=8, top_k=2,
)

QWEN3_235B = WorkloadConfig(
    name="qwen3-235b", num_layers=94, d_model=4096, num_heads=64,
    num_kv_heads=4, head_dim=128, d_ff=1536, vocab=151936,
    num_experts=128, top_k=8,
)

PAPER_WORKLOADS = {w.name: w for w in (GPT3_175B, GROK_1, QWEN3_235B)}


# ---------------------------------------------------------------------------
# Graph nodes
# ---------------------------------------------------------------------------

NodeKind = Literal["matmul", "attention", "collective", "elementwise"]


@dataclasses.dataclass(frozen=True)
class Node:
    """One schedulable operator.

    flops           — per-GPU floating point operations
    local_bytes     — per-GPU local-memory traffic during execution
                      (activations + weights once resident)
    pageable_bytes  — per-GPU bytes that live in the FengHuang remote tier
                      and must be paged in before execution (weights, KV
                      pages).  0 for the shared-nothing baseline.
    collective      — (kind, payload_bytes) if the node is a communication op
    matmul_dims     — (M, K, N) per-GPU for the MFU model, if a matmul
    """

    name: str
    kind: NodeKind
    flops: float = 0.0
    local_bytes: float = 0.0
    pageable_bytes: float = 0.0
    collective: tuple[str, float] | None = None
    matmul_dims: tuple[float, float, float] | None = None
    layer: int = -1


def expected_active_experts(num_experts: int, top_k: int, tokens: int) -> float:
    """E[distinct experts hit] for `tokens` tokens each drawing top_k experts.

    Uniform-routing approximation: E * (1 - (1 - 1/E)^(tokens*top_k)).
    """
    if num_experts <= 1:
        return 1.0
    draws = tokens * top_k
    return num_experts * (1.0 - (1.0 - 1.0 / num_experts) ** draws)


def _matmul_node(name: str, layer: int, tokens: float, k: float, n: float,
                 tp: int, *, paged: bool, act_bytes: float = BYTES_PER_PARAM,
                 shard_k: bool = False) -> Node:
    """A TP-sharded matmul: N (or K) dim divided across `tp` GPUs."""
    if shard_k:
        k_l, n_l = k / tp, n
    else:
        k_l, n_l = k, n / tp
    flops = 2.0 * tokens * k_l * n_l
    w_bytes = k_l * n_l * BYTES_PER_PARAM
    a_bytes = tokens * (k_l + n_l) * act_bytes
    return Node(
        name=name, kind="matmul", flops=flops,
        local_bytes=w_bytes + a_bytes,
        pageable_bytes=w_bytes if paged else 0.0,
        matmul_dims=(tokens, k_l, n_l), layer=layer,
    )


def build_graph(
    cfg: WorkloadConfig,
    phase: Literal["prefill", "decode"],
    *,
    batch: int,
    prompt_len: int,
    ctx_len: int | None = None,
    tp: int,
    paged: bool,
    page_kv: bool = True,
) -> list[Node]:
    """Emit the operator sequence for one forward pass.

    prefill: processes ``batch * prompt_len`` tokens, builds the KV cache.
    decode:  one new token per sequence against a KV cache of ``ctx_len``.
    """
    nodes: list[Node] = []
    d = cfg.d_model
    hd = cfg.head_dim
    nh, nkv = cfg.num_heads, cfg.num_kv_heads
    if phase == "prefill":
        tokens = float(batch * prompt_len)
        attn_ctx = prompt_len
    else:
        tokens = float(batch)
        attn_ctx = ctx_len if ctx_len is not None else prompt_len

    # Embedding lookup (gather — memory traffic only, sharded over TP).
    emb_bytes = tokens * d * BYTES_PER_PARAM / tp
    nodes.append(Node("embed", "elementwise", flops=0.0,
                      local_bytes=emb_bytes + tokens * d * BYTES_PER_PARAM,
                      pageable_bytes=0.0, layer=-1))

    moe_tokens = tokens  # every token routed
    active = expected_active_experts(cfg.num_experts, cfg.top_k, int(tokens))

    for layer in range(cfg.num_layers):
        # ---- attention block -------------------------------------------
        nodes.append(_matmul_node(
            f"L{layer}.qkv", layer, tokens, d,
            (nh + 2 * nkv) * hd, tp, paged=paged))
        # attention core: FA-style.  flops: QK^T + PV.
        if phase == "prefill":
            # causal: half the S^2 work
            att_flops = 2.0 * 2.0 * batch * (nh / tp) * (prompt_len ** 2) * hd / 2.0
            kv_bytes = 2.0 * batch * prompt_len * (nkv / tp) * hd * BYTES_PER_PARAM
            io_bytes = tokens * (nh + 2 * nkv) / tp * hd * BYTES_PER_PARAM * 2
            att_local = io_bytes + kv_bytes
            att_paged = 0.0  # prefill writes KV; write-back modelled as local
        else:
            att_flops = 2.0 * 2.0 * batch * (nh / tp) * attn_ctx * hd
            kv_bytes = 2.0 * batch * attn_ctx * (nkv / tp) * hd * BYTES_PER_PARAM
            att_local = kv_bytes + tokens * nh / tp * hd * BYTES_PER_PARAM * 3
            att_paged = kv_bytes if (paged and page_kv) else 0.0
        nodes.append(Node(f"L{layer}.attn", "attention", flops=att_flops,
                          local_bytes=att_local, pageable_bytes=att_paged,
                          layer=layer))
        nodes.append(_matmul_node(
            f"L{layer}.attn_out", layer, tokens, nh * hd, d, tp,
            paged=paged, shard_k=True))
        # TP allreduce of the attention output.
        ar_bytes = tokens * d * BYTES_PER_PARAM
        nodes.append(Node(f"L{layer}.attn_allreduce", "collective",
                          collective=("allreduce", ar_bytes), layer=layer))

        # ---- FFN / MoE block --------------------------------------------
        if cfg.num_experts > 1:
            # router
            nodes.append(_matmul_node(f"L{layer}.router", layer, moe_tokens,
                                      d, cfg.num_experts, 1, paged=False))
            if phase == "prefill":
                n_active = float(cfg.num_experts)
                tok_per_expert = moe_tokens * cfg.top_k / cfg.num_experts
            else:
                n_active = active
                tok_per_expert = max(1.0, moe_tokens * cfg.top_k / max(active, 1.0))
            # experts are TP-sharded on d_ff; each GPU touches all active
            # experts' shards (SGLang FusedMoE-TP style).
            up_flops = 2.0 * moe_tokens * cfg.top_k * d * (2 * cfg.d_ff / tp)
            down_flops = 2.0 * moe_tokens * cfg.top_k * (cfg.d_ff / tp) * d
            w_bytes = n_active * 3 * d * (cfg.d_ff / tp) * BYTES_PER_PARAM
            a_bytes = moe_tokens * cfg.top_k * (d + cfg.d_ff / tp) * BYTES_PER_PARAM * 2
            nodes.append(Node(
                f"L{layer}.moe", "matmul", flops=up_flops + down_flops,
                local_bytes=w_bytes + a_bytes,
                pageable_bytes=w_bytes if paged else 0.0,
                matmul_dims=(tok_per_expert, d, 3 * cfg.d_ff / tp),
                layer=layer))
        else:
            nodes.append(_matmul_node(f"L{layer}.ffn_up", layer, tokens, d,
                                      2 * cfg.d_ff, tp, paged=paged))
            nodes.append(_matmul_node(f"L{layer}.ffn_down", layer, tokens,
                                      cfg.d_ff, d, tp, paged=paged,
                                      shard_k=True))
        nodes.append(Node(f"L{layer}.ffn_allreduce", "collective",
                          collective=("allreduce", ar_bytes), layer=layer))

    # LM head (only the sampled position matters for decode; prefill computes
    # the final position per sequence => batch tokens through the head).
    head_tokens = float(batch)
    nodes.append(_matmul_node("lm_head", cfg.num_layers, head_tokens, d,
                              cfg.vocab, tp, paged=paged))
    nodes.append(Node("lm_head_allgather", "collective",
                      collective=("allgather",
                                  head_tokens * cfg.vocab / tp * BYTES_PER_PARAM),
                      layer=cfg.num_layers))
    return nodes


def graph_totals(nodes: Iterable[Node]) -> dict:
    t = {"flops": 0.0, "local_bytes": 0.0, "pageable_bytes": 0.0,
         "collective_bytes": 0.0, "num_nodes": 0}
    for n in nodes:
        t["flops"] += n.flops
        t["local_bytes"] += n.local_bytes
        t["pageable_bytes"] += n.pageable_bytes
        if n.collective:
            t["collective_bytes"] += n.collective[1]
        t["num_nodes"] += 1
    return t
