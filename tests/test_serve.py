"""Serving runtime: batched server end-to-end + sampling semantics +
the fused block-decode loop and continuous batching."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, build_model
from repro.models.base import DecodeState
from repro.models.transformer import decode_loop
from repro.runtime.serve import (BatchedServer, make_decode_loop,
                                 make_serve_step, sample)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("qwen2.5-14b").reduced()
    cfg = dataclasses.replace(cfg, remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_sample_greedy_masks_padded_vocab():
    logits = jnp.zeros((2, 1, 512))
    # put the max in the PADDED region — must never be sampled
    logits = logits.at[:, :, 500:].set(100.0)
    toks = sample(logits, vocab=500, temperature=0.0,
                  key=jax.random.PRNGKey(0))
    assert int(toks.max()) < 500


def test_server_serves_batch(tiny_model):
    model, params = tiny_model
    server = BatchedServer(model, params, batch_size=2, max_seq=64)
    r1 = server.submit(np.asarray([5, 6, 7], np.int32), max_new_tokens=6)
    r2 = server.submit(np.asarray([9, 10], np.int32), max_new_tokens=6)
    done = server.run_once()
    assert {r.uid for r in done} == {r1.uid, r2.uid}
    assert len(r1.output) == 6 and len(r2.output) == 6
    assert all(0 <= t < model.cfg.vocab for t in r1.output)
    assert server.stats["tokens"] > 0


def test_server_greedy_deterministic(tiny_model):
    model, params = tiny_model
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    outs = []
    for _ in range(2):
        server = BatchedServer(model, params, batch_size=1, max_seq=64)
        r = server.submit(prompt, max_new_tokens=8)
        server.run_once()
        outs.append(tuple(r.output))
    assert outs[0] == outs[1]


def _prefilled(model, params, batch, plen, max_seq=64):
    prompts = jax.random.randint(jax.random.PRNGKey(3), (batch, plen), 0,
                                 model.cfg.vocab)
    cache = model.init_cache(batch, max_seq)
    logits, cache = jax.jit(lambda p, t, c: model.prefill(p, t, c))(
        params, prompts, cache)
    cur = sample(logits, model.cfg.vocab, 0.0, jax.random.PRNGKey(0))
    return cur, cache


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_decode_loop_matches_per_token(tiny_model, temperature):
    """Block decode == the old host-driven per-token loop, bit-exact:
    greedy AND sampled (same per-step PRNG folding)."""
    model, params = tiny_model
    batch, plen, steps = 2, 8, 6
    cur, cache = _prefilled(model, params, batch, plen)
    key0 = jax.random.PRNGKey(7)

    sstep = jax.jit(make_serve_step(model, temperature=temperature))
    key, ref_cache, c = key0, cache, cur
    pos = jnp.full((batch,), plen, jnp.int32)
    ref = []
    for _ in range(steps):
        key, k = jax.random.split(key)
        c, _, ref_cache = sstep(params, c, ref_cache, pos, k)
        pos = pos + 1
        ref.append(np.asarray(c[:, 0]))
    ref = np.stack(ref, axis=1)

    state = DecodeState(tokens=cur, pos=jnp.full((batch,), plen, jnp.int32),
                        active=jnp.ones((batch,), bool),
                        remaining=jnp.full((batch,), steps, jnp.int32),
                        key=key0)
    toks, valid, blk_cache, _ = jax.jit(
        lambda p, ca, st: decode_loop(model, p, ca, st, num_steps=steps,
                                      temperature=temperature))(
        params, cache, state)
    np.testing.assert_array_equal(ref, np.asarray(toks))
    assert np.asarray(valid).all()
    np.testing.assert_array_equal(np.asarray(ref_cache["k"], np.float32),
                                  np.asarray(blk_cache["k"], np.float32))


def test_decode_loop_masks_freeze_finished_slots(tiny_model):
    """A drained slot stops emitting (valid=False), freezes its pos, and
    does not perturb the tokens of still-active neighbours."""
    model, params = tiny_model
    batch, plen, steps = 2, 8, 6
    cur, cache = _prefilled(model, params, batch, plen)

    def run(remaining):
        state = DecodeState(
            tokens=cur, pos=jnp.full((batch,), plen, jnp.int32),
            active=jnp.asarray(remaining) > 0,
            remaining=jnp.asarray(remaining, jnp.int32),
            key=jax.random.PRNGKey(7))
        return jax.jit(lambda p, ca, st: decode_loop(
            model, p, ca, st, num_steps=steps))(params, cache, state)

    toks_all, valid_all, _, _ = run([steps, steps])
    toks, valid, _, state = run([steps, 2])
    valid = np.asarray(valid)
    assert valid[0].all() and valid[1, :2].all() and not valid[1, 2:].any()
    assert int(state.pos[1]) == plen + 2 and not bool(state.active[1])
    # slot 1 freezes its fed token after draining
    assert (np.asarray(toks)[1, 2:] == np.asarray(toks)[1, 1]).all()
    # slot 0 is untouched by slot 1 finishing
    np.testing.assert_array_equal(np.asarray(toks)[0], np.asarray(toks_all)[0])
    # and the frozen slot's valid prefix matches the all-active run
    np.testing.assert_array_equal(np.asarray(toks)[1, :2],
                                  np.asarray(toks_all)[1, :2])


def test_decode_loop_donates_cache_and_state(tiny_model):
    """The jitted loop consumes (cache, state): donated buffers die."""
    model, params = tiny_model
    cur, cache = _prefilled(model, params, 2, 8)
    state = DecodeState(tokens=cur, pos=jnp.full((2,), 8, jnp.int32),
                        active=jnp.ones((2,), bool),
                        remaining=jnp.full((2,), 4, jnp.int32),
                        key=jax.random.PRNGKey(0))
    loop = make_decode_loop(model, block_size=4)
    _, _, new_cache, _ = loop(params, cache, state)
    if not cache["k"].is_deleted():
        pytest.skip("backend does not implement buffer donation")
    assert cache["k"].is_deleted() and cache["v"].is_deleted()
    assert state.tokens.is_deleted()
    assert not new_cache["k"].is_deleted()


def test_server_one_dispatch_and_sync_per_block(tiny_model):
    model, params = tiny_model
    server = BatchedServer(model, params, batch_size=2, max_seq=64,
                           block_size=4)
    server.submit(np.asarray([5, 6, 7], np.int32), max_new_tokens=9)
    server.submit(np.asarray([9, 10], np.int32), max_new_tokens=9)
    server.run_once()
    # 8 decode tokens per slot after prefill -> 2 blocks of 4
    assert server.stats["blocks"] == 2
    assert server.stats["dispatches"] == server.stats["blocks"]
    assert server.stats["host_syncs"] == server.stats["blocks"]
    assert server.stats["tokens"] == 18


def test_continuous_batching_admits_mid_stream(tiny_model):
    """3 requests, 2 slots, ONE batch: the third request joins the live
    batch when a slot frees — no restart, no re-prefill of neighbours."""
    model, params = tiny_model
    server = BatchedServer(model, params, batch_size=2, max_seq=64,
                           block_size=4)
    ra = server.submit(np.asarray([1, 2, 3], np.int32), max_new_tokens=12)
    rb = server.submit(np.asarray([4, 5], np.int32), max_new_tokens=3)
    rc = server.submit(np.asarray([6], np.int32), max_new_tokens=5)
    done = server.run_once()
    assert {r.uid for r in done} == {ra.uid, rb.uid, rc.uid}
    assert server.stats["batches"] == 1          # the batch never restarted
    assert [len(r.output) for r in (ra, rb, rc)] == [12, 3, 5]
    assert server.stats["admitted"] == 3
    # long request must be identical to a solo run (mid-stream admission
    # of rc into rb's slot didn't disturb it)
    solo = BatchedServer(model, params, batch_size=2, max_seq=64,
                         block_size=4)
    rs = solo.submit(np.asarray([1, 2, 3], np.int32), max_new_tokens=12)
    solo.run_once()
    assert rs.output == ra.output


def test_admission_edge_cases(tiny_model):
    """Oversized work is rejected at submit (the caller's frame, so no
    dequeued request is ever dropped); tight-fitting requests never write
    KV past the cache end; EOS sampled at admission finishes the request
    without ever activating the slot on device."""
    model, params = tiny_model
    server = BatchedServer(model, params, batch_size=1, max_seq=32)
    with pytest.raises(ValueError, match="exceeds max_seq"):
        server.submit(np.arange(40, dtype=np.int32), max_new_tokens=2)
    with pytest.raises(ValueError, match="exceeds max_seq"):
        server.submit(np.arange(9, dtype=np.int32), max_new_tokens=25)

    # 9 + 15 tokens in max_seq=24: bucket(9)=16 would overflow, so
    # admission falls back to the exact length and every write fits
    tight = BatchedServer(model, params, batch_size=1, max_seq=24,
                          block_size=4)
    r = tight.submit(np.arange(1, 10, dtype=np.int32), max_new_tokens=15)
    tight.run_once()
    assert len(r.output) == 15
    assert int(np.asarray(tight.state.pos)[0]) <= 24

    probe = BatchedServer(model, params, batch_size=1, max_seq=64)
    r = probe.submit(np.asarray([3, 1, 4], np.int32), max_new_tokens=12)
    probe.run_once()
    eos = r.output[0]
    server2 = BatchedServer(model, params, batch_size=1, max_seq=64,
                            eos_id=eos)
    r2 = server2.submit(np.asarray([3, 1, 4], np.int32), max_new_tokens=12)
    done = server2.run_once()
    assert done == [r2] and r2.output == [eos]
    assert server2.stats["blocks"] == 0           # no ghost decode dispatch
    assert not bool(np.asarray(server2.state.active).any())


def test_server_uses_configured_temperature(tiny_model):
    """Seed-sensitive outputs prove the post-prefill sample no longer
    hardcodes temperature=0.0 (the seed-repo bug)."""
    model, params = tiny_model
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)

    def first_token(seed):
        server = BatchedServer(model, params, batch_size=1, max_seq=64,
                               temperature=2.0, seed=seed)
        r = server.submit(prompt, max_new_tokens=8)
        server.run_once()
        return r.output[0]

    # with the old hardcoded temperature=0.0 the first token is greedy,
    # hence identical for every seed; at temperature 2.0 it must vary
    assert len({first_token(s) for s in range(4)}) > 1
