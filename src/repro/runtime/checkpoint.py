"""Sharded checkpointing with elastic (mesh-changing) restore.

Format: ``<dir>/step_<n>/arrays.npz`` (flattened pytree with path keys) +
``manifest.json`` (tree structure, shapes, dtypes, step).  Saves are
atomic (write to ``.tmp`` then rename) and optionally asynchronous.

``restore(..., mesh=..., specs=...)`` re-shards every leaf for the target
mesh — which is exactly elastic scaling: train on (2,16,16), lose a pod,
restore onto (16,16) and keep going.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

SEP = "/"


def _storage_view(arr: np.ndarray) -> np.ndarray:
    """npz-safe view: custom dtypes (bfloat16, fp8) stored as raw uints."""
    if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
        return arr.view({1: np.uint8, 2: np.uint16}[arr.dtype.itemsize])
    return arr


def _unstorage_view(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if arr.dtype.name == dtype_name:
        return arr
    import ml_dtypes
    tgt = np.dtype(getattr(ml_dtypes, dtype_name, dtype_name))
    if tgt.itemsize == arr.dtype.itemsize:
        return arr.view(tgt)
    return arr.astype(tgt)


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save(ckpt_dir: str | Path, step: int, tree: Any, *,
         keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz",
             **{k: _storage_view(v) for k, v in flat.items()})
    manifest = {
        "step": step,
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                 for k, v in flat.items()},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    _gc(ckpt_dir, keep)
    return final


def save_async(ckpt_dir: str | Path, step: int, tree: Any, *,
               keep: int = 3) -> threading.Thread:
    """Non-blocking save: device_get happens on the calling thread (cheap
    on CPU; on TPU this is the D2H snapshot), IO on a worker."""
    flat = _flatten(tree)   # snapshot now so training may mutate

    def _io():
        ckpt_dir_p = Path(ckpt_dir)
        ckpt_dir_p.mkdir(parents=True, exist_ok=True)
        tmp = ckpt_dir_p / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz",
                 **{k: _storage_view(v) for k, v in flat.items()})
        manifest = {"step": step,
                    "keys": {k: {"shape": list(v.shape),
                                 "dtype": str(v.dtype)}
                             for k, v in flat.items()}}
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        final = ckpt_dir_p / f"step_{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        _gc(ckpt_dir_p, keep)

    t = threading.Thread(target=_io, daemon=True)
    t.start()
    return t


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(ckpt_dir.glob("step_*"))
    for old in steps[:-keep]:
        shutil.rmtree(old, ignore_errors=True)


def latest_step(ckpt_dir: str | Path) -> int | None:
    steps = sorted(Path(ckpt_dir).glob("step_*"))
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def restore(ckpt_dir: str | Path, template: Any, *, step: int | None = None,
            mesh=None, specs: Any = None) -> tuple[Any, int]:
    """Load a checkpoint into the structure of ``template``.

    With ``mesh`` + ``specs`` (PartitionSpec tree) the leaves are placed
    as NamedShardings on that mesh — restoring onto a different mesh than
    the one that saved is supported (elastic restart).
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = ckpt_dir / f"step_{step:08d}"
    data = np.load(path / "arrays.npz")

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    if specs is not None and mesh is not None:
        from repro.runtime.sharding import resolve_spec
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec_leaves = jax.tree.leaves(
            specs, is_leaf=lambda s: isinstance(s, P))
    else:
        spec_leaves = [None] * len(paths)

    leaves = []
    for (path_parts, leaf), spec in zip(paths, spec_leaves):
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_parts)
        arr = _unstorage_view(data[key], np.dtype(leaf.dtype).name)
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        val = jnp.asarray(arr, dtype=leaf.dtype)
        if spec is not None:
            from repro.memory import tiers as memtiers
            from repro.runtime.sharding import resolve_spec
            # tier-registry sharding (local tier), not a bare
            # NamedSharding: restored params land with the memory kind
            # the current backend actually exposes
            val = jax.device_put(
                val, memtiers.tier_sharding(mesh, resolve_spec(spec, mesh),
                                            memtiers.LOCAL))
        leaves.append(val)
    return jax.tree_util.tree_unflatten(treedef, leaves), step
