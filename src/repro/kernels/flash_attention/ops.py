"""jit'd wrapper: (B, S, H, d) API with GQA expansion, padding, head fold."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention import ref as _ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int = 0, bq: int = 256,
              bk: int = 256, interpret: bool = False) -> jax.Array:
    """q: (B, Sq, Hq, d); k, v: (B, Sk, Hkv, d), Hq % Hkv == 0."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    assert hq % hkv == 0
    if hq != hkv:   # GQA: expand kv heads
        k = jnp.repeat(k, hq // hkv, axis=2)
        v = jnp.repeat(v, hq // hkv, axis=2)
    bq_ = min(bq, sq)
    bk_ = min(bk, sk)
    pq = (-sq) % bq_
    pk = (-sk) % bk_
    qf = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kf = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vf = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    # fold heads into batch: (B*H, S, d)
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(-1, x.shape[1], d)
    # q positions align to the suffix of the true (unpadded) kv sequence;
    # kv_valid masks the padded rows for the non-causal case too.
    out = flash_attention(fold(qf), fold(kf), fold(vf), causal=causal,
                          window=window, bq=bq_, bk=bk_,
                          q_offset=sk - sq, kv_valid=sk,
                          interpret=interpret)
    out = out.reshape(b, hq, sq + pq, d).transpose(0, 2, 1, 3)
    return out[:, :sq]


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    if hq != hkv:
        k = jnp.repeat(k, hq // hkv, axis=2)
        v = jnp.repeat(v, hq // hkv, axis=2)
    return _ref.attention_ref(q, k, v, causal=causal, window=window)
