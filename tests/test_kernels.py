"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 runs without hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.streamed_matmul import ops as sm
from repro.kernels.flash_attention import ops as fa
from repro.kernels.paged_attention import ops as pa
from repro.kernels.write_accumulate import ops as wa

RNG = np.random.RandomState(42)


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# streamed matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (128, 256, 64),
                                   (100, 300, 50), (7, 513, 129)])
def test_streamed_matmul_sweep(m, k, n, dtype):
    x = jnp.asarray(RNG.randn(m, k), dtype)
    w = jnp.asarray(RNG.randn(k, n), dtype)
    out = sm.matmul(x, w, bm=64, bk=128, bn=64, interpret=True)
    ref = sm.matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@given(m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96))
@settings(max_examples=12, deadline=None)
def test_streamed_matmul_property(m, k, n):
    x = jnp.asarray(np.random.RandomState(m * 97 + k).randn(m, k), jnp.float32)
    w = jnp.asarray(np.random.RandomState(n).randn(k, n), jnp.float32)
    out = sm.matmul(x, w, bm=32, bk=32, bn=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(sm.matmul_ref(x, w)),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (1, 513, 1), (3, 5, 2),
                                   (33, 17, 9)])
def test_streamed_matmul_tiny_and_unaligned(m, k, n):
    """Shapes below / not aligned to the default block sizes clamp to
    single-block streams and still match the reference exactly."""
    x = jnp.asarray(RNG.randn(m, k), jnp.float32)
    w = jnp.asarray(RNG.randn(k, n), jnp.float32)
    out = sm.matmul(x, w, interpret=True)          # default 256/512/256 blocks
    assert out.shape == (m, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(sm.matmul_ref(x, w)),
                               atol=2e-4, rtol=2e-4)


def test_streamed_matmul_rejects_bad_shapes():
    """Empty operands error instead of silently streaming degenerate
    1-wide blocks (the old ``min(bm, m) or 1`` clamp); so do rank and
    contraction mismatches."""
    good = jnp.ones((4, 8), jnp.float32)
    with pytest.raises(ValueError, match="non-empty"):
        sm.matmul(jnp.ones((0, 8), jnp.float32),
                  jnp.ones((8, 3), jnp.float32), interpret=True)
    with pytest.raises(ValueError, match="non-empty"):
        sm.matmul(good, jnp.ones((8, 0), jnp.float32), interpret=True)
    with pytest.raises(ValueError, match="non-empty"):
        sm.matmul(jnp.ones((4, 0), jnp.float32),
                  jnp.ones((0, 8), jnp.float32), interpret=True)
    with pytest.raises(ValueError, match="contraction mismatch"):
        sm.matmul(good, jnp.ones((9, 3), jnp.float32), interpret=True)
    with pytest.raises(ValueError, match="2-D"):
        sm.matmul(jnp.ones((2, 4, 8), jnp.float32), good, interpret=True)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 13), (False, 0)])
@pytest.mark.parametrize("sq,sk,hq,hkv", [(64, 64, 4, 4), (64, 64, 4, 2),
                                          (50, 50, 2, 1), (32, 96, 4, 2)])
def test_flash_attention_sweep(sq, sk, hq, hkv, causal, window, dtype):
    d = 32
    q = jnp.asarray(RNG.randn(2, sq, hq, d), dtype) * 0.3
    k = jnp.asarray(RNG.randn(2, sk, hkv, d), dtype) * 0.3
    v = jnp.asarray(RNG.randn(2, sk, hkv, d), dtype)
    out = fa.attention(q, k, v, causal=causal, window=window, bq=32, bk=32,
                       interpret=True)
    ref = fa.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_matches_model_layer_path():
    """The kernel and models.layers.flash_attention agree (same oracle)."""
    from repro.models.layers import flash_attention as jnp_flash
    q = jnp.asarray(RNG.randn(1, 64, 4, 32), jnp.float32) * 0.3
    k = jnp.asarray(RNG.randn(1, 64, 2, 32), jnp.float32) * 0.3
    v = jnp.asarray(RNG.randn(1, 64, 2, 32), jnp.float32)
    a = fa.attention(q, k, v, causal=True, bq=32, bk=32, interpret=True)
    b = jnp_flash(q, k, v, causal=True, q_block=32, kv_block=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("extra", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hkv,g,npages,page", [(2, 2, 2, 4, 8),
                                                 (3, 1, 4, 3, 16),
                                                 (1, 4, 1, 6, 4)])
def test_paged_attention_sweep(b, hkv, g, npages, page, dtype, extra):
    d = 32
    pool = npages * b + 1
    kp = jnp.asarray(RNG.randn(pool, page, hkv, d), dtype) * 0.3
    vp = jnp.asarray(RNG.randn(pool, page, hkv, d), dtype)
    q = jnp.asarray(RNG.randn(b, hkv, g, d), dtype) * 0.3
    table = jnp.asarray(
        1 + np.arange(b * npages).reshape(b, npages), jnp.int32)
    lens = jnp.asarray(RNG.randint(1, npages * page + 1, size=(b,)),
                       jnp.int32)
    # extra_kv = the serving hot path's current-token column (the pool is
    # read-only in the decode scan; the new token joins at the flush step)
    kv0 = (jnp.asarray(RNG.randn(b, hkv, d), dtype) * 0.3,
           jnp.asarray(RNG.randn(b, hkv, d), dtype)) if extra else None
    out = pa.attend(q, kp, vp, table, lens, kv0, interpret=True)
    ref = pa.attend_ref(q, kp, vp, table, lens, kv0)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_block_pool_lifecycle():
    from repro.memory import BlockPoolResidency
    pool = BlockPoolResidency(num_pages=8, page_size=4, kv_heads=2,
                              head_dim=8)
    pool.alloc_seq(1)
    k_blk = jnp.stack([jnp.full((2, 8), float(i)) for i in range(6)])
    pool.append_block(1, k_blk, -k_blk)   # 6 tokens cross a page boundary
    assert pool.manager.lens[1] == 6
    assert len(pool.manager.pages[1]) == 2
    t = pool.batch_tables([1], 3)
    assert t.shape == (1, 3)
    pool.free_seq(1)
    assert 1 not in pool.manager.pages


# ---------------------------------------------------------------------------
# write accumulate
# ---------------------------------------------------------------------------

@given(n=st.integers(2, 12), rows=st.integers(1, 40),
       cols=st.integers(1, 80))
@settings(max_examples=15, deadline=None)
def test_write_accumulate_property(n, rows, cols):
    sh = jnp.asarray(np.random.RandomState(n).randn(n, rows, cols),
                     jnp.float32)
    out = wa.accumulate(sh, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(wa.accumulate_ref(sh)),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_write_accumulate_dtypes(dtype):
    sh = jnp.asarray(RNG.randn(8, 64, 128), dtype)
    out = wa.accumulate(sh, interpret=True)
    ref = wa.accumulate_ref(sh)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_write_accumulate_commutativity():
    """§3.3.1: accumulation is order-independent (commutative reduction)."""
    sh = jnp.asarray(RNG.randn(6, 32, 64), jnp.float32)
    perm = np.random.RandomState(1).permutation(6)
    a = wa.accumulate(sh, interpret=True)
    b = wa.accumulate(sh[perm], interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
