"""recurrentgemma-9b: 38L d=4096 16H (MQA kv=1) d_ff=12288 vocab=256000.
RG-LRU + local attention, pattern (rec, rec, att); 38 = 12 groups + 2 rec
tail [arXiv:2402.19427].  Sub-quadratic => long_500k runs."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256,
    sliding_window=2048, block_pattern=("rec", "rec", "att"),
)
