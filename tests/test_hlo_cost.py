"""Trip-count-aware HLO cost walker: exactness on known programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import module_cost, parse_module, shape_bytes


def _cost(fn, *specs):
    return module_cost(jax.jit(fn).lower(*specs).compile().as_text())


def test_single_matmul_exact():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = _cost(lambda a, b: a @ b, x, x)
    assert c["flops"] == 2 * 128 ** 3


def test_scan_multiplies_trip_count():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)

    def scanned(a, w):
        return jax.lax.scan(lambda c, wi: (c @ wi, None), a, w)[0]

    c = _cost(scanned, x, ws)
    assert c["flops"] == pytest.approx(10 * 2 * 128 ** 3, rel=0.01)


def test_nested_scan_multiplies():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 4, 64, 64), jnp.float32)

    def nested(a, w):
        def outer(c, wo):
            def inner(ci, wi):
                return ci @ wi, None
            return jax.lax.scan(inner, c, wo)[0], None
        return jax.lax.scan(outer, a, w)[0]

    c = _cost(nested, x, ws)
    assert c["flops"] == pytest.approx(12 * 2 * 64 ** 3, rel=0.01)


def test_xla_cost_analysis_undercounts_loops():
    """Documents WHY the walker exists: XLA counts loop bodies once."""
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)

    def scanned(a, w):
        return jax.lax.scan(lambda c, wi: (c @ wi, None), a, w)[0]

    ca = jax.jit(scanned).lower(x, ws).compile().cost_analysis()
    if isinstance(ca, list):     # jax < 0.5 returns one dict per device
        ca = ca[0]
    walker = _cost(scanned, x, ws)["flops"]
    assert walker > 5 * ca["flops"]


def test_bytes_reasonable_for_copy():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _cost(lambda a: a * 2.0, x)
    nbytes = 1024 * 1024 * 4
    # one read + one write, modulo minor bookkeeping
    assert nbytes <= c["bytes"] <= 4 * nbytes


def test_shape_bytes_tuple_and_layout():
    assert shape_bytes("f32[4,4]{1,0}") == 64
    assert shape_bytes("(f32[2], bf16[3,3]{1,0})") == 8 + 18
    assert shape_bytes("pred[]") == 1


def test_parse_module_finds_entry():
    hlo = jax.jit(lambda a: a + 1).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)).compile().as_text()
    comps, entry = parse_module(hlo)
    assert entry is not None
    assert entry in comps
