"""Quickstart: build an assigned architecture, run a forward pass, train a
few steps, then serve it — all on CPU with a reduced config.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen3-14b]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, build_model
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.runtime import optim
from repro.runtime.serve import BatchedServer
from repro.runtime.train import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b", choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"[quickstart] {args.arch} (reduced): {cfg.num_layers}L "
          f"d={cfg.d_model} heads={cfg.num_heads} vocab={cfg.vocab}")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[quickstart] {n_params/1e6:.2f}M parameters")

    # --- forward ---
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    extra = {}
    if cfg.family == "encdec":
        extra["frames"] = jnp.zeros((2, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        extra["patches"] = jnp.zeros((2, cfg.num_patches, cfg.d_model))
    logits = jax.jit(lambda p, t: model.forward(p, t, extra or None))(
        params, tokens)
    print(f"[quickstart] forward: logits {logits.shape}")

    # --- train a few steps ---
    tcfg = TrainConfig(adamw=optim.AdamWConfig(
        lr=3e-3, warmup_steps=2, total_steps=max(args.steps, 4)))
    step = jax.jit(make_train_step(model, tcfg))
    opt = optim.init_opt_state(params)
    data = SyntheticLM(DataConfig(batch=4, seq=32, vocab=cfg.vocab))
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        batch.update(extra)
        params, opt, m = step(params, opt, batch)
        if i % 2 == 0 or i == args.steps - 1:
            print(f"[quickstart] step {i}: loss {float(m['loss']):.4f} "
                  f"lr {float(m['lr']):.2e}")

    # --- serve (decoder families) ---
    if cfg.family not in ("encdec",):
        server = BatchedServer(model, params, batch_size=2, max_seq=64)
        req = server.submit(np.asarray([1, 2, 3], np.int32),
                            max_new_tokens=8)
        server.run_once()
        print(f"[quickstart] served tokens: {req.output}")
    print("[quickstart] OK")


if __name__ == "__main__":
    main()
