"""TAB shared-memory collectives (§3.3) mapped to JAX.

The FengHuang Tensor Addressable Bridge turns every collective into
shared-memory traffic: each xPU `write-accumulate`s its contribution into a
striped shared buffer (one transfer), the TAB notifies completion, and
consumers read.  TPU has no memory-side reduction, but the *schedule* —
"one write per device, accumulation at the owner, then direct reads" — is
exactly reduce-scatter(+all-gather) semantics.  We expose both:

* ``tab_*``  — one-shot implementations (`psum_scatter`/`all_gather`/
  `all_to_all`) matching FengHuang's single-transfer-per-device pattern.
* ``ring_*`` — explicit 2(N-1)-step `ppermute` rings modelling the paper's
  NVLink baseline.  These exist so benchmarks/tests can compare transfer
  *counts* (Enabler 1) on real HLO, and so the collective schedule is
  swappable per model config.

All functions are written against a named mesh axis and must run inside
``jax.shard_map``.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax import lax

Schedule = Literal["tab", "ring"]


def _axis_size(axis_name: str) -> int:
    try:
        return lax.axis_size(axis_name)
    except AttributeError:          # jax < 0.5
        frame = jax.core.axis_frame(axis_name)
        return frame if isinstance(frame, int) else frame.size


# ---------------------------------------------------------------------------
# One-shot "TAB" collectives.
# ---------------------------------------------------------------------------

def tab_write_accumulate(x: jax.Array, axis_name: str) -> jax.Array:
    """The TAB's in-memory accumulate: every device's contribution summed
    into the shared buffer.  Per-device traffic: one write of |x| (Enabler 1
    latency-bound count = 1) + one read of the result == psum."""
    return lax.psum(x, axis_name)


def tab_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """AllReduce (Fig 3.5): write-accumulate + completion + read-all."""
    return lax.psum(x, axis_name)


def tab_reduce_scatter(x: jax.Array, axis_name: str,
                       scatter_dimension: int = 0) -> jax.Array:
    """ReduceScatter (Fig 3.5): identical writes; each xPU reads its shard."""
    return lax.psum_scatter(x, axis_name,
                            scatter_dimension=scatter_dimension, tiled=True)


def tab_allgather(x: jax.Array, axis_name: str, axis: int = 0) -> jax.Array:
    """AllGather (Fig 3.6): each xPU writes its shard; all read the result."""
    return lax.all_gather(x, axis_name, axis=axis, tiled=True)


def tab_all_to_all(x: jax.Array, axis_name: str, *, split_axis: int = 0,
                   concat_axis: int = 0) -> jax.Array:
    """AllToAll (Fig 3.6): shard writes + sliced reads."""
    return lax.all_to_all(x, axis_name, split_axis=split_axis,
                          concat_axis=concat_axis, tiled=True)


def tab_p2p(x: jax.Array, axis_name: str, shift: int = 1) -> jax.Array:
    """P2P send/recv (Fig 3.7) as a single shared-memory hop."""
    n = _axis_size(axis_name)
    perm = [(i, (i + shift) % n) for i in range(n)]
    return lax.ppermute(x, axis_name, perm)


# ---------------------------------------------------------------------------
# Ring baselines ("NVLink" schedule): explicit 2(N-1) transfer steps.
# ---------------------------------------------------------------------------

def ring_reduce_scatter(x: jax.Array, axis_name: str) -> jax.Array:
    """(N-1)-step ring reduce-scatter over leading-dim chunks.

    x: (d0, ...) with d0 divisible by N.  Returns this device's reduced
    chunk of shape (d0/N, ...).
    """
    n = _axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    chunks = jnp.stack(jnp.split(x, n, axis=0))          # (N, d0/N, ...)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def step(k, acc_chunks):
        # At step k, device i sends its partial of chunk (i - k - 1) mod N
        # and accumulates the incoming partial into chunk (i - k - 2) mod N;
        # after N-1 steps device i owns the fully-reduced chunk i (matching
        # psum_scatter placement).
        send_idx = (idx - k - 1) % n
        send = jnp.take(acc_chunks, send_idx, axis=0)
        recv = lax.ppermute(send, axis_name, perm)
        tgt = (idx - k - 2) % n
        updated = jnp.take(acc_chunks, tgt, axis=0) + recv
        return acc_chunks.at[tgt].set(updated)

    chunks = lax.fori_loop(0, n - 1, step, chunks)
    return jnp.take(chunks, idx, axis=0)


def ring_allgather(x: jax.Array, axis_name: str) -> jax.Array:
    """(N-1)-step ring all-gather of per-device chunks along axis 0."""
    n = _axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    out = jnp.zeros((n,) + x.shape, x.dtype).at[idx].set(x)

    def step(k, state):
        buf, cur = state
        nxt = lax.ppermute(cur, axis_name, perm)
        src = (idx - k - 1) % n
        return buf.at[src].set(nxt), nxt

    out, _ = lax.fori_loop(0, n - 1, step, (out, x))
    return out.reshape((n * x.shape[0],) + x.shape[1:])


def ring_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Ring allreduce = ring reduce-scatter + ring all-gather: the paper's
    2(N-1)-transfer NVLink baseline (Enabler 1)."""
    n = _axis_size(axis_name)
    orig_shape = x.shape
    size = _size(orig_shape)
    flat = x.reshape(-1)
    pad = (-size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    shard = ring_reduce_scatter(flat, axis_name)
    full = ring_allgather(shard, axis_name)
    return full[:size].reshape(orig_shape)


def _size(shape) -> int:
    s = 1
    for d in shape:
        s *= int(d)
    return s


# ---------------------------------------------------------------------------
# Schedule dispatch used by model layers.
# ---------------------------------------------------------------------------

def allreduce(x: jax.Array, axis_name: str,
              schedule: Schedule = "tab") -> jax.Array:
    if schedule == "ring":
        return ring_allreduce(x, axis_name)
    return tab_allreduce(x, axis_name)


def reduce_scatter(x: jax.Array, axis_name: str,
                   schedule: Schedule = "tab") -> jax.Array:
    if schedule == "ring":
        return ring_reduce_scatter(x, axis_name)
    return tab_reduce_scatter(x, axis_name)


def allgather(x: jax.Array, axis_name: str,
              schedule: Schedule = "tab") -> jax.Array:
    if schedule == "ring":
        return ring_allgather(x, axis_name)
    return tab_allgather(x, axis_name)
