"""Sharding resolution: logical specs -> physical NamedShardings.

Model code writes PartitionSpecs against logical axes (``"model"`` and the
``BATCH_AXES`` tuple ``("pod", "data")``).  This module resolves them for a
concrete mesh:

* single-pod mesh ("data", "model"): batch -> ("data",)
* multi-pod mesh ("pod", "data", "model"): batch -> ("pod", "data")
* smoke meshes (1 device): everything -> None

It also applies the FengHuang memory tiers: the memory kind of every
NamedSharding is resolved through :mod:`repro.memory.tiers` — local for
ordinary params, remote for pageable groups when the pager is enabled —
so the same spec tree places correctly on GPU/TPU (``device`` /
``pinned_host``) and on the CPU backend (where both tiers are
``unpinned_host`` and a hardcoded kind would be rejected outright).

The serving runtime runs its dispatches inside :func:`activate_mesh`
so :func:`maybe_constraint` — the logical-spec constraint model code
sprinkles on residuals and attention internals — resolves against the
serving mesh; outside a mesh context it stays a no-op.
"""
from __future__ import annotations

import contextlib
import re
import threading

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.memory import tiers as memtiers
from repro.models.base import BATCH_AXES

try:  # jax <= 0.4 ambient-mesh plumbing (Mesh context manager)
    from jax.interpreters import pxla as _pxla
except ImportError:  # pragma: no cover - future jax without the shim
    _pxla = None

PAGEABLE_GROUPS = ("layers", "groups", "dec_layers", "enc_layers")


def resolve_spec(spec: P, mesh: Mesh) -> P:
    """Map logical axis entries to the axes present in ``mesh``."""
    axes = set(mesh.axis_names)
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, tuple):           # e.g. ("pod", "data")
            kept = tuple(a for a in entry if a in axes)
            out.append(kept if kept else None)
        elif entry == "model":
            out.append("model" if "model" in axes else None)
        elif entry in ("pod", "data"):
            out.append(entry if entry in axes else None)
        else:
            out.append(entry if entry in axes else None)
    return P(*out)


def _treat_as_leaf(x) -> bool:
    return isinstance(x, P)


def resolve_tree(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: resolve_spec(s, mesh), spec_tree,
                        is_leaf=_treat_as_leaf)


def named_shardings(spec_tree: Any, mesh: Mesh, *,
                    pageable_remote: bool = False) -> Any:
    """PartitionSpec tree -> NamedSharding tree.

    With ``pageable_remote=True``, specs under PAGEABLE_GROUPS are placed
    in the FengHuang remote tier — the weights will be paged into local
    memory by the orchestrator's layer scans.  Both tiers' memory kinds
    come from the :class:`~repro.memory.tiers.TierRegistry` for the
    current backend (``pinned_host`` remote on GPU/TPU, ``unpinned_host``
    on CPU — the old hardcoded kind broke CPU placement entirely).
    """

    def convert(path, s):
        tier = memtiers.LOCAL
        if pageable_remote and path and getattr(path[0], "key", None) in PAGEABLE_GROUPS:
            tier = memtiers.REMOTE
        return memtiers.tier_sharding(mesh, resolve_spec(s, mesh), tier)

    return jax.tree_util.tree_map_with_path(convert, spec_tree,
                                            is_leaf=_treat_as_leaf)


def batch_spec(mesh: Mesh, *trailing) -> P:
    """Spec for (batch, ...) data: batch over ("pod","data") as available."""
    axes = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
    return P(axes if axes else None, *trailing)


def replicated(mesh: Mesh) -> NamedSharding:
    """Fully replicated placement on ``mesh`` (decode state, page tables,
    per-slot bookkeeping — everything the host mirrors byte-exactly).
    Local-tier resident, with the memory kind resolved through the tier
    registry like every other NamedSharding here — tier resolution has
    one owner."""
    return memtiers.tier_sharding(mesh, P(), memtiers.LOCAL)


def constraint(x, mesh: Mesh, spec: P):
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve_spec(spec, mesh)))


# ---------------------------------------------------------------------------
# Ambient mesh
# ---------------------------------------------------------------------------

def mesh_axis_sizes(mesh) -> dict[str, int]:
    """Axis name -> size for a concrete ``Mesh`` or an ``AbstractMesh``
    — the ONE size-lookup used everywhere (no per-call duck typing)."""
    if hasattr(mesh, "axis_sizes"):         # AbstractMesh (jax >= 0.5)
        return dict(zip(mesh.axis_names, mesh.axis_sizes))
    return dict(mesh.shape)                 # Mesh: OrderedDict name->size


def ambient_mesh():
    """The mesh enclosing the current trace, or None.

    jax >= 0.5 exposes it as :func:`jax.sharding.get_abstract_mesh`;
    jax <= 0.4 tracks the ``with mesh:`` context in
    ``pxla.thread_resources``.  Neither probe swallows real errors — a
    broken mesh propagates instead of silently no-op'ing constraints.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        am = get()
        if am is not None and not getattr(am, "empty", False):
            return am
    env = getattr(_pxla, "thread_resources", None)
    mesh = getattr(getattr(env, "env", None), "physical_mesh", None)
    if mesh is not None and not mesh.empty:
        return mesh
    return None


def activate_mesh(mesh: Mesh | None):
    """Context manager making ``mesh`` ambient for traces inside it, so
    bare-PartitionSpec constraints (and :func:`maybe_constraint`) resolve.
    ``None`` is a no-op context (single-device serving)."""
    if mesh is None:
        return contextlib.nullcontext()
    use = getattr(jax.sharding, "use_mesh", None)   # jax >= 0.5
    if use is not None:
        return use(mesh)
    return mesh          # jax <= 0.4: Mesh is itself a context manager


def maybe_constraint(x, spec: P):
    """Sharding constraint against the *ambient* mesh.

    Model code calls this with logical specs (e.g. sequence-parallel
    residuals P(batch, "model", None)); outside a mesh context, or when an
    axis is missing / the dim isn't divisible, it's a no-op — so smoke
    tests and single-device runs are unaffected.
    """
    am = ambient_mesh()
    if am is None:
        return x
    sizes = mesh_axis_sizes(am)
    out = []
    for dim, entry in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        names = ()
        if entry is None:
            names = ()
        elif isinstance(entry, tuple):
            names = tuple(a for a in entry if a in sizes)
        elif entry in sizes:
            names = (entry,)
        total = 1
        for n in names:
            total *= sizes[n]
        if names and total > 1 and dim % total == 0:
            out.append(names if len(names) > 1 else names[0])
        else:
            out.append(None)
    if all(e is None for e in out):
        return x
    return jax.lax.with_sharding_constraint(x, P(*out))


_TP_STATE = threading.local()


@contextlib.contextmanager
def gather_tp_mode():
    """Arm :func:`replicate_constraint` for the extent of a trace.

    The all-gather-TP boundary belongs ONLY to the serving placement
    (output projections replicated — ``serving_param_specs``); traced
    under a mesh with the *training* placement (e.g. the dry-run cost
    model, where ``wo`` stays contraction-sharded) the same constraint
    would inject per-layer replication gathers on top of the row-
    parallel partial sums — strictly worse traffic and wrong cost
    tables.  ``BatchedServer`` enters this context around every
    dispatch; everything else leaves the constraint a no-op."""
    prev = getattr(_TP_STATE, "gather", False)
    _TP_STATE.gather = True
    try:
        yield
    finally:
        _TP_STATE.gather = prev


def replicate_constraint(x):
    """Explicitly constrain ``x`` to FULLY REPLICATED under the ambient
    mesh — an all-gather when it is currently sharded.  This is the
    all-gather-TP boundary ``maybe_constraint`` cannot express: an
    all-``None`` spec is its no-op, while here replication is the whole
    point.  No-op outside :func:`gather_tp_mode` or a mesh context."""
    if not getattr(_TP_STATE, "gather", False):
        return x
    if ambient_mesh() is None:
        return x
    return jax.lax.with_sharding_constraint(x, P())


# ---------------------------------------------------------------------------
# Per-axis collective accounting (the serving bench's wire-traffic row)
# ---------------------------------------------------------------------------

_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[0-9,]*\})?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_COMPONENT_RE = re.compile(r"[a-z0-9]+\[[0-9,]*\]")
_GROUPS_RE = re.compile(
    r"replica_groups=(\{\{[^=]*?\}\}|\[[0-9,]+\]<=\[[0-9,]+\](?:T\([0-9,]+\))?)")
_IOTA_RE = re.compile(r"\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def _parse_groups(text: str) -> list[tuple[int, ...]] | None:
    """``replica_groups`` -> concrete device-id groups, for either HLO
    syntax: explicit ``{{0,1},{2,3}}`` or iota ``[g,s]<=[dims]T(perm)``
    (arange over dims, transposed by perm, reshaped to (g, s))."""
    import numpy as np

    if text.startswith("{"):
        found = re.findall(r"\{([0-9, ]+)\}", text)
        groups = [tuple(int(t) for t in g.split(",") if t.strip())
                  for g in found]
        return groups or None
    m = _IOTA_RE.match(text)
    if not m:
        return None
    g, s = int(m.group(1)), int(m.group(2))
    dims = [int(d) for d in m.group(3).split(",")]
    ids = np.arange(int(np.prod(dims))).reshape(dims)
    if m.group(4):
        ids = ids.transpose([int(p) for p in m.group(4).split(",")])
    return [tuple(int(i) for i in row) for row in ids.reshape(g, s)]


def _axes_for_groups(mesh, groups: list[tuple[int, ...]]) -> str:
    """Attribute a collective's device groups to mesh axes EXACTLY: the
    axis combination whose slices of ``mesh.devices`` reproduce the
    groups (so two same-size axes — data=2, model=2 — still attribute
    correctly).  Falls back to group-size matching when the mesh carries
    no concrete devices."""
    import itertools

    import numpy as np

    sizes = mesh_axis_sizes(mesh)
    live = [n for n in mesh.axis_names if sizes[n] > 1]
    actual = {frozenset(g) for g in groups}
    devices = getattr(mesh, "devices", None)
    if devices is not None:
        ids = np.vectorize(lambda d: d.id)(devices)
        names = list(mesh.axis_names)
        for r in range(1, len(live) + 1):
            for combo in itertools.combinations(live, r):
                order = ([names.index(n) for n in names if n not in combo]
                         + [names.index(n) for n in combo])
                k = 1
                for n in combo:
                    k *= sizes[n]
                expected = {frozenset(int(i) for i in row)
                            for row in ids.transpose(order).reshape(-1, k)}
                if expected == actual:
                    return "+".join(combo)
    # size heuristic (abstract meshes / exotic group shapes)
    g = len(next(iter(actual)))
    for r in range(1, len(live) + 1):
        for combo in itertools.combinations(live, r):
            total = 1
            for n in combo:
                total *= sizes[n]
            if total == g:
                return "+".join(combo)
    return f"group{g}"


def collective_bytes_by_axis(hlo_text: str, mesh: Mesh) -> dict[str, int]:
    """Payload bytes of every collective in ``hlo_text``, attributed to
    mesh axes by their concrete ``replica_groups`` device sets.

    Returns ``{axis_name: bytes, ...}`` (an axis that saw no traffic is
    absent); a group spanning several axes lands on a '+'-joined key.
    Collectives inside a scan/while body appear once in the text, so
    the result is per loop ITERATION — callers scale by trip count.
    """
    from repro.launch.hlo_cost import shape_bytes

    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        mg = _GROUPS_RE.search(line)
        groups = _parse_groups(mg.group(1)) if mg else None
        if not groups or len(groups[0]) <= 1:
            continue                      # degenerate single-device group
        axis = _axes_for_groups(mesh, groups)
        shape_text = m.group(1)
        if m.group(3) and shape_text.startswith("("):
            # async op: the tuple is (operand..., result) — only the
            # result component is wire payload, not the held operand
            parts = _SHAPE_COMPONENT_RE.findall(shape_text)
            if parts:
                shape_text = parts[-1]
        out[axis] = out.get(axis, 0) + shape_bytes(shape_text)
    return out


#: logical spec for sequence-parallel residual activations (B, S, d)
SEQ_SHARDED_ACTS = P(BATCH_AXES, "model", None)
