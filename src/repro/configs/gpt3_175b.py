"""GPT-3 175B (paper workload §4.1.2): dense 96L d=12288 96H MHA."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="gpt3-175b", family="dense",
    num_layers=96, d_model=12288, num_heads=96, num_kv_heads=96,
    d_ff=32768, vocab=50257, head_dim=128,
)
