"""End-to-end FengHuang serving driver (the paper's workload shape):
a small dense LM serving batched requests, run twice — shared-nothing
baseline vs FengHuang-paged (weights in the remote tier, TensorPager
double-buffered prefetch) — and verified to emit identical tokens.
Then the new expert-paging scenario: a small MoE LM whose expert banks
stay at rest in the remote tier while decode pages in only the routed
(top-k) rows per step.

All placement goes through ``repro.memory.MemoryOrchestrator`` — the
policy matrix is planned from the model config, and every residency
number printed below comes from the orchestrator's shared ledger.

    PYTHONPATH=src python examples/serve_fenghuang.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro import memory
from repro.configs import get_config, build_model
from repro.runtime.serve import BatchedServer

PROMPTS = [
    np.asarray([11, 42, 7, 3], np.int32),
    np.asarray([5, 9], np.int32),
    np.asarray([100, 101, 102, 103, 104], np.int32),
    np.asarray([1], np.int32),
]


def serve_all(model, params, tag, paged=None, batch_size=2,
              prompts=PROMPTS):
    # 2 slots for 4 requests: the back half is admitted MID-STREAM via
    # continuous batching when the front half's slots free up.
    server = BatchedServer(model, params, batch_size=batch_size, max_seq=96,
                           block_size=8, paged=paged)
    t0 = time.perf_counter()
    reqs = [server.submit(p, max_new_tokens=12) for p in prompts]
    while any(not r.done.is_set() for r in reqs):
        server.run_once()
    dt = time.perf_counter() - t0
    s = server.stats
    print(f"[{tag}] served {len(reqs)} requests, {s['tokens']} tokens "
          f"in {dt:.2f}s — {s['dispatches']} block dispatches "
          f"({s['tokens'] / max(s['dispatches'], 1):.1f} tok/dispatch), "
          f"{s['host_syncs']} host syncs")
    if server.paged:
        m = server.manager
        print(f"[{tag}] block-pool KV: page={m.page_size} tok, peak "
              f"{m.hwm}/{m.capacity} pages "
              f"({server.kv_bytes_capacity()/1e3:.0f} KB pool, dense slab "
              f"would be resident at 100%)")
    return [tuple(r.output) for r in reqs], server


def main():
    cfg = get_config("qwen2.5-14b").reduced(num_layers=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve] model: {cfg.name} "
          f"({sum(x.size for x in jax.tree.leaves(params))/1e6:.1f}M params)")

    # 1) shared-nothing baseline: weights AND a dense KV slab in device
    #    memory
    base_out, _ = serve_all(model, params, "baseline ", paged=False)

    # 1b) block-pool paged KV (the serving default for dense models):
    #     fixed-size pages allocated on demand, reclaimed on EOS —
    #     identical tokens, KV footprint tracking live tokens
    paged_out, _ = serve_all(model, params, "paged-kv ")
    assert paged_out == base_out, "paged KV must be semantically invisible"

    # 2) FengHuang: stacked layer weights live in the remote tier
    #    (pinned_host); the TensorPager pages them per layer with
    #    lookahead-1 double buffering.  The orchestrator plans the policy
    #    matrix from the config and places the weights.
    print(f"[serve] memory spaces supported: "
          f"{memory.supports_memory_spaces()}")
    paged_cfg = cfg.with_pager(enabled=True, lookahead=1)
    paged_model = build_model(paged_cfg)
    print(f"[serve] policy matrix: {paged_model.mem.describe()}")
    paged_params = dict(params)
    paged_params["layers"] = paged_model.mem.place_layer_weights(
        params["layers"])
    ledger = paged_model.mem.ledger
    resident = ledger.classes(memory.LOCAL)["layer_weights_window"]
    total = ledger.in_use(memory.REMOTE)
    print(f"[serve] FengHuang local window: {resident/1e6:.2f} MB resident "
          f"of {total/1e6:.2f} MB weights "
          f"({100 * memory.capacity_reduction(resident, total):.1f}% "
          f"local-capacity reduction)")
    fh_out, fh_server = serve_all(paged_model, paged_params, "fenghuang")
    assert base_out == fh_out, "paged serving must be semantically invisible"
    print(f"[serve] per-tier residency: {fh_server.tier_stats()}")
    print("[serve] OK — identical tokens with and without paging")

    # 3) NEW scenario — MoE expert paging: expert banks at rest in the
    #    remote tier, decode pages in only the routed top-k rows
    #    (TopKExpertPrefetch).  Single slot => resident expert bytes are
    #    (top_k + 1)/num_experts of the dense expert footprint.
    moe_expert_paging_demo()


def moe_expert_paging_demo():
    cfg = get_config("granite-moe-3b-a800m").reduced(num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [np.asarray([11, 42, 7, 3], np.int32)]

    base_out, _ = serve_all(model, params, "moe-dense", batch_size=1,
                            prompts=prompts)

    ecfg = cfg.with_pager(enabled=True, page_experts=True)
    emodel = build_model(ecfg)
    print(f"[moe] policy matrix: {emodel.mem.describe()}")
    eparams = dict(params)
    eparams["layers"] = emodel.mem.place_layer_weights(params["layers"])
    paged_out, server = serve_all(emodel, eparams, "moe-paged", batch_size=1,
                                  prompts=prompts)
    assert paged_out == base_out, \
        "expert paging must be semantically invisible"

    ledger = emodel.mem.ledger
    dense_bank = ledger.classes(memory.REMOTE)["expert_weights"]
    per_layer_bank = dense_bank // ecfg.num_layers
    resident = ledger.classes(memory.LOCAL)["expert_weights"]
    bound = (ecfg.top_k + 1) / ecfg.padded_experts
    print(f"[moe] expert banks: {dense_bank/1e3:.0f} KB at rest in the "
          f"remote tier; decode keeps {resident/1e3:.0f} KB of one "
          f"layer's {per_layer_bank/1e3:.0f} KB bank resident "
          f"({resident/per_layer_bank:.1%} vs the "
          f"(top_k+1)/num_experts = {bound:.1%} bound)")
    assert resident <= bound * per_layer_bank + 1, \
        (resident, bound * per_layer_bank)
    print("[moe] OK — identical tokens with expert paging, resident "
          "expert bytes within the top-k bound")


if __name__ == "__main__":
    main()
