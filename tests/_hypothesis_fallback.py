"""Minimal stand-in for ``hypothesis`` so the tier-1 suite still runs
where the real package is absent (see requirements-dev.txt for full runs).

Implements just the surface these tests use: ``given`` with keyword
strategies, ``settings(max_examples=..., deadline=...)``, and
``strategies.integers/floats/lists/tuples/sampled_from/booleans/none/
one_of``.  Drawing is deterministic (seeded
PRNG) and always covers the strategy's boundary values first — a fixed
sample sweep, not property search, but the same assertions execute.
"""
from __future__ import annotations

import functools
import inspect
import random

_MAX_EXAMPLES_CAP = 20          # keep the fallback sweep cheap


class _Strategy:
    def __init__(self, edges, draw):
        self.edges = list(edges)     # boundary examples, tried first
        self.draw = draw             # draw(rng) -> random example


class strategies:  # noqa: N801 - mimics the hypothesis module name
    @staticmethod
    def integers(min_value=0, max_value=1 << 16):
        return _Strategy([min_value, max_value],
                         lambda r: r.randint(min_value, max_value))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy([min_value, max_value],
                         lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def tuples(*elements):
        return _Strategy([tuple(s.edges[0] for s in elements),
                          tuple(s.edges[-1] for s in elements)],
                         lambda r: tuple(s.draw(r) for s in elements))

    @staticmethod
    def sampled_from(choices):
        choices = list(choices)
        return _Strategy([choices[0], choices[-1]],
                         lambda r: r.choice(choices))

    @staticmethod
    def booleans():
        return _Strategy([False, True], lambda r: r.random() < 0.5)

    @staticmethod
    def none():
        return _Strategy([None], lambda r: None)

    @staticmethod
    def one_of(*options):
        return _Strategy([s.edges[0] for s in options],
                         lambda r: r.choice(options).draw(r))

    @staticmethod
    def lists(elements, min_size=0, max_size=10, **_kw):
        def draw(r):
            n = r.randint(min_size, max_size)
            return [elements.draw(r) for _ in range(n)]
        edge_elem = elements.edges[0] if elements.edges \
            else elements.draw(random.Random(0))
        edges = [[edge_elem] * min_size] if min_size else [[]]
        edges.append([edge_elem] * max_size)
        return _Strategy(edges, draw)


def settings(max_examples=20, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**strategy_kw):
    def deco(fn):
        sig = inspect.signature(fn)
        kept = [p for name, p in sig.parameters.items()
                if name not in strategy_kw]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            budget = min(getattr(fn, "_fallback_max_examples", 20),
                         _MAX_EXAMPLES_CAP)
            rng = random.Random(0xF36)
            n_edges = max(len(s.edges) for s in strategy_kw.values())
            for j in range(min(n_edges, budget)):
                drawn = {k: s.edges[min(j, len(s.edges) - 1)]
                         for k, s in strategy_kw.items()}
                fn(*args, **drawn, **kwargs)
            for _ in range(budget - min(n_edges, budget)):
                drawn = {k: s.draw(rng) for k, s in strategy_kw.items()}
                fn(*args, **drawn, **kwargs)

        # hide strategy params from pytest's fixture resolution
        wrapper.__signature__ = sig.replace(parameters=kept)
        return wrapper
    return deco
