"""TensorPager: paged execution must be bit-compatible with unpaged."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import memory as pager


@pytest.fixture(scope="module")
def ws():
    rng = np.random.RandomState(0)
    return jnp.asarray(rng.randn(6, 16, 16), jnp.float32) * 0.1


def body(c, w):
    return jnp.tanh(c @ w), c.sum()


def test_supports_memory_spaces():
    assert pager.supports_memory_spaces()


def test_paged_scan_matches_plain(ws):
    c0 = jnp.ones((2, 16))
    ref_c, ref_y = jax.jit(
        lambda c, w: jax.lax.scan(body, c, w))(c0, ws)
    ws_host = pager.host_put(ws)
    got_c, got_y = jax.jit(
        lambda c, w: pager.paged_scan(body, c, w,
                                      config=pager.PagerConfig(enabled=True))
    )(c0, ws_host)
    np.testing.assert_allclose(ref_c, got_c, atol=1e-6)
    np.testing.assert_allclose(ref_y, got_y, atol=1e-6)


def test_paged_scan_disabled_is_plain(ws):
    c0 = jnp.ones((2, 16))
    a = jax.jit(lambda c, w: pager.paged_scan(
        body, c, w, config=pager.PagerConfig(enabled=False)))(c0, ws)
    b = jax.jit(lambda c, w: jax.lax.scan(body, c, w))(c0, ws)
    np.testing.assert_allclose(a[0], b[0], atol=0)


def test_grad_through_paging(ws):
    c0 = jnp.ones((2, 16))

    def loss(c, w):
        out, _ = pager.paged_scan(
            lambda cc, ww: (jnp.tanh(cc @ ww), None), c, w,
            config=pager.PagerConfig(enabled=True))
        return jnp.sum(out ** 2)

    def loss_plain(c, w):
        out, _ = jax.lax.scan(
            lambda cc, ww: (jnp.tanh(cc @ ww), None), c, w)
        return jnp.sum(out ** 2)

    ws_host = pager.host_put(ws)
    g1 = jax.jit(jax.grad(loss, argnums=1))(c0, ws_host)
    g2 = jax.jit(jax.grad(loss_plain, argnums=1))(c0, ws)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)


def test_paged_scan_cache_matches_loop(ws):
    """Cache-in-carry scan == hand-rolled python loop."""
    L = ws.shape[0]
    cache = jnp.zeros((L, 2, 16))

    def cbody(c, w, cl):
        c = jnp.tanh(c @ w + cl.sum() * 0.01)
        return c, cl + 1.0

    c0 = jnp.ones((2, 16))
    got_c, got_cache = jax.jit(lambda c, w, ca: pager.paged_scan_cache(
        cbody, c, w, ca, config=pager.PagerConfig(enabled=False)))(
            c0, ws, cache)

    ref_c, ref_cache = c0, cache
    for i in range(L):
        ref_c, upd = cbody(ref_c, ws[i], ref_cache[i])
        ref_cache = ref_cache.at[i].set(upd)
    np.testing.assert_allclose(got_c, ref_c, atol=1e-6)
    np.testing.assert_allclose(got_cache, ref_cache, atol=1e-6)

    # paged variant agrees too
    ws_host = pager.host_put(ws)
    got2_c, got2_cache = jax.jit(lambda c, w, ca: pager.paged_scan_cache(
        cbody, c, w, ca, config=pager.PagerConfig(enabled=True)))(
            c0, ws_host, cache)
    np.testing.assert_allclose(got2_c, ref_c, atol=1e-6)
    np.testing.assert_allclose(got2_cache, ref_cache, atol=1e-6)


def test_resident_window_bytes(ws):
    per_layer = 16 * 16 * 4
    assert pager.resident_window_bytes(ws, 1) == 2 * per_layer
    assert pager.resident_window_bytes(ws, 3) == 4 * per_layer


def test_page_roundtrip():
    x = jnp.arange(32.0)
    h = pager.page_out(x)
    d = pager.page_in(h)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(x))
