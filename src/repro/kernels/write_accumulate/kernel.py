"""Write-accumulate — the TAB's line-rate in-memory tensor reduction
(§3.3.1) as a Pallas kernel.

N xPU contributions stream through VMEM block-by-block and accumulate into
the shared output buffer in fp32 — the memory-side half of the FengHuang
AllReduce (each device's `write` targets the same address range; the
accumulator applies `+=` at line rate; commutativity means no ordering is
required, which is exactly why a grid-order-agnostic accumulation is
legal).

Grid: (num_blocks, N).  The shard index is the innermost dimension so the
output block stays resident in the VMEM accumulator across contributions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref, acc_ref, *, n_shards: int):
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += x_ref[0].astype(jnp.float32)

    @pl.when(n == n_shards - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def write_accumulate(shards: jax.Array, *, block: int = 512,
                     interpret: bool = False) -> jax.Array:
    """shards: (N, rows, cols) -> (rows, cols) elementwise sum."""
    n, rows, cols = shards.shape
    block = min(block, rows)
    assert rows % block == 0, (rows, block)
    grid = (rows // block, n)

    return pl.pallas_call(
        functools.partial(_kernel, n_shards=n),
        grid=grid,
        in_specs=[pl.BlockSpec((1, block, cols), lambda i, j: (j, i, 0))],
        out_specs=pl.BlockSpec((block, cols), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), shards.dtype),
        scratch_shapes=[pltpu.VMEM((block, cols), jnp.float32)],
        interpret=interpret,
    )(shards)
