"""jit'd wrapper for paged decode attention + cache pool management."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


@functools.partial(jax.jit, static_argnames=("interpret",))
def attend(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
           page_table: jax.Array, seq_lens: jax.Array, *,
           interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, G, d) single decode token -> (B, Hkv, G, d)."""
    return paged_attention(q, k_pages, v_pages, page_table, seq_lens,
                           interpret=interpret)


def attend_ref(q, k_pages, v_pages, page_table, seq_lens):
    return paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens)


class PagePool:
    """Host-side page allocator for the paged KV cache.

    Sequences own lists of fixed-size pages from a global pool — the
    FengHuang remote tier holds the pool; per-sequence page tables are the
    prefetcher's routing metadata."""

    def __init__(self, num_pages: int, page_size: int, kv_heads: int,
                 head_dim: int, dtype=jnp.bfloat16):
        self.page_size = page_size
        self.k = jnp.zeros((num_pages, page_size, kv_heads, head_dim), dtype)
        self.v = jnp.zeros((num_pages, page_size, kv_heads, head_dim), dtype)
        self.free = list(range(num_pages - 1, 0, -1))   # page 0 = null page
        self.tables: dict[int, list[int]] = {}
        self.lens: dict[int, int] = {}

    def alloc_seq(self, uid: int) -> None:
        self.tables[uid] = []
        self.lens[uid] = 0

    def append(self, uid: int, k_tok: jax.Array, v_tok: jax.Array) -> None:
        """k_tok/v_tok: (kv_heads, head_dim) — one token's KV."""
        pos = self.lens[uid]
        if pos % self.page_size == 0:
            if not self.free:
                raise MemoryError("page pool exhausted")
            self.tables[uid].append(self.free.pop())
        page_id = self.tables[uid][-1]
        slot = pos % self.page_size
        self.k = self.k.at[page_id, slot].set(k_tok)
        self.v = self.v.at[page_id, slot].set(v_tok)
        self.lens[uid] = pos + 1

    def free_seq(self, uid: int) -> None:
        self.free.extend(self.tables.pop(uid, []))
        self.lens.pop(uid, None)

    def batch_tables(self, uids: list[int], n_pages: int) -> jax.Array:
        out = []
        for u in uids:
            t = self.tables.get(u, [])
            out.append(t[:n_pages] + [0] * max(0, n_pages - len(t)))
        return jnp.asarray(out, jnp.int32)

    def batch_lens(self, uids: list[int]) -> jax.Array:
        return jnp.asarray([self.lens.get(u, 0) for u in uids], jnp.int32)
