"""Hardware constants.

Two families live here:

* ``PAPER_*`` — the constants FengHuang's own analysis uses (Table 3.1,
  Table 4.1/4.2, §3.3.3).  These feed the faithful simulator/analysis.
* ``TPU_V5E`` — the roofline target for the JAX/Pallas system half
  (per-chip peaks used by ``benchmarks/roofline.py``).
"""
from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# Paper constants (FengHuang §3.3.3, Table 3.1, Table 4.1/4.2)
# ---------------------------------------------------------------------------

#: Table 3.1 — minimal operation latency components, nanoseconds (2KB data).
PAPER_LATENCY_COMPONENTS_NS = {
    "read": {
        "cmd_gpu_to_fh": 40,
        "cmd_processing": 10,
        "cmd_fh_to_hbm": 40,
        "hbm_read": 50,
        "data_hbm_to_fh": 40,
        "data_fh_to_gpu": 40,
    },
    "write": {  # post-write scheme
        "cmd_and_data_gpu_to_fh": 40,
        "cmd_processing": 10,
        "completion_fh_to_gpu": 40,
    },
    "atomic_completion": {"notification": 40},
}

#: Totals implied by Table 3.1 (ns).
PAPER_READ_LATENCY_NS = 220.0
PAPER_WRITE_LATENCY_NS = 90.0
PAPER_WRITE_ACCUM_LATENCY_NS = 90.0
PAPER_COMPLETION_NOTIFICATION_NS = 40.0

#: NVLink reference latencies used in §3.3.3 ("measured in real systems").
PAPER_NVLINK_READ_LATENCY_NS = 1000.0
PAPER_NVLINK_WRITE_LATENCY_NS = 500.0

#: Link bandwidths (§3.3.3).  NVLink 4.0 per-direction; FengHuang crossbar
#: per-GPU.  The paper's Enabler-2 bandwidth ratio uses 4000/450 = 8.89x.
PAPER_NVLINK_BW_GBPS = 450.0           # GB/s uni-directional per GPU
PAPER_FH_CROSSBAR_BW_GBPS = 4800.0     # GB/s bi-directional crossbar per GPU
PAPER_FH_EFFECTIVE_BW_GBPS = 4000.0    # GB/s "factoring in typical hw efficiency"

#: Evaluation sweep of remote-memory bandwidth (Figure 4.1), TB/s.
PAPER_REMOTE_BW_SWEEP_TBPS = (4.0, 4.8, 5.6, 6.4)

#: Baseline8 node (Table 4.1/4.2).
PAPER_BASELINE_NUM_GPUS = 8
PAPER_H200_HBM_BW_TBPS = 4.8           # per GPU
PAPER_H200_HBM_CAP_GB = 144.0          # per GPU
PAPER_H200_BF16_TFLOPS = 989.0         # H200 dense bf16 (no sparsity)

#: FengHuang node (Table 4.1): 4 GPUs, each 1.33x H200 compute and
#: 1.5x / 2.0x local HBM bandwidth.
PAPER_FH_NUM_GPUS = 4
PAPER_FH_COMPUTE_SCALE = 1.33
PAPER_FH_LOCAL_BW_SCALE = {"FH4-1.5xM": 1.5, "FH4-2.0xM": 2.0}
PAPER_FH_REMOTE_CAP_GB = 1152.0

# ---------------------------------------------------------------------------
# TPU v5e roofline target (per chip) — used by the systems half.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_bf16_flops: float      # FLOP/s
    hbm_bw: float               # bytes/s
    ici_link_bw: float          # bytes/s per link
    hbm_capacity: float         # bytes
    vmem_capacity: float        # bytes


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    hbm_capacity=16 * 1024**3,
    vmem_capacity=128 * 1024**2,
)

#: MXU-friendly tiling quanta.
MXU_DIM = 128
VPU_LANES = 128
VPU_SUBLANES = 8


def dtype_bytes(dtype_str: str) -> float:
    return {
        "float32": 4.0, "f32": 4.0,
        "bfloat16": 2.0, "bf16": 2.0,
        "float16": 2.0, "f16": 2.0,
        "int8": 1.0, "s8": 1.0, "fp8": 1.0,
        "int32": 4.0, "s32": 4.0,
    }[dtype_str]
