"""PageSwapper: batched KV-page transfers between the device block pool
and the remote tier — the mechanism behind page-granular preemption.

Swapping a victim sequence out gathers its live pages from the stacked
device pools in ONE batched take per pool, moves the bytes to the remote
tier (host-resident stash on every backend; on CPU local == remote and
the copy degenerates to a host copy with identical semantics), and hands
back an opaque :class:`SwapHandle`.  Swapping back in scatters the
stashed pages into freshly allocated page ids with one donated dispatch
per pool pair — bucketed to a power-of-two page count so executables
stay O(log pool) over a server's lifetime.

Every transfer is a *fallible, bounded-latency* operation: it runs
through :func:`repro.memory.tiers.transfer_with_retry` (fault-injection
checkpoint, retry with exponential backoff, timeout) and reports its
duration to an optional :class:`repro.runtime.ft.StragglerMonitor` so
slow tier transfers are flagged.  Stashed bytes are ledger-accounted
per tier under the ``kv_swap`` tensor class, and every stash movement
(swap-out, swap-in, :meth:`PageSwapper.park` to the cold tier,
:meth:`PageSwapper.promote` back up) charges the ledger's tier-edge
transfer model.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.memory import tiers
from repro.memory.accounting import MemoryLedger


@dataclasses.dataclass
class SwapHandle:
    """Remote-tier stash of one sequence's KV pages (host arrays).

    Quantized pools stash their per-slot dequant scales alongside the
    values (``k_scale``/``v_scale``, (L, n, page, Hkv)) so a restore is
    byte-for-byte the pages that were swapped out — the quantized
    preemption bit-identity contract.

    A DEFERRED stash (``swap_out(..., defer=True)``) holds the staged
    copy as device arrays behind ``_pull`` until someone actually reads
    the bytes (snapshot serialization, swap-in) — callers touching
    ``k``/``v`` directly must :meth:`materialize` first.  Accounting and
    fault injection are NOT deferred: the stash's bytes joined the
    remote-tier ledger line and its transfer slot fired when it was
    created.

    ``tier`` names the hierarchy level the stash currently occupies
    (``remote`` at creation; ``cold`` after
    :meth:`PageSwapper.park` demotes a long-idle stash).  Moving a
    stash between tiers never touches the bytes — only accounting and
    the modeled transfer cost move — so a cold-parked stash restores
    bit-identically."""

    page_count: int
    k: np.ndarray | None     # (L, n, page, Hkv, hd)
    v: np.ndarray | None
    nbytes: int
    k_scale: np.ndarray | None = None
    v_scale: np.ndarray | None = None
    _pull: object = None     # () -> [k, v(, k_scale, v_scale)] host pull
    tier: str = tiers.REMOTE

    def materialize(self) -> "SwapHandle":
        """Resolve a deferred stash to host arrays (idempotent)."""
        if self._pull is not None:
            host = self._pull()
            self.k, self.v = host[0], host[1]
            if len(host) > 2:
                self.k_scale, self.v_scale = host[2], host[3]
            self._pull = None
        return self


def _bucket_pages(n: int, quantum: int = 4) -> int:
    b = quantum
    while b < n:
        b *= 2
    return b


class PageSwapper:
    """Batched swap-out/swap-in of block-pool KV pages.

    One instance per server; ``retries``/``backoff_s``/``timeout_s``
    parameterize the transfer contract and ``monitor`` (a
    ``StragglerMonitor``) flags slow transfers.  The swap-in scatter is
    jitted with the pool donated, so restores splice into the live cache
    without copying it.
    """

    def __init__(self, *, ledger: MemoryLedger | None = None,
                 tier: str = tiers.REMOTE, retries: int = 3,
                 backoff_s: float = 0.001, timeout_s: float | None = None,
                 monitor=None, tensor_class: str = "kv_swap"):
        # "kv_swap" for preemption stashes; "kv_handoff" when the same
        # gather/stash machinery stages prefill->decode page handoffs
        # (see repro.runtime.prefill) — separate ledger lines so the two
        # uses of the remote tier stay independently auditable
        self.tensor_class = tensor_class
        self.ledger = ledger
        self.tier = tier
        self.retries = retries
        self.backoff_s = backoff_s
        self.timeout_s = timeout_s
        self.monitor = monitor
        self.swap_outs = 0
        self.swap_ins = 0
        self.parks = 0               # stashes demoted to a colder tier
        self.promotes = 0            # stashes promoted back up
        self.retry_attempts = 0      # failed attempts that were retried
        self.live_handles = 0        # stashes created and not yet released
        # per-tier stash accounting: a swapper's stashes may sit in
        # several hierarchy levels at once (fresh stashes remote,
        # long-idle ones cold-parked)
        self._stash_bytes: dict[str, int] = {}
        self._stash_hwm: dict[str, int] = {}
        self._scatter = jax.jit(self._scatter_fn, donate_argnums=(0,))
        self._gather = jax.jit(self._gather_fn)

    # ----- ledger ------------------------------------------------------------
    def _record(self, tier: str | None = None) -> None:
        if self.ledger is None:
            return
        for t in ([tier] if tier is not None else self._stash_bytes):
            b = self._stash_bytes.get(t, 0)
            self.ledger.record(t, self.tensor_class, b)
            # the stash arena grows on demand: its provisioned capacity
            # is the largest footprint it ever held, keeping the tier's
            # hwm <= capacity invariant auditable
            hwm = max(self._stash_hwm.get(t, 0), b)
            self._stash_hwm[t] = hwm
            self.ledger.record_capacity(t, self.tensor_class, hwm)

    def _account(self, tier: str, delta: int) -> None:
        self._stash_bytes[tier] = self._stash_bytes.get(tier, 0) + delta
        self._record(tier)

    def _transfer(self, fn, *, what: str, nbytes: int):
        before = (tiers.active_fault_plan().failures
                  if tiers.active_fault_plan() else 0)
        try:
            return tiers.transfer_with_retry(
                fn, what=what, nbytes=nbytes, retries=self.retries,
                backoff_s=self.backoff_s, timeout_s=self.timeout_s,
                monitor=self.monitor)
        finally:
            plan = tiers.active_fault_plan()
            if plan is not None:
                self.retry_attempts += plan.failures - before

    # ----- swap out ----------------------------------------------------------
    def _gather_fn(self, cache: dict, pids: jax.Array) -> list[jax.Array]:
        """One fused dispatch for the whole stash gather (the un-jitted
        per-pool ``jnp.take`` chain costs a device round trip per pool,
        which dominates small swaps — e.g. every prefill handoff)."""
        from repro.kernels.paged_attention.ref import gatherable_view

        def take(pool):
            # fp8 pools gather as a uint8 bit-view and bitcast back —
            # bit-preserving, and it keeps the stash gather off XLA:CPU's
            # ~8x-slower fp8 gather kernel
            g = jnp.take(gatherable_view(pool), pids, axis=1)
            if g.dtype != pool.dtype:
                g = jax.lax.bitcast_convert_type(g, pool.dtype)
            return g

        out = [take(cache["k_pages"]), take(cache["v_pages"])]
        if "k_scale" in cache:
            out += [jnp.take(cache["k_scale"], pids, axis=1),
                    jnp.take(cache["v_scale"], pids, axis=1)]
        return out

    def swap_out(self, cache: dict, page_ids: list[int],
                 defer: bool = False, tier: str | None = None) -> SwapHandle:
        """Gather ``page_ids`` from the stacked pools and stash them in
        ``tier`` (the swapper's home tier — normally remote — when not
        given; ``tiers.COLD`` stashes a deep-preemption victim directly
        in the cold tier so the remote tier never holds it); raises
        :class:`tiers.TierTransferError` after the retry budget is
        exhausted (the caller's degradation policy — shed the victim —
        takes over).

        ``defer=True`` keeps the staged copy on device and postpones the
        host byte movement until the stash is read (a handoff adopted
        in-process releases it unread, so the hot path never pays the
        pull).  The transfer SLOT is not deferred: seeded fault/latency
        injection, the straggler monitor and the retry budget all fire
        here, at the same schedule position as an eager swap."""
        tier = self.tier if tier is None else tier
        # bucket the gather width so the jitted executable is reused
        # across nearby page counts (pad with the null page, slice the
        # true count back out on the host)
        n = len(page_ids)
        b = _bucket_pages(n)
        pids = jnp.asarray(list(page_ids) + [0] * (b - n), jnp.int32)
        grab = self._gather(cache, pids)
        quant = "k_scale" in cache
        # per-array bytes (true pages only): a quantized stash mixes
        # int8/fp8 values with bf16 scales, so a single shared itemsize
        # would misaccount
        nbytes = sum(a.size // b * n * a.dtype.itemsize for a in grab)

        def pull():
            return [np.asarray(a[:, :n]) for a in jax.device_get(grab)]

        if defer:
            self._transfer(lambda: None, what="kv_swap_out", nbytes=nbytes)
            handle = SwapHandle(page_count=n, k=None, v=None,
                                nbytes=nbytes, _pull=pull, tier=tier)
        else:
            host = self._transfer(pull, what="kv_swap_out", nbytes=nbytes)
            handle = SwapHandle(page_count=n, k=host[0], v=host[1],
                                nbytes=nbytes,
                                k_scale=host[2] if quant else None,
                                v_scale=host[3] if quant else None,
                                tier=tier)
        self.swap_outs += 1
        self.live_handles += 1
        self._account(tier, nbytes)
        if self.ledger is not None:
            self.ledger.charge_transfer(tiers.LOCAL, tier, nbytes)
        return handle

    # ----- swap in -----------------------------------------------------------
    def _scatter_fn(self, cache: dict, pids: jax.Array, k: jax.Array,
                    v: jax.Array, k_scale: jax.Array | None = None,
                    v_scale: jax.Array | None = None) -> dict:
        from repro.runtime.sharding import maybe_constraint
        from jax.sharding import PartitionSpec as P
        k = maybe_constraint(k, P(None, None, None, "model", None))
        v = maybe_constraint(v, P(None, None, None, "model", None))
        out = dict(cache)
        out["k_pages"] = cache["k_pages"].at[:, pids].set(
            k.astype(cache["k_pages"].dtype))
        out["v_pages"] = cache["v_pages"].at[:, pids].set(
            v.astype(cache["v_pages"].dtype))
        if k_scale is not None:
            sc = P(None, None, None, "model")
            out["k_scale"] = cache["k_scale"].at[:, pids].set(
                maybe_constraint(k_scale, sc))
            out["v_scale"] = cache["v_scale"].at[:, pids].set(
                maybe_constraint(v_scale, sc))
        return out

    def swap_in(self, cache: dict, page_ids: list[int],
                handle: SwapHandle) -> dict:
        """Scatter a stash back into freshly allocated ``page_ids`` (same
        order as the swap-out) and release the stash.  Returns the new
        cache; the old one is donated.  Padding entries (bucketed width)
        point at the null page 0, which no live table ever reads."""
        if len(page_ids) != handle.page_count:
            raise ValueError(f"swap_in got {len(page_ids)} pages for a "
                             f"{handle.page_count}-page stash")
        handle.materialize()
        n = handle.page_count
        cap = _bucket_pages(max(n, 1))
        pids = np.zeros(cap, np.int32)
        pids[:n] = page_ids
        pad = ((0, 0), (0, cap - n)) + ((0, 0),) * (handle.k.ndim - 2)
        k = np.pad(handle.k, pad)
        v = np.pad(handle.v, pad)
        scales = ()
        if handle.k_scale is not None:
            spad = pad[:-1]
            scales = (jnp.asarray(np.pad(handle.k_scale, spad)),
                      jnp.asarray(np.pad(handle.v_scale, spad)))

        def push():
            return self._scatter(cache, jnp.asarray(pids), jnp.asarray(k),
                                 jnp.asarray(v), *scales)

        new_cache = self._transfer(push, what="kv_swap_in",
                                   nbytes=handle.nbytes)
        self.swap_ins += 1
        if self.ledger is not None:
            self.ledger.charge_transfer(handle.tier, tiers.LOCAL,
                                        handle.nbytes)
        self.release(handle)
        return new_cache

    # ----- tier moves ---------------------------------------------------------
    def _move(self, handle: SwapHandle, tier: str, *, what: str) -> SwapHandle:
        """Move a stash between hierarchy levels.  The bytes are never
        touched — a park/promote is a fault-injection checkpoint, a
        per-tier accounting move and a modeled edge charge — so a
        round-tripped stash restores bit-identically by construction.
        Deferred stashes materialize first: cold-parking is the moment
        the bytes must actually leave the device."""
        if handle.tier == tier or not handle.nbytes:
            return handle
        handle.materialize()
        src = handle.tier
        self._transfer(lambda: None, what=what, nbytes=handle.nbytes)
        self._account(src, -handle.nbytes)
        self._account(tier, handle.nbytes)
        if self.ledger is not None:
            self.ledger.charge_transfer(src, tier, handle.nbytes)
        handle.tier = tier
        return handle

    def park(self, handle: SwapHandle, tier: str = tiers.COLD) -> SwapHandle:
        """Demote a stash to a colder tier (default ``cold``) — the
        long-idle-preemption path.  Fallible like any transfer: a
        :class:`tiers.TierTransferError` leaves the stash where it was."""
        h = self._move(handle, tier, what="kv_cold_park")
        self.parks += 1
        return h

    def promote(self, handle: SwapHandle,
                tier: str = tiers.REMOTE) -> SwapHandle:
        """Promote a stash back up the hierarchy (default ``remote`` —
        the promote-through-remote step a cold-parked victim pays before
        its swap-in; resume then charges remote->local as usual)."""
        h = self._move(handle, tier, what="kv_cold_promote")
        self.promotes += 1
        return h

    def adopt(self, handle: SwapHandle) -> None:
        """Account for a stash produced elsewhere (snapshot restore): the
        bytes join this swapper's ledger line for the tier the handle
        says it lives in, as if it had swapped them out itself."""
        self._account(handle.tier, handle.nbytes)
        self.live_handles += 1

    def release(self, handle: SwapHandle) -> None:
        """Drop a stash without restoring it (victim shed / expired
        deadline or lease / restore into a snapshot).  Idempotent: a
        double release — e.g. the lease watchdog racing a snapshot —
        is accounting-neutral."""
        if handle.nbytes:
            self._account(handle.tier, -handle.nbytes)
            handle.nbytes = 0
            self.live_handles -= 1

    @property
    def outstanding_bytes(self) -> int:
        """Stash bytes currently parked anywhere in the hierarchy — the
        leak gauge the chaos harness drives to zero after every
        reclamation (ledger drift zero <=> this is zero after a
        drain)."""
        return sum(self._stash_bytes.values())
