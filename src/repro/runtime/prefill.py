"""Disaggregated prefill: an async prefill engine feeding the decode
engine through KV page handoffs staged in the remote tier.

Monolithic admission (``BatchedServer._admit``) prefills the whole
prompt in one synchronous dispatch between decode blocks, so a long
prompt arriving mid-stream stalls every live decode slot for the full
prefill.  :class:`PrefillEngine` splits serving into two engines that
communicate ONLY through KV pages:

* The **prefill engine** drains the admission backlog asynchronously in
  page-aligned chunks of ``chunk_tokens`` prompt tokens — each
  scheduling round injects at most one chunk of prefill work ahead of
  decode, bounding the decode stall to ``ceil(chunk / block_size)``
  blocks regardless of prompt length.  Chunk continuations resume from
  the request's own pool-resident earlier chunks
  (:meth:`~repro.models.transformer.DenseLM.prefill_paged_chunk`), so a
  chunked prompt is **bit-identical** — logits and pool bytes — to a
  monolithic prefill.
* A completed prefill becomes a transferable :class:`KVHandoff`: the
  page ids (detached from the prefill's pseudo-slot into the
  :class:`~repro.kernels.paged_attention.ops.BlockManager` handoff
  registry — owned by no slot, refcounted by the handoff), the
  quantized page bytes + scales staged through a ledger-accounted
  remote-tier buffer (a ``"kv_handoff"``
  :class:`~repro.memory.swap.PageSwapper`), the first sampled token and
  the request's PRNG key.
* The **decode engine** adopts ready handoffs into free slots with a
  cheap bucketed-delta splice (ownership transfer + ``.at[slot]``
  state writes — never a blocking prefill dispatch); the staged bytes
  are released on adoption because the pages never left the shared
  pool.  The engine boundary runs entirely through the staging
  swapper's gather/scatter contract, so a multi-host deployment only
  has to re-point those transfers at a real remote peer — the
  scheduling, accounting and determinism story is already this one.

Determinism: sampling stays a pure function of ``(seed, uid,
position)`` — the engine samples the first token from
``fold_in(req_key, plen)`` exactly like monolithic admission, and
adoption installs ``req_key`` as the slot key at ``pos = plen`` exactly
like a resume — so disaggregated tokens are bit-identical to the
monolithic server at any temperature, including prefix-shared,
quantized and tensor-parallel serving.

Fairness: a prefill RESERVES its worst-case page count when it STARTS,
and starts are strictly FIFO (the backlog head is never overtaken).
Completions may land out of order — a later short prompt finishes in
fewer chunks — but the earlier long prompt's pages are already
reserved, so it can never be starved by the overtaker.
"""
from __future__ import annotations

import collections
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.memory import tiers as memtiers
from repro.memory.swap import SwapHandle
from repro.models.transformer import sample_tokens


@dataclasses.dataclass
class KVHandoff:
    """A completed prefill in flight between the engines: everything the
    decode engine needs to adopt the sequence without recomputing or
    copying a single KV byte."""

    req: object                      # runtime.serve.Request
    plen: int                        # bucketed prompt length (positions)
    token: int                       # BlockManager handoff-registry token
    # staged page bytes; handle.tier names the hierarchy level the
    # staging buffer occupies (remote — the staging swapper's home tier)
    handle: SwapHandle
    nxt: jax.Array                   # (1, 1) token sampled at
                                     # fold_in(req_key, plen), device
    key: jax.Array                   # (2,) uint32 per-request PRNG key
    pslot: int                       # prefill pseudo-slot (reservation key)
    pages: int                       # page count (stats)
    # lease: stats["blocks"] value past which the staged pages may be
    # reclaimed by the decode server's watchdog (an un-adopted handoff —
    # e.g. its producer crashed — must not pin pool pages forever)
    lease_expiry_block: int = 0

    @functools.cached_property
    def first_token(self) -> int:
        """Host view of the sampled token.  Materialized lazily (and
        cached) so completing a prefill never blocks on the device —
        the sync lands at adoption/snapshot time, after every queued
        engine dispatch is already in flight."""
        return int(jax.device_get(self.nxt)[0, 0])


@dataclasses.dataclass
class _InflightPrefill:
    """A prefill in progress: chunk cursor over the padded prompt."""

    req: object
    slot: int                        # negative pseudo-slot id
    toks: np.ndarray                 # (1, plen) left-padded prompt
    plen: int
    done: int                        # positions already in the pool
    share: bool                      # publishing prefix pages on finish
    key: jax.Array                   # per-request PRNG key (device)


class PrefillEngine:
    """Async chunked prefill engine sharing the decode server's model,
    params, cache, page pool and reservation accounting.

    Prefill work runs in pseudo-slots (negative ids ``-1000 - uid``) of
    the shared :class:`BlockManager` — reservations live in the
    server's ``_reserved`` dict under the pseudo-slot key, so the
    admission/resume page gates and the pressure predicate see engine
    demand exactly like live-slot demand.  ``pump_once`` advances ONE
    chunk of one in-flight prefill (round-robin) per call; the server
    calls it once per scheduling round while decode is live and loops
    it freely when idle.
    """

    def __init__(self, server, *, chunk_tokens: int | None = None,
                 max_inflight: int = 2):
        self.srv = server
        page = server.page_size
        if chunk_tokens is None:
            chunk_tokens = 4 * page
        # page-aligned chunks keep every continuation boundary exact:
        # a chunk's positions start where the previous chunk's pages end
        self.chunk_tokens = max(page, (chunk_tokens // page) * page)
        self.max_inflight = max_inflight
        self.lease_blocks = getattr(server, "handoff_lease_blocks", 64)
        self.inflight: list[_InflightPrefill] = []
        self.ready: collections.deque[KVHandoff] = collections.deque()
        self._rr = 0
        self.staging = server.mem.staging_swapper(
            retries=server._swap_retries,
            timeout_s=server._swap_timeout_s,
            monitor=server.transfer_monitor)
        model = server.model
        vocab, temperature = model.cfg.vocab, server.temperature

        def first_step(params, toks, cache, ptable, req_key, plen):
            """First chunk: prefill into fresh pages; sample the
            prompt's next token from fold_in(req_key, plen) — the SAME
            rule as monolithic admission, so the sampled value is only
            meaningful (and only used) when this chunk is the last."""
            logits, cache = model.prefill_paged(params, toks, cache, ptable)
            k = jax.random.fold_in(req_key, plen)
            return sample_tokens(logits, vocab, temperature, k), cache

        def cont_step(params, toks, cache, done_pages, new_pages, req_key,
                      plen):
            """Chunk continuation (also the prefix-shared first chunk —
            adopted prefix pages ARE completed chunks): attend the
            request's pool-resident earlier pages, write this chunk."""
            logits, cache = model.prefill_paged_chunk(
                params, toks, cache, done_pages, new_pages)
            k = jax.random.fold_in(req_key, plen)
            return sample_tokens(logits, vocab, temperature, k), cache

        self._first_step = server.mem.donating_jit(first_step,
                                                   donate_argnums=(2,))
        self._cont_step = server.mem.donating_jit(cont_step,
                                                  donate_argnums=(2,))

    # ----- intake -------------------------------------------------------------
    def start(self, req) -> None:
        """Begin prefilling ``req`` (caller holds FIFO order and the
        page gate): reserve its worst-case page count under the
        pseudo-slot, adopt any shared prefix pages, set the chunk
        cursor.  Reservation-at-start is the fairness anchor — once
        started, a prefill can always finish and admit."""
        srv = self.srv
        slot = -1000 - req.uid
        srv._reserved[slot] = srv._worst_pages(len(req.prompt),
                                               req.max_new_tokens)
        plen = srv._admit_plen(len(req.prompt), req.max_new_tokens)
        toks = np.zeros((1, plen), np.int32)
        toks[0, plen - len(req.prompt):] = req.prompt        # left-pad
        share = srv.prefix_cache
        if share and srv._under_pressure():
            share = False
            srv.stats["prefix_drops"] += 1
        shared = srv._shared_prefix_pages(toks, plen) if share else []
        if shared:
            srv.manager.adopt(slot, shared)
            srv.stats["prefix_hits"] += 1
            srv.stats["prefix_shared_pages"] += len(shared)
        self.inflight.append(_InflightPrefill(
            req=req, slot=slot, toks=toks, plen=plen,
            done=len(shared) * srv.page_size, share=share,
            key=srv._req_key(req.uid)))

    @property
    def idle(self) -> bool:
        return not self.inflight and not self.ready

    # ----- failure ------------------------------------------------------------
    def crash(self) -> None:
        """This engine's process dies mid-flight (injected via
        ``FaultPlan.crash_prefill_at_chunk`` or called directly by the
        chaos harness).  Its state moves to the decode server's
        watchdog: in-flight prefills' partial pages are ORPHANS
        (garbage — reclaimed and the victims retried immediately),
        staged-but-unadopted handoffs keep their LEASE (complete,
        adoptable pool state another engine might still take) and are
        reclaimed only when it runs out."""
        srv = self.srv
        for inf in self.inflight:
            srv._orphan_prefills.append((inf.slot, inf.req))
        self.inflight.clear()
        while self.ready:
            srv._orphan_handoffs.append(self.ready.popleft())
        srv.stats["engine_crashes"] += 1

    # ----- pump ---------------------------------------------------------------
    def pump_once(self, finished: list) -> bool:
        """Advance ONE chunk of one in-flight prefill (round-robin);
        True if a chunk was dispatched.  A completed prefill is staged
        and moved to ``ready`` for the decode engine to adopt."""
        if not self.inflight:
            return False
        srv = self.srv
        plan = memtiers.active_fault_plan()
        if plan is not None and plan.take_prefill_crash():
            # the crash lands where the chunk would have: "mid-chunk"
            # means the chunk's pages may be partially written — they
            # are treated as garbage either way
            self.crash()
            return True
        inf = self.inflight[self._rr % len(self.inflight)]
        self._rr += 1
        chunk = min(self.chunk_tokens, inf.plen - inf.done)
        try:
            new_ids = srv.manager.ensure(inf.slot, inf.done + chunk)
        except MemoryError:
            # physically out of pages (injected exhaustion window):
            # the reservation guarantees this clears — retry later
            return False
        srv._note_prefill_dispatch(chunk)
        tchunk = jnp.asarray(inf.toks[:, inf.done:inf.done + chunk])
        plen_s = jnp.asarray(inf.plen, jnp.int32)
        with srv._mesh_ctx():
            if inf.done == 0:
                nxt, srv.cache = self._first_step(
                    srv.params, tchunk, srv.cache,
                    jnp.asarray([new_ids], jnp.int32), inf.key, plen_s)
            else:
                done_ids = srv.manager.slot_pages(
                    inf.slot)[:inf.done // srv.page_size]
                nxt, srv.cache = self._cont_step(
                    srv.params, tchunk, srv.cache,
                    jnp.asarray([done_ids], jnp.int32),
                    jnp.asarray([new_ids], jnp.int32), inf.key, plen_s)
        inf.done += chunk
        srv.manager.note_tokens(inf.slot, inf.done)
        srv.stats["prefill_chunks"] += 1
        srv.kv.record()
        srv._note_peak()
        if inf.done >= inf.plen:
            self._complete(inf, nxt, finished)
        return True

    def _complete(self, inf: _InflightPrefill, nxt, finished: list) -> None:
        """Last chunk done: publish prefix pages, stage the page bytes
        through the remote tier, detach the pages into the handoff
        registry and queue the :class:`KVHandoff`."""
        srv = self.srv
        self.inflight.remove(inf)
        req = inf.req
        if inf.share:
            srv._register_prefix(inf.toks, inf.plen, inf.slot)
        pids = srv.manager.slot_pages(inf.slot)
        try:
            with srv._mesh_ctx():
                # deferred: the staged copy stays on device until the
                # handle is actually read (snapshot / real transport) —
                # an in-process adoption releases it unread, so the
                # steady-state path never pays the host round trip.
                # Fault injection and accounting still fire HERE.
                handle = self.staging.swap_out(srv.cache, pids, defer=True)
        except memtiers.TierTransferError as e:
            # degradation: the handoff could not be staged — shed the
            # request with a structured error (the engines survive)
            srv.manager.free_slot(inf.slot)
            srv._reserved.pop(inf.slot, None)
            req.error = {"reason": "handoff_stage_failed", "detail": str(e),
                         "uid": req.uid, "tokens_emitted": 0}
            srv._finalize(req, "shed", finished)
            srv.kv.record()
            return
        token = srv.manager.detach_to_handoff(inf.slot)
        self.ready.append(KVHandoff(
            req=req, plen=inf.plen, token=token, handle=handle,
            nxt=nxt, key=inf.key, pslot=inf.slot, pages=len(pids),
            lease_expiry_block=srv.stats["blocks"] + self.lease_blocks))
        srv.stats["handoffs"] += 1
        srv.kv.record()
