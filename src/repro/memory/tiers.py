"""Memory-tier registry: the FengHuang hierarchy resolved per backend.

Maps the paper's multi-tier shared-memory hierarchy onto JAX memory
kinds, as an ORDERED hierarchy (fastest first):

* **local tier**  = ``memory_kind="device"`` (HBM),
* **remote tier** = the best host-side kind the backend exposes —
  ``pinned_host`` (host DRAM behind the DMA engine; the TAB-attached
  LPDDR6 pool in the paper's node) on GPU/TPU, ``unpinned_host`` on the
  CPU backend (where local == remote, so paging degenerates to the
  identity while keeping every transform's semantics intact),
* **cold tier**   = the capacity backstop (the High-Bandwidth-Flash
  direction in Ma & Patterson): the next distinct host kind after the
  remote tier's, or — on backends exposing only one host kind — the
  SAME kind as remote.  Tiers are logical levels of the hierarchy, not
  memory kinds: on CPU all three share ``unpinned_host``, yet the
  ledger, the swapper and the bandwidth model keep them distinct, so
  the placement/accounting semantics are exactly what a real flash
  tier would see.

Each :class:`Tier` carries a *modeled* ``bandwidth_gbps`` /
``latency_us`` for its link into the hierarchy; tier-edge transfer time
(:meth:`TierRegistry.edge`) goes through the same
:func:`repro.memory.accounting.modeled_transfer_s` formula the Table-4.3
simulator's :class:`~repro.core.latency.LinkModel` uses, so measured
(ledger-charged) and simulated transfer costs stay one code path.

Resolution is cached **per backend** in a :class:`TierRegistry` — unlike
the old module-level ``lru_cache`` in ``core.pager`` it is invalidated
by :func:`reset` (used by tests and by anything that swaps the default
backend mid-process, e.g. ``jax.config.update("jax_platform_name", …)``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.memory.accounting import modeled_transfer_s

# Canonical tier names used across policies, the ledger and BENCH JSON,
# in hierarchy order (fastest/smallest first).
LOCAL = "local"
REMOTE = "remote"
COLD = "cold"
HIERARCHY = (LOCAL, REMOTE, COLD)

LOCAL_KIND = "device"
REMOTE_KIND = "pinned_host"

# Host-side kinds that can back the FengHuang remote tier, best first.
_HOST_KINDS = ("pinned_host", "unpinned_host")

# Modeled per-tier link parameters (bandwidth_gbps, latency_us) — the
# bandwidth of each tier's link into the hierarchy and its access
# latency.  local ~ H200-class HBM; remote ~ the FengHuang TAB crossbar
# slice per GPU (§4.1, 4 TB/s); cold ~ High-Bandwidth-Flash (Ma &
# Patterson: HBM-adjacent bandwidth class, but a real latency gap).
# These are MODEL numbers charged by the ledger, not measurements.
DEFAULT_TIER_LINKS: dict[str, tuple[float, float]] = {
    LOCAL: (4800.0, 0.22),
    REMOTE: (4000.0, 2.0),
    COLD: (64.0, 50.0),
}

try:  # public since jax 0.5
    from jax.sharding import TransferToMemoryKind as _TransferToMemoryKind
except ImportError:  # pragma: no cover - version specific
    try:
        from jax._src.sharding_impls import (
            TransferToMemoryKind as _TransferToMemoryKind)
    except ImportError:
        _TransferToMemoryKind = None


def _link(name: str) -> tuple[float, float]:
    return DEFAULT_TIER_LINKS.get(name, DEFAULT_TIER_LINKS[REMOTE])


@dataclasses.dataclass(frozen=True)
class Tier:
    """One level of the hierarchy: a logical name bound to the memory
    kind that backs it on the current backend (None = unavailable),
    plus the modeled bandwidth/latency of its link into the hierarchy.

    Several tiers may share one memory kind (the CPU degenerate case:
    remote and cold both resolve to ``unpinned_host``) — the logical
    level, not the kind, is what the ledger and policies reason about.
    """

    name: str
    kind: str | None
    bandwidth_gbps: float = 0.0
    latency_us: float = 0.0

    def __post_init__(self) -> None:
        if not self.bandwidth_gbps:
            bw, lat = _link(self.name)
            object.__setattr__(self, "bandwidth_gbps", bw)
            if not self.latency_us:
                object.__setattr__(self, "latency_us", lat)

    @property
    def available(self) -> bool:
        return self.kind is not None


@dataclasses.dataclass(frozen=True)
class TierEdge:
    """The modeled link between two tiers: bandwidth is the bottleneck
    of the two endpoints, latency crosses both interfaces."""

    src: str
    dst: str
    bandwidth_gbps: float
    latency_us: float

    def transfer_s(self, nbytes: int) -> float:
        """Modeled time to move ``nbytes`` across this edge (the same
        formula the simulator's LinkModel uses — one code path)."""
        return modeled_transfer_s(nbytes,
                                  bandwidth_gbps=self.bandwidth_gbps,
                                  latency_us=self.latency_us)


class TierRegistry:
    """Backend-scoped tier resolution.

    ``registry().local`` / ``.remote`` resolve lazily against the
    *current* default backend and are re-resolved after :func:`reset`
    or when the default backend changes — fixing the stale module-level
    ``lru_cache`` the old ``core.pager`` carried."""

    def __init__(self) -> None:
        self._tiers: dict[str, dict[str, Tier]] = {}

    def _backend(self) -> str:
        try:
            return jax.default_backend()
        except Exception:  # pragma: no cover - no backend at all
            return "<none>"

    def _resolve(self, backend: str) -> dict[str, Tier]:
        """Resolve the ORDERED hierarchy (local, remote, cold) against
        the backend's exposed memory kinds.  Backends with fewer
        distinct kinds degenerate cleanly: the cold tier falls back to
        the remote tier's host kind (and on CPU local aliases them too)
        — tiers stay logically distinct even when physically aliased."""
        try:
            kinds = frozenset(
                m.kind for m in jax.devices()[0].addressable_memories())
        except Exception:  # pragma: no cover - platform specific
            kinds = frozenset()
        local = LOCAL_KIND if LOCAL_KIND in kinds else None
        if local is None:
            try:
                local = jax.devices()[0].default_memory().kind
            except Exception:  # pragma: no cover - platform specific
                local = None
        remote = next((k for k in _HOST_KINDS if k in kinds), None)
        # cold: the next distinct host kind after remote's, else remote's
        cold = next((k for k in _HOST_KINDS
                     if k in kinds and k != remote), remote)
        return {LOCAL: Tier(LOCAL, local), REMOTE: Tier(REMOTE, remote),
                COLD: Tier(COLD, cold)}

    def tiers(self) -> dict[str, Tier]:
        backend = self._backend()
        if backend not in self._tiers:
            self._tiers[backend] = self._resolve(backend)
        return self._tiers[backend]

    def hierarchy(self) -> tuple[Tier, ...]:
        """The resolved tiers in hierarchy order, fastest first."""
        return tuple(self.tiers().values())

    def tier(self, name: str) -> Tier:
        t = self.tiers().get(name)
        if t is None:
            raise KeyError(f"unknown tier {name!r}; hierarchy is "
                           f"{[x.name for x in self.hierarchy()]}")
        return t

    def edge(self, src: str, dst: str) -> TierEdge:
        """The modeled link between two tiers.  Unknown names fall back
        to the default link table, so ledger charging never throws on a
        custom tier label."""
        resolved = self.tiers()

        def params(name):
            t = resolved.get(name)
            if t is not None:
                return t.bandwidth_gbps, t.latency_us
            return _link(name)

        (sbw, slat), (dbw, dlat) = params(src), params(dst)
        return TierEdge(src=src, dst=dst,
                        bandwidth_gbps=min(sbw, dbw) or max(sbw, dbw),
                        latency_us=slat + dlat)

    @property
    def local(self) -> Tier:
        return self.tiers()[LOCAL]

    @property
    def remote(self) -> Tier:
        return self.tiers()[REMOTE]

    @property
    def cold(self) -> Tier:
        return self.tier(COLD)

    def reset(self) -> None:
        """Drop every cached resolution (tests; backend swaps)."""
        self._tiers.clear()


_REGISTRY = TierRegistry()


def registry() -> TierRegistry:
    return _REGISTRY


def reset() -> None:
    """Invalidate the process-wide tier registry."""
    _REGISTRY.reset()


def resolved_kind(tier: str) -> str | None:
    """The memory kind backing ``tier`` on this backend (None for a
    tier the backend cannot back — placement degenerates to a no-op)."""
    t = _REGISTRY.tiers().get(tier)
    return t.kind if t is not None else None


def resolved_local_kind() -> str | None:
    """The memory kind backing the local tier on this backend."""
    return _REGISTRY.local.kind


def resolved_remote_kind() -> str | None:
    """The memory kind backing the remote tier on this backend."""
    return _REGISTRY.remote.kind


def resolved_cold_kind() -> str | None:
    """The memory kind backing the cold tier on this backend."""
    return resolved_kind(COLD)


def supports_memory_spaces() -> bool:
    """True if the backend exposes a host memory kind the remote tier can
    live in (distinct from HBM on GPU/TPU; aliased with it on CPU)."""
    return _REGISTRY.remote.available


# ---------------------------------------------------------------------------
# Fault injection: tier transfers as fallible, bounded-latency operations
# ---------------------------------------------------------------------------

class TierTransferError(RuntimeError):
    """A tier transfer failed (injected by a :class:`FaultPlan`, or a
    real backend failure surfaced through the retry wrapper)."""


@dataclasses.dataclass
class FaultPlan:
    """Deterministic (seeded) fault injection for tier transfers.

    Installed process-wide via :func:`install_fault_plan` /
    :func:`fault_plan`; every *eager* tier transfer (``host_put``, the
    PageSwapper's swap copies) consults the active plan before moving
    bytes.  Two injection styles compose:

    * counted — ``fail_first_n`` / ``spike_first_n`` hit the first N
      transfer attempts exactly (reproducible single-fault scenarios);
    * sampled — ``fail_rate`` / ``spike_rate`` draw per attempt from a
      ``numpy`` generator seeded with ``seed``, so a run with the same
      plan and the same transfer sequence injects the same faults.

    ``exhaust_at_block`` arms pool-exhaustion-mid-decode: the serving
    loop asks :meth:`take_pool_exhaustion` once per decode block and, at
    the armed block, steals every free page for ``exhaust_blocks``
    blocks — forcing a real mid-decode ``MemoryError`` and exercising
    the emergency-preemption recovery path.

    ``crash_prefill_at_chunk`` / ``crash_adopt_at_block`` arm
    **engine-crash injection** for disaggregated serving: the prefill
    engine asks :meth:`take_prefill_crash` before every chunk dispatch
    and, at the armed chunk, dies mid-prompt (its in-flight prefills
    and un-adopted handoffs become orphans whose pool pages only the
    server-side lease watchdog can reclaim); the decode engine asks
    :meth:`take_adopt_crash` at every handoff adoption and, at the
    armed block, drops the handoff mid-adoption — the staged pages
    survive in the registry until the handoff's lease expires.
    """

    seed: int = 0
    fail_first_n: int = 0
    fail_rate: float = 0.0
    spike_first_n: int = 0
    spike_rate: float = 0.0
    spike_s: float = 0.05
    exhaust_at_block: int | None = None
    exhaust_blocks: int = 2
    crash_prefill_at_chunk: int | None = None
    crash_adopt_at_block: int | None = None

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)
        self.transfers = 0       # attempts observed
        self.failures = 0        # attempts failed
        self.spikes = 0          # attempts delayed
        self._exhaust_armed = self.exhaust_at_block is not None
        self._prefill_chunks = 0
        self._prefill_crash_armed = self.crash_prefill_at_chunk is not None
        self._adopt_crash_armed = self.crash_adopt_at_block is not None

    def before_transfer(self, what: str, nbytes: int = 0) -> None:
        """Called by the transfer wrapper before each attempt; sleeps for
        an injected latency spike, raises for an injected failure."""
        idx = self.transfers
        self.transfers += 1
        spike = idx < self.spike_first_n or (
            self.spike_rate > 0.0 and self._rng.random() < self.spike_rate)
        if spike:
            self.spikes += 1
            time.sleep(self.spike_s)
        fail = idx < self.fail_first_n or (
            self.fail_rate > 0.0 and self._rng.random() < self.fail_rate)
        if fail:
            self.failures += 1
            raise TierTransferError(
                f"injected transfer failure #{self.failures} "
                f"({what}, attempt {idx}, {nbytes} bytes)")

    def take_pool_exhaustion(self, block: int) -> bool:
        """True exactly once, at the armed decode block (the caller then
        steals the pool's free pages and releases them after
        ``exhaust_blocks`` blocks)."""
        if self._exhaust_armed and block >= self.exhaust_at_block:
            self._exhaust_armed = False
            return True
        return False

    def take_prefill_crash(self) -> bool:
        """Counts prefill chunk dispatches; True exactly once, when the
        armed chunk is about to go out (the prefill engine then dies
        mid-prompt, orphaning its in-flight work)."""
        self._prefill_chunks += 1
        if (self._prefill_crash_armed
                and self._prefill_chunks >= self.crash_prefill_at_chunk):
            self._prefill_crash_armed = False
            return True
        return False

    def take_adopt_crash(self, block: int) -> bool:
        """True exactly once, at the armed decode block's handoff
        adoption (the decode engine then drops the handoff mid-adoption
        without rebinding its pages)."""
        if self._adopt_crash_armed and block >= self.crash_adopt_at_block:
            self._adopt_crash_armed = False
            return True
        return False


_FAULT_PLAN: FaultPlan | None = None


def install_fault_plan(plan: FaultPlan | None) -> FaultPlan | None:
    """Install (or clear, with None) the process-wide fault plan;
    returns the previously installed plan."""
    global _FAULT_PLAN
    prev, _FAULT_PLAN = _FAULT_PLAN, plan
    return prev


def active_fault_plan() -> FaultPlan | None:
    return _FAULT_PLAN


@contextlib.contextmanager
def fault_plan(plan: FaultPlan):
    """Scoped fault injection (chaos tests)."""
    prev = install_fault_plan(plan)
    try:
        yield plan
    finally:
        install_fault_plan(prev)


def check_transfer(what: str, nbytes: int = 0) -> None:
    """Fault-injection checkpoint for one eager tier-transfer attempt."""
    if _FAULT_PLAN is not None:
        _FAULT_PLAN.before_transfer(what, nbytes)


def transfer_with_retry(fn: Callable[[], Any], *, what: str,
                        nbytes: int = 0, retries: int = 3,
                        backoff_s: float = 0.001,
                        timeout_s: float | None = None,
                        monitor=None) -> Any:
    """Run one tier transfer with retry + exponential backoff + timeout.

    ``fn`` performs the actual bytes movement and may raise
    :class:`TierTransferError` (injected or real).  Each attempt's
    duration is reported to ``monitor`` (a
    :class:`repro.runtime.ft.StragglerMonitor`) so slow-but-successful
    transfers are flagged rather than silently absorbed.  An attempt
    exceeding ``timeout_s`` violates the bounded-latency contract and is
    treated as failed (its result is discarded and the transfer
    retried).  After ``retries`` retries the error propagates as
    :class:`TierTransferError` — the caller's graceful-degradation
    policy takes over from there."""
    delay = backoff_s
    last: Exception | None = None
    for attempt in range(retries + 1):
        t0 = time.monotonic()
        try:
            check_transfer(what, nbytes)
            out = fn()
        except TierTransferError as e:
            last = e
        else:
            dt = time.monotonic() - t0
            if monitor is not None:
                monitor.observe(dt)
            if timeout_s is None or dt <= timeout_s:
                return out
            last = TierTransferError(
                f"{what} attempt {attempt} took {dt:.3f}s "
                f"(> timeout {timeout_s:.3f}s)")
        if attempt < retries:
            time.sleep(delay)
            delay *= 2
    raise TierTransferError(
        f"{what} failed after {retries + 1} attempts: {last}") from last


# ---------------------------------------------------------------------------
# Placement primitives
# ---------------------------------------------------------------------------

def tier_sharding(mesh, pspec: P, tier: str) -> NamedSharding:
    """NamedSharding placing data in ``tier`` (any hierarchy level) with
    the memory kind the *current backend* actually exposes — resolved
    through the registry, never hardcoded.  A ``None`` kind (tier not
    backed on this platform) falls back to the backend default, so CPU —
    where local == remote == cold == ``unpinned_host`` — degenerates
    cleanly."""
    kind = _REGISTRY.tiers().get(tier, Tier(tier, None)).kind
    return NamedSharding(mesh, pspec, memory_kind=kind)


def remote_sharding(mesh, pspec: P) -> NamedSharding:
    """NamedSharding in the FengHuang remote tier."""
    return tier_sharding(mesh, pspec, REMOTE)


def local_sharding(mesh, pspec: P) -> NamedSharding:
    return tier_sharding(mesh, pspec, LOCAL)


def to_remote(tree: Any, mesh, pspec_tree: Any) -> Any:
    """Move a pytree of arrays into the remote tier (sharded)."""
    return jax.tree.map(
        lambda x, ps: jax.device_put(x, remote_sharding(mesh, ps)),
        tree, pspec_tree)


def _put_kind(x: jax.Array, kind: str | None) -> jax.Array:
    if kind is None:
        return x
    if isinstance(x, jax.core.Tracer):
        if _TransferToMemoryKind is None:  # pragma: no cover - old jax
            return x
        return jax.device_put(x, _TransferToMemoryKind(kind))
    return jax.device_put(x, x.sharding.with_memory_kind(kind))


def page_in(tree: Any) -> Any:
    """Fetch a pytree from the remote tier into local (device) memory.

    Traceable: inside jit this lowers to an async H2D copy that XLA
    schedules concurrently with unrelated compute (the paging stream).
    """
    return jax.tree.map(lambda x: _put_kind(x, resolved_local_kind()), tree)


def page_out(tree: Any) -> Any:
    """Evict a pytree to the remote tier (write-back)."""
    return jax.tree.map(lambda x: _put_kind(x, resolved_remote_kind()), tree)


def eager_to_tier(tree: Any, tier: str, *, what: str | None = None) -> Any:
    """Eagerly place a pytree in ``tier`` (single-device helper for
    examples/tests; sharded placement goes through :func:`tier_sharding`
    / :func:`to_remote`).

    As an *eager* tier transfer it is a fault-injection checkpoint: an
    installed :class:`FaultPlan` may delay or fail it, and callers with
    a degradation policy (``MemoryOrchestrator.place`` /
    ``place_kv_pool``) catch :class:`TierTransferError`, fall back to
    local residency and record the degradation in
    ``MemoryOrchestrator.degraded``."""
    leaves = [x for x in jax.tree.leaves(tree) if hasattr(x, "nbytes")]
    check_transfer(what or f"eager_to_{tier}",
                   sum(x.nbytes for x in leaves))
    kind = resolved_kind(tier)
    return jax.tree.map(lambda x: _put_kind(jnp.asarray(x), kind), tree)


def eager_to_remote(tree: Any) -> Any:
    """Eagerly place a pytree in the remote tier (fault-checkpointed)."""
    return eager_to_tier(tree, REMOTE, what="host_put")


def host_put(tree: Any) -> Any:
    """Historic name for :func:`eager_to_remote` (kept: it is the eager
    placement primitive every policy's ``place`` rides)."""
    return eager_to_remote(tree)
