"""Serving runtime: fused on-device block decode + continuous batching
over a block-pool paged KV cache.

The decode hot path is ONE dispatch per ``block_size`` tokens: a
``lax.scan`` decode loop (:func:`repro.models.transformer.decode_loop`)
emits a ``(B, block)`` token block with per-slot ``active``/``remaining``
masks, the KV cache and decode state are **donated** into every dispatch
(updated in place, never copied), and the host syncs once per block to
harvest tokens.  On top of it, :class:`BatchedServer` does continuous
batching: requests are admitted into individual slots between blocks —
no batch restart — and slots are recycled the moment a sequence hits EOS
or its token budget.

For models that support it (dense-family transformers with full causal
attention) the KV cache is a **device-resident block page pool** instead
of a dense ``(L, B, Hkv, max_seq, hd)`` slab: fixed-size pages allocated
on demand at block boundaries by a host-side :class:`BlockManager` and
reclaimed on EOS/eviction, with prefill writing straight into freshly
allocated pages and decode attention reading only the pages each slot's
table maps (the Pallas ``paged_attention`` kernel on TPU, its gather
oracle elsewhere).  KV memory then scales with live tokens rather than
``batch × max_seq``, and per-step attention cost with the actual
sequence length — while emitting bit-identical tokens to the dense path.

Three structures close the paged-vs-dense throughput gap:

* **Device-resident page tables.**  The (B, n_pages) table persists in
  ``DecodeState.pages`` across dispatches; the host keeps a byte-exact
  mirror and ships only the per-block *delta* (entries for slots that
  crossed a page boundary, were admitted, or were evicted — an evicted
  row is re-pointed at the null page so the dead slot's frozen-position
  writes stay harmless), applied inside the decode dispatch with one
  scatter.  The table width is
  power-of-two bucketed and the full table is re-transferred only when
  the width changes, so executable count stays O(log max_pages) over a
  server's lifetime (``stats["compiles"]`` / ``stats["table_rebuilds"]``).
* **Async double-buffered dispatch.**  ``run_once`` keeps up to two
  decode blocks in flight: block N+1 is dispatched — page growth folded
  into its delta — before block N's token harvest is synced, so host
  scheduling overlaps device compute instead of serializing
  dispatch→sync→schedule.  Donation keeps exactly two state buffers
  alive.  Speculative page allocation is safe because admission reserves
  every request's worst-case page count up front.
* **Prefix caching.**  Requests whose padded prompts share leading whole
  pages map those table entries to the same physical pages (per-page
  refcounts in :class:`BlockManager`; a prompt-prefix hash index keyed
  by exact token bytes).  Admission then prefills only the suffix —
  bit-identical to a full prefill — cutting both prefill FLOPs and pool
  residency by roughly the share ratio.  Divergence after the shared
  prefix is copy-on-write by construction: the first partial (or
  non-matching) page is always a private page, and shared pages are
  never written after registration.

``serve_step`` (one per-token dispatch) is kept for dry-run lowering and
as the baseline the serving benchmark measures against.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import os
import queue
import threading
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import memory
from repro.memory import MemoryOrchestrator
from repro.memory import tiers as memtiers
from repro.memory.swap import PageSwapper, SwapHandle
from repro.models.base import DecodeState
from repro.models.transformer import (decode_loop, sample_tokens,
                                      vocab_mask_logits)
from repro.runtime.ft import StragglerMonitor
from repro.runtime.sharding import (activate_mesh, gather_tp_mode,
                                    mesh_axis_sizes, replicated)

# Single source of truth for the logits -> token step; the old
# ``serve.sample`` duplicate of ``transformer.sample_tokens`` is gone.
sample = sample_tokens


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    output: list = dataclasses.field(default_factory=list)
    # structured degradation outcome: None on success, else a dict with
    # at least {"reason", "detail"} when the server terminated the
    # request instead of completing it (unrecoverable tier fault, pool
    # exhaustion with no victim, admission rejection, expired deadline,
    # poisoned logits).  ``done`` is set either way.
    error: dict | None = None
    admitted_at_block: int | None = None   # stats["blocks"] at admission
    # TTFT instrumentation, in decode-block units (the server's clock):
    # stats["blocks"] when the request entered the queue and when its
    # first token was produced (admission prefill or handoff adoption)
    submitted_block: int | None = None
    first_token_block: int | None = None
    # SLA deadline, in decode-block units relative to submitted_block:
    # the request is cancelled at whatever lifecycle stage it is in —
    # queued, backlogged, mid-prefill, mid-decode, preempted-and-swapped
    # — once ``deadline_blocks`` blocks elapse without completion.
    # None = no deadline.
    deadline_blocks: int | None = None
    # terminal outcome, stamped exactly once by BatchedServer._finalize:
    # "completed" | "shed" | "rejected" | "expired" (None = in flight)
    outcome: str | None = None
    _pending_counted: bool = dataclasses.field(default=False, repr=False)


@dataclasses.dataclass
class _Preempted:
    """A sequence swapped out of the live batch: its request, the decode
    position it will resume from, the KV stash (``handle.tier`` says
    which hierarchy level it currently occupies), and its per-request
    PRNG key (so resumed sampling is bit-identical)."""

    req: Request
    pos: int
    handle: SwapHandle
    key: np.ndarray                  # (2,) uint32
    # stats["blocks"] when the stash was created — the cold-park sweep's
    # age clock (stash age = blocks - stashed_block)
    stashed_block: int = 0


def make_prefill_step(model) -> Callable:
    def prefill_step(params, tokens, cache, extra=None):
        logits, cache = model.prefill(params, tokens, cache, extra)
        return logits, cache
    return prefill_step


def make_serve_step(model, *, temperature: float = 0.0) -> Callable:
    """One decode step: (params, tokens (B,1), cache, cur_pos, key) ->
    (next_tokens (B,1), logits, cache).  The per-token baseline."""
    vocab = model.cfg.vocab

    def serve_step(params, tokens, cache, cur_pos, key):
        logits, cache = model.decode_step(params, tokens, cache, cur_pos)
        nxt = sample(logits, vocab, temperature, key)
        return nxt, logits, cache
    return serve_step


def make_decode_loop(model, *, block_size: int, temperature: float = 0.0,
                     eos_id: int | None = None, donate: bool = True,
                     detect_nonfinite: bool = False) -> Callable:
    """Jit the fused decode loop with the donation contract: the cache
    (arg 1) and decode state (arg 2) are consumed by every dispatch.

    ``delta`` (optional) is a ``(slots, cols, pids)`` int32 triple of
    page-table updates applied to the device-resident table with ONE
    scatter before the block decodes — the host never re-transfers the
    whole table on the steady-state path.  Padding entries carry an
    out-of-range column and are dropped by the scatter.

    ``detect_nonfinite=True`` (the server's setting) adds the per-slot
    poison mask to the returned tuple — see
    :func:`repro.models.transformer.decode_loop` — so a NaN in one
    sequence's logits sheds that sequence at harvest instead of
    silently corrupting its stream."""
    def loop(params, cache, state, delta=None):
        if delta is not None and state.pages is not None:
            d_slots, d_cols, d_pids = delta
            state = dataclasses.replace(
                state, pages=state.pages.at[d_slots, d_cols].set(d_pids))
        return decode_loop(model, params, cache, state, num_steps=block_size,
                           temperature=temperature, eos_id=eos_id,
                           detect_nonfinite=detect_nonfinite)
    return memory.donating_jit(loop, donate_argnums=(1, 2) if donate else ())


def _bucket(n: int, quantum: int = 8) -> int:
    """Pad lengths to a bucket so admission compiles O(log) shapes."""
    b = quantum
    while b < n:
        b *= 2
    return b


class BatchedServer:
    """Continuous-batching inference server (single process).

    Decode runs in fixed-size fused blocks over a persistent ``batch_size``
    -slot state.  Between blocks, finished slots are recycled and queued
    requests are admitted into the live cache — mid-stream, without
    restarting or re-prefilling the rest of the batch.  Exactly one host
    transfer happens per decoded block (the token-block harvest).

    ``paged`` (default: auto) selects the block-pool paged KV cache when
    the model supports it.  ``num_pages`` sizes the pool — the default
    matches dense capacity (``batch × ceil(max_seq/page)`` plus the null
    page), so admission never blocks; smaller pools oversubscribe and
    engage **page-granular preemption** (``preempt``, default on): when
    the backlog head would starve, victim sequences chosen by
    ``preempt_policy`` (``"lru"`` / ``"fewest_pages"`` /
    ``"lowest_progress"`` / a callable) have their KV pages swapped to
    the remote tier by a :class:`~repro.memory.swap.PageSwapper`, their
    physical pages freed, and are transparently restored — resume-FIFO
    ahead of the backlog — when pages free up again.  Per-slot PRNG keys
    (``fold_in(request key, position)``) make a preempted+resumed
    sequence emit bit-identical tokens to an unpreempted run at any
    temperature.  Tier transfers retry with exponential backoff under an
    installed :class:`~repro.memory.tiers.FaultPlan`; unrecoverable
    faults degrade per policy (victim shed with a structured
    ``Request.error``, prefix sharing dropped under pool pressure,
    injected mid-decode exhaustion recovered by emergency preemption).
    ``audit`` (or ``REPRO_AUDIT=1``) cross-checks the block-pool
    invariants after every scheduling step.

    ``pipeline`` (default on) keeps up to two decode blocks in flight so
    host scheduling overlaps device compute; tokens are bit-identical to
    the serialized loop (the device-side masks decide everything), only
    the block/admission interleaving — and hence sampled tokens of
    requests admitted mid-stream at temperature > 0 — can shift.
    ``prefix_cache`` (default on, paged only) shares prompt-prefix pages
    across requests via per-page refcounts.

    ``prefill_async`` (default off, paged only) disaggregates serving
    into a prefill engine and a decode engine communicating only
    through KV pages staged in the remote tier (see
    :mod:`repro.runtime.prefill`): prompts prefill asynchronously in
    page-aligned ``prefill_chunk_tokens`` chunks and finished prompts
    are adopted as page handoffs, so a long prompt arriving mid-stream
    stalls decode by at most one chunk instead of its whole length —
    with bit-identical tokens at any temperature
    (``stats["decode_stall_blocks_max"]`` /
    ``stats["ttft_p50_blocks"]`` quantify the interference).

    ``mesh`` (default None = single device) turns on tensor-parallel
    serving: params are placed by ``runtime.sharding.named_shardings``
    over the model's ``serving_param_specs()`` (pageable groups in the
    remote tier when the pager is on), the KV cache — dense slab or
    page pools — is sharded over the ``"model"`` axis by KV heads, the
    decode state and page tables are replicated, and every dispatch is
    traced under the mesh so the model-side constraint specs resolve.
    Tokens are bit-identical to the single-device server at any
    temperature **because serving TP is all-gather based**: activations
    are replicated before the attention/MLP output projections and
    those weights stay replicated, so every cross-device transfer is
    pure data movement and every dot runs full-width exactly as on one
    device.  (Partial-sum row-parallel TP is NOT safe here: each
    shard's partial rounds separately and flips greedy ties — that path
    is kept for training only.)  Models without ``serving_param_specs``
    are rejected rather than served with silently diverging tokens.

    ``deterministic=False`` opts OUT of that contract for raw speed:
    the output projections keep their Megatron row-parallel contraction
    shard (plain ``param_specs``) and the all-gather constraints stay
    disarmed, so XLA lowers a partial-sum all-reduce per projection —
    less wire per step on wide models, but each shard's partials round
    separately, so tokens may differ from the single-device server
    (greedy ties can flip).  Single-run determinism is preserved; only
    cross-placement bit-identity is traded away.
    """

    # async prefill engine (repro.runtime.prefill.PrefillEngine) or None
    # (monolithic admission); class defaults so scheduler-only harness
    # subclasses that skip __init__ resolve the monolithic / host-only
    # paths (kv/manager/swapper are rebound by _init_live_state or the
    # harness itself)
    prefill = None
    kv = None
    manager = None
    swapper = None
    # overload admission control (None = unbounded, the pre-SLA
    # behavior): max_pending caps queued+backlogged requests;
    # overload_factor caps the MemoryLedger-projected worst-case page
    # demand (live reservations + pending) at overload_factor x pool
    # capacity — beyond either, submit() returns a fast structured
    # rejection instead of growing the queue
    max_pending: int | None = None
    overload_factor: float | None = None
    # blocks a staged KVHandoff stays adoptable before the lease
    # watchdog may reclaim its pages and re-enqueue the victim
    handoff_lease_blocks: int = 64
    # cold-tier parking of preemption stashes (class default so
    # scheduler-only harnesses that skip __init__ resolve it): None =
    # disabled, 0 = stash victims directly to cold, N > 0 = park
    # stashes older than N decode blocks
    cold_park_after_blocks: int | None = None

    def __init__(self, model, params, *, batch_size: int = 4,
                 max_seq: int = 256, temperature: float = 0.0, seed: int = 0,
                 block_size: int = 8, eos_id: int | None = None,
                 paged: bool | None = None, page_size: int | None = None,
                 num_pages: int | None = None, pipeline: bool = True,
                 prefix_cache: bool = True, mesh=None, preempt: bool = True,
                 preempt_policy="lru", audit: bool | None = None,
                 swap_retries: int = 3, swap_timeout_s: float | None = None,
                 deterministic: bool = True, prefill_async: bool = False,
                 prefill_chunk_tokens: int | None = None,
                 max_pending: int | None = None,
                 overload_factor: float | None = None,
                 handoff_lease_blocks: int = 64,
                 cold_park_after_blocks: int | None = None):
        self.model = model
        self.batch = batch_size
        self.max_seq = max_seq
        self.block_size = block_size
        self.temperature = temperature
        self.eos_id = eos_id
        self.seed = seed
        self._preempt_arg = bool(preempt)
        self.preempt_policy = preempt_policy
        self.audit_every_block = (audit if audit is not None
                                  else os.environ.get("REPRO_AUDIT") == "1")
        self._swap_retries = swap_retries
        self._swap_timeout_s = swap_timeout_s
        self.max_pending = max_pending
        self.overload_factor = overload_factor
        self.handoff_lease_blocks = handoff_lease_blocks
        # cold-tier parking of preemption stashes: None = disabled (the
        # pre-hierarchy behavior, zero drift); 0 = deep preemption —
        # victims stash DIRECTLY to the cold tier (the remote tier never
        # holds them); N > 0 = stashes older than N decode blocks are
        # demoted remote -> cold by the park sweep.  Either way a parked
        # victim promotes back THROUGH the remote tier on resume and
        # decodes bit-identically (tier moves never touch the bytes).
        self.cold_park_after_blocks = cold_park_after_blocks
        if paged is None:
            paged = getattr(model, "supports_paged_kv", lambda: False)()
        self.paged = bool(paged)
        if prefill_async and not self.paged:
            raise ValueError("prefill_async requires the paged KV cache "
                             "(the engines hand off pool pages)")
        self._prefill_async = bool(prefill_async)
        self._prefill_chunk_tokens = prefill_chunk_tokens
        # the model's orchestrator (shared ledger: weight windows, expert
        # residency and KV pool report into one per-tier accounting);
        # models without one get a fresh plan from their config.
        self.mem: MemoryOrchestrator = (
            getattr(model, "mem", None) or MemoryOrchestrator.plan(model.cfg))
        self.deterministic = bool(deterministic)
        # validate BEFORE binding: a rejected mesh must not leave the
        # model's shared orchestrator/ledger in sharded mode
        spec_fn = None
        if mesh is not None:
            model.cfg.assert_mesh_compatible(mesh_axis_sizes(mesh))
            if self.deterministic:
                spec_fn = getattr(model, "serving_param_specs", None)
                if spec_fn is None:
                    raise ValueError(
                        f"{type(model).__name__} does not expose "
                        f"serving_param_specs; its family is not wired for "
                        f"the all-gather-TP serving placement, and serving "
                        f"it over a mesh would emit silently diverging "
                        f"tokens (partial-sum rounding)")
            else:
                # opt-in Megatron row-parallel serving: wo stays
                # contraction-sharded, partial sums all-reduce
                spec_fn = model.param_specs
        self.mesh = mesh
        self.mem.bind_mesh(mesh)
        try:
            self._init_live_state(model, params, spec_fn, batch_size,
                                  max_seq, seed, page_size, num_pages,
                                  pipeline, prefix_cache, mesh)
        except BaseException:
            # ANY post-bind construction failure (param tree mismatch,
            # placement error, cache init) must not leave the model's
            # shared orchestrator/ledger in sharded mode
            self.mem.bind_mesh(None)
            raise

    def _init_sched_state(self, batch_size: int) -> None:
        """Pure-host scheduler state: queues, reservations, outcome and
        lifecycle bookkeeping, stats.  Split out of the device-touching
        construction so the scheduler-only test harnesses (which skip
        ``__init__`` and fake the device steps) initialize EXACTLY the
        state the real scheduler methods touch — one source of truth
        for what the scheduler needs."""
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self._backlog: collections.deque[Request] = collections.deque()
        self._uid = 0
        self._preempted: list[_Preempted] = []   # resume-FIFO
        self._reserved: dict[int, int] = {}    # slot -> worst-case pages
        self._planned = [0] * batch_size       # in-flight decode tokens
        self._pool_fault = False       # mid-decode exhaustion latched
        self._fault_release_block: int | None = None
        self._fault_slot = -1          # phantom slot holding stolen pages
        self._sched_counter = 0
        self._last_sched = [0] * batch_size      # for the LRU policy
        self._peak_pages = -1
        self.tiers_peak: dict = {}
        # request-lifecycle robustness state: slots whose harvest hit
        # non-finite logits (slot -> poisoned request), orphaned prefill
        # pseudo-slots and un-adopted handoffs left behind by an engine
        # crash (reclaimed by the lease watchdog), and the admission-
        # control view of not-yet-started demand
        self._poisoned: dict[int, Request] = {}
        self._orphan_prefills: list[tuple[int, Request]] = []
        self._orphan_handoffs: list = []         # KVHandoff
        self._pending_count = 0
        self._pending_pages = 0
        self._pending_lock = threading.Lock()
        # decode-stall accounting: prompt tokens dispatched synchronously
        # ahead of pending decode work since the last decode dispatch —
        # folded into decode_stall_blocks_* at the next dispatch
        self._stall_tokens = 0
        self._ttft_samples: list[int] = []
        self._e2e_samples: list[int] = []
        self.stats = {"steps": 0, "tokens": 0, "batches": 0, "blocks": 0,
                      "dispatches": 0, "admitted": 0, "host_syncs": 0,
                      "kv_pages_in_use": 0, "kv_pages_hwm": 0,
                      "compiles": 0, "table_rebuilds": 0,
                      "table_delta_entries": 0, "prefix_hits": 0,
                      "prefix_shared_pages": 0,
                      "preemptions": 0, "resumes": 0, "sheds": 0,
                      "cold_parks": 0, "cold_promotes": 0,
                      "preempted_pages": 0, "pool_faults": 0,
                      "prefix_drops": 0, "swap_retries": 0,
                      "slow_transfers": 0, "audits": 0,
                      "model_shards": getattr(getattr(self, "mem", None),
                                              "model_shards", 1),
                      "prefill_chunks": 0, "handoffs": 0,
                      "decode_stall_blocks_max": 0,
                      "decode_stall_blocks_total": 0,
                      "ttft_p50_blocks": 0.0, "ttft_p99_blocks": 0.0,
                      "completed": 0, "rejected": 0, "expired": 0,
                      "poison_sheds": 0, "engine_crashes": 0,
                      "lease_reclaims": 0, "crash_requeues": 0,
                      "e2e_p50_blocks": 0.0, "e2e_p99_blocks": 0.0}

    def _init_live_state(self, model, params, spec_fn, batch_size, max_seq,
                         seed, page_size, num_pages, pipeline, prefix_cache,
                         mesh) -> None:
        """Everything after the mesh is bound: placement, jit entry
        points, caches, slot state (split out so __init__ can unbind the
        mesh if any of it fails)."""
        self._init_sched_state(batch_size)
        if spec_fn is not None:
            # serving placement: all-gather TP (output projections
            # replicated) so sharded tokens are bit-identical — see
            # DenseLM.serving_param_specs.  deterministic=False keeps
            # the training-layout row-parallel shards instead.
            params = self.mem.place_params(params, spec_fn())
        self.params = params
        self.pipeline = bool(pipeline)
        self.max_inflight = 2 if self.pipeline else 1
        self.prefix_cache = bool(prefix_cache)
        self._decode_loop = make_decode_loop(
            model, block_size=self.block_size, temperature=self.temperature,
            eos_id=self.eos_id, detect_nonfinite=True)
        self._admit_step = self.mem.donating_jit(self._make_admit_step(),
                                                 donate_argnums=(2, 3))
        self._admit_step_prefix = None
        # live slot state — donated through every dispatch
        if self.paged:
            cfg = model.cfg
            self.page_size = page_size or cfg.page_size
            per_seq = -(-max_seq // self.page_size)
            self.num_pages = num_pages or batch_size * per_seq + 1
            self.kv = self.mem.block_pool(self.num_pages, self.page_size)
            self.manager = self.kv.manager
            quantized = bool(getattr(cfg, "kv_quantized", False))
            pool_dt = (cfg.kv_pool_dtype() if quantized else cfg.dtype)
            self.kv.bind_kv_shape(cfg.padded_kv_heads, cfg.head_dim,
                                  jnp.dtype(pool_dt).itemsize,
                                  cfg.num_layers,
                                  scale_itemsize=2 if quantized else 0)
            self.cache = self.mem.place_kv_pool(
                model.init_paged_cache(self.num_pages, self.page_size),
                specs=(model.paged_cache_specs() if mesh is not None
                       else None))
            self._admit_step_prefix = self.mem.donating_jit(
                self._make_admit_step_prefix(), donate_argnums=(2, 3))
            # persistent device-resident page table: starts at the
            # canonical width-1 null table; the host mirror below tracks
            # its exact device contents so block deltas can be computed
            # without ever re-reading (or re-sending) the whole table
            self._table_w = 1
            self._narrow_blocks = 0
            self._mirror = np.zeros((batch_size, 1), np.int32)
            init_pages = self._dev(jnp.asarray(self._mirror))
        else:
            self.kv = None
            self.manager = None
            # dense slab: resident at full size regardless of occupancy
            # (capacity == residency), in the kv_pool policy's tier;
            # per-shard bytes under a mesh (heads axis "model"-sharded)
            self.cache = self.mem.place_kv_pool(
                model.init_cache(batch_size, max_seq),
                specs=(model.cache_specs() if mesh is not None else None))
            self.mem.ledger.record(
                self.mem.policies["kv_pool"].tier, "kv_pool",
                self.mem.placed_bytes(self.cache))
            init_pages = None
        # per-request PRNG: every request uid gets fold_in(base, uid);
        # the token at sequence position q is sampled from
        # fold_in(request_key, q), making sampling a pure function of
        # (seed, uid, position) — invariant under preemption, resume,
        # snapshot/restore and scheduling order
        self._base_key = jax.random.PRNGKey(seed)
        self.state = DecodeState.init(
            batch_size, jax.random.PRNGKey(seed), pages=init_pages,
            slot_keys=jnp.zeros((batch_size, 2), jnp.uint32))
        if mesh is not None:
            # decode state is host-mirrored bookkeeping: replicate it
            self.state = jax.device_put(self.state, replicated(mesh))
        self.slots: list[Request | None] = [None] * batch_size
        self._slot_pos = [0] * batch_size      # host mirror of state.pos
        # preemption / fault-recovery state (paged only)
        self.preempt_enabled = self._preempt_arg and self.paged
        self.transfer_monitor = StragglerMonitor(factor=3.0)
        self.swapper = (PageSwapper(ledger=self.mem.ledger,
                                    retries=self._swap_retries,
                                    timeout_s=self._swap_timeout_s,
                                    monitor=self.transfer_monitor)
                        if self.paged else None)
        # disaggregated prefill/decode: the async prefill engine drains
        # the backlog in chunks and hands finished prompts to decode as
        # KV page handoffs (see repro.runtime.prefill)
        self.prefill = None
        if self._prefill_async:
            from repro.runtime.prefill import PrefillEngine
            self.prefill = PrefillEngine(
                self, chunk_tokens=self._prefill_chunk_tokens)

            def adopt_step(state, nxt, slot, plen, remaining, key):
                """Handoff adoption splice, fused into one dispatch
                (the un-jitted ``.at[].set`` chain costs ~5 tiny device
                round trips per adoption — measurable at smoke scale)."""
                return dataclasses.replace(
                    state,
                    tokens=state.tokens.at[slot, 0].set(nxt[0, 0]),
                    pos=state.pos.at[slot].set(plen),
                    active=state.active.at[slot].set(True),
                    remaining=state.remaining.at[slot].set(remaining),
                    slot_keys=state.slot_keys.at[slot].set(key))

            self._adopt_step = self.mem.donating_jit(adopt_step,
                                                     donate_argnums=(0,))

    # ----- mesh plumbing -----------------------------------------------------
    def _mesh_ctx(self):
        """Ambient-mesh context for every trace/dispatch, with the
        all-gather-TP constraints armed (they belong to the serving
        placement ONLY — other mesh users like the dry-run keep the
        Megatron row-parallel lowering).  No-op context single-device."""
        if self.mesh is None:
            return contextlib.nullcontext()
        stack = contextlib.ExitStack()
        stack.enter_context(activate_mesh(self.mesh))
        if self.deterministic:
            stack.enter_context(gather_tp_mode())
        return stack

    def _dev(self, x: jax.Array) -> jax.Array:
        """Pin a host-built array (page tables, deltas) to its
        steady-state placement: replicated on the mesh, so dispatches see
        one consistent input sharding instead of compiling an extra
        executable for the uncommitted first transfer."""
        if self.mesh is None:
            return x
        return jax.device_put(x, replicated(self.mesh))

    # ----- request intake ----------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32, *,
               deadline_blocks: int | None = None) -> Request:
        """Enqueue a request.  ``deadline_blocks`` (optional) is an SLA
        TTL in decode-block units: once that many blocks elapse without
        completion the request is cancelled at whatever stage it is in
        and finishes with ``outcome == "expired"``.

        Under overload admission control (``max_pending`` /
        ``overload_factor``) a request the server cannot credibly serve
        is REJECTED here — returned immediately with ``done`` set,
        ``outcome == "rejected"`` and a structured ``error`` — instead
        of joining an unbounded queue."""
        prompt = np.asarray(prompt, np.int32)
        # validate HERE so the caller sees the error; a raise mid-admission
        # would drop an already-dequeued request with done never set
        if len(prompt) + max(max_new_tokens - 1, 0) > self.max_seq:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens})"
                f" exceeds max_seq={self.max_seq}")
        worst = 0
        if self.paged:
            worst = self._worst_pages(len(prompt), max_new_tokens)
            if worst > self.manager.capacity:
                raise ValueError(
                    f"request needs up to {worst} KV pages but the pool "
                    f"only has {self.manager.capacity}")
        self._uid += 1
        req = Request(self._uid, prompt, max_new_tokens=max_new_tokens)
        req.submitted_block = self.stats["blocks"]
        req.deadline_blocks = deadline_blocks
        overload = self._admission_gate(req, worst)
        if overload is not None:
            req.error = {"reason": "admission_rejected", "detail": overload,
                         "uid": req.uid, "tokens_emitted": 0}
            self._finalize(req, "rejected")
            return req
        self.queue.put(req)
        return req

    # ----- request lifecycle: outcomes, deadlines, overload control -----------
    # terminal outcome -> stats counter it increments
    _OUTCOME_KEYS = {"completed": "completed", "shed": "sheds",
                     "rejected": "rejected", "expired": "expired"}

    def _finalize(self, req: Request, outcome: str,
                  finished: list[Request] | None = None) -> None:
        """The ONE terminal transition of a request: stamp its outcome,
        release its admission-control accounting, count it, sample e2e
        latency (completions only) and set ``done``.  Idempotent — every
        cancellation path funnels here, so racing paths (e.g. a lease
        reclaim against a deadline sweep) can never double-count."""
        if req.outcome is not None:
            return
        req.outcome = outcome
        self._pending_remove(req)
        self.stats[self._OUTCOME_KEYS[outcome]] += 1
        if outcome == "completed" and req.submitted_block is not None:
            self._e2e_samples.append(self.stats["blocks"]
                                     - req.submitted_block)
        req.done.set()
        if finished is not None:
            finished.append(req)

    def _admission_gate(self, req: Request, worst: int) -> str | None:
        """Overload admission control, one lock hold: accept (count the
        request into the pending demand view and return None) or return
        the structured-rejection detail.  The page term projects the
        ledger-backed worst case — live reservations plus every
        not-yet-started request's worst-case page need — against
        ``overload_factor x`` pool capacity: demand beyond that cannot
        make its deadline anyway, so rejecting it FAST keeps the
        admitted requests' tail latency bounded."""
        with self._pending_lock:
            if (self.max_pending is not None
                    and self._pending_count >= self.max_pending):
                return (f"pending requests at max_pending="
                        f"{self.max_pending}")
            if (self.overload_factor is not None and self.paged
                    and self.manager is not None):
                projected = (sum(self._reserved.values())
                             + self._pending_pages + worst)
                budget = self.overload_factor * self.manager.capacity
                if projected > budget:
                    return (f"projected worst-case demand {projected} pages"
                            f" > {budget:.0f} "
                            f"(overload_factor={self.overload_factor} x "
                            f"capacity {self.manager.capacity})")
            req._pending_counted = True
            self._pending_count += 1
            self._pending_pages += worst
            return None

    def _pending_add(self, req: Request) -> None:
        """(Re-)count a not-yet-started request into the admission-
        control demand view (crash requeue, restore, admission
        rollback).  Flag-guarded: never double-counts."""
        with self._pending_lock:
            if not req._pending_counted:
                req._pending_counted = True
                self._pending_count += 1
                if self.paged and self.manager is not None:
                    self._pending_pages += self._worst_pages(
                        len(req.prompt), req.max_new_tokens)

    def _pending_remove(self, req: Request) -> None:
        with self._pending_lock:
            if req._pending_counted:
                req._pending_counted = False
                self._pending_count -= 1
                if self.paged and self.manager is not None:
                    self._pending_pages -= self._worst_pages(
                        len(req.prompt), req.max_new_tokens)

    def _deadline_passed(self, req: Request) -> bool:
        return (req.deadline_blocks is not None
                and req.submitted_block is not None
                and self.stats["blocks"]
                >= req.submitted_block + req.deadline_blocks)

    def _expire_req(self, req: Request, finished: list[Request],
                    stage: str) -> None:
        req.error = {"reason": "deadline_expired",
                     "detail": f"deadline of {req.deadline_blocks} blocks "
                               f"passed while {stage}",
                     "uid": req.uid, "tokens_emitted": len(req.output)}
        self._finalize(req, "expired", finished)

    def _expiry_stall(self) -> bool:
        """A LIVE slot past its deadline stalls dispatch so the pipeline
        drains before eviction — evicting a slot with a later block in
        flight and re-admitting into it would mis-attribute that block's
        harvested tokens to the new occupant."""
        return any(r is not None and self._deadline_passed(r)
                   for r in self.slots)

    def _expire_sweep(self, finished: list[Request], drained: bool) -> None:
        """Cancel every expired request at whatever lifecycle stage it
        is in — backlog, swapped-out victim, mid-prefill, staged
        handoff, and (only with the pipeline drained) live decode slot —
        reclaiming its pages so ``audit()`` stays clean."""
        if self._backlog and any(self._deadline_passed(r)
                                 for r in self._backlog):
            keep: collections.deque = collections.deque()
            for req in self._backlog:
                if self._deadline_passed(req):
                    self._expire_req(req, finished, "backlogged")
                else:
                    keep.append(req)
            self._backlog = keep
        for ps in list(self._preempted):
            if self._deadline_passed(ps.req):
                self._preempted.remove(ps)
                if self.swapper is not None and ps.handle is not None:
                    self.swapper.release(ps.handle)
                self._expire_req(ps.req, finished, "preempted")
        eng = self.prefill
        if eng is not None:
            for inf in list(eng.inflight):
                if self._deadline_passed(inf.req):
                    eng.inflight.remove(inf)
                    self.manager.free_slot(inf.slot)
                    self._reserved.pop(inf.slot, None)
                    self._expire_req(inf.req, finished, "mid-prefill")
                    self.kv.record()
            for h in list(eng.ready):
                if self._deadline_passed(h.req):
                    eng.ready.remove(h)
                    self.manager.release_handoff(h.token)
                    self._reserved.pop(h.pslot, None)
                    eng.staging.release(h.handle)
                    self._expire_req(h.req, finished, "staged for handoff")
                    self.kv.record()
        if drained:
            for i, req in enumerate(self.slots):
                if req is not None and self._deadline_passed(req):
                    self._evict_slot(i)
                    self._expire_req(req, finished, "decoding")
                    if self.kv is not None:
                        self.kv.record()

    def _requeue(self, req: Request, finished: list[Request]) -> None:
        """Put an engine-crash victim back at the FRONT of the backlog
        (it is older than everything queued behind it) — unless its
        deadline already passed, in which case the retry would be dead
        on arrival.  The retried tokens are bit-identical to the lost
        attempt's at any temperature: prefill and sampling are pure
        functions of (seed, uid, position)."""
        if self._deadline_passed(req):
            self._expire_req(req, finished, "awaiting crash retry")
            return
        self._backlog.appendleft(req)
        self._pending_add(req)
        self.stats["crash_requeues"] += 1

    def _reclaim_orphan_handoff(self, h, finished: list[Request]) -> None:
        """Release an orphaned/expired handoff's pool pages through the
        manager's handoff registry, drop its staged remote-tier bytes,
        and retry the victim."""
        self.manager.release_handoff(h.token)
        self._reserved.pop(h.pslot, None)
        if h.handle is not None and self.prefill is not None:
            self.prefill.staging.release(h.handle)
        self.stats["lease_reclaims"] += 1
        self._requeue(h.req, finished)
        if self.kv is not None:
            self.kv.record()

    def _lease_watchdog(self, finished: list[Request],
                        force: bool = False) -> None:
        """Reclaim engine-crash leftovers.  A crashed prefill's partial
        pages are garbage — freed and the victim retried immediately.
        An un-adopted handoff holds COMPLETE, adoptable state, so its
        pages stay pinned until its lease expires (another decode engine
        might still adopt it); then the registry entry is released and
        the victim retried.  ``force=True`` (snapshot) cuts every lease
        short — a restart is a new lease epoch."""
        if self._orphan_prefills:
            for pslot, req in self._orphan_prefills:
                self.manager.free_slot(pslot)
                self._reserved.pop(pslot, None)
                self._requeue(req, finished)
            self._orphan_prefills.clear()
            if self.kv is not None:
                self.kv.record()
        for h in list(self._orphan_handoffs):
            if (force or self.stats["blocks"] >= h.lease_expiry_block
                    or self._deadline_passed(h.req)):
                self._orphan_handoffs.remove(h)
                self._reclaim_orphan_handoff(h, finished)
        eng = self.prefill
        if eng is not None and eng.ready:
            # leases bind NON-crashed handoffs too: one staged longer
            # than its lease (decode wedged, no free slot) is reclaimed
            # and retried rather than pinning pool pages indefinitely
            for h in list(eng.ready):
                if self.stats["blocks"] >= h.lease_expiry_block:
                    eng.ready.remove(h)
                    self._reclaim_orphan_handoff(h, finished)

    # ----- admission ---------------------------------------------------------
    def _admit_plen(self, prompt_len: int, max_new_tokens: int) -> int:
        """Bucketed admission prompt length (see _admit)."""
        limit = self.max_seq - max(max_new_tokens - 1, 0)
        bucket = _bucket(prompt_len)
        return bucket if bucket <= limit else prompt_len

    def _make_admit_step(self) -> Callable:
        return (self._make_admit_step_paged() if self.paged
                else self._make_admit_step_dense())

    def _make_admit_step_dense(self) -> Callable:
        model, max_seq = self.model, self.max_seq
        vocab, temperature = self.model.cfg.vocab, self.temperature
        eos_id = self.eos_id

        def admit_step(params, ptoks, cache, state, slot, max_new, req_key):
            """Prefill ONE request and splice it into the live batch state.

            ptoks: (1, P) left-padded prompt; slot/max_new: traced
            scalars; req_key: (2,) uint32 per-request key.  The first
            token lands at sequence position ``plen``, so it is sampled
            from ``fold_in(req_key, plen)`` — the same rule the decode
            loop applies per slot.  Donates (cache, state) — the splice
            is in place.
            """
            fresh = model.init_cache(1, max_seq)
            logits, fresh = model.prefill(params, ptoks, fresh)
            k = jax.random.fold_in(req_key, ptoks.shape[1])
            nxt = sample_tokens(logits, vocab, temperature, k)   # (1, 1)

            def splice(big, small):
                """Write the single-request leaf into the batch leaf at
                ``slot``.  The batch axis is found per leaf (the unique
                axis where the shapes differ), so non-transformer caches
                — e.g. recurrent state with batch leading — splice too."""
                if big.shape == small.shape:  # batch-1 server: whole swap
                    return small.astype(big.dtype)
                diff = [i for i, (bs, ss) in enumerate(zip(big.shape,
                                                           small.shape))
                        if bs != ss]
                if len(diff) != 1:
                    raise ValueError(
                        f"cannot infer the batch axis of cache leaf "
                        f"{big.shape} from single-request leaf "
                        f"{small.shape}")
                ax = diff[0]
                starts = (0,) * ax + (slot,) + (0,) * (big.ndim - ax - 1)
                return jax.lax.dynamic_update_slice(
                    big, small.astype(big.dtype), starts)

            cache = jax.tree.map(splice, cache, fresh)
            plen = ptoks.shape[1]
            state = self._spliced_state(state, nxt, plen, slot, max_new,
                                        req_key)
            return nxt, cache, state
        return admit_step

    def _make_admit_step_paged(self) -> Callable:
        model = self.model
        vocab, temperature = self.model.cfg.vocab, self.temperature

        def admit_step(params, ptoks, cache, state, slot, max_new, req_key,
                       ptable):
            """Prefill ONE request straight into its freshly allocated
            pages — no dense staging cache, no splice.  ptable: (1, n)
            page ids covering the bucketed prompt.  Donates (cache,
            state): the page writes and slot activation are in place."""
            logits, cache = model.prefill_paged(params, ptoks, cache, ptable)
            k = jax.random.fold_in(req_key, ptoks.shape[1])
            nxt = sample_tokens(logits, vocab, temperature, k)   # (1, 1)
            plen = ptoks.shape[1]
            state = self._spliced_state(state, nxt, plen, slot, max_new,
                                        req_key)
            return nxt, cache, state
        return admit_step

    def _make_admit_step_prefix(self) -> Callable:
        model = self.model
        vocab, temperature = self.model.cfg.vocab, self.temperature

        def admit_step(params, ptoks, cache, state, slot, max_new, req_key,
                       prefix_pages, new_pages):
            """Prefix-cached admission: prefill ONLY the prompt suffix.

            ptoks: (1, S_new) suffix tokens (position n_pre*page
            onwards); prefix_pages: (1, n_pre) shared pages read, never
            written; new_pages: (1, n_new) pages receiving the suffix
            KV.  Sampling folds the request key with the SAME total
            prompt length as the unshared path, so shared and unshared
            admission stay PRNG-identical."""
            logits, cache = model.prefill_paged_prefix(
                params, ptoks, cache, prefix_pages, new_pages)
            page = cache["k_pages"].shape[2]
            plen = prefix_pages.shape[1] * page + ptoks.shape[1]
            k = jax.random.fold_in(req_key, plen)
            nxt = sample_tokens(logits, vocab, temperature, k)   # (1, 1)
            state = self._spliced_state(state, nxt, plen, slot, max_new,
                                        req_key)
            return nxt, cache, state
        return admit_step

    def _spliced_state(self, state, nxt, plen, slot, max_new, req_key):
        """Activate ``slot`` in the decode state (shared by both admit
        paths) and install the request's per-slot PRNG key.  The page
        table is NOT touched here — the host refreshes it at every block
        boundary."""
        active = max_new > 1
        if self.eos_id is not None:   # EOS at admission: never activate
            active = active & (nxt[0, 0] != self.eos_id)
        upd1 = lambda buf, val: jax.lax.dynamic_update_slice(
            buf, jnp.asarray(val, buf.dtype)[None], (slot,))
        return DecodeState(
            tokens=jax.lax.dynamic_update_slice(state.tokens, nxt,
                                                (slot, 0)),
            pos=upd1(state.pos, plen),
            active=upd1(state.active, active),
            remaining=upd1(state.remaining, max_new - 1),
            key=state.key, pages=state.pages,
            slot_keys=jax.lax.dynamic_update_slice(
                state.slot_keys, req_key.astype(jnp.uint32)[None],
                (slot, 0)))

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def _worst_pages(self, prompt_len: int, max_new_tokens: int) -> int:
        """Worst-case page need of a request over its whole lifetime."""
        plen = self._admit_plen(prompt_len, max_new_tokens)
        return self.manager.pages_for(
            min(plen + max(max_new_tokens - 1, 0), self.max_seq))

    def _admission_pages_ready(self, req: Request) -> bool:
        """Page-accounting gate: every admitted request RESERVES its
        worst-case page count (allocation itself stays on-demand, so the
        live footprint still tracks actual tokens) — mid-decode pool
        exhaustion is then impossible (absent an injected fault, which
        emergency preemption recovers), and queued requests wait for
        reclamation or trigger preemption."""
        reserved = sum(self._reserved.values())
        worst = self._worst_pages(len(req.prompt), req.max_new_tokens)
        return worst <= self.manager.capacity - reserved

    def _req_key(self, uid: int) -> jax.Array:
        """The per-request PRNG key: ``fold_in(PRNGKey(seed), uid)`` —
        a pure function of construction seed and admission order, so
        identically configured servers sample identically."""
        return jax.random.fold_in(self._base_key, uid)

    def _under_pressure(self) -> bool:
        """Pool-pressure predicate for graceful degradation: sharing new
        prefix pages is skipped while victims sit swapped out (their
        resume must not contend with refcount-pinned pages) or while
        worst-case reservations crowd the pool."""
        if not self.paged:
            return False
        if self._preempted or self._pool_fault:
            return True
        return sum(self._reserved.values()) > 0.9 * self.manager.capacity

    # ----- prefix caching ----------------------------------------------------
    def _shareable_pages(self, plen: int) -> int:
        """Prompt pages eligible for sharing: whole pages strictly before
        the last prompt token.  The final page — partial or not — stays
        private so admission always has at least one suffix token to
        prefill (the one whose logits seed sampling), and decode's first
        write (position >= plen) can never touch a shared page."""
        return (plen - 1) // self.page_size

    def _shared_prefix_pages(self, toks: np.ndarray, plen: int) -> list[int]:
        """Longest run of already-pooled pages matching this padded
        prompt's leading whole pages.  Keys are the exact padded token
        bytes up to each page boundary — positions matter (left-padding
        included), so a hit guarantees bit-identical KV."""
        page, out = self.page_size, []
        for i in range(self._shareable_pages(plen)):
            pid = self.manager.lookup_prefix(toks[0, :(i + 1) * page]
                                             .tobytes())
            if pid is None:
                break
            out.append(pid)
        return out

    def _register_prefix(self, toks: np.ndarray, plen: int,
                         slot: int) -> None:
        """Publish this admission's freshly written whole prompt pages
        for future sharers (already-shared leading pages re-register as
        no-ops; the index keeps the first writer)."""
        page = self.page_size
        table = self.manager.slot_pages(slot)
        for i in range(self._shareable_pages(plen)):
            self.manager.register_prefix(toks[0, :(i + 1) * page].tobytes(),
                                         table[i])

    def _note_peak(self) -> None:
        """Capture a mid-flight per-tier ledger snapshot whenever pool
        occupancy reaches a new (or equal) peak, so the bench's residency
        block reflects peak load rather than the drained end state."""
        if self.manager.pages_in_use >= self._peak_pages:
            self._peak_pages = self.manager.pages_in_use
            self.tiers_peak = self.mem.ledger.snapshot()

    def _admit(self, req: Request, slot: int) -> bool:
        """Prefill ``req`` into ``slot`` of the live batch; True if the
        request finished at admission (budget of 1 / immediate EOS).

        Left-pad tokens (id 0) inside the bucket are attended like the
        seed server attended its batch-wide left-padding — deterministic,
        but outputs depend on the bucket quantum (see EXPERIMENTS.md).
        """
        # the bucketed start position must leave room for every decode
        # write (pos < max_seq, KV scatter past the cache end is silently
        # dropped by jit) — fall back to the exact prompt length (one
        # extra compile) when the bucket would overflow
        plen = self._admit_plen(len(req.prompt), req.max_new_tokens)
        toks = np.zeros((1, plen), np.int32)
        toks[0, plen - len(req.prompt):] = req.prompt        # left-pad
        req_key = self._req_key(req.uid)
        # admission never reads or writes the device page table, so hold
        # it aside and admit with pages=None: admit executables are then
        # keyed only on the bucketed prompt shape, never on whatever
        # width the live table happens to have (the width x plen compile
        # cross-product would otherwise defeat the bucketing).
        # try/finally: a MemoryError from ensure() (injected pool
        # exhaustion) must not leave the live state without its table.
        saved_pages = self.state.pages
        if saved_pages is not None:
            self.state = dataclasses.replace(self.state, pages=None)
        try:
            if self.paged:
                self._reserved[slot] = self._worst_pages(
                    len(req.prompt), req.max_new_tokens)
                share = self.prefix_cache
                if share and self._under_pressure():
                    # degradation policy: under pool pressure new
                    # admissions neither reuse nor publish shared pages
                    # (sharing is semantically invisible, so tokens are
                    # unchanged — only residency is)
                    share = False
                    self.stats["prefix_drops"] += 1
                shared = (self._shared_prefix_pages(toks, plen)
                          if share else [])
                if shared:
                    self.manager.adopt(slot, shared)
                new_ids = self.manager.ensure(slot, plen)
                if shared:
                    suffix = toks[:, len(shared) * self.page_size:]
                    self._note_prefill_dispatch(suffix.shape[1])
                    with self._mesh_ctx():
                        nxt, self.cache, self.state = self._admit_step_prefix(
                            self.params, jnp.asarray(suffix), self.cache,
                            self.state, jnp.asarray(slot, jnp.int32),
                            jnp.asarray(req.max_new_tokens, jnp.int32),
                            req_key,
                            jnp.asarray([shared], jnp.int32),
                            jnp.asarray([new_ids], jnp.int32))
                    self.stats["prefix_hits"] += 1
                    self.stats["prefix_shared_pages"] += len(shared)
                else:
                    ptable = jnp.asarray([new_ids], jnp.int32)
                    self._note_prefill_dispatch(plen)
                    with self._mesh_ctx():
                        nxt, self.cache, self.state = self._admit_step(
                            self.params, jnp.asarray(toks), self.cache,
                            self.state, jnp.asarray(slot, jnp.int32),
                            jnp.asarray(req.max_new_tokens, jnp.int32),
                            req_key, ptable)
                self.manager.note_tokens(slot, plen)
                if share:
                    self._register_prefix(toks, plen, slot)
                self.kv.record()
                self._note_peak()
            else:
                self._note_prefill_dispatch(plen)
                with self._mesh_ctx():
                    nxt, self.cache, self.state = self._admit_step(
                        self.params, jnp.asarray(toks), self.cache,
                        self.state, jnp.asarray(slot, jnp.int32),
                        jnp.asarray(req.max_new_tokens, jnp.int32), req_key)
        finally:
            if saved_pages is not None and self.state.pages is None:
                self.state = dataclasses.replace(self.state,
                                                 pages=saved_pages)
        self._slot_pos[slot] = plen
        self._sched_counter += 1
        self._last_sched[slot] = self._sched_counter
        req.admitted_at_block = self.stats["blocks"]
        first = int(jax.device_get(nxt)[0, 0])
        req.output.append(first)
        self._record_first_token(req)
        self.stats["tokens"] += 1
        self.stats["admitted"] += 1
        if req.max_new_tokens <= 1 or (self.eos_id is not None
                                       and first == self.eos_id):
            if self.paged:
                self.manager.free_slot(slot)   # reclaim at once
                self._reserved.pop(slot, None)
                self.kv.record()    # ledger must track the reclaim
            self._finalize(req, "completed")
            return True
        self.slots[slot] = req
        return False

    def _admit_from_queue(self, finished: list[Request],
                          allow_preempt: bool = False) -> None:
        """Fill free slots (non-blocking, mid-stream): swapped-out
        victims resume FIRST (resume-FIFO — they are older than every
        queued request, so preemption can never starve them), then the
        backlog in arrival order.  With a paged pool, admission is
        page-gated: the head request waits (FIFO order preserved) until
        reclamation frees enough — or, with ``allow_preempt`` (the
        pipeline is drained), triggers page-granular preemption.

        Lifecycle upkeep runs first: crash leftovers are reclaimed and
        expired requests cancelled (live slots only when the pipeline is
        drained — ``allow_preempt`` doubles as that signal)."""
        self._drain_queue()
        self._lease_watchdog(finished)
        self._expire_sweep(finished, drained=allow_preempt)
        while self._preempted and self._free_slots():
            ps = self._preempted[0]
            if not self._resume_ready(ps):
                break
            self._preempted.pop(0)
            if not self._resume(ps, self._free_slots()[0], finished):
                self._preempted.insert(0, ps)   # physically blocked
                break
        if self.prefill is not None:
            self._async_admission(finished, allow_preempt)
            return
        while True:
            free = self._free_slots()
            if not free:
                return
            if not self._backlog:
                try:
                    self._backlog.append(self.queue.get_nowait())
                except queue.Empty:
                    return
            req = self._backlog[0]
            if self.paged and not self._admission_pages_ready(req):
                if not (allow_preempt and self._try_preempt_for(req,
                                                                finished)):
                    return            # blocked on pages, not on slots
                free = self._free_slots()
                if not free or not self._admission_pages_ready(req):
                    return
            self._backlog.popleft()
            self._pending_remove(req)
            try:
                done_now = self._admit(req, free[0])
            except MemoryError:
                # physically out of pages (injected exhaustion window):
                # roll back the reservation and keep FIFO order
                self.manager.free_slot(free[0])
                self._reserved.pop(free[0], None)
                self._backlog.appendleft(req)
                self._pending_add(req)
                return
            if done_now:
                finished.append(req)      # done at admission: slot stays free

    # ----- disaggregated admission (async prefill engine) ---------------------
    def _async_admission(self, finished: list[Request],
                         allow_preempt: bool) -> None:
        """Admission through the prefill engine: starts are strictly
        FIFO behind the page gate, ONE prefill chunk advances per
        scheduling round while decode work is pending (so a long prompt
        never stalls decode for more than a chunk), and ready handoffs
        are adopted into free slots.  With decode idle the loop pumps
        freely — chunking costs nothing when there is nothing to
        stall."""
        eng = self.prefill
        while True:
            self._drain_queue()
            started = False
            while (self._backlog and len(eng.inflight) < eng.max_inflight
                   and self._admission_pages_ready(self._backlog[0])):
                req = self._backlog.popleft()
                self._pending_remove(req)
                eng.start(req)
                started = True
            if (self._backlog and not started and allow_preempt
                    and not self._admission_pages_ready(self._backlog[0])
                    and self._try_preempt_for(self._backlog[0], finished)):
                continue
            progressed = eng.pump_once(finished)
            if not self._can_dispatch() and (progressed or started):
                # decode idle: finish the whole burst before adopting —
                # the first adoption would make decode dispatchable and
                # serialize the remaining prefills one chunk per block,
                # ramping the batch one slot at a time.  Batching the
                # burst here is exactly monolithic admission's timing
                # (it too admits every queued request before decoding),
                # and chunking costs nothing while nothing can stall.
                continue
            adopted = False
            while eng.ready and self._free_slots():
                self._adopt_handoff(eng.ready.popleft(),
                                    self._free_slots()[0], finished)
                adopted = True
            if self._can_dispatch():
                return               # decode work pending: yield to it
            if not (progressed or adopted or started):
                return               # engine drained or blocked

    def _adopt_handoff(self, h, slot: int, finished: list[Request]) -> None:
        """Decode-side adoption of a completed prefill: pure ownership
        transfer — the handoff's pool-resident pages rebind to ``slot``
        (their table lands in the next block's bucketed delta), the
        staged remote-tier bytes are released, and the slot state is
        spliced exactly like a resume at ``pos = plen``.  No prefill
        compute, no KV copy, no blocking dispatch."""
        plan = memtiers.active_fault_plan()
        if plan is not None and plan.take_adopt_crash(self.stats["blocks"]):
            # injected decode-engine crash mid-adoption: the handoff's
            # pages stay staged under the registry and LEASED — another
            # engine might still adopt them — so reclamation waits for
            # the lease watchdog, which then retries the victim
            self._orphan_handoffs.append(h)
            self.stats["engine_crashes"] += 1
            return
        req = h.req
        self.manager.adopt_from_handoff(slot, h.token)
        # worst-case reservation transfers from the prefill pseudo-slot
        self._reserved[slot] = self._reserved.pop(
            h.pslot, self._worst_pages(len(req.prompt), req.max_new_tokens))
        self.prefill.staging.release(h.handle)
        req.admitted_at_block = self.stats["blocks"]
        req.output.append(h.first_token)
        self._record_first_token(req)
        self.stats["tokens"] += 1
        self.stats["admitted"] += 1
        if req.max_new_tokens <= 1 or (self.eos_id is not None
                                       and h.first_token == self.eos_id):
            self.manager.free_slot(slot)     # done at adoption
            self._reserved.pop(slot, None)
            self._finalize(req, "completed", finished)
            self.kv.record()
            return
        # adoption never touches the device page table — hold it aside
        # so the splice executable is keyed on the state shape alone
        # (same idiom as _admit), then run the fused one-dispatch splice
        saved_pages = self.state.pages
        if saved_pages is not None:
            self.state = dataclasses.replace(self.state, pages=None)
        try:
            with self._mesh_ctx():
                self.state = self._adopt_step(
                    self.state, h.nxt, jnp.asarray(slot, jnp.int32),
                    jnp.asarray(h.plen, jnp.int32),
                    jnp.asarray(req.max_new_tokens - 1, jnp.int32), h.key)
        finally:
            if saved_pages is not None and self.state.pages is None:
                self.state = dataclasses.replace(self.state,
                                                 pages=saved_pages)
        self.slots[slot] = req
        self._slot_pos[slot] = h.plen
        self._planned[slot] = 0
        self._sched_counter += 1
        self._last_sched[slot] = self._sched_counter
        self.kv.record()
        self._note_peak()

    # ----- preemption & fault recovery ---------------------------------------
    def _victim_order(self, cands: list[int]) -> list[int]:
        """Rank live slots by the configured victim policy (first =
        preempted first).  ``preempt_policy`` may also be a callable
        ``(server, cands) -> ordered cands`` for experimentation."""
        pol = self.preempt_policy
        if callable(pol):
            return list(pol(self, cands))
        if pol == "lru":          # least recently scheduled work
            return sorted(cands, key=lambda i: self._last_sched[i])
        if pol == "fewest_pages":  # cheapest swap traffic
            return sorted(cands,
                          key=lambda i: len(self.manager.slot_pages(i)))
        if pol == "lowest_progress":   # least sunk decode cost
            return sorted(cands, key=lambda i: (
                len(self.slots[i].output)
                / max(self.slots[i].max_new_tokens, 1)))
        raise ValueError(f"unknown preempt_policy {pol!r}")

    def _select_victims(self, shortfall: int) -> list[int]:
        """Fewest victims (in policy order) whose reservations cover
        ``shortfall`` pages; [] when even preempting everyone falls
        short (then waiting on reclamation is the only option)."""
        cands = [i for i, r in enumerate(self.slots) if r is not None]
        out, freed = [], 0
        for i in self._victim_order(cands):
            if freed >= shortfall:
                break
            out.append(i)
            freed += self._reserved.get(i, 0)
        return out if freed >= shortfall else []

    def _preempt_wanted(self) -> bool:
        """Should the pipeline drain so the backlog head can preempt?
        Requires: preemption on, no victim already swapped out
        (anti-thrash: one preemption round resolves before the next
        starts), a free slot, a head blocked on pages, and victims whose
        reservations cover the shortfall."""
        if not (self.preempt_enabled and self._backlog
                and not self._preempted and self._free_slots()):
            return False
        req = self._backlog[0]
        if self._admission_pages_ready(req):
            return False
        worst = self._worst_pages(len(req.prompt), req.max_new_tokens)
        shortfall = worst - (self.manager.capacity
                             - sum(self._reserved.values()))
        return bool(self._select_victims(shortfall))

    def _try_preempt_for(self, req: Request,
                         finished: list[Request]) -> bool:
        """Swap out enough victims for ``req`` to admit.  Only called
        with the pipeline drained (no block in flight), so the gathered
        pages are exactly the harvested positions."""
        if not (self.preempt_enabled and not self._preempted):
            return False
        worst = self._worst_pages(len(req.prompt), req.max_new_tokens)
        shortfall = worst - (self.manager.capacity
                             - sum(self._reserved.values()))
        victims = self._select_victims(shortfall)
        if not victims:
            return False
        for i in victims:
            self._preempt_slot(i, finished)
        return True

    def _preempt_slot(self, i: int, finished: list[Request]) -> None:
        """Swap slot ``i``'s live KV pages to the remote tier and free
        its physical pages + reservation.  Requires no block in flight.
        Shared prefix pages are stashed like private ones and restored
        private — prefix sharing is dropped under pressure (documented
        degradation; tokens are unaffected, only residency).  On an
        unrecoverable transfer fault the victim is shed with a
        structured error instead of poisoning the pool."""
        req = self.slots[i]
        pos = self._slot_pos[i]
        pids = self.manager.slot_pages(i)[:self.manager.pages_for(pos)]
        # deep preemption (threshold 0): stash straight to the cold tier
        # so the remote tier never holds the victim — its hwm stays flat
        # through the preemption round
        tier = (memtiers.COLD if self.cold_park_after_blocks == 0
                else memtiers.REMOTE)
        try:
            with self._mesh_ctx():
                handle = self.swapper.swap_out(self.cache, pids, tier=tier)
        except memtiers.TierTransferError as e:
            self._shed(i, finished, reason="preempt_swap_failed",
                       detail=str(e))
            return
        if tier == memtiers.COLD:
            self.stats["cold_parks"] += 1
        key = np.asarray(jax.device_get(self._req_key(req.uid)))
        self._preempted.append(_Preempted(req=req, pos=pos, handle=handle,
                                          key=key,
                                          stashed_block=self.stats["blocks"]))
        self._evict_slot(i)
        self.stats["preemptions"] += 1
        self.stats["preempted_pages"] += len(pids)
        self.kv.record()

    def _cold_park_sweep(self) -> None:
        """Demote remote-tier stashes whose age (decode blocks since the
        swap-out) exceeds ``cold_park_after_blocks`` to the cold tier.
        Fallible like any transfer: a park that exhausts its retry
        budget leaves the stash in the remote tier (the degradation is
        just capacity not reclaimed — correctness is untouched)."""
        thresh = self.cold_park_after_blocks
        if not thresh or self.swapper is None:   # None or 0: no sweep
            return
        for ps in self._preempted:
            if (ps.handle.tier == memtiers.REMOTE
                    and self.stats["blocks"] - ps.stashed_block >= thresh):
                try:
                    self.swapper.park(ps.handle)
                    self.stats["cold_parks"] += 1
                except memtiers.TierTransferError:
                    pass

    def _evict_slot(self, i: int) -> None:
        """Release slot ``i``'s pages/reservation and deactivate it on
        device (shared by preempt and shed).  The zeroed table row at
        the next block's delta re-points any frozen-position ghost
        writes at the null page."""
        if self.manager is not None:        # dense server: no pool to free
            self.manager.free_slot(i)
        self._reserved.pop(i, None)
        self.slots[i] = None
        self._planned[i] = 0
        self._slot_pos[i] = 0
        st = self.state
        self.state = dataclasses.replace(
            st, active=st.active.at[i].set(False),
            remaining=st.remaining.at[i].set(0))

    def _shed(self, i: int, finished: list[Request], *, reason: str,
              detail: str) -> None:
        """Degradation of last resort: drop slot ``i``'s request with a
        structured error (the server survives; the caller sees why)."""
        req = self.slots[i]
        self._evict_slot(i)
        req.error = {"reason": reason, "detail": detail, "uid": req.uid,
                     "tokens_emitted": len(req.output)}
        self._finalize(req, "shed", finished)
        if self.kv is not None:
            self.kv.record()

    def _shed_preempted(self, ps: _Preempted, finished: list[Request], *,
                        reason: str, detail: str) -> None:
        """Shed a swapped-out victim whose restore failed."""
        if self.swapper is not None and ps.handle is not None:
            self.swapper.release(ps.handle)
        ps.req.error = {"reason": reason, "detail": detail,
                        "uid": ps.req.uid,
                        "tokens_emitted": len(ps.req.output)}
        self._finalize(ps.req, "shed", finished)

    def _service_poison(self, finished: list[Request]) -> None:
        """Shed every slot whose harvest hit non-finite logits — only
        that sequence dies; the rest of the batch decodes on.  Runs with
        the pipeline drained (poisoned slots stall dispatch exactly like
        a pool fault) so eviction can never race an in-flight block."""
        for i, req in list(self._poisoned.items()):
            if self.slots[i] is req:
                self.stats["poison_sheds"] += 1
                self._shed(i, finished, reason="poisoned_logits",
                           detail=f"non-finite logits in decode block "
                                  f"{self.stats['blocks']} at position "
                                  f"{self._slot_pos[i]}")
        self._poisoned.clear()

    def _resume_ready(self, ps: _Preempted) -> bool:
        """A victim resumes only when its remaining worst case fits the
        unreserved pool — the same accounting gate as admission, so a
        resumed sequence can never exhaust the pool either."""
        worst = self._resume_worst(ps)
        return worst <= self.manager.capacity - sum(self._reserved.values())

    def _resume_worst(self, ps: _Preempted) -> int:
        left = ps.req.max_new_tokens - len(ps.req.output)
        return self.manager.pages_for(min(ps.pos + left, self.max_seq))

    def _resume(self, ps: _Preempted, slot: int,
                finished: list[Request]) -> bool:
        """Restore a swapped-out victim into ``slot``: re-allocate pages
        covering its position, scatter the stash back, and re-activate
        the device slot with its original per-slot key — decode then
        continues bit-identically.  False = physically blocked (retry
        later); True = consumed (resumed or shed)."""
        self._reserved[slot] = self._resume_worst(ps)
        try:
            new_ids = self.manager.ensure(slot, ps.pos)
        except MemoryError:
            self._reserved.pop(slot, None)
            return False
        try:
            with self._mesh_ctx():
                if ps.handle.tier != memtiers.REMOTE:
                    # promote-through-remote: a cold-parked stash pays
                    # the cold->remote edge first, then the ordinary
                    # remote->local swap-in — the hierarchy is a path,
                    # not a teleport
                    self.swapper.promote(ps.handle)
                    self.stats["cold_promotes"] += 1
                self.cache = self.swapper.swap_in(self.cache, new_ids,
                                                  ps.handle)
        except memtiers.TierTransferError as e:
            self.manager.free_slot(slot)
            self._reserved.pop(slot, None)
            self._shed_preempted(ps, finished,
                                 reason="resume_swap_failed", detail=str(e))
            return True
        self.manager.note_tokens(slot, ps.pos)
        st = self.state
        self.state = dataclasses.replace(
            st,
            tokens=st.tokens.at[slot, 0].set(ps.req.output[-1]),
            pos=st.pos.at[slot].set(ps.pos),
            active=st.active.at[slot].set(True),
            remaining=st.remaining.at[slot].set(
                ps.req.max_new_tokens - len(ps.req.output)),
            slot_keys=st.slot_keys.at[slot].set(
                jnp.asarray(ps.key, jnp.uint32)))
        self.slots[slot] = ps.req
        self._slot_pos[slot] = ps.pos
        self._planned[slot] = 0
        self._sched_counter += 1
        self._last_sched[slot] = self._sched_counter
        self.stats["resumes"] += 1
        self.kv.record()
        self._note_peak()
        return True

    def _fault_injection_tick(self) -> None:
        """Service an armed pool-exhaustion fault: steal every free page
        into a phantom slot at the armed block, release them
        ``exhaust_blocks`` blocks later (both host-side — the device
        never sees the phantom)."""
        plan = memtiers.active_fault_plan()
        if (self._fault_release_block is not None
                and self.stats["blocks"] >= self._fault_release_block):
            self.manager.free_slot(self._fault_slot)
            self._fault_release_block = None
        if plan is None:
            return
        if plan.take_pool_exhaustion(self.stats["blocks"]):
            steal = self.manager.free_pages * self.page_size
            if steal:
                self.manager.ensure(self._fault_slot, steal)
            self._fault_release_block = (self.stats["blocks"]
                                         + plan.exhaust_blocks)
            self.stats["pool_faults"] += 1

    def _recover_pool_fault(self, finished: list[Request]) -> None:
        """Mid-decode pool exhaustion (injected): with the pipeline
        drained, emergency-preempt one victim so decode can proceed; if
        only one sequence is live there is nothing to preempt FOR it —
        shed it with a structured error (the server survives)."""
        self._pool_fault = False
        live = [i for i, r in enumerate(self.slots) if r is not None]
        if not live:
            return
        order = self._victim_order(live)
        if len(live) == 1:
            self._shed(order[0], finished, reason="pool_exhausted",
                       detail="mid-decode page allocation failed with no "
                              "preemptable victim")
            return
        self._preempt_slot(order[0], finished)

    def _maybe_audit(self) -> None:
        """Debug mode: run the block-pool invariant auditor (refcounts,
        free-list disjointness, table/pool consistency, ledger residency)
        after every scheduling step."""
        if self.audit_every_block and self.paged:
            self.kv.audit()
            self.stats["audits"] += 1

    # ----- decode ------------------------------------------------------------
    def _live_remaining(self, i: int) -> int:
        """Decode tokens slot ``i`` still owes BEYOND every in-flight
        block (host view).  EOS can only shorten this on device, so a
        positive value guarantees the next dispatch is not a ghost block
        for budget reasons (with EOS enabled a slot may still die early —
        tokens stay correct, the block is merely wasted)."""
        req = self.slots[i]
        if req is None:
            return 0
        return req.max_new_tokens - len(req.output) - self._planned[i]

    def _can_dispatch(self) -> bool:
        return any(self._live_remaining(i) > 0 for i in range(self.batch))

    # ----- prefill/decode interference accounting -----------------------------
    def _note_prefill_dispatch(self, ntokens: int) -> None:
        """Record ``ntokens`` of synchronous prefill work dispatched
        while decode work was pending: until the next decode block goes
        out, those tokens ARE the decode stall.  Prefill with no decode
        pending (engine warm-up, idle server) is free and not counted.
        Device-work based, so the metric is deterministic."""
        if self._can_dispatch():
            self._stall_tokens += ntokens

    def _fold_stall(self) -> None:
        """At a decode dispatch, convert the accrued prefill tokens of
        the preceding gap into stalled decode blocks (ceil in
        block-size units) — the bench's worst-case interference gauge:
        monolithic admission of a long prompt charges the whole prompt
        to one gap; the async engine bounds every gap to one chunk."""
        if self._stall_tokens:
            stall = -(-self._stall_tokens // self.block_size)
            self.stats["decode_stall_blocks_max"] = max(
                self.stats["decode_stall_blocks_max"], stall)
            self.stats["decode_stall_blocks_total"] += stall
            self._stall_tokens = 0

    def _record_first_token(self, req: Request) -> None:
        """TTFT sample in decode-block units (queue entry -> first
        token), aggregated into p50/p99 at the end of ``run_once``."""
        req.first_token_block = self.stats["blocks"]
        if req.submitted_block is not None:
            self._ttft_samples.append(req.first_token_block
                                      - req.submitted_block)

    # blocks a narrower bucketed width must persist before the table
    # shrinks: growth is immediate (an unmapped page would corrupt
    # decode), but shrinking only saves attention columns, so it waits
    # out transient dips — e.g. the start of a fresh batch — instead of
    # paying a rebuild + regrow round trip every serving round
    SHRINK_PATIENCE = 8

    def _table_delta(self):
        """Diff the manager's desired per-slot tables against the host
        mirror of the device-resident table.  Steady state returns a
        bucketed ``(slots, cols, pids)`` delta (padding entries carry an
        out-of-range column, dropped by the in-dispatch scatter); a
        width change — growth, or a shrink that outlasted
        ``SHRINK_PATIENCE`` — re-transfers the whole table and returns
        None.  Widths repeat, so executables stay O(log max_pages)
        (``stats["table_rebuilds"]`` counts the transfers)."""
        w_need = _bucket(max(self.manager.max_slot_pages(), 1), 1)
        if w_need < self._table_w:
            self._narrow_blocks += 1
            if self._narrow_blocks < self.SHRINK_PATIENCE:
                w_need = self._table_w      # tolerate the extra null cols
        else:
            self._narrow_blocks = 0
        # desired: live slots' exact tables; evicted slots' rows are
        # ZEROED (the manager no longer knows them), re-pointing a dead
        # slot's frozen-position ghost writes at the null page.  The row
        # must be cleared, not left stale: an inactive slot keeps
        # re-writing its frozen position every dispatch, so a stale row
        # would corrupt a freed page long after its reallocation — the
        # _harvest safety argument only covers the bounded in-flight
        # window between the eviction and this delta.
        desired = self.manager.table(list(range(self.batch)), w_need)
        if w_need != self._table_w:
            self._table_w = w_need
            self._narrow_blocks = 0
            self._mirror = desired
            self.state = dataclasses.replace(
                self.state, pages=self._dev(jnp.asarray(desired)))
            self.stats["table_rebuilds"] += 1
            return None
        rows, cols = np.nonzero(desired != self._mirror)
        self._mirror = desired
        n = len(rows)
        self.stats["table_delta_entries"] += n
        cap = _bucket(max(n, 1), 4)
        d_slots = np.zeros(cap, np.int32)
        d_cols = np.full(cap, w_need, np.int32)  # out of range -> dropped
        d_pids = np.zeros(cap, np.int32)
        d_slots[:n], d_cols[:n] = rows, cols
        d_pids[:n] = desired[rows, cols]
        return (self._dev(jnp.asarray(d_slots)),
                self._dev(jnp.asarray(d_cols)),
                self._dev(jnp.asarray(d_pids)))

    def _dispatch_block(self):
        """Dispatch ONE fused decode block without waiting for earlier
        blocks (the donated cache/state buffers chain dispatches in
        order on device).  Page growth covering every planned write is
        folded into this block's table delta; the allocation is
        speculative past in-flight blocks and can only exhaust the pool
        under an injected fault (admission reserved each request's worst
        case) — exhaustion rolls the plan back, latches ``_pool_fault``
        and returns None so ``run_once`` can run emergency recovery."""
        advances: dict[int, tuple[Request, int]] = {}
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            adv = min(self.block_size, self._live_remaining(i))
            if adv > 0:
                advances[i] = (req, adv)
                self._planned[i] += adv
        if self.paged:
            self._fault_injection_tick()
            try:
                for i in advances:
                    self.manager.ensure(i, min(self._slot_pos[i]
                                               + self._planned[i],
                                               self.max_seq))
            except MemoryError:
                for i, (req, adv) in advances.items():
                    self._planned[i] -= adv
                self._pool_fault = True
                return None
            delta = self._table_delta()
            self.kv.record()
            self._note_peak()
            with self._mesh_ctx():
                toks, valid, poison, self.cache, self.state = \
                    self._decode_loop(self.params, self.cache, self.state,
                                      delta)
        else:
            with self._mesh_ctx():
                toks, valid, poison, self.cache, self.state = \
                    self._decode_loop(self.params, self.cache, self.state)
        self._fold_stall()
        self.stats["dispatches"] += 1
        self.stats["blocks"] += 1
        self.stats["steps"] += self.block_size
        return toks, valid, poison, advances

    def _harvest(self, block, finished: list[Request]) -> None:
        """Sync ONE in-flight block's token harvest (the only host sync
        per block) and fold the outcome back into host bookkeeping:
        slot recycling, refcounted page reclamation, ledger accounting.

        Reclamation while a later block is in flight is safe: a slot
        that died in this block is inactive in every later in-flight
        state, so its only writes are frozen-position ghost writes into
        its own tail page — and any reallocation of that page is either
        fully overwritten (admission prefill writes whole pages) or
        masked until the new owner actually writes each position."""
        toks, valid, poison, advances = block
        toks_h, valid_h, poison_h = jax.device_get((toks, valid, poison))
        self.stats["host_syncs"] += 1
        for i, (req, adv) in advances.items():
            if self.slots[i] is req:
                self._planned[i] -= adv
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if i in self._poisoned:
                # flagged in an earlier in-flight block: everything this
                # slot produced since is downstream of non-finite state
                continue
            emitted = 0
            bad = False
            for t in range(self.block_size):
                if not valid_h[i, t]:
                    break                 # active mask is monotone per slot
                if poison_h[i, t]:
                    bad = True            # this and later tokens: garbage
                    break
                req.output.append(int(toks_h[i, t]))
                emitted += 1
                self.stats["tokens"] += 1
            self._slot_pos[i] += emitted
            if self.paged:
                self.manager.note_tokens(i, self._slot_pos[i])
            if bad:
                # poison stalls dispatch (run_once) and the slot is shed
                # once the pipeline drains — never evict under a block
                # in flight (a recycled slot would steal its harvest)
                self._poisoned[i] = req
                continue
            if (len(req.output) >= req.max_new_tokens
                    or (self.eos_id is not None and req.output
                        and req.output[-1] == self.eos_id)):
                self._finalize(req, "completed", finished)
                self.slots[i] = None       # slot recycled for admission
                self._planned[i] = 0
                if self.paged:
                    self.manager.free_slot(i)   # refcounted reclamation
                    self._reserved.pop(i, None)
        if self.paged:
            self.stats["kv_pages_in_use"] = self.manager.pages_in_use
            self.stats["kv_pages_hwm"] = self.manager.hwm
            self.kv.record()               # per-tier ledger accounting
        self._cold_park_sweep()            # demote over-age stashes

    def run_once(self, max_blocks: int | None = None) -> list[Request]:
        """Admit queued requests and serve until every admitted request
        completes; returns the finished ones (shed requests too — check
        ``Request.error``).  Requests that arrive (or overflow the slot
        count) while serving are admitted mid-stream.  Non-blocking when
        idle: empty queue + no live slots returns [].

        With ``pipeline`` on, up to two blocks stay in flight: the next
        block is dispatched before the previous block's harvest is
        synced, so host scheduling (token harvest, reclamation,
        admission, the next table delta) overlaps device compute.  When
        preemption is wanted (backlog head starving) or a pool fault is
        latched, dispatching pauses so the pipeline drains first —
        swaps and emergency recovery only run against fully harvested
        state.  ``max_blocks`` bounds the blocks dispatched this call
        (the pipeline still drains before returning), for
        checkpoint-between-blocks callers."""
        finished: list[Request] = []
        self._admit_from_queue(finished)
        inflight: collections.deque = collections.deque()
        dispatched = 0
        while True:
            stall = (self._pool_fault or self._poisoned
                     or self._preempt_wanted() or self._expiry_stall())
            if not stall:
                while (len(inflight) < self.max_inflight
                       and self._can_dispatch()
                       and (max_blocks is None or dispatched < max_blocks)):
                    blk = self._dispatch_block()
                    if blk is None:      # pool fault latched: drain first
                        break
                    dispatched += 1
                    inflight.append(blk)
            if inflight:
                self._harvest(inflight.popleft(), finished)
                self._admit_from_queue(finished,
                                       allow_preempt=not inflight)
                self._maybe_audit()
                continue
            if self._pool_fault:
                self._recover_pool_fault(finished)
                self._maybe_audit()
                continue
            if self._poisoned:
                self._service_poison(finished)
                self._maybe_audit()
                continue
            if max_blocks is not None and dispatched >= max_blocks:
                break
            # idle pipeline: give blocked work one more chance (resume
            # swapped-out victims, preempt for the backlog head)
            self._admit_from_queue(finished, allow_preempt=True)
            self._maybe_audit()
            if not (self._can_dispatch() or self._pool_fault):
                if self._fault_release_block is not None:
                    # nothing can decode, so the block counter will never
                    # reach the release point — the injected exhaustion
                    # window is over by definition; return the pages
                    self.manager.free_slot(self._fault_slot)
                    self._fault_release_block = None
                    self._admit_from_queue(finished, allow_preempt=True)
                    if self._can_dispatch():
                        continue
                if self._orphan_handoffs:
                    # decode idle freezes the block clock, so a lease
                    # measured in blocks can never lapse — force the
                    # reclaim now instead of livelocking the orphans
                    self._lease_watchdog(finished, force=True)
                    self._admit_from_queue(finished, allow_preempt=True)
                    if self._can_dispatch():
                        continue
                break
        if finished:
            self.stats["batches"] += 1
        self.stats["compiles"] = self._compiles()
        if self.swapper is not None:
            self.stats["swap_retries"] = self.swapper.retry_attempts
        self.stats["slow_transfers"] = self.transfer_monitor.flags
        if self._ttft_samples:
            arr = np.asarray(self._ttft_samples, np.float64)
            self.stats["ttft_p50_blocks"] = float(np.percentile(arr, 50))
            self.stats["ttft_p99_blocks"] = float(np.percentile(arr, 99))
        if self._e2e_samples:
            arr = np.asarray(self._e2e_samples, np.float64)
            self.stats["e2e_p50_blocks"] = float(np.percentile(arr, 50))
            self.stats["e2e_p99_blocks"] = float(np.percentile(arr, 99))
        return finished

    def _compiles(self) -> int:
        """Executables compiled across the serving hot path's jit entry
        points — the observable for the O(log) shape-bucketing claim."""
        fns = [self._decode_loop, self._admit_step, self._admit_step_prefix]
        if self.prefill is not None:
            fns += [self.prefill._first_step, self.prefill._cont_step]
        return sum(f._cache_size() for f in fns
                   if f is not None and hasattr(f, "_cache_size"))

    # ----- checkpoint/restart ------------------------------------------------
    def _drain_queue(self) -> None:
        while True:
            try:
                self._backlog.append(self.queue.get_nowait())
            except queue.Empty:
                return

    def snapshot(self) -> dict:
        """Serialize every in-flight sequence — live slots (KV pages
        gathered through the swapper), swapped-out victims (their stash
        verbatim) and queued requests — into a host dict that
        :meth:`restore` (same model/params/seed) rehydrates.  Call
        between ``run_once`` calls (no block in flight).  Feeds
        ``repro.runtime.ft.save_server_snapshot`` for on-disk restart."""
        if not self.paged:
            raise ValueError("snapshot requires the paged server")
        self._drain_queue()
        # engine-crash leftovers must not serialize as leaked pages:
        # cut their leases short (a restart is a new lease epoch),
        # reclaim, and let the victims re-enter as backlog entries
        self._lease_watchdog([], force=True)
        seqs = []

        def entry(req, pos, h=None):
            e = {"uid": req.uid, "prompt": np.asarray(req.prompt, np.int32),
                 "max_new_tokens": req.max_new_tokens,
                 "output": list(req.output), "pos": int(pos),
                 "submitted_block": req.submitted_block,
                 "deadline_blocks": req.deadline_blocks}
            if pos:
                e["k"], e["v"] = h.k, h.v
                if h.k_scale is not None:    # quantized pool: scales too
                    e["k_scale"], e["v_scale"] = h.k_scale, h.v_scale
                # which hierarchy level the stash occupied — restore
                # puts it back in the SAME tier (a cold-parked victim
                # stays cold-parked across a restart)
                e["tier"] = h.tier
            return e

        for i, req in enumerate(self.slots):
            if req is None:
                continue
            pos = self._slot_pos[i]
            pids = self.manager.slot_pages(i)[:self.manager.pages_for(pos)]
            with self._mesh_ctx():
                h = self.swapper.swap_out(self.cache, pids)
            self.swapper.release(h)     # accounting-neutral read-out
            seqs.append(entry(req, pos, h))
        for ps in self._preempted:
            seqs.append(entry(ps.req, ps.pos, ps.handle))
        if self.prefill is not None:
            # a completed handoff is a sequence at pos = plen whose only
            # output is its first token — its staged stash serializes
            # verbatim and restores through the resume path, finishing
            # bit-identically; a mid-chunk prefill re-enters as backlog
            # (prefill is deterministic, so recomputing it is exact)
            for h in self.prefill.ready:
                e = entry(h.req, h.plen, h.handle.materialize())
                e["output"] = [h.first_token]
                seqs.append(e)
            for inf in self.prefill.inflight:
                seqs.append(entry(inf.req, 0))
        for req in self._backlog:
            seqs.append(entry(req, 0))
        seqs.sort(key=lambda e: e["uid"])
        # "blocks" anchors deadline/lease clocks: restore rebases each
        # request's submitted_block so its REMAINING TTL carries over
        return {"seed": self.seed, "uid": self._uid,
                "blocks": self.stats["blocks"], "sequences": seqs}

    def restore(self, snap: dict) -> None:
        """Rehydrate a :meth:`snapshot` into this (idle, same-seed)
        server.  Sequences with decoded positions come back as
        swapped-out stashes — the resume path splices their KV into
        fresh pages and, with per-slot keys, continues bit-identically;
        undecoded ones rejoin the backlog.  Prefix pages restore private
        (sharing re-forms only across NEW admissions)."""
        if snap["seed"] != self.seed:
            raise ValueError(f"snapshot seed {snap['seed']} != server "
                             f"seed {self.seed} (tokens would diverge)")
        if any(r is not None for r in self.slots) or self._preempted \
                or self._backlog or not self.queue.empty() \
                or (self.prefill is not None and not self.prefill.idle):
            raise ValueError("restore requires an idle server")
        self._uid = max(self._uid, int(snap["uid"]))
        snap_blocks = int(snap.get("blocks", 0))
        for s in sorted(snap["sequences"], key=lambda e: e["uid"]):
            req = Request(int(s["uid"]), np.asarray(s["prompt"], np.int32),
                          max_new_tokens=int(s["max_new_tokens"]))
            req.output = [int(t) for t in s["output"]]
            # rebase the deadline clock into THIS server's block counter:
            # the remaining TTL at snapshot time is the remaining TTL now
            # (restart downtime does not run the clock — blocks, not
            # wall time, are the server's SLA unit)
            dl = s.get("deadline_blocks")
            req.deadline_blocks = None if dl is None else int(dl)
            sb = s.get("submitted_block")
            req.submitted_block = (
                self.stats["blocks"] if sb is None
                else self.stats["blocks"] - snap_blocks + int(sb))
            if int(s["pos"]):
                k = np.asarray(s["k"])
                v = np.asarray(s["v"])
                ksc = (np.asarray(s["k_scale"]) if "k_scale" in s else None)
                vsc = (np.asarray(s["v_scale"]) if "v_scale" in s else None)
                arrs = [a for a in (k, v, ksc, vsc) if a is not None]
                handle = SwapHandle(
                    page_count=k.shape[1], k=k, v=v,
                    nbytes=sum(a.size * a.dtype.itemsize for a in arrs),
                    k_scale=ksc, v_scale=vsc,
                    tier=s.get("tier", memtiers.REMOTE))
                self.swapper.adopt(handle)
                key = np.asarray(jax.device_get(self._req_key(req.uid)))
                self._preempted.append(_Preempted(
                    req=req, pos=int(s["pos"]), handle=handle, key=key,
                    stashed_block=self.stats["blocks"]))
            else:
                self._backlog.append(req)
                self._pending_add(req)

    # ----- accounting --------------------------------------------------------
    def kv_bytes_in_use(self) -> int:
        """Live KV footprint: allocated pages only (paged) or the whole
        dense slab (which is resident regardless of occupancy)."""
        if not self.paged:
            return memory.tree_bytes(self.cache)
        kp = self.cache["k_pages"]
        sc = self.cache.get("k_scale")
        per_page = self.manager.bytes_per_page(
            kp.shape[3], kp.shape[4], kp.dtype.itemsize,
            num_layers=kp.shape[0],
            scale_itemsize=(sc.dtype.itemsize if sc is not None else 0))
        return self.manager.pages_in_use * per_page

    def kv_bytes_capacity(self) -> int:
        return memory.tree_bytes(self.cache)

    def tier_stats(self) -> dict:
        """Per-tier residency snapshot (feeds ``BENCH_serve.json``)."""
        return self.mem.ledger.snapshot()

    def tier_stats_peak(self) -> dict:
        """Per-tier snapshot captured mid-flight at peak pool occupancy
        (the end-of-run ``tier_stats`` is drained: ``kv_pool`` reads 0
        after every page is reclaimed)."""
        return self.tiers_peak or self.tier_stats()