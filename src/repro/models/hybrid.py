"""Pattern-structured LMs: RecurrentGemma-style hybrids (RG-LRU + local
attention, pattern rec/rec/att) and the machinery shared with xLSTM.

``GroupedLM`` scans over *groups* (one repetition of ``cfg.block_pattern``);
layers left over when ``num_layers % len(pattern) != 0`` form an explicit
tail (e.g. recurrentgemma-9b: 38 = 12x(rec,rec,att) + 2x rec).  Each block
kind defines init/specs/train/prefill/decode hooks; recurrent kinds carry
O(1) state, which is what makes the ``long_500k`` decode shape runnable for
these families.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.base import ModelConfig, dense_init, split_keys
from repro.memory import MemoryOrchestrator

RGLRU_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------

def rglru_params(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    w = cfg.rglru_conv_width
    return {
        "ln": jnp.ones((d,), cfg.dtype),
        "w_x": dense_init(ks[0], (d, d), cfg.dtype),
        "w_y": dense_init(ks[1], (d, d), cfg.dtype),
        "conv_w": dense_init(ks[2], (w, d), cfg.dtype, scale=1.0 / w),
        "conv_b": jnp.zeros((d,), cfg.dtype),
        "w_a": dense_init(ks[3], (d, d), cfg.dtype),
        "b_a": jnp.zeros((d,), cfg.dtype),
        "w_i": dense_init(ks[4], (d, d), cfg.dtype),
        "b_i": jnp.zeros((d,), cfg.dtype),
        # Λ init so a^c in ~(0.9, 0.999)
        "lam": jnp.asarray(
            jax.random.uniform(ks[5], (d,), jnp.float32, 0.3, 1.5)),
        "w_out": dense_init(ks[6], (d, d), cfg.dtype),
    }


def rglru_specs() -> dict:
    return {
        "ln": P(None, None), "w_x": P(None, None, "model"),
        "w_y": P(None, None, "model"),
        "conv_w": P(None, None, "model"), "conv_b": P(None, "model"),
        "w_a": P(None, None, "model"), "b_a": P(None, "model"),
        "w_i": P(None, None, "model"), "b_i": P(None, "model"),
        "lam": P(None, "model"), "w_out": P(None, "model", None),
    }


def _rglru_gates(p: dict, u: jax.Array):
    """u: (..., d) conv output.  Returns (a, beta*i*u) in fp32."""
    u32 = u.astype(jnp.float32)
    r = jax.nn.sigmoid(u32 @ p["w_a"].astype(jnp.float32) +
                       p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(u32 @ p["w_i"].astype(jnp.float32) +
                       p["b_i"].astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    return a, beta * i * u32


def _causal_conv(p: dict, x: jax.Array, state: jax.Array | None = None):
    """Per-channel causal conv, width W.  x: (B,S,d).

    Returns (y, new_state) where state is the last W-1 inputs."""
    w = p["conv_w"].shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], w - 1, x.shape[-1]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)               # (B, S+W-1, d)
    y = sum(xx[:, i:i + x.shape[1]] * p["conv_w"][i] for i in range(w))
    y = y + p["conv_b"]
    new_state = xx[:, -(w - 1):]
    return y, new_state


def rglru_seq(p: dict, x: jax.Array, h0: jax.Array | None = None):
    """Full-sequence RG-LRU via associative scan.  x: (B,S,d) normed input.

    Returns (out (B,S,d), (h_last, conv_state))."""
    xb = x @ p["w_x"]
    gate = jax.nn.gelu(x @ p["w_y"])
    u, conv_state = _causal_conv(p, xb)
    a, b = _rglru_gates(p, u)                               # (B,S,d) fp32
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_c, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(x.dtype)
    out = (h * gate) @ p["w_out"]
    return out, (h[:, -1], conv_state)


def rglru_step(p: dict, x: jax.Array, h: jax.Array, conv_state: jax.Array):
    """Single-token RG-LRU.  x: (B,1,d); h: (B,d); conv_state: (B,W-1,d)."""
    xb = x @ p["w_x"]
    gate = jax.nn.gelu(x @ p["w_y"])
    u, conv_state = _causal_conv(p, xb, conv_state)
    a, b = _rglru_gates(p, u[:, 0])                         # (B,d)
    h = (a * h.astype(jnp.float32) + b).astype(x.dtype)
    out = (h[:, None] * gate) @ p["w_out"]
    return out, h, conv_state


# ---------------------------------------------------------------------------
# Block-kind registry
# ---------------------------------------------------------------------------

class BlockKinds:
    """Hooks per block kind; subclassed by families to add kinds."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- init / specs
    def init_block(self, key, kind: str) -> dict:
        cfg = self.cfg
        if kind == "att":
            k1, k2 = jax.random.split(key)
            return {"attn": L.attn_params(k1, cfg),
                    "mlp": L.mlp_params(k2, cfg),
                    "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
                    "ln2": jnp.ones((cfg.d_model,), cfg.dtype)}
        if kind == "rec":
            k1, k2 = jax.random.split(key)
            return {"rglru": rglru_params(k1, cfg),
                    "mlp": L.mlp_params(k2, cfg),
                    "ln2": jnp.ones((cfg.d_model,), cfg.dtype)}
        raise ValueError(kind)

    def block_specs(self, kind: str) -> dict:
        if kind == "att":
            return {"attn": L.attn_specs(self.cfg), "mlp": L.mlp_specs(),
                    "ln1": P(None, None), "ln2": P(None, None)}
        if kind == "rec":
            return {"rglru": rglru_specs(), "mlp": L.mlp_specs(),
                    "ln2": P(None, None)}
        raise ValueError(kind)

    # -- state
    def init_state(self, kind: str, batch: int, max_seq: int) -> Any:
        cfg = self.cfg
        if kind == "att":
            s = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
            shape = (batch, cfg.padded_kv_heads, s, cfg.head_dim)
            return {"k": jnp.zeros(shape, cfg.dtype),
                    "v": jnp.zeros(shape, cfg.dtype)}
        if kind == "rec":
            return {"h": jnp.zeros((batch, cfg.d_model), cfg.dtype),
                    "conv": jnp.zeros((batch, cfg.rglru_conv_width - 1,
                                       cfg.d_model), cfg.dtype)}
        raise ValueError(kind)

    def state_specs(self, kind: str) -> Any:
        from repro.models.base import BATCH_AXES
        if kind == "att":
            s = P(None, BATCH_AXES, "model", None, None)
            return {"k": s, "v": s}
        if kind == "rec":
            return {"h": P(None, BATCH_AXES, "model"),
                    "conv": P(None, BATCH_AXES, None, "model")}
        raise ValueError(kind)

    # -- apply
    def train(self, kind: str, p: dict, x, positions):
        cfg = self.cfg
        if kind == "att":
            h = x + L.attn_forward(
                p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), positions, cfg)
            return h + L.mlp_forward(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps))
        if kind == "rec":
            o, _ = rglru_seq(p["rglru"], L.rmsnorm(x, p["rglru"]["ln"], cfg.norm_eps))
            h = x + o
            return h + L.mlp_forward(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps))
        raise ValueError(kind)

    def prefill(self, kind: str, p: dict, x, positions, state):
        cfg = self.cfg
        if kind == "att":
            a, (k, v) = L.attn_prefill_kv(
                p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), positions, cfg)
            h = x + a
            out = h + L.mlp_forward(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps))
            cs = state["k"].shape[2]
            seq = x.shape[1]
            k = L.to_cache_layout(k[:, -cs:])
            v = L.to_cache_layout(v[:, -cs:])
            if cfg.sliding_window and cs == cfg.sliding_window:
                shift = seq % cs
                k = jnp.roll(k, shift, axis=2)
                v = jnp.roll(v, shift, axis=2)
            pad = cs - min(cs, seq)
            if pad:  # prompt shorter than cache window
                k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
            return out, {"k": k.astype(state["k"].dtype),
                         "v": v.astype(state["v"].dtype)}
        if kind == "rec":
            o, (h_last, conv) = rglru_seq(
                p["rglru"], L.rmsnorm(x, p["rglru"]["ln"], cfg.norm_eps))
            h = x + o
            out = h + L.mlp_forward(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps))
            return out, {"h": h_last.astype(state["h"].dtype),
                         "conv": conv.astype(state["conv"].dtype)}
        raise ValueError(kind)

    def decode(self, kind: str, p: dict, x, state, cur_pos):
        """Returns (out, update).  For "att" the update is the current
        token's {k0, v0} (cache read-only; written post-scan); recurrent
        kinds return their full (small) replacement state."""
        cfg = self.cfg
        if kind == "att":
            a, k0, v0 = L.attn_decode(
                p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps),
                state["k"], state["v"], cur_pos, cfg)
            h = x + a
            out = h + L.mlp_forward(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps))
            return out, {"k0": k0, "v0": v0}
        if kind == "rec":
            o, hh, conv = rglru_step(
                p["rglru"], L.rmsnorm(x, p["rglru"]["ln"], cfg.norm_eps),
                state["h"], state["conv"])
            h = x + o
            out = h + L.mlp_forward(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps))
            return out, {"h": hh.astype(state["h"].dtype), "conv": conv}
        raise ValueError(kind)

    def is_token_update(self, kind: str) -> bool:
        return kind == "att"

    def apply_token_update(self, state, update, cur_pos):
        """Batched write of token (k, v) into stacked attention caches.
        state: {"k": (G?, B, H, W, d), ...}; update: {"k0": (G?, B, H, d)}."""
        cfg = self.cfg
        k, v = state["k"], state["v"]
        stacked = k.ndim == 5
        w_dim = k.shape[-2]
        w = cfg.sliding_window
        slot = (cur_pos % w_dim) if (w > 0 and w_dim <= w) else cur_pos
        b = cur_pos.shape[0]
        bidx = jnp.arange(b)
        if stacked:
            return {
                "k": k.at[:, bidx, :, slot].set(
                    update["k0"].transpose(1, 0, 2, 3).astype(k.dtype)),
                "v": v.at[:, bidx, :, slot].set(
                    update["v0"].transpose(1, 0, 2, 3).astype(v.dtype)),
            }
        return {"k": k.at[bidx, :, slot].set(update["k0"].astype(k.dtype)),
                "v": v.at[bidx, :, slot].set(update["v0"].astype(v.dtype))}


class GroupedLM:
    """LM whose layer stack is ``num_layers`` blocks following
    ``cfg.block_pattern`` (scan over full pattern groups + explicit tail)."""

    def __init__(self, cfg: ModelConfig, kinds: BlockKinds | None = None):
        self.cfg = cfg
        self.mem = MemoryOrchestrator.plan(cfg)
        self.kinds = kinds or BlockKinds(cfg)
        plen = len(cfg.block_pattern)
        assert plen > 0, "GroupedLM needs cfg.block_pattern"
        self.n_groups = cfg.num_layers // plen
        self.tail = cfg.block_pattern[: cfg.num_layers % plen]

    # ----- params -----
    def init(self, key) -> dict:
        cfg = self.cfg
        ke, kg, kt = jax.random.split(key, 3)

        def init_group(k):
            ks = split_keys(k, len(cfg.block_pattern))
            return {f"b{i}": self.kinds.init_block(ks[i], kind)
                    for i, kind in enumerate(cfg.block_pattern)}

        gkeys = jnp.stack(split_keys(kg, self.n_groups))
        params = {
            "embed": L.embed_params(ke, cfg),
            "groups": jax.vmap(init_group)(gkeys),
            "ln_f": jnp.ones((cfg.d_model,), cfg.dtype),
        }
        if self.tail:
            tks = split_keys(kt, len(self.tail))
            params["tail"] = {f"t{i}": self.kinds.init_block(tks[i], kind)
                              for i, kind in enumerate(self.tail)}
        return params

    def param_specs(self) -> dict:
        cfg = self.cfg
        specs = {
            "embed": L.embed_specs(cfg),
            "groups": {f"b{i}": self.kinds.block_specs(kind)
                       for i, kind in enumerate(cfg.block_pattern)},
            "ln_f": P(None),
        }
        if self.tail:
            # tail blocks are unstacked: drop the leading layer axis
            def unstack(spec):
                return P(*spec[1:])
            specs["tail"] = {
                f"t{i}": jax.tree.map(
                    unstack, self.kinds.block_specs(kind),
                    is_leaf=lambda s: isinstance(s, P))
                for i, kind in enumerate(self.tail)}
        return specs

    # ----- cache -----
    def init_cache(self, batch: int, max_seq: int) -> dict:
        cfg = self.cfg

        def stack_state(kind):
            st = self.kinds.init_state(kind, batch, max_seq)
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x, (self.n_groups,) + x.shape), st)

        cache = {f"b{i}": stack_state(kind)
                 for i, kind in enumerate(cfg.block_pattern)}
        for i, kind in enumerate(self.tail):
            cache[f"t{i}"] = self.kinds.init_state(kind, batch, max_seq)
        return cache

    def cache_specs(self) -> dict:
        cfg = self.cfg
        cache = {f"b{i}": self.kinds.state_specs(kind)
                 for i, kind in enumerate(cfg.block_pattern)}

        def unstack(spec):
            return P(*spec[1:])
        for i, kind in enumerate(self.tail):
            cache[f"t{i}"] = jax.tree.map(
                unstack, self.kinds.state_specs(kind),
                is_leaf=lambda s: isinstance(s, P))
        return cache

    # ----- passes -----
    def forward_hidden(self, params: dict, tokens: jax.Array,
                       extra: dict | None = None) -> jax.Array:
        from repro.runtime.sharding import SEQ_SHARDED_ACTS, maybe_constraint
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], tokens)
        positions = jnp.arange(x.shape[1])

        def body(h, gp):
            h = maybe_constraint(h, SEQ_SHARDED_ACTS)
            def run(h):
                for i, kind in enumerate(cfg.block_pattern):
                    h = self.kinds.train(kind, gp[f"b{i}"], h, positions)
                return h
            if cfg.remat:
                run = jax.checkpoint(run)
            return run(h), None

        x, _ = self.mem.layer_scan(body, x, params["groups"])
        for i, kind in enumerate(self.tail):
            x = self.kinds.train(kind, params["tail"][f"t{i}"], x, positions)
        return L.rmsnorm(x, params["ln_f"], cfg.norm_eps)

    def forward(self, params: dict, tokens: jax.Array,
                extra: dict | None = None) -> jax.Array:
        x = self.forward_hidden(params, tokens, extra)
        return L.lm_head(params["embed"], x, self.cfg)

    def prefill(self, params: dict, tokens: jax.Array, cache: dict,
                extra: dict | None = None):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], tokens)
        positions = jnp.arange(x.shape[1])

        def body(h, gp, cache_group):
            new_states = {}
            for i, kind in enumerate(cfg.block_pattern):
                h, st = self.kinds.prefill(kind, gp[f"b{i}"], h, positions,
                                           cache_group[f"b{i}"])
                new_states[f"b{i}"] = st
            return h, new_states

        group_cache = {k: v for k, v in cache.items() if k.startswith("b")}
        x, new_group_cache = self.mem.layer_scan(
            body, x, params["groups"], xs=group_cache)
        new_cache = dict(new_group_cache)
        for i, kind in enumerate(self.tail):
            x, st = self.kinds.prefill(kind, params["tail"][f"t{i}"], x,
                                       positions, cache[f"t{i}"])
            new_cache[f"t{i}"] = st
        x = L.rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps)
        return L.lm_head(params["embed"], x, cfg), new_cache

    def decode_step(self, params: dict, tokens: jax.Array, cache: dict,
                    cur_pos: jax.Array, extra: dict | None = None):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], tokens)

        def body(h, gp, cache_group):
            updates = {}
            for i, kind in enumerate(cfg.block_pattern):
                h, upd = self.kinds.decode(kind, gp[f"b{i}"], h,
                                           cache_group[f"b{i}"], cur_pos)
                updates[f"b{i}"] = upd
            return h, updates

        group_cache = {k: v for k, v in cache.items() if k.startswith("b")}
        # caches are READ-ONLY inside the scan; token updates come out as
        # small ys and are merged in batched post-scan writes (§Perf A').
        x, updates = self.mem.layer_scan(
            body, x, params["groups"], xs=group_cache,
            page_xs=cfg.pager.offload_kv)
        new_cache = {}
        for i, kind in enumerate(cfg.block_pattern):
            key = f"b{i}"
            if self.kinds.is_token_update(kind):
                new_cache[key] = self.kinds.apply_token_update(
                    cache[key], updates[key], cur_pos)
            else:
                new_cache[key] = updates[key]   # full replacement (stacked)
        for i, kind in enumerate(self.tail):
            key = f"t{i}"
            x, upd = self.kinds.decode(kind, params["tail"][key], x,
                                       cache[key], cur_pos)
            if self.kinds.is_token_update(kind):
                new_cache[key] = self.kinds.apply_token_update(
                    cache[key], upd, cur_pos)
            else:
                new_cache[key] = upd
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return L.lm_head(params["embed"], x, cfg), new_cache


class HybridLM(GroupedLM):
    """RecurrentGemma-style hybrid (rec/rec/att)."""
