"""Serving runtime: fused on-device block decode + continuous batching.

The decode hot path is ONE dispatch per ``block_size`` tokens: a
``lax.scan`` decode loop (:func:`repro.models.transformer.decode_loop`)
emits a ``(B, block)`` token block with per-slot ``active``/``remaining``
masks, the KV cache and decode state are **donated** into every dispatch
(updated in place, never copied), and the host syncs once per block to
harvest tokens.  On top of it, :class:`BatchedServer` does continuous
batching: requests are admitted into individual slots between blocks via
``dynamic_update_slice`` into the *live* cache/state — no batch restart —
and slots are recycled the moment a sequence hits EOS or its token budget.

``serve_step`` (one per-token dispatch) is kept for dry-run lowering and
as the baseline the serving benchmark measures against.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pager
from repro.models.base import DecodeState
from repro.models.transformer import (decode_loop, sample_tokens,
                                      vocab_mask_logits)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    output: list = dataclasses.field(default_factory=list)


def sample(logits: jax.Array, vocab: int, temperature: float,
           key: jax.Array) -> jax.Array:
    """logits: (B, 1, V) -> (B, 1) token ids."""
    return sample_tokens(logits, vocab, temperature, key)


def make_prefill_step(model) -> Callable:
    def prefill_step(params, tokens, cache, extra=None):
        logits, cache = model.prefill(params, tokens, cache, extra)
        return logits, cache
    return prefill_step


def make_serve_step(model, *, temperature: float = 0.0) -> Callable:
    """One decode step: (params, tokens (B,1), cache, cur_pos, key) ->
    (next_tokens (B,1), logits, cache).  The per-token baseline."""
    vocab = model.cfg.vocab

    def serve_step(params, tokens, cache, cur_pos, key):
        logits, cache = model.decode_step(params, tokens, cache, cur_pos)
        nxt = sample(logits, vocab, temperature, key)
        return nxt, logits, cache
    return serve_step


def make_decode_loop(model, *, block_size: int, temperature: float = 0.0,
                     eos_id: int | None = None, donate: bool = True
                     ) -> Callable:
    """Jit the fused decode loop with the donation contract: the cache
    (arg 1) and decode state (arg 2) are consumed by every dispatch."""
    def loop(params, cache, state):
        return decode_loop(model, params, cache, state, num_steps=block_size,
                           temperature=temperature, eos_id=eos_id)
    return pager.donating_jit(loop, donate_argnums=(1, 2) if donate else ())


def _bucket(n: int, quantum: int = 8) -> int:
    """Pad prompt lengths to a bucket so admission compiles O(log) shapes."""
    b = quantum
    while b < n:
        b *= 2
    return b


class BatchedServer:
    """Continuous-batching inference server (single process).

    Decode runs in fixed-size fused blocks over a persistent ``batch_size``
    -slot state.  Between blocks, finished slots are recycled and queued
    requests are admitted into the live cache — mid-stream, without
    restarting or re-prefilling the rest of the batch.  Exactly one host
    transfer happens per decoded block (the token-block harvest).
    """

    def __init__(self, model, params, *, batch_size: int = 4,
                 max_seq: int = 256, temperature: float = 0.0, seed: int = 0,
                 block_size: int = 8, eos_id: int | None = None):
        self.model = model
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq
        self.block_size = block_size
        self.temperature = temperature
        self.eos_id = eos_id
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self._uid = 0
        self._decode_loop = make_decode_loop(
            model, block_size=block_size, temperature=temperature,
            eos_id=eos_id)
        self._admit_step = pager.donating_jit(self._make_admit_step(),
                                              donate_argnums=(2, 3))
        # live slot state — donated through every dispatch
        self.cache = model.init_cache(batch_size, max_seq)
        self.state = DecodeState.init(batch_size, jax.random.PRNGKey(seed))
        self.slots: list[Request | None] = [None] * batch_size
        self.stats = {"steps": 0, "tokens": 0, "batches": 0, "blocks": 0,
                      "dispatches": 0, "admitted": 0, "host_syncs": 0}

    # ----- request intake ----------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> Request:
        prompt = np.asarray(prompt, np.int32)
        # validate HERE so the caller sees the error; a raise mid-admission
        # would drop an already-dequeued request with done never set
        if len(prompt) + max(max_new_tokens - 1, 0) > self.max_seq:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens})"
                f" exceeds max_seq={self.max_seq}")
        self._uid += 1
        req = Request(self._uid, prompt, max_new_tokens=max_new_tokens)
        self.queue.put(req)
        return req

    # ----- admission ---------------------------------------------------------
    def _make_admit_step(self) -> Callable:
        model, max_seq = self.model, self.max_seq
        vocab, temperature = self.model.cfg.vocab, self.temperature
        eos_id = self.eos_id

        def admit_step(params, ptoks, cache, state, slot, max_new):
            """Prefill ONE request and splice it into the live batch state.

            ptoks: (1, P) left-padded prompt; slot/max_new: traced scalars.
            Donates (cache, state) — the splice is in place.
            """
            key, k = jax.random.split(state.key)
            fresh = model.init_cache(1, max_seq)
            logits, fresh = model.prefill(params, ptoks, fresh)
            nxt = sample_tokens(logits, vocab, temperature, k)   # (1, 1)

            def splice(big, small):
                """Write the single-request leaf into the batch leaf at
                ``slot``.  The batch axis is found per leaf (the unique
                axis where the shapes differ), so non-transformer caches
                — e.g. recurrent state with batch leading — splice too."""
                if big.shape == small.shape:  # batch-1 server: whole swap
                    return small.astype(big.dtype)
                diff = [i for i, (bs, ss) in enumerate(zip(big.shape,
                                                           small.shape))
                        if bs != ss]
                if len(diff) != 1:
                    raise ValueError(
                        f"cannot infer the batch axis of cache leaf "
                        f"{big.shape} from single-request leaf "
                        f"{small.shape}")
                ax = diff[0]
                starts = (0,) * ax + (slot,) + (0,) * (big.ndim - ax - 1)
                return jax.lax.dynamic_update_slice(
                    big, small.astype(big.dtype), starts)

            cache = jax.tree.map(splice, cache, fresh)
            plen = ptoks.shape[1]
            active = max_new > 1
            if eos_id is not None:      # EOS at admission: never activate
                active = active & (nxt[0, 0] != eos_id)
            upd1 = lambda buf, val: jax.lax.dynamic_update_slice(
                buf, jnp.asarray(val, buf.dtype)[None], (slot,))
            state = DecodeState(
                tokens=jax.lax.dynamic_update_slice(state.tokens, nxt,
                                                    (slot, 0)),
                pos=upd1(state.pos, plen),
                active=upd1(state.active, active),
                remaining=upd1(state.remaining, max_new - 1),
                key=key)
            return nxt, cache, state
        return admit_step

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def _admit(self, req: Request, slot: int) -> bool:
        """Prefill ``req`` into ``slot`` of the live batch; True if the
        request finished at admission (budget of 1 / immediate EOS).

        Left-pad tokens (id 0) inside the bucket are attended like the
        seed server attended its batch-wide left-padding — deterministic,
        but outputs depend on the bucket quantum (see EXPERIMENTS.md).
        """
        # the bucketed start position must leave room for every decode
        # write (pos < max_seq, KV scatter past the cache end is silently
        # dropped by jit) — fall back to the exact prompt length (one
        # extra compile) when the bucket would overflow
        limit = self.max_seq - max(req.max_new_tokens - 1, 0)
        bucket = _bucket(len(req.prompt))
        plen = bucket if bucket <= limit else len(req.prompt)
        toks = np.zeros((1, plen), np.int32)
        toks[0, plen - len(req.prompt):] = req.prompt        # left-pad
        nxt, self.cache, self.state = self._admit_step(
            self.params, jnp.asarray(toks), self.cache, self.state,
            jnp.asarray(slot, jnp.int32),
            jnp.asarray(req.max_new_tokens, jnp.int32))
        first = int(jax.device_get(nxt)[0, 0])
        req.output.append(first)
        self.stats["tokens"] += 1
        self.stats["admitted"] += 1
        if req.max_new_tokens <= 1 or (self.eos_id is not None
                                       and first == self.eos_id):
            req.done.set()
            return True
        self.slots[slot] = req
        return False

    def _admit_from_queue(self, finished: list[Request]) -> None:
        """Fill free slots from the queue (non-blocking, mid-stream)."""
        while True:
            free = self._free_slots()
            if not free:
                return
            try:
                req = self.queue.get_nowait()
            except queue.Empty:
                return
            if self._admit(req, free[0]):
                finished.append(req)      # done at admission: slot stays free

    # ----- decode ------------------------------------------------------------
    def run_block(self) -> list[Request]:
        """One fused dispatch = ``block_size`` decode steps, then ONE host
        sync to harvest the token block.  Returns requests that finished."""
        toks, valid, self.cache, self.state = self._decode_loop(
            self.params, self.cache, self.state)
        self.stats["dispatches"] += 1
        self.stats["blocks"] += 1
        self.stats["steps"] += self.block_size
        toks_h, valid_h = jax.device_get((toks, valid))      # the one sync
        self.stats["host_syncs"] += 1
        finished = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            for t in range(self.block_size):
                if not valid_h[i, t]:
                    break                 # active mask is monotone per slot
                req.output.append(int(toks_h[i, t]))
                self.stats["tokens"] += 1
            if (len(req.output) >= req.max_new_tokens
                    or (self.eos_id is not None and req.output
                        and req.output[-1] == self.eos_id)):
                req.done.set()
                finished.append(req)
                self.slots[i] = None       # slot recycled for admission
        return finished

    def run_once(self) -> list[Request]:
        """Admit queued requests and serve until every admitted request
        completes; returns the finished ones.  Requests that arrive (or
        overflow the slot count) while serving are admitted mid-stream.
        Non-blocking when idle: empty queue + no live slots returns [].
        """
        finished: list[Request] = []
        self._admit_from_queue(finished)
        while any(r is not None for r in self.slots):
            finished.extend(self.run_block())
            self._admit_from_queue(finished)
        if finished:
            self.stats["batches"] += 1
        return finished
