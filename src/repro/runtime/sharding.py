"""Sharding resolution: logical specs -> physical NamedShardings.

Model code writes PartitionSpecs against logical axes (``"model"`` and the
``BATCH_AXES`` tuple ``("pod", "data")``).  This module resolves them for a
concrete mesh:

* single-pod mesh ("data", "model"): batch -> ("data",)
* multi-pod mesh ("pod", "data", "model"): batch -> ("pod", "data")
* smoke meshes (1 device): everything -> None

It also applies the FengHuang memory tier: params whose top-level group is
pageable get ``memory_kind="pinned_host"`` when the pager is enabled.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.memory.tiers import REMOTE_KIND
from repro.models.base import BATCH_AXES

PAGEABLE_GROUPS = ("layers", "groups", "dec_layers", "enc_layers")


def resolve_spec(spec: P, mesh: Mesh) -> P:
    """Map logical axis entries to the axes present in ``mesh``."""
    axes = set(mesh.axis_names)
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, tuple):           # e.g. ("pod", "data")
            kept = tuple(a for a in entry if a in axes)
            out.append(kept if kept else None)
        elif entry == "model":
            out.append("model" if "model" in axes else None)
        elif entry in ("pod", "data"):
            out.append(entry if entry in axes else None)
        else:
            out.append(entry if entry in axes else None)
    return P(*out)


def _treat_as_leaf(x) -> bool:
    return isinstance(x, P)


def resolve_tree(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: resolve_spec(s, mesh), spec_tree,
                        is_leaf=_treat_as_leaf)


def named_shardings(spec_tree: Any, mesh: Mesh, *,
                    pageable_remote: bool = False) -> Any:
    """PartitionSpec tree -> NamedSharding tree.

    With ``pageable_remote=True``, specs under PAGEABLE_GROUPS are placed in
    the FengHuang remote tier (pinned_host) — the weights will be paged into
    device memory by the TensorPager inside the step function.
    """

    def convert(path, s):
        kind = "device"
        if pageable_remote and path and getattr(path[0], "key", None) in PAGEABLE_GROUPS:
            kind = REMOTE_KIND
        return NamedSharding(mesh, resolve_spec(s, mesh), memory_kind=kind)

    return jax.tree_util.tree_map_with_path(convert, spec_tree,
                                            is_leaf=_treat_as_leaf)


def batch_spec(mesh: Mesh, *trailing) -> P:
    """Spec for (batch, ...) data: batch over ("pod","data") as available."""
    axes = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
    return P(axes if axes else None, *trailing)


def constraint(x, mesh: Mesh, spec: P):
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve_spec(spec, mesh)))


def maybe_constraint(x, spec: P):
    """Best-effort sharding constraint against the *ambient* mesh.

    Model code calls this with logical specs (e.g. sequence-parallel
    residuals P(batch, "model", None)); outside a mesh context, or when an
    axis is missing / the dim isn't divisible, it's a no-op — so smoke
    tests and single-device runs are unaffected.
    """
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:   # pragma: no cover
        return x
    if am is None or getattr(am, "empty", True):
        return x
    axes = set(am.axis_names)
    sizes = dict(zip(am.axis_names, am.axis_sizes)) if hasattr(am, "axis_sizes") \
        else {n: am.shape[n] for n in am.axis_names}
    out = []
    for dim, entry in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        names = ()
        if entry is None:
            names = ()
        elif isinstance(entry, tuple):
            names = tuple(a for a in entry if a in axes)
        elif entry in axes:
            names = (entry,)
        total = 1
        for n in names:
            total *= sizes[n]
        if names and dim % total == 0:
            out.append(names if len(names) > 1 else names[0])
        else:
            out.append(None)
    if all(e is None for e in out):
        return x
    return jax.lax.with_sharding_constraint(x, P(*out))


#: logical spec for sequence-parallel residual activations (B, S, d)
SEQ_SHARDED_ACTS = P(BATCH_AXES, "model", None)
