"""Qwen3-235B-A22B (paper workload §4.1.2): fine-grained MoE 128e top-8."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-235b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    d_ff=1536, vocab=151936, head_dim=128,
    qk_norm=True, num_experts=128, top_k=8,
)
