"""moonshot-v1-16b-a3b (kimi/moonlight): 48L d=2048 16H (kv=16)
per-expert d_ff=1408, vocab=163840, MoE 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B].  EP: 64/16 = 4 experts per shard."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab=163840, head_dim=128,
    num_experts=64, top_k=6,
)
