"""FengHuang simulator: paper-claim validation + scheduling invariants."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 runs without hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import graphs as G
from repro.core import hw, simulator as S


@pytest.fixture(scope="module")
def baseline_results():
    base = S.baseline8()
    return {name: S.run_workload(cfg, S.QA_TASK, base)
            for name, cfg in G.PAPER_WORKLOADS.items()}


def test_ttft_fh_beats_baseline(baseline_results):
    """§4.2: FH4-1.5xM at 4.0 TB/s improves TTFT for all three workloads."""
    for name, cfg in G.PAPER_WORKLOADS.items():
        rf = S.run_workload(cfg, S.QA_TASK, S.fh4(1.5, 4.0))
        assert rf["ttft_s"] < baseline_results[name]["ttft_s"], name


def test_tpot_improves_with_remote_bandwidth(baseline_results):
    """§4.2: TPOT reductions become more pronounced 4.0 -> 6.4 TB/s."""
    for name, cfg in G.PAPER_WORKLOADS.items():
        t40 = S.run_workload(cfg, S.QA_TASK, S.fh4(1.5, 4.0))["tpot_s"]
        t64 = S.run_workload(cfg, S.QA_TASK, S.fh4(1.5, 6.4))["tpot_s"]
        assert t64 <= t40 * 1.001, name


def test_e2e_comparable_at_4_8(baseline_results):
    """§4.2: E2E comparable to Baseline8 once remote bw reaches 4.8 TB/s."""
    for name, cfg in G.PAPER_WORKLOADS.items():
        rf = S.run_workload(cfg, S.QA_TASK, S.fh4(1.5, 4.8))
        rel = rf["e2e_s"] / baseline_results[name]["e2e_s"]
        assert rel < 1.30, (name, rel)


def test_local_memory_order_of_table_4_3():
    """Table 4.3: peak local capacity ~10-20 GB (ours: same order), i.e.
    >85% below the 144 GB resident baseline."""
    for name, cfg in G.PAPER_WORKLOADS.items():
        r = S.run_workload(cfg, S.QA_TASK, S.fh4(1.5, 4.0))
        assert r["peak_local_gb"] < 25.0, name
        assert r["peak_local_gb"] < 0.15 * hw.PAPER_H200_HBM_CAP_GB


def test_local_bandwidth_scaling_helps_decode():
    """§4.2: 'improvements in local memory bandwidth also yield substantial
    reductions in TPOT'."""
    cfg = G.GPT3_175B
    t15 = S.run_workload(cfg, S.QA_TASK, S.fh4(1.5, 6.4))["tpot_s"]
    t20 = S.run_workload(cfg, S.QA_TASK, S.fh4(2.0, 6.4))["tpot_s"]
    assert t20 <= t15 * 1.001


@given(w=st.integers(min_value=0, max_value=24))
@settings(max_examples=12, deadline=None)
def test_lookahead_monotone(w):
    """Deeper prefetch windows never hurt (more overlap, same work)."""
    cfg = G.GPT3_175B
    nodes = G.build_graph(cfg, "decode", batch=8, prompt_len=4096,
                          ctx_len=4608, tp=4, paged=True)
    sys_w = S.fh4(1.5, 4.0, lookahead=w)
    sys_w1 = S.fh4(1.5, 4.0, lookahead=w + 1)
    a = S.simulate(nodes, sys_w, warm_window=True).elapsed_s
    b = S.simulate(nodes, sys_w1, warm_window=True).elapsed_s
    assert b <= a * 1.0001


@given(bw=st.floats(min_value=1.0, max_value=20.0))
@settings(max_examples=15, deadline=None)
def test_remote_bw_monotone(bw):
    cfg = G.QWEN3_235B
    a = S.run_workload(cfg, S.QA_TASK, S.fh4(1.5, bw))["tpot_s"]
    b = S.run_workload(cfg, S.QA_TASK, S.fh4(1.5, bw * 1.5))["tpot_s"]
    assert b <= a * 1.0001


def test_simulate_invariants():
    """elapsed >= busy time of each stream; paging only when paged."""
    cfg = G.GROK_1
    nodes = G.build_graph(cfg, "prefill", batch=8, prompt_len=1024,
                          tp=8, paged=False)
    base = S.simulate(nodes, S.baseline8())
    assert base.paging_busy_s == 0.0
    assert base.elapsed_s >= base.compute_busy_s
    nodes_p = G.build_graph(cfg, "prefill", batch=8, prompt_len=1024,
                            tp=4, paged=True)
    fh = S.simulate(nodes_p, S.fh4(1.5, 4.0))
    assert fh.paging_busy_s > 0.0
    assert fh.elapsed_s >= fh.compute_busy_s
    assert fh.peak_paged_window_bytes > 0


def test_expected_active_experts():
    assert G.expected_active_experts(1, 1, 100) == 1.0
    e = G.expected_active_experts(8, 2, 8)
    assert 6.0 < e < 8.0
    # more tokens activate more experts, saturating at E
    assert G.expected_active_experts(128, 8, 1000) <= 128.0
    assert (G.expected_active_experts(128, 8, 1000) >
            G.expected_active_experts(128, 8, 10))


def test_graph_totals_match_param_scale():
    """prefill pageable bytes ~= per-GPU weight bytes (everything pages)."""
    cfg = G.GPT3_175B
    nodes = G.build_graph(cfg, "prefill", batch=8, prompt_len=4096,
                          tp=4, paged=True)
    t = G.graph_totals(nodes)
    per_gpu_weight_bytes = cfg.total_params * G.BYTES_PER_PARAM / 4
    assert t["pageable_bytes"] == pytest.approx(per_gpu_weight_bytes, rel=0.2)
