"""FengHuang latency model — Table 3.1, Eq. (3.1)-(3.4) and Eq. (4.1).

All functions are pure python floats (no jax) so the simulator and the
analysis layer can run anywhere, and hypothesis can sweep them cheaply.

Units: seconds internally; ``*_ns`` helpers where the paper speaks ns.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import hw

NS = 1e-9
GB = 1e9
TB = 1e12


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """A (fixed latency, bandwidth, efficiency-curve) link.

    ``efficiency(size)`` models Eq. (4.1): larger transfers achieve a higher
    fraction of peak bandwidth, mirroring empirical NVLink behaviour.  The
    curve saturates at ``eff_max`` with half-saturation size ``eff_knee``.
    """

    fixed_latency_s: float
    bandwidth_Bps: float
    eff_max: float = 0.95
    eff_min: float = 0.20
    eff_knee_bytes: float = 256 * 1024.0

    def efficiency(self, size_bytes: float) -> float:
        if size_bytes <= 0:
            return self.eff_max
        # Smooth saturating curve: eff_min at 0, -> eff_max as size >> knee.
        frac = size_bytes / (size_bytes + self.eff_knee_bytes)
        return self.eff_min + (self.eff_max - self.eff_min) * frac

    def transfer_time(self, size_bytes: float) -> float:
        """Eq. (4.1): size / (BW * efficiency(size)) + fixed latency.

        Routed through :func:`repro.memory.accounting.modeled_transfer_s`
        — the same formula the live MemoryLedger charges per tier edge —
        so simulated and measured transfer costs are one code path.
        (Function-level import: this module stays jax-free at import.)"""
        from repro.memory.accounting import modeled_transfer_s
        return modeled_transfer_s(size_bytes,
                                  bandwidth_gbps=self.bandwidth_Bps / GB,
                                  latency_us=self.fixed_latency_s * 1e6,
                                  efficiency=self.efficiency(size_bytes))


# ---------------------------------------------------------------------------
# Eq. (3.1)-(3.4): FengHuang minimal operation latencies.
# ---------------------------------------------------------------------------

def fh_read_latency_s(data_size_bytes: float, bandwidth_Bps: float) -> float:
    """Eq. (3.1): Read = 220ns + size/bandwidth."""
    return hw.PAPER_READ_LATENCY_NS * NS + data_size_bytes / bandwidth_Bps


def fh_write_latency_s(data_size_bytes: float, bandwidth_Bps: float) -> float:
    """Eq. (3.2): Write = 90ns + size/bandwidth."""
    return hw.PAPER_WRITE_LATENCY_NS * NS + data_size_bytes / bandwidth_Bps


def fh_write_accumulate_latency_s(data_size_bytes: float,
                                  bandwidth_Bps: float) -> float:
    """Eq. (3.3): Write-Accumulate = 90ns + size/bandwidth."""
    return hw.PAPER_WRITE_ACCUM_LATENCY_NS * NS + data_size_bytes / bandwidth_Bps


def fh_completion_notification_latency_s() -> float:
    """Eq. (3.4): Write-Completion Notification = 40ns."""
    return hw.PAPER_COMPLETION_NOTIFICATION_NS * NS


def table_3_1_totals_ns() -> dict:
    """Recompute Table 3.1 totals from the component breakdown."""
    comp = hw.PAPER_LATENCY_COMPONENTS_NS
    return {
        "read": float(sum(comp["read"].values())),
        "write": float(sum(comp["write"].values())),
        "atomic_completion": float(sum(comp["atomic_completion"].values())),
    }


# ---------------------------------------------------------------------------
# Collective time models: FengHuang shared memory vs NVLink ring.
#
# These are the per-GPU wall-clock models used by the simulator; §3.3.3's
# closed-form speed-ups fall out of them in the appropriate limits (verified
# in tests/test_analysis.py).
# ---------------------------------------------------------------------------

def make_fh_link(bandwidth_Bps: float = hw.PAPER_FH_EFFECTIVE_BW_GBPS * GB,
                 *, ideal: bool = False) -> LinkModel:
    """FengHuang crossbar link. Latency handled per-op, so fixed=0 here."""
    if ideal:
        return LinkModel(0.0, bandwidth_Bps, eff_max=1.0, eff_min=1.0)
    return LinkModel(0.0, bandwidth_Bps)


def make_nvlink(bandwidth_Bps: float = hw.PAPER_NVLINK_BW_GBPS * GB,
                *, ideal: bool = False) -> LinkModel:
    if ideal:
        return LinkModel(0.0, bandwidth_Bps, eff_max=1.0, eff_min=1.0)
    # eff_max 0.78: measured NCCL ring-allreduce bus bandwidth on NVL8
    # nodes plateaus at ~75-80% of the link peak.
    return LinkModel(0.0, bandwidth_Bps, eff_max=0.78)


def fh_allreduce_time_s(tensor_bytes: float, num_gpus: int,
                        link: LinkModel | None = None) -> float:
    """FengHuang AllReduce (§3.3.2, Fig 3.5).

    Each GPU write-accumulates its full tensor into shared memory (all GPUs
    in parallel, each over its own crossbar port), TAB notifies completion,
    then each GPU reads the aggregated tensor back.
    Per-GPU data moved: 1x write + 1x read  (vs ring's 2(N-1)/N x 2... see
    nvlink_ring_allreduce_time_s).
    """
    link = link or make_fh_link()
    up = hw.PAPER_WRITE_ACCUM_LATENCY_NS * NS + tensor_bytes / (
        link.bandwidth_Bps * link.efficiency(tensor_bytes))
    note = fh_completion_notification_latency_s()
    down = hw.PAPER_READ_LATENCY_NS * NS + tensor_bytes / (
        link.bandwidth_Bps * link.efficiency(tensor_bytes))
    return up + note + down


def fh_reduce_scatter_time_s(tensor_bytes: float, num_gpus: int,
                             link: LinkModel | None = None) -> float:
    """Like AllReduce but each GPU reads back only its 1/N shard."""
    link = link or make_fh_link()
    shard = tensor_bytes / num_gpus
    up = hw.PAPER_WRITE_ACCUM_LATENCY_NS * NS + tensor_bytes / (
        link.bandwidth_Bps * link.efficiency(tensor_bytes))
    note = fh_completion_notification_latency_s()
    down = hw.PAPER_READ_LATENCY_NS * NS + shard / (
        link.bandwidth_Bps * link.efficiency(shard))
    return up + note + down


def fh_allgather_time_s(shard_bytes: float, num_gpus: int,
                        link: LinkModel | None = None) -> float:
    """Each GPU writes its shard; all read the concatenated tensor."""
    link = link or make_fh_link()
    total = shard_bytes * num_gpus
    up = hw.PAPER_WRITE_LATENCY_NS * NS + shard_bytes / (
        link.bandwidth_Bps * link.efficiency(shard_bytes))
    note = fh_completion_notification_latency_s()
    down = hw.PAPER_READ_LATENCY_NS * NS + total / (
        link.bandwidth_Bps * link.efficiency(total))
    return up + note + down


def fh_all_to_all_time_s(shard_bytes: float, num_gpus: int,
                         link: LinkModel | None = None) -> float:
    """Each GPU writes its full local tensor, reads back its 1/N slices."""
    link = link or make_fh_link()
    up = hw.PAPER_WRITE_LATENCY_NS * NS + shard_bytes / (
        link.bandwidth_Bps * link.efficiency(shard_bytes))
    note = fh_completion_notification_latency_s()
    down = hw.PAPER_READ_LATENCY_NS * NS + shard_bytes / (
        link.bandwidth_Bps * link.efficiency(shard_bytes))
    return up + note + down


def fh_p2p_time_s(tensor_bytes: float,
                  link: LinkModel | None = None) -> float:
    """P2P send/recv: one write + completion + one read (Fig 3.7)."""
    link = link or make_fh_link()
    up = hw.PAPER_WRITE_LATENCY_NS * NS + tensor_bytes / (
        link.bandwidth_Bps * link.efficiency(tensor_bytes))
    note = fh_completion_notification_latency_s()
    down = hw.PAPER_READ_LATENCY_NS * NS + tensor_bytes / (
        link.bandwidth_Bps * link.efficiency(tensor_bytes))
    return up + note + down


def nvlink_ring_allreduce_time_s(tensor_bytes: float, num_gpus: int,
                                 link: LinkModel | None = None) -> float:
    """Ring AllReduce over NVLink: 2(N-1) steps of T/N chunks per GPU.

    Per-GPU data transferred = 2(N-1) * T/N (the §3.3.3 accounting), and each
    of the 2(N-1) steps pays a link latency (paper uses the read latency as
    the per-step cost in the latency-bound limit).
    """
    link = link or make_nvlink()
    n = num_gpus
    if n <= 1:
        return 0.0
    chunk = tensor_bytes / n
    steps = 2 * (n - 1)
    per_step = hw.PAPER_NVLINK_READ_LATENCY_NS * NS + chunk / (
        link.bandwidth_Bps * link.efficiency(chunk))
    return steps * per_step


def nvlink_ring_reduce_scatter_time_s(tensor_bytes: float, num_gpus: int,
                                      link: LinkModel | None = None) -> float:
    link = link or make_nvlink()
    n = num_gpus
    if n <= 1:
        return 0.0
    chunk = tensor_bytes / n
    steps = n - 1
    per_step = hw.PAPER_NVLINK_READ_LATENCY_NS * NS + chunk / (
        link.bandwidth_Bps * link.efficiency(chunk))
    return steps * per_step


def nvlink_ring_allgather_time_s(shard_bytes: float, num_gpus: int,
                                 link: LinkModel | None = None) -> float:
    link = link or make_nvlink()
    n = num_gpus
    if n <= 1:
        return 0.0
    steps = n - 1
    per_step = hw.PAPER_NVLINK_READ_LATENCY_NS * NS + shard_bytes / (
        link.bandwidth_Bps * link.efficiency(shard_bytes))
    return steps * per_step


def nvlink_all_to_all_time_s(shard_bytes: float, num_gpus: int,
                             link: LinkModel | None = None) -> float:
    """All-to-all: each GPU exchanges (N-1)/N of its tensor pairwise."""
    link = link or make_nvlink()
    n = num_gpus
    if n <= 1:
        return 0.0
    per_peer = shard_bytes / n
    steps = n - 1
    per_step = hw.PAPER_NVLINK_READ_LATENCY_NS * NS + per_peer / (
        link.bandwidth_Bps * link.efficiency(per_peer))
    return steps * per_step


def nvlink_p2p_time_s(tensor_bytes: float,
                      link: LinkModel | None = None) -> float:
    link = link or make_nvlink()
    return hw.PAPER_NVLINK_WRITE_LATENCY_NS * NS + tensor_bytes / (
        link.bandwidth_Bps * link.efficiency(tensor_bytes))


COLLECTIVES = ("allreduce", "reduce_scatter", "allgather", "all_to_all", "p2p")


def collective_time_s(kind: str, fabric: str, tensor_bytes: float,
                      num_gpus: int, link: LinkModel | None = None) -> float:
    """Dispatch helper used by the simulator. fabric in {'fh','nvlink'}."""
    table = {
        ("fh", "allreduce"): lambda: fh_allreduce_time_s(tensor_bytes, num_gpus, link),
        ("fh", "reduce_scatter"): lambda: fh_reduce_scatter_time_s(tensor_bytes, num_gpus, link),
        ("fh", "allgather"): lambda: fh_allgather_time_s(tensor_bytes, num_gpus, link),
        ("fh", "all_to_all"): lambda: fh_all_to_all_time_s(tensor_bytes, num_gpus, link),
        ("fh", "p2p"): lambda: fh_p2p_time_s(tensor_bytes, link),
        ("nvlink", "allreduce"): lambda: nvlink_ring_allreduce_time_s(tensor_bytes, num_gpus, link),
        ("nvlink", "reduce_scatter"): lambda: nvlink_ring_reduce_scatter_time_s(tensor_bytes, num_gpus, link),
        ("nvlink", "allgather"): lambda: nvlink_ring_allgather_time_s(tensor_bytes, num_gpus, link),
        ("nvlink", "all_to_all"): lambda: nvlink_all_to_all_time_s(tensor_bytes, num_gpus, link),
        ("nvlink", "p2p"): lambda: nvlink_p2p_time_s(tensor_bytes, link),
    }
    try:
        return table[(fabric, kind)]()
    except KeyError:
        raise ValueError(f"unknown collective {fabric}/{kind}") from None


def prefetch_overhead_s(tensor_bytes: float, remote_bw_Bps: float,
                        link: LinkModel | None = None) -> float:
    """Eq. (4.1): PrefetchingOverhead = size / (BW * Efficiency(size))."""
    link = link or LinkModel(hw.PAPER_READ_LATENCY_NS * NS, remote_bw_Bps)
    return link.transfer_time(tensor_bytes)
