"""LLaVA-NeXT-style VLM backbone (llava-next-34b).

The assignment specifies the transformer BACKBONE only; the anyres vision
tower is a STUB — ``input_specs()`` provides precomputed patch embeddings
(B, num_patches, d_model) which are prepended to the token embeddings
(positions 0..P-1), exactly how the projected CLIP patches enter the
language model in LLaVA.  Everything else (GQA attention, SwiGLU MLP,
paging, caching) is the dense LM.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.base import ModelConfig
from repro.models.transformer import DenseLM


class VLM(DenseLM):
    """DenseLM consuming ``extra={'patches': (B, P, d)}`` during the
    full-sequence passes; decode steps are pure text continuation."""

    def text_len(self, total_seq: int) -> int:
        """Text tokens for a given total sequence budget."""
        return max(1, total_seq - self.cfg.num_patches)
