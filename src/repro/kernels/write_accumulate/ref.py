"""Pure-jnp oracle for the TAB write-accumulate reduction."""
from __future__ import annotations

import jax.numpy as jnp


def write_accumulate_ref(shards: jnp.ndarray) -> jnp.ndarray:
    """shards: (N, ...) — N xPU contributions -> elementwise sum (fp32
    accumulation, result in input dtype)."""
    return shards.astype(jnp.float32).sum(axis=0).astype(shards.dtype)
