"""Quantized KV page pools: round-trip error bounds, per-page scale
correctness, swap/restore and kill/restore bit-identity, prefix-cache
stability, and the fused-dequant attention read path.

Two-tier correctness contract under test:

* quantized-vs-quantized is BIT-IDENTICAL across preemption, snapshot
  restart and sharding — swap/restore round-trips the quantized bytes
  and their bf16 scales verbatim, and sampling keys off (uid, position);
* quantized-vs-bf16 is APPROXIMATE: bounded per-vector round-trip error
  (gated end-to-end in ``check_bench_schema.py``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ops import BlockManager, attend_ref
from repro.memory.swap import PageSwapper
from repro.models import layers as L
from repro.models.base import ModelConfig
from repro.runtime import ft
from repro.runtime.serve import BatchedServer

PAGE = 4
MAX_SEQ = 64
SMALL_POOL = 18          # oversubscribed: forces preemption (see chaos)

KV_DTYPES = [("int8", jnp.int8, 127.0), ("fp8_e4m3", jnp.float8_e4m3fn,
                                         448.0)]


@pytest.fixture(scope="module", params=["int8", "fp8_e4m3"])
def quant_model(request):
    cfg = get_config("qwen2.5-14b").reduced()
    cfg = dataclasses.replace(cfg, remat=False, page_size=PAGE,
                              kv_dtype=request.param)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _server(quant_model, **kw):
    model, params = quant_model
    kw.setdefault("batch_size", 3)
    kw.setdefault("max_seq", MAX_SEQ)
    kw.setdefault("page_size", PAGE)
    kw.setdefault("audit", True)
    return BatchedServer(model, params, **kw)


def _drive(server, reqs, max_rounds=50):
    finished = []
    for _ in range(max_rounds):
        finished += server.run_once()
        if all(r.done.is_set() for r in reqs):
            return finished
    raise AssertionError("requests stuck")


def _submit_three(server):
    return [server.submit(np.arange(1, 5, dtype=np.int32),
                          max_new_tokens=24) for _ in range(3)]


# ---------------------------------------------------------------------------
# config surface
# ---------------------------------------------------------------------------

def test_kv_dtype_none_is_full_precision():
    cfg = get_config("qwen2.5-14b").reduced()
    assert cfg.kv_dtype is None and not cfg.kv_quantized
    assert cfg.kv_pool_dtype() == cfg.dtype


def test_unknown_kv_dtype_rejected():
    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              kv_dtype="int4")
    with pytest.raises(ValueError, match="kv_dtype"):
        cfg.kv_pool_dtype()


@pytest.mark.parametrize("name,dt,qmax", KV_DTYPES)
def test_kv_dtype_resolution(name, dt, qmax):
    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              kv_dtype=name)
    assert cfg.kv_quantized
    assert cfg.kv_pool_dtype() == dt
    assert cfg.kv_qmax() == qmax


def test_quantized_pool_shapes_and_dtypes(quant_model):
    model, _ = quant_model
    cfg = model.cfg
    cache = model.init_paged_cache(6)
    assert cache["k_pages"].dtype == cfg.kv_pool_dtype()
    assert cache["k_scale"].dtype == jnp.bfloat16
    assert cache["k_scale"].shape == cache["k_pages"].shape[:-1]
    assert cache["v_scale"].shape == cache["v_pages"].shape[:-1]


def test_quantized_bytes_per_page_halves_pool(quant_model):
    """True per-page bytes (scales INCLUDED) must be <= 0.55x the bf16
    pool — the capacity headline the benchmark gates."""
    model, _ = quant_model
    cfg = model.cfg
    m = BlockManager(num_pages=8, page_size=cfg.page_size)
    bf16 = m.bytes_per_page(cfg.padded_kv_heads, cfg.head_dim, 2,
                            cfg.num_layers)
    qdt = jnp.dtype(cfg.kv_pool_dtype()).itemsize
    quant = m.bytes_per_page(cfg.padded_kv_heads, cfg.head_dim, qdt,
                             cfg.num_layers, scale_itemsize=2)
    assert quant / bf16 <= 0.55
    # and it matches the real allocation exactly
    cache = model.init_paged_cache(8)
    from repro.memory import tree_bytes
    assert quant * 8 == tree_bytes(cache)


# ---------------------------------------------------------------------------
# round-trip error bounds + per-page scales
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,dt,qmax", KV_DTYPES)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_round_trip_error_bound(name, dt, qmax, seed):
    """Per-vector absmax quantization: |x - dq(q(x))| <= amax/qmax per
    int8 step, or one fp8 ulp (2^-3 relative) — checked against the
    per-vector amax, over magnitudes spanning 1e-3..1e3."""
    key = jax.random.PRNGKey(seed)
    mags = jnp.asarray([1e-3, 1e-1, 1.0, 1e2, 1e3])[:, None, None]
    x = jax.random.normal(key, (5, 16, 64), jnp.float32) * mags
    q, s = L.kv_pool_quantize(x, dt, qmax)
    assert q.dtype == dt and s.dtype == jnp.bfloat16
    back = L.kv_dequantize(q, s, jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    # bf16 scale storage adds <= 2^-8 relative on top of the quant step
    if name == "int8":
        bound = amax / qmax * (0.5 + 1e-2) + amax * 2 ** -8
    else:
        bound = amax * 2.0 ** -3 + amax * 2 ** -8
    assert jnp.max(jnp.abs(back - x) - bound) <= 0, \
        float(jnp.max(jnp.abs(back - x) / jnp.maximum(amax, 1e-9)))


@pytest.mark.parametrize("name,dt,qmax", KV_DTYPES)
def test_round_trip_is_idempotent(name, dt, qmax):
    """Quantizing a dequantized tensor reproduces the same bytes — the
    write/read fixed point the bit-identity contract needs."""
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 4, 32), jnp.float32)
    q1, s1 = L.kv_pool_quantize(x, dt, qmax)
    back = L.kv_dequantize(q1, s1, jnp.float32)
    q2, s2 = L.kv_pool_quantize(back, dt, qmax)
    back2 = L.kv_dequantize(q2, s2, jnp.float32)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(back2))


def test_zero_vectors_survive_quantization():
    """Null pages and padding are all-zero: amax 0 must not divide by
    zero, and dequant must give back exact zeros."""
    for _, dt, qmax in KV_DTYPES:
        q, s = L.kv_pool_quantize(jnp.zeros((2, 3, 8)), dt, qmax)
        back = L.kv_dequantize(q, s, jnp.float32)
        assert np.all(np.isfinite(np.asarray(s, np.float32)))
        np.testing.assert_array_equal(np.asarray(back), 0.0)


def test_per_page_scales_do_not_bleed(quant_model):
    """A prompt whose pages differ in magnitude by 1e3: each written
    page must dequantize within ITS OWN amax bound.  A shared (per-pool
    or per-sequence) scale would crush the small page to zero."""
    model, params = quant_model
    cfg = model.cfg
    seq = 3 * PAGE                     # three full pages
    cache = model.init_paged_cache(4)
    pages = jnp.asarray([[1, 2, 3]], jnp.int32)
    tokens = jnp.asarray(np.arange(1, seq + 1)[None], jnp.int32)
    _, cache = jax.jit(model.prefill_paged)(params, tokens, cache, pages)
    ks = np.asarray(cache["k_scale"], np.float32)    # (L, P, page, Hkv)
    live = ks[:, 1:4]
    assert np.all(live > 0)
    # scales are PER page slot: pages see different activations, so a
    # constant scale across all slots would mean the per-slot amax never
    # reached storage
    assert len({round(float(v), 10) for v in live.ravel()}) > 1
    # dequantized pool values stay within each slot's own scale * qmax
    kq = np.asarray(cache["k_pages"][:, 1:4], np.float32)
    assert np.all(np.abs(kq) <= cfg.kv_qmax() + 1e-6)


# ---------------------------------------------------------------------------
# fused-dequant attention read path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,dt,qmax", KV_DTYPES)
def test_kernel_matches_ref_with_scales(name, dt, qmax):
    """The Pallas kernel (interpret mode) and the gather oracle must
    agree on quantized pools — same online-softmax, same fused dequant."""
    key = jax.random.PRNGKey(0)
    b, hkv, g, hd, n_pages, page, pool = 2, 2, 2, 64, 3, 8, 8
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, hkv, g, hd), jnp.float32)
    kf = jax.random.normal(ks[1], (pool, page, hkv, hd), jnp.float32)
    vf = jax.random.normal(ks[2], (pool, page, hkv, hd), jnp.float32)
    k_pages, k_scales = L.kv_pool_quantize(kf, dt, qmax)
    v_pages, v_scales = L.kv_pool_quantize(vf, dt, qmax)
    table = jax.random.randint(ks[3], (b, n_pages), 1, pool, jnp.int32)
    seq_lens = jnp.asarray([13, 22], jnp.int32)
    ref = attend_ref(q, k_pages, v_pages, table, seq_lens,
                     k_scales=k_scales, v_scales=v_scales)
    out = paged_attention(q, k_pages, v_pages, table, seq_lens,
                          k_scales=k_scales, v_scales=v_scales,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_scales_must_come_in_pairs():
    q = jnp.zeros((1, 1, 1, 8))
    kp = jnp.zeros((2, 4, 1, 8), jnp.int8)
    sc = jnp.zeros((2, 4, 1), jnp.bfloat16)
    with pytest.raises(ValueError, match="k_scales and v_scales"):
        paged_attention(q, kp, kp, jnp.zeros((1, 1), jnp.int32),
                        jnp.ones((1,), jnp.int32), k_scales=sc,
                        interpret=True)


# ---------------------------------------------------------------------------
# PageSwapper: scales ride along bit-identically
# ---------------------------------------------------------------------------

def test_swap_round_trip_preserves_quantized_bytes(quant_model):
    model, _ = quant_model
    cache = model.init_paged_cache(10)
    key = jax.random.PRNGKey(5)
    k1, k2 = jax.random.split(key)
    qdt, qmax = model.cfg.kv_pool_dtype(), model.cfg.kv_qmax()
    kq, ks = L.kv_pool_quantize(
        jax.random.normal(k1, cache["k_pages"].shape, jnp.float32),
        qdt, qmax)
    vq, vs = L.kv_pool_quantize(
        jax.random.normal(k2, cache["v_pages"].shape, jnp.float32),
        qdt, qmax)
    cache = {"k_pages": kq, "v_pages": vq, "k_scale": ks, "v_scale": vs}
    want_k = np.asarray(cache["k_pages"][:, [2, 5, 7]])
    want_s = np.asarray(cache["k_scale"][:, [2, 5, 7]])

    sw = PageSwapper()
    h = sw.swap_out(cache, [2, 5, 7])
    assert h.k_scale is not None and h.v_scale is not None
    # nbytes mixes pool-dtype values with bf16 scales
    assert h.nbytes == 2 * (want_k.size * want_k.dtype.itemsize
                            + want_s.size * 2)
    np.testing.assert_array_equal(
        h.k.view(np.uint8), want_k.view(np.uint8))
    np.testing.assert_array_equal(
        h.k_scale.view(np.uint8), want_s.view(np.uint8))
    # restore into DIFFERENT page ids: bytes land verbatim
    cache = sw.swap_in(cache, [1, 3, 8], h)
    np.testing.assert_array_equal(
        np.asarray(cache["k_pages"][:, [1, 3, 8]]).view(np.uint8),
        want_k.view(np.uint8))
    np.testing.assert_array_equal(
        np.asarray(cache["k_scale"][:, [1, 3, 8]]).view(np.uint8),
        want_s.view(np.uint8))


@pytest.mark.parametrize("temp", [0.0, 0.7])
def test_quantized_preemption_bit_identical(quant_model, temp):
    """Oversubscribed quantized pool: preempt/swap/resume must not
    change a single token vs the uncontended quantized run."""
    ref_srv = _server(quant_model, temperature=temp)
    ref = _submit_three(ref_srv)
    _drive(ref_srv, ref)
    assert ref_srv.stats["preemptions"] == 0

    srv = _server(quant_model, temperature=temp, num_pages=SMALL_POOL)
    got = _submit_three(srv)
    _drive(srv, got)
    assert srv.stats["preemptions"] >= 1
    assert srv.stats["resumes"] >= 1
    assert srv.stats["sheds"] == 0
    for a, b in zip(ref, got):
        assert a.output == b.output, (temp, a.uid, a.output, b.output)
        assert b.error is None


@pytest.mark.parametrize("temp", [0.0, 0.7])
def test_quantized_kill_and_restore_bit_identical(quant_model, tmp_path,
                                                  temp):
    """Snapshot mid-decode -> disk -> fresh server: the quantized pages
    and their scales round-trip through npz storage views and every
    sequence finishes with the uninterrupted run's tokens."""
    ref_srv = _server(quant_model, temperature=temp,
                      num_pages=SMALL_POOL)
    ref = _submit_three(ref_srv)
    _drive(ref_srv, ref)

    srv = _server(quant_model, temperature=temp, num_pages=SMALL_POOL)
    reqs = _submit_three(srv)
    early = srv.run_once(max_blocks=1)
    snap = srv.snapshot()
    assert any("k_scale" in s for s in snap["sequences"]
               if s["pos"]), "snapshot dropped the quantized scales"
    path = ft.save_server_snapshot(tmp_path / "qserve_ckpt", snap)
    del srv

    srv2 = _server(quant_model, temperature=temp, num_pages=SMALL_POOL)
    ft.restore_server(srv2, ft.load_server_snapshot(path))
    finished = list(early)
    for _ in range(50):
        finished += srv2.run_once()
        if len(finished) == 3:
            break
    by_uid = {r.uid: r for r in finished}
    assert len(by_uid) == 3
    for a in ref:
        b = by_uid[a.uid]
        assert a.output == b.output, (a.uid, a.output, b.output)
        assert b.error is None


# ---------------------------------------------------------------------------
# prefix cache on quantized pools
# ---------------------------------------------------------------------------

def test_quantized_prefix_sharing_deterministic(quant_model):
    """The prefix hash keys on TOKEN bytes (precision-independent), so
    quantized servers share prefix pages; shared admissions must be
    deterministic run-to-run and bit-identical across restarts."""
    sys_toks = np.arange(3, 15, dtype=np.int32)        # 3 whole pages

    def run():
        srv = _server(quant_model, prefix_cache=True)
        reqs = [srv.submit(
            np.concatenate([sys_toks, np.asarray([50 + i, 60 + i],
                                                 np.int32)]),
            max_new_tokens=16) for i in range(3)]
        _drive(srv, reqs)
        return [tuple(r.output) for r in reqs], srv

    out1, srv1 = run()
    out2, srv2 = run()
    assert srv1.stats["prefix_hits"] > 0
    assert srv1.stats["prefix_shared_pages"] > 0
    assert out1 == out2, "quantized prefix sharing is nondeterministic"


def test_quantized_prefix_hash_matches_bf16_hash(quant_model):
    """Same tokens -> same prefix index keys regardless of kv_dtype:
    the index is over padded token bytes, never pool bytes."""
    model, params = quant_model
    cfg_bf16 = dataclasses.replace(model.cfg, kv_dtype=None)
    sys_toks = np.arange(3, 15, dtype=np.int32)

    def keys(m_cfg):
        srv = BatchedServer(build_model(m_cfg), params, batch_size=3,
                            max_seq=MAX_SEQ, page_size=PAGE,
                            prefix_cache=True, audit=True)
        # spy on registration: index entries are dropped as soon as the
        # last reference to a shared page is freed, which can happen
        # inside a single run_once for short requests
        seen = set()
        orig = srv.manager.register_prefix

        def spy(key, page_id):
            seen.add(key)
            return orig(key, page_id)

        srv.manager.register_prefix = spy
        reqs = [srv.submit(
            np.concatenate([sys_toks, np.asarray([50 + i], np.int32)]),
            max_new_tokens=8) for i in range(2)]
        _drive(srv, reqs)
        return seen

    kq, kb = keys(model.cfg), keys(cfg_bf16)
    assert kq and kq == kb


# ---------------------------------------------------------------------------
# sharded quantized serving (subprocess, forced 8 host devices)
# ---------------------------------------------------------------------------

SHARDED_QUANT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import dataclasses
import jax, numpy as np
from repro.configs import get_config, build_model
from repro.launch.mesh import make_serving_mesh
from repro.runtime.serve import BatchedServer

cfg = get_config("qwen2.5-14b").reduced()
cfg = dataclasses.replace(cfg, remat=False, page_size=4, kv_dtype="int8")
params = build_model(cfg).init(jax.random.PRNGKey(0))

def serve(mesh, num_pages):
    srv = BatchedServer(build_model(cfg), params, batch_size=3, max_seq=64,
                        page_size=4, num_pages=num_pages, temperature=0.7,
                        paged=True, mesh=mesh, audit=True)
    reqs = [srv.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=24)
            for _ in range(3)]
    for _ in range(50):
        srv.run_once()
        if all(r.done.is_set() for r in reqs):
            break
    return [tuple(r.output) for r in reqs], srv

mesh = make_serving_mesh(model=2)
single, _ = serve(None, None)              # unsharded, uncontended
ref, _ = serve(mesh, None)                 # sharded, uncontended
got, srv = serve(mesh, 18)                 # sharded + preemption
assert srv.stats["model_shards"] == 2
assert srv.stats["preemptions"] >= 1, srv.stats
assert srv.stats["resumes"] >= 1, srv.stats
assert ref == single, "sharded quantized tokens diverged from 1-device"
assert got == ref, "sharded quantized preemption diverged"
print("SHARDED_QUANT_OK")
"""


@pytest.mark.slow
def test_sharded_quantized_preemption_bit_identical():
    """Head-sharded quantized pools (scales shard with their pages):
    mesh serving and preempt/swap/resume across the "model" axis must
    keep every token identical to the single-device quantized run."""
    import os
    import subprocess
    import sys
    from pathlib import Path

    src = str(Path(__file__).resolve().parent.parent / "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SHARDED_QUANT_SCRIPT, src],
                         capture_output=True, text=True, timeout=600,
                         env=env)
    assert "SHARDED_QUANT_OK" in out.stdout, \
        out.stdout[-1500:] + out.stderr[-3000:]


# ---------------------------------------------------------------------------
# ledger: quantized pool accounting
# ---------------------------------------------------------------------------

def test_quantized_server_accounts_true_bytes(quant_model):
    """kv_bytes_in_use charges pool-dtype values PLUS bf16 scales, and
    the per-page rate matches the real allocation."""
    from repro.memory import tree_bytes
    srv = _server(quant_model)
    req = srv.submit(np.arange(1, 9, dtype=np.int32), max_new_tokens=4)
    _drive(srv, [req])
    per_page = tree_bytes(srv.cache) // srv.num_pages
    assert srv.kv_bytes_capacity() == per_page * srv.num_pages
    # hwm pages x true per-page bytes is what the benchmark reports
    assert srv.manager.hwm > 0
